"""Batched serving engine: prefill + decode loop with the paper's approx
top-k sampler, continuous-batching-shaped request management.

The engine runs a fixed decode batch; requests join at free slots after
their (batched) prefill and leave on EOS/length.  All device work is two
jitted callables (prefill_step, decode_step) so the engine loop is pure
bookkeeping — this is the structure a production server keeps, minus RPC.

Retrieval augmentation goes through the unified ``repro.search`` front door:
attach an ``Index`` over retrieval keys (``attach_retrieval``) and the
engine can look up neighbour tokens per decode step — and, because the
index is index-free, ingest new keys between steps with no rebuild
(``retrieval_index.add(...)``), the paper's frequent-update serving story.
Per-step retrieval is a single device dispatch over pre-packed operands
(even for multi-block query batches, via the streaming executor), so the
decode loop never stalls on host-side search bookkeeping.

Passing ``attach_retrieval(..., server=...)`` routes lookups through a
``repro.search.serve.SearchServer`` instead of calling the index directly:
each engine submits its slot batch as one request and the server coalesces
requests across engines (and any other client sharing the index) into one
micro-batch dispatch, which is how many concurrent decode streams keep
retrieval at batch (peak-FLOP/s) efficiency instead of one small dispatch
per engine step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models import transformer as tfm
from repro.search import Index
from repro.search.serve import SearchServer

__all__ = ["Request", "ServingEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    generated: Optional[List[int]] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch: int, max_seq: int,
                 use_knn: bool = False, sample: str = "approx_topk",
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self._decode = jax.jit(
            M.make_decode_step(cfg, use_knn=use_knn, sample=sample)
        )
        self.caches = tfm.init_caches(cfg, batch, max_seq)
        self.tokens = jnp.zeros((batch, 1), jnp.int32)
        self.rng = jax.random.PRNGKey(seed)
        self.cur_index = 0
        self._slots: List[Optional[Request]] = [None] * batch
        self.retrieval_index: Optional[Index] = None
        self.retrieval_tokens: Optional[jnp.ndarray] = None
        self.retrieval_server: Optional[SearchServer] = None

    # -- retrieval (kNN-LM style) via the unified search API ----------------
    def attach_retrieval(
        self,
        index: Index,
        value_tokens: jnp.ndarray,
        *,
        server: Optional[SearchServer] = None,
    ) -> "ServingEngine":
        """Attach a ``repro.search.Index`` over retrieval keys.

        ``value_tokens[i]`` is the token predicted by key row ``i`` (aligned
        with the index's append-only row space, so ``index.add`` callers
        extend both together).  The packed search state is materialized
        here (normally a no-op — ``Index.build`` packs eagerly) so the
        decode loop's ``retrieve`` calls never pay build-time packing.

        ``server`` (a ``SearchServer`` over the same index) makes
        ``retrieve`` submit through the coalescing queue, so lookups from
        several engines sharing one retrieval datastore merge into
        micro-batch dispatches.  Out-of-band ``index.add``/``delete``
        while a wall-clock server runs must go through
        ``server.mutation()`` (``Index`` is not thread-safe).
        """
        if server is not None and server.index is not index:
            raise ValueError(
                "server must serve the attached index (server.index is a "
                "different Index instance)"
            )
        index.pack()
        self.retrieval_index = index
        self.retrieval_tokens = jnp.asarray(value_tokens)
        self.retrieval_server = server
        return self

    def stats(self) -> dict:
        """Engine-side serving observability: slot occupancy plus the
        retrieval path's telemetry (server stats and the live recall
        gauge when retrieval is attached) — one dict for dashboards,
        same shape conventions as ``KNNDatastore.stats()``."""
        live = sum(1 for r in self._slots if r is not None)
        info: dict = {
            "batch": self.batch,
            "live_slots": live,
            "slot_occupancy": live / self.batch if self.batch else 0.0,
            "use_retrieval": self.retrieval_index is not None,
        }
        if self.retrieval_index is not None:
            info["retrieval_cache"] = self.retrieval_index.cache_info()
            info["expected_recall_live"] = (
                self.retrieval_index.expected_recall_live
            )
        if self.retrieval_server is not None:
            info["retrieval_server"] = self.retrieval_server.stats()
        return info

    def retrieve(self, queries: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """-> (scores (M, k), neighbour tokens (M, k)) from the attached index."""
        if self.retrieval_index is None:
            raise ValueError("no retrieval index attached; call attach_retrieval")
        if self.retrieval_tokens.shape[0] < self.retrieval_index.num_appended:
            # jnp.take clamps out-of-range indices, which would silently map
            # newly added keys onto the last stale token — fail loudly.
            raise ValueError(
                f"retrieval_tokens covers {self.retrieval_tokens.shape[0]} rows "
                f"but the index has {self.retrieval_index.num_appended} appended "
                "rows; extend value tokens alongside retrieval_index.add(...)"
            )
        if self.retrieval_server is not None:
            # One request for the whole slot batch (splitting it per slot
            # would only add ticket overhead — whole-request FIFO
            # coalescing gives the same batches); the server merges it
            # with requests from other engines/callers sharing the index.
            vals, idxs = self.retrieval_server.search(queries)
        else:
            vals, idxs = self.retrieval_index.search(queries)
        return vals, jnp.take(self.retrieval_tokens, idxs, axis=0)

    # -- batched prefill: replay prompts through the decode step ------------
    def admit(self, requests: List[Request]):
        """Assign requests to free slots; prompts are replayed via decode.

        (A production engine prefills with the chunked full-sequence kernel;
        replay keeps this reference engine single-step and is exact.)
        """
        free = [i for i, s in enumerate(self._slots) if s is None]
        for req, slot in zip(requests, free):
            req.generated = []
            self._slots[slot] = req
        max_len = max((len(r.prompt) for r in requests), default=0)
        toks = np.zeros((self.batch, max_len), np.int32)
        for req, slot in zip(requests, free):
            toks[slot, : len(req.prompt)] = req.prompt
        for t in range(max_len):
            self.step(forced_tokens=jnp.asarray(toks[:, t : t + 1]))

    def step(self, forced_tokens: Optional[jnp.ndarray] = None):
        self.rng, sub = jax.random.split(self.rng)
        inp = forced_tokens if forced_tokens is not None else self.tokens
        next_tokens, logits, self.caches = self._decode(
            self.params, inp, self.caches, jnp.int32(self.cur_index), sub
        )
        self.tokens = next_tokens
        self.cur_index += 1
        out = np.asarray(next_tokens[:, 0])
        for i, req in enumerate(self._slots):
            if req is not None and forced_tokens is None:
                req.generated.append(int(out[i]))
                if len(req.generated) >= req.max_new_tokens:
                    self._slots[i] = None
        return out

    def run(self, new_tokens: int):
        for _ in range(new_tokens):
            self.step()
        return {r.rid: r.generated for r in self._slots if r is not None}
