"""Serving-side cache utilities: sizing, layout, and cache growth planning."""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.configs.base import ModelConfig

__all__ = ["cache_bytes_per_token", "plan_max_seq"]


def cache_bytes_per_token(cfg: ModelConfig, *, bytes_per_el: int = 2) -> int:
    """Per-token KV (or latent/state) cache footprint across all layers."""
    total = 0
    hd = cfg.resolved_head_dim
    for kind in cfg.layer_kinds():
        if kind == "ssm":
            continue  # O(1) state, no per-token growth
        if kind == "rglru":
            continue
        if kind == "local_attn":
            continue  # ring buffer: bounded by window, not seq
        if kind.startswith("mla"):
            total += (cfg.kv_lora_rank + cfg.qk_rope_dim) * bytes_per_el
        else:
            total += 2 * cfg.num_kv_heads * hd * bytes_per_el
    return total


def plan_max_seq(cfg: ModelConfig, batch: int, hbm_budget_bytes: float) -> int:
    """Longest cache that fits the HBM budget at this batch size."""
    per_tok = cache_bytes_per_token(cfg) * batch
    if per_tok == 0:
        return 1 << 30  # stateless growth (pure SSM/recurrent)
    return int(hbm_budget_bytes // per_tok)
