"""Instruction-throughput-aware roofline model (paper §4, Eq. 6).

P  <=  min( pi,  beta * I_MEM,  gamma * I_COP )

with pi = peak matmul FLOP/s, beta = HBM bytes/s, gamma = peak
coefficient-wise op (COP) throughput.  Includes the paper's Table 1 hardware
plus TPU v5e (this repo's deployment target) and the kernel cost accounting
of Appendix A.3/A.5 (I_MEM Eq. 20, COPs-per-dot C).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

__all__ = [
    "Hardware",
    "HARDWARE",
    "KernelCost",
    "attainable_flops",
    "bottleneck",
    "partial_reduce_cost",
    "RooflineTerms",
    "roofline_terms",
]


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # pi  [FLOP/s]
    hbm_bandwidth: float       # beta [bytes/s]
    peak_cops: float           # gamma [COP/s]
    hbm_bytes: float = 16e9    # per-chip HBM capacity
    ici_bandwidth: float = 50e9  # per-link interconnect [bytes/s]
    # Fast on-chip memory available to one kernel instance (TPU: VMEM per
    # core; GPU: shared memory + L2 slice).  The kernel planner
    # (repro.search.plan) sizes its tiles against a fraction of this
    # (operand tiles are double-buffered; see plan._vmem_budget).
    vmem_bytes: float = 16 * 2**20


HARDWARE: Dict[str, Hardware] = {
    # Paper Table 1.
    "v100": Hardware("GPU V100", 125e12, 900e9, 15.7e12),
    "a100": Hardware("GPU A100", 312e12, 1555e9, 19.5e12),
    "tpu_v3": Hardware("TPU V3", 126e12, 858e9, 4.0e12),
    "tpu_v4": Hardware("TPU V4", 274e12, 1144e9, 4.3e12),
    # Deployment target for this repo (brief): 197 bf16 TFLOP/s, 819 GB/s HBM,
    # ~50 GB/s/link ICI.  gamma estimated from VPU geometry (8x128 lanes x 2
    # unit x ~940MHz x 2 cores) ~= 3.9 TCOP/s, same methodology as Table 1.
    "tpu_v5e": Hardware("TPU v5e", 197e12, 819e9, 3.9e12, hbm_bytes=16e9),
    # Development host (the CI/interpret-mode environment).  Rough orders of
    # magnitude for a server-class CPU socket; the planner only uses the
    # *ratios* (roofline walls) and the vmem tile budget, which is set to the
    # TPU value so host-planned tiles match what the TPU would get.
    "cpu": Hardware("CPU host", 0.5e12, 100e9, 0.1e12, hbm_bytes=64e9),
}


@dataclasses.dataclass(frozen=True)
class KernelCost:
    """Workload description of one kernel: FLOPs, HBM bytes, COPs."""

    flops: float
    hbm_bytes: float
    cops: float

    @property
    def i_mem(self) -> float:
        return self.flops / max(self.hbm_bytes, 1e-30)

    @property
    def i_cop(self) -> float:
        return self.flops / max(self.cops, 1e-30)


def attainable_flops(cost: KernelCost, hw: Hardware) -> float:
    """Eq. 6: attainable performance of a kernel on given hardware."""
    return min(hw.peak_flops, hw.hbm_bandwidth * cost.i_mem, hw.peak_cops * cost.i_cop)


def bottleneck(cost: KernelCost, hw: Hardware) -> str:
    terms = {
        "compute": hw.peak_flops,
        "memory": hw.hbm_bandwidth * cost.i_mem,
        "instruction": hw.peak_cops * cost.i_cop,
    }
    return min(terms, key=terms.get)


def partial_reduce_cost(
    m: int,
    n: int,
    d: int,
    l: int,
    *,
    cops_per_dot: float = 3.0,
    block_rows: int = 512,
    dtype_bytes: int = 4,
    db_bytes: int = None,
) -> KernelCost:
    """Cost model of the PartialReduce kernel (Appendix A.3).

    FLOPs  = 2MND (the einsum)
    bytes  = 4(MD + MND/ib + 2ML)  -- Eq. 20, ib = query block rows
    COPs   = C * M * N             -- C per dot product (A.5 accounting)

    ``db_bytes`` prices the database-stream term (the MND/ib bytes)
    separately from the query/winner traffic — reduced-precision storage
    tiers (``repro.search.quant``) stream 2- or 1-byte rows while queries
    and bin winners stay at ``dtype_bytes``.  ``None`` keeps the classic
    single-dtype Eq. 20 form.
    """
    if db_bytes is None:
        db_bytes = dtype_bytes
    flops = 2.0 * m * n * d
    hbm = (
        dtype_bytes * (m * d + 2 * m * l)
        + db_bytes * (m / block_rows) * n * d
    )
    cops = cops_per_dot * m * n
    return KernelCost(flops=flops, hbm_bytes=hbm, cops=cops)


def partial_reduce_fused_cost(
    m: int,
    n: int,
    d: int,
    k_scan: int,
    *,
    cops_per_dot: float = 3.0,
    block_rows: int = 512,
    dtype_bytes: int = 4,
    db_bytes: float = None,
    block_n: int = 1024,
    bins_per_block: int = 64,
) -> KernelCost:
    """Cost model of the single-pass fused scan→select kernel (Eq. 20).

    FLOPs  = 2MND (the einsum, unchanged)
    bytes  = dtype(MD) + db_bytes * ceil(M/ib) * ND + 8 M k_scan
    COPs   = C*M*N + M * (N/block_n) * k_scan * (k_scan + bins_per_block)

    Versus :func:`partial_reduce_cost`, the ``2ML`` bin-winner HBM term
    (the (M, N/bin_size) score-tile round trip the two-pass select pays)
    collapses to the O(M·k_scan) final result — the carry buffer lives in
    VMEM across the database stream.  The database term uses the *integer*
    pass count ``ceil(M/ib)``: each query-block grid row streams the whole
    database once, so a fractional M/ib would under-price small batches.
    The extra COP term prices the in-VMEM merge (k_scan first-lane max
    extractions over k_scan + bins_per_block lanes, once per database
    tile); amortized over the tile's block_n rows it is a lower-order
    term, priced so tile escalation cannot pretend the merge is free.
    """
    if db_bytes is None:
        db_bytes = dtype_bytes
    passes = max(1, -(-m // block_rows))  # ceil, floored at one stream
    flops = 2.0 * m * n * d
    hbm = (
        dtype_bytes * m * d
        + db_bytes * passes * n * d
        + 8.0 * m * k_scan
    )
    tiles = max(1.0, n / max(1, block_n))
    cops = (
        cops_per_dot * m * n
        + m * tiles * k_scan * (k_scan + bins_per_block)
    )
    return KernelCost(flops=flops, hbm_bytes=hbm, cops=cops)


def cops_per_dot(
    *,
    base: int = 3,
    l2: bool = False,
    non_pow2_n: bool = False,
    padded_d: bool = False,
    broadcast_norm: bool = False,
) -> int:
    """Appendix A.5 COP accounting: 3 base + 1 per listed condition."""
    c = base
    c += int(l2)              # relaxed distance subtract
    c += int(non_pow2_n)      # database masking
    c += int(padded_d)        # D not a multiple of 128
    c += int(broadcast_norm)  # broadcasting ||x||^2/2
    return c


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Three-term time decomposition for a compiled step on a mesh."""

    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        # Lower bound: perfectly-overlapped execution is max(); serialized is
        # sum().  We report the max-model (roofline convention).
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(
    *,
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    chips: int,
    hw: Hardware,
    ici_links: int = 1,
) -> RooflineTerms:
    """Brief-specified three-term roofline for a whole compiled step.

    compute    = FLOPs / (chips * pi)
    memory     = bytes / (chips * HBM bw)
    collective = collective bytes / (chips * ici_links * link bw)

    ici_links defaults to 1 (the brief's convention: ~50 GB/s/link and one
    link's worth of bandwidth counted per chip).
    """
    return RooflineTerms(
        compute_s=hlo_flops / (chips * hw.peak_flops),
        memory_s=hlo_bytes / (chips * hw.hbm_bandwidth),
        collective_s=collective_bytes / (chips * ici_links * hw.ici_bandwidth),
    )
