"""Recall <-> bin-count analytics for the PartialReduce kernel.

Implements Section 5.1 of the paper (Eqs. 13/14 and Appendix A.4):
the top-K entries are modelled as K balls thrown independently and
uniformly at random into L bins; PartialReduce keeps only the top-1 of
each bin, so a top-K entry survives iff no *better* top-K entry shares
its bin.  E[recall] = ((L-1)/L)^(K-1).
"""
from __future__ import annotations

import dataclasses
import math

__all__ = [
    "expected_recall",
    "bins_for_recall",
    "bins_for_recall_approx",
    "BinPlan",
    "plan_bins",
    "round_up",
]


def round_up(x: int, mult: int) -> int:
    """Smallest multiple of ``mult`` >= ``x`` (tiling/layout helper)."""
    return ((x + mult - 1) // mult) * mult


def expected_recall(num_bins: int, k: int) -> float:
    """E[recall] of bin-wise top-1 reduction (Eq. 13)."""
    if k <= 0:
        raise ValueError(f"k must be positive, got {k}")
    if num_bins <= 0:
        raise ValueError(f"num_bins must be positive, got {num_bins}")
    if k == 1:
        return 1.0  # the single best entry always wins its bin
    return ((num_bins - 1) / num_bins) ** (k - 1)


def bins_for_recall(k: int, recall_target: float) -> int:
    """Minimal L such that E[recall] >= recall_target (Eq. 14, exact inverse)."""
    if not 0.0 < recall_target < 1.0:
        raise ValueError(f"recall_target must be in (0, 1), got {recall_target}")
    if k <= 1:
        return 1
    # L >= 1 / (1 - r^{1/(K-1)})
    l = 1.0 / (1.0 - recall_target ** (1.0 / (k - 1)))
    l_int = int(math.ceil(l))
    # Guard against float round-off in both directions: the returned L is
    # the true minimum satisfying the guarantee.
    while expected_recall(l_int, k) < recall_target:
        l_int += 1
    while l_int > 1 and expected_recall(l_int - 1, k) >= recall_target:
        l_int -= 1
    return l_int


def bins_for_recall_approx(k: int, recall_target: float) -> float:
    """First-order approximation L ~= (K-1)/(1-r) (Eq. 14 / Appendix A.4)."""
    return (k - 1) / (1.0 - recall_target)


@dataclasses.dataclass(frozen=True)
class BinPlan:
    """Concrete binning layout chosen for an (N, K, recall_target) problem.

    Attributes:
      n: database size (reduction dimension length).
      k: number of neighbours requested.
      num_bins: L — number of bins actually emitted by PartialReduce.
      log2_bin_size: W — bins hold 2**W consecutive database entries.
      padded_n: num_bins * 2**W  (>= n; the tail is masked to -inf).
      expected_recall: analytical E[recall] of this plan (Eq. 13).
    """

    n: int
    k: int
    num_bins: int
    log2_bin_size: int
    padded_n: int
    expected_recall: float

    @property
    def bin_size(self) -> int:
        return 1 << self.log2_bin_size


def plan_bins(
    n: int,
    k: int,
    recall_target: float = 0.95,
    *,
    reduction_input_size_override: int = -1,
) -> BinPlan:
    """Choose (L, W) for PartialReduce.

    Mirrors the XLA ApproxTopK sizing logic: find the minimal L meeting the
    recall target (but at least K so rescoring can return K items), then use
    the largest power-of-two bin size 2**W with ceil(n / 2**W) >= L.

    ``reduction_input_size_override``: when the database is sharded across
    devices, each shard sees only n_local entries but the recall math must be
    evaluated against the *global* N (paper §7 / jax.lax.approx_max_k
    parameter of the same name).  The override sets the N used for recall
    accounting while bins are laid out over the local n.
    """
    if n <= 0:
        raise ValueError(f"n must be positive, got {n}")
    if k > n:
        raise ValueError(f"k={k} exceeds database size n={n}")
    accounting_n = reduction_input_size_override if reduction_input_size_override > 0 else n

    l_min = max(bins_for_recall(k, recall_target), k)
    # Scale the global bin budget down to this shard.  The k-floor lives on
    # the *global* bin count (Eq. 13 holds over the union of shards; the
    # gathered candidate list has l * (N/n) >= l_min >= k entries), so a
    # shard only carries its proportional share of bins.
    l_target = (
        max(1, math.ceil(l_min * (n / accounting_n)))
        if accounting_n > n
        else l_min
    )
    if l_target >= n:
        # Degenerate: need (nearly) every entry — fall back to exact top-k
        # layout with bin size 1.
        w = 0
        l = n
    else:
        w = max(0, int(math.floor(math.log2(n / l_target))))
        l = math.ceil(n / (1 << w))
    padded = l * (1 << w)
    # Recall accounting always against the global bin count.
    l_global = l * max(1, accounting_n // n)
    return BinPlan(
        n=n,
        k=k,
        num_bins=l,
        log2_bin_size=w,
        padded_n=padded,
        expected_recall=expected_recall(l_global, k),
    )
