"""Pure-JAX PartialReduce (paper Alg. 1 / Alg. 2, reference semantics).

Reduces an (..., N) score tensor to the top-1 value+index of each of L
contiguous bins of size 2**W: bin(j) = j >> W, matching the
``RegisterAlignedShiftRight`` mapping in Alg. 2.  The Pallas kernel in
``repro.kernels.partial_reduce`` fuses this with the distance matmul; this
module is the algorithmic source of truth (and the oracle for kernel tests).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.binning import BinPlan, plan_bins

__all__ = ["partial_reduce", "partial_reduce_with_plan", "NEG_INF"]

NEG_INF = float("-inf")


def partial_reduce_with_plan(
    scores: jnp.ndarray,
    plan: BinPlan,
    *,
    mode: str = "max",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Bin-wise top-1 over the last axis of ``scores``.

    Args:
      scores: (..., N) array. N == plan.n.
      plan: binning layout from ``plan_bins``.
      mode: "max" (MIPS) or "min" (distance search).

    Returns:
      (values, indices): both (..., L).  ``indices`` are positions in the
      original (unpadded) N axis; bins that contain only padding return
      index of their first element with value +/-inf.
    """
    if scores.shape[-1] != plan.n:
        raise ValueError(f"scores last dim {scores.shape[-1]} != plan.n {plan.n}")
    neutral = NEG_INF if mode == "max" else -NEG_INF
    pad = plan.padded_n - plan.n
    if pad:
        # Masking the non-power-of-2 tail: the "+1 COP" of Appendix A.5.
        pad_widths = [(0, 0)] * (scores.ndim - 1) + [(0, pad)]
        scores = jnp.pad(scores, pad_widths, constant_values=neutral)
    binned = scores.reshape(scores.shape[:-1] + (plan.num_bins, plan.bin_size))
    if mode == "max":
        vals = jnp.max(binned, axis=-1)
        args = jnp.argmax(binned, axis=-1)
    elif mode == "min":
        vals = jnp.min(binned, axis=-1)
        args = jnp.argmin(binned, axis=-1)
    else:
        raise ValueError(f"mode must be 'max' or 'min', got {mode!r}")
    offsets = jnp.arange(plan.num_bins, dtype=jnp.int32) * plan.bin_size
    idx = offsets + args.astype(jnp.int32)
    # Clamp padded-bin indices back into range (their value is +/-inf anyway).
    idx = jnp.minimum(idx, plan.n - 1)
    return vals, idx


def partial_reduce(
    scores: jnp.ndarray,
    k: int,
    recall_target: float = 0.95,
    *,
    mode: str = "max",
    reduction_input_size_override: int = -1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Convenience wrapper: plan bins from (N, k, recall_target) then reduce."""
    plan = plan_bins(
        scores.shape[-1],
        k,
        recall_target,
        reduction_input_size_override=reduction_input_size_override,
    )
    return partial_reduce_with_plan(scores, plan, mode=mode)
