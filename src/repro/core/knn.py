"""K-nearest-neighbour search ops (paper Listings 1 & 2).

All three distance modes reduce to a single MXU einsum plus at most one COP
per dot product:
  * MIPS:    argmax  <q, x>
  * cosine:  MIPS on l2-normalised vectors
  * L2:      argmin  ||x||^2/2 - <q, x>   (halved-norm trick, Eq. 19 — 1 COP)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.topk import approx_max_k

__all__ = ["half_norms", "mips", "l2nns", "cosine_nns", "exact_mips", "exact_l2nns"]


def half_norms(database: jnp.ndarray) -> jnp.ndarray:
    """Precomputed ||x||^2 / 2 per database row (Eq. 19)."""
    return 0.5 * jnp.sum(jnp.square(database), axis=-1)


def mips(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    k: int = 10,
    *,
    recall_target: float = 0.95,
    reduction_input_size_override: int = -1,
    aggregate_to_topk: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Maximum inner product search (paper Listing 1)."""
    scores = jnp.einsum("ik,jk->ij", queries, database)
    return approx_max_k(
        scores,
        k,
        recall_target=recall_target,
        reduction_input_size_override=reduction_input_size_override,
        aggregate_to_topk=aggregate_to_topk,
    )


def l2nns(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    k: int = 10,
    *,
    db_half_norm: Optional[jnp.ndarray] = None,
    recall_target: float = 0.95,
    reduction_input_size_override: int = -1,
    aggregate_to_topk: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Euclidean NN search (paper Listing 2, relaxed distance Eq. 19).

    Note the returned "values" are the relaxed scores ||x||^2/2 - <q,x>,
    monotone in true L2 distance for each query (the query norm is dropped).
    """
    if db_half_norm is None:
        db_half_norm = half_norms(database)
    dots = jnp.einsum("ik,jk->ij", queries, database)
    dists = db_half_norm[None, :] - dots
    # approx_min == approx_max on negated scores; keeps a single kernel.
    neg_vals, idxs = approx_max_k(
        -dists,
        k,
        recall_target=recall_target,
        reduction_input_size_override=reduction_input_size_override,
        aggregate_to_topk=aggregate_to_topk,
    )
    return -neg_vals, idxs


def cosine_nns(
    queries: jnp.ndarray,
    database_normalized: jnp.ndarray,
    k: int = 10,
    **kwargs,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cosine similarity search == MIPS on l2-normalised data (paper §2)."""
    q = queries / jnp.linalg.norm(queries, axis=-1, keepdims=True)
    return mips(q, database_normalized, k, **kwargs)


# --- Exact baselines (for recall evaluation / Faiss-Flat analogue) ---------


def exact_mips(queries, database, k=10):
    scores = jnp.einsum("ik,jk->ij", queries, database)
    import jax

    return jax.lax.top_k(scores, k)


def exact_l2nns(queries, database, k=10):
    dists = half_norms(database)[None, :] - jnp.einsum("ik,jk->ij", queries, database)
    import jax

    vals, idxs = jax.lax.top_k(-dists, k)
    return -vals, idxs
