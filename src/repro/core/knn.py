"""DEPRECATED shim — use ``repro.search`` instead.

The five historical entry points were unified behind ``repro.search``
(``Index.build(...).search(...)`` or the functional ``repro.search.search``).
This module re-exports the functional equivalents with their original
signatures so existing callers keep working; new code should not import it.

Value/sign conventions (including the L2 relaxed-distance contract) are
documented once, in ``repro.search.metrics``.  The old -> new mapping is
tabulated in ``docs/migration.md``.
"""
from __future__ import annotations

import warnings

import jax  # noqa: F401  (kept at module top; was function-local pre-shim)

warnings.warn(
    "repro.core.knn is a deprecated shim; use repro.search "
    "(Index.build(...).search(...)) — see docs/migration.md",
    DeprecationWarning,
    stacklevel=2,
)

from repro.search.functional import (
    cosine_nns,
    exact_cosine_nns,
    exact_l2nns,
    exact_mips,
    half_norms,
    l2nns,
    mips,
)

__all__ = [
    "half_norms",
    "mips",
    "l2nns",
    "cosine_nns",
    "exact_mips",
    "exact_l2nns",
    "exact_cosine_nns",
]
