"""Distributed KNN (paper §7): shard the database, PartialReduce locally,
all-gather the L bin-winners, ExactRescore globally.

Built with shard_map so the communication pattern is explicit:
  * database rows sharded over ``db_axis`` (each shard holds N/S rows),
  * queries replicated over ``db_axis`` (optionally sharded over a batch axis),
  * each shard reduces its N/S scores to L/S candidates using the *global* N
    for recall accounting (``reduction_input_size_override``),
  * one all-gather of (M, L/S) values+indices per shard group,
  * rescoring runs replicated (L is tiny).

This same pattern is reused by ``models.attention.knn_topk_attention`` for
sequence-sharded KV caches (context-parallel long-context decode).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.binning import plan_bins
from repro.core.partial_reduce import partial_reduce_with_plan
from repro.core.rescoring import exact_rescoring

__all__ = ["sharded_mips", "sharded_l2nns", "make_sharded_searcher"]


def _local_partial_reduce(scores, *, global_n, k, recall_target, shard_offset):
    """PartialReduce on a local score shard; indices are globalized."""
    n_local = scores.shape[-1]
    plan = plan_bins(
        n_local, k, recall_target, reduction_input_size_override=global_n
    )
    vals, idxs = partial_reduce_with_plan(scores, plan, mode="max")
    return vals, idxs + shard_offset


def make_sharded_searcher(
    mesh: Mesh,
    *,
    k: int = 10,
    recall_target: float = 0.95,
    db_axis: str = "model",
    batch_axis: Optional[str] = None,
    metric: str = "mips",
):
    """Build a jit-able sharded search fn: (queries, database[, half_norms]) -> (vals, idxs).

    database is expected sharded P(db_axis, None); queries sharded
    P(batch_axis, None) (or replicated when batch_axis is None).
    """

    def searcher(queries, database, db_half_norm=None):
        global_n = database.shape[0]
        n_shards = mesh.shape[db_axis]
        if global_n % n_shards:
            raise ValueError(
                f"database rows {global_n} not divisible by {n_shards} shards"
            )

        qspec = P(batch_axis, None) if batch_axis else P(None, None)
        hspec = P(db_axis) if db_half_norm is not None else None
        out_batch = batch_axis  # rescoring output keeps the query sharding

        def local_fn(q, db, hn):
            axis_idx = jax.lax.axis_index(db_axis)
            n_local = db.shape[0]
            offset = axis_idx.astype(jnp.int32) * n_local
            scores = jnp.einsum("ik,jk->ij", q, db)
            if metric == "l2":
                scores = scores - hn[None, :]  # == -(||x||^2/2 - <q,x>)
            vals, idxs = _local_partial_reduce(
                scores,
                global_n=global_n,
                k=k,
                recall_target=recall_target,
                shard_offset=offset,
            )
            # Gather the candidate lists from every database shard.
            vals = jax.lax.all_gather(vals, db_axis, axis=-1, tiled=True)
            idxs = jax.lax.all_gather(idxs, db_axis, axis=-1, tiled=True)
            top_v, top_i = exact_rescoring(vals, idxs, k, mode="max")
            if metric == "l2":
                top_v = -top_v
            return top_v, top_i

        in_specs = (qspec, P(db_axis, None), P(db_axis))
        out_specs = (P(out_batch, None), P(out_batch, None))
        hn = (
            db_half_norm
            if db_half_norm is not None
            else jnp.zeros((global_n,), queries.dtype)
        )
        # check_vma=False: the all_gather over db_axis makes outputs
        # replicated over that axis, which the static VMA check cannot infer.
        fn = jax.shard_map(
            local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
        return fn(queries, database, hn)

    return searcher


def sharded_mips(queries, database, k, mesh, **kw):
    """One-shot distributed MIPS (convenience wrapper)."""
    return make_sharded_searcher(mesh, k=k, metric="mips", **kw)(queries, database)


def sharded_l2nns(queries, database, k, mesh, *, db_half_norm=None, **kw):
    if db_half_norm is None:
        db_half_norm = 0.5 * jnp.sum(jnp.square(database), axis=-1)
    return make_sharded_searcher(mesh, k=k, metric="l2", **kw)(
        queries, database, db_half_norm
    )
