"""DEPRECATED shim — use ``repro.search`` instead.

The distributed KNN pattern (paper §7: shard the database, PartialReduce
locally with global-N recall accounting, all-gather the bin winners,
ExactRescore globally) now lives in
``repro.search.backends.make_sharded_search_fn``; the convenient way to use
it is ``repro.search.Index.build(db).shard(mesh, db_axis=...)``.

These wrappers preserve the historical signatures (including the
positive-half-norm convention of ``db_half_norm``).  The old -> new mapping
is tabulated in ``docs/migration.md``.
"""
from __future__ import annotations

import warnings

from typing import Optional

warnings.warn(
    "repro.core.distributed is a deprecated shim; use repro.search "
    "(Index.build(db).shard(mesh, ...)) — see docs/migration.md",
    DeprecationWarning,
    stacklevel=2,
)

import jax.numpy as jnp
from jax.sharding import Mesh

from repro.search.backends import make_sharded_search_fn

__all__ = ["sharded_mips", "sharded_l2nns", "make_sharded_searcher"]


def make_sharded_searcher(
    mesh: Mesh,
    *,
    k: int = 10,
    recall_target: float = 0.95,
    db_axis: str = "model",
    batch_axis: Optional[str] = None,
    metric: str = "mips",
):
    """Build a jit-able sharded search fn: (queries, database[, half_norms]) -> (vals, idxs).

    database is expected sharded P(db_axis, None); queries sharded
    P(batch_axis, None) (or replicated when batch_axis is None).
    """
    fn = make_sharded_search_fn(
        mesh, metric=metric, k=k, recall_target=recall_target,
        db_axis=db_axis, batch_axis=batch_axis,
    )

    def searcher(queries, database, db_half_norm=None):
        row_bias = None if db_half_norm is None else -db_half_norm
        return fn(queries, database, row_bias)

    return searcher


def sharded_mips(queries, database, k, mesh, **kw):
    """One-shot distributed MIPS (convenience wrapper)."""
    return make_sharded_searcher(mesh, k=k, metric="mips", **kw)(queries, database)


def sharded_l2nns(queries, database, k, mesh, *, db_half_norm=None, **kw):
    if db_half_norm is None:
        db_half_norm = 0.5 * jnp.sum(jnp.square(database), axis=-1)
    return make_sharded_searcher(mesh, k=k, metric="l2", **kw)(
        queries, database, db_half_norm
    )
