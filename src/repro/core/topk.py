"""approx_max_k / approx_min_k — the paper's public operator.

Mirrors the interface the authors upstreamed to JAX/XLA
(``jax.lax.approx_max_k``) but is implemented from scratch on top of
``core.partial_reduce`` + ``core.rescoring`` so the repro owns the algorithm.

Options (paper Appendix A.1):
  * recall_target          -> derives the bin count L (Eq. 14)
  * reduction_input_size_override -> recall accounting N for sharded inputs
  * aggregate_to_topk      -> emit the ExactRescoring kernel (default True)
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.binning import plan_bins
from repro.core.partial_reduce import partial_reduce_with_plan
from repro.core.rescoring import exact_rescoring

__all__ = ["approx_max_k", "approx_min_k"]


def _approx_k(
    operand: jnp.ndarray,
    k: int,
    *,
    mode: str,
    recall_target: float,
    reduction_input_size_override: int,
    aggregate_to_topk: bool,
    use_bitonic: bool,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n = operand.shape[-1]
    plan = plan_bins(
        n,
        k,
        recall_target,
        reduction_input_size_override=reduction_input_size_override,
    )
    vals, idxs = partial_reduce_with_plan(operand, plan, mode=mode)
    if not aggregate_to_topk:
        return vals, idxs
    return exact_rescoring(vals, idxs, k, mode=mode, use_bitonic=use_bitonic)


def approx_max_k(
    operand: jnp.ndarray,
    k: int,
    *,
    recall_target: float = 0.95,
    reduction_input_size_override: int = -1,
    aggregate_to_topk: bool = True,
    use_bitonic: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Approximate top-k maxima along the last axis (paper Listing 1)."""
    return _approx_k(
        operand,
        k,
        mode="max",
        recall_target=recall_target,
        reduction_input_size_override=reduction_input_size_override,
        aggregate_to_topk=aggregate_to_topk,
        use_bitonic=use_bitonic,
    )


def approx_min_k(
    operand: jnp.ndarray,
    k: int,
    *,
    recall_target: float = 0.95,
    reduction_input_size_override: int = -1,
    aggregate_to_topk: bool = True,
    use_bitonic: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Approximate top-k minima along the last axis (paper Listing 2)."""
    return _approx_k(
        operand,
        k,
        mode="min",
        recall_target=recall_target,
        reduction_input_size_override=reduction_input_size_override,
        aggregate_to_topk=aggregate_to_topk,
        use_bitonic=use_bitonic,
    )
