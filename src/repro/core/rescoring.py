"""ExactRescoring kernel (paper §5): bitonic sort + truncation.

Aggregates the (..., L) bin winners emitted by PartialReduce into the exact
top-K among them.  The paper specifies an O(M·L·log²L) bitonic sort; we
implement the full bitonic network with vectorized compare-exchange stages
(each stage is a shuffle + select, exactly what the TPU VPU executes), plus a
``jax.lax.top_k`` fast path for comparison.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["bitonic_sort_pairs", "exact_rescoring"]


def _compare_exchange(vals, idxs, stage: int, substage: int, descending: bool):
    n = vals.shape[-1]
    d = 1 << substage
    lane = jnp.arange(n, dtype=jnp.int32)
    partner = lane ^ d
    v_p = jnp.take(vals, partner, axis=-1)
    i_p = jnp.take(idxs, partner, axis=-1)
    # Block direction: within blocks of 2**(stage+1), alternate sort order to
    # build bitonic sequences; the final merge stage is monotone.
    block_desc = ((lane >> (stage + 1)) & 1) == 0
    if not descending:
        block_desc = ~block_desc
    is_lower = (lane & d) == 0
    # In a descending block the lower lane keeps the max.
    keep_max = block_desc == is_lower
    swap = jnp.where(keep_max, vals < v_p, vals > v_p)
    vals = jnp.where(swap, v_p, vals)
    idxs = jnp.where(swap, i_p, idxs)
    return vals, idxs


def bitonic_sort_pairs(
    vals: jnp.ndarray,
    idxs: jnp.ndarray,
    *,
    descending: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Bitonic sort of (vals, idxs) pairs along the last axis.

    Last axis length is padded to the next power of two internally.
    """
    n = vals.shape[-1]
    p = max(1, (n - 1).bit_length())
    padded = 1 << p
    if padded != n:
        fill = float("-inf") if descending else float("inf")
        pad_w = [(0, 0)] * (vals.ndim - 1) + [(0, padded - n)]
        vals = jnp.pad(vals, pad_w, constant_values=fill)
        idxs = jnp.pad(idxs, pad_w, constant_values=0)
    for stage in range(p):
        for substage in range(stage, -1, -1):
            vals, idxs = _compare_exchange(vals, idxs, stage, substage, descending)
    return vals[..., :n], idxs[..., :n]


def exact_rescoring(
    vals: jnp.ndarray,
    idxs: jnp.ndarray,
    k: int,
    *,
    mode: str = "max",
    use_bitonic: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact top-k of the PartialReduce candidates, with original indices.

    Args:
      vals, idxs: (..., L) candidate values and database indices.
      k: number of results.
      mode: "max" or "min" — matches the PartialReduce mode.
      use_bitonic: paper-faithful bitonic network (True) or lax.top_k (False).
    """
    if k > vals.shape[-1]:
        raise ValueError(f"k={k} exceeds candidate count L={vals.shape[-1]}")
    sort_vals = vals if mode == "max" else -vals
    if use_bitonic:
        sv, si = bitonic_sort_pairs(sort_vals, idxs, descending=True)
        top_v, top_i = sv[..., :k], si[..., :k]
    else:
        top_v, gather = jax.lax.top_k(sort_vals, k)
        top_i = jnp.take_along_axis(idxs, gather, axis=-1)
    if mode == "min":
        top_v = -top_v
    return top_v, top_i
