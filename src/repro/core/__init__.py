"""Core library: the paper's contribution as composable JAX modules."""
from repro.core.binning import (
    BinPlan,
    bins_for_recall,
    bins_for_recall_approx,
    expected_recall,
    plan_bins,
)
from repro.core.knn import (
    cosine_nns,
    exact_cosine_nns,
    exact_l2nns,
    exact_mips,
    half_norms,
    l2nns,
    mips,
)
from repro.core.partial_reduce import partial_reduce, partial_reduce_with_plan
from repro.core.rescoring import bitonic_sort_pairs, exact_rescoring
from repro.core.roofline import (
    HARDWARE,
    Hardware,
    KernelCost,
    RooflineTerms,
    attainable_flops,
    bottleneck,
    cops_per_dot,
    partial_reduce_cost,
    roofline_terms,
)
from repro.core.topk import approx_max_k, approx_min_k
