"""Core library: the paper's contribution as composable JAX modules."""
from repro.core.binning import (
    BinPlan,
    bins_for_recall,
    bins_for_recall_approx,
    expected_recall,
    plan_bins,
)
from repro.core.partial_reduce import partial_reduce, partial_reduce_with_plan
from repro.core.rescoring import bitonic_sort_pairs, exact_rescoring
from repro.core.roofline import (
    HARDWARE,
    Hardware,
    KernelCost,
    RooflineTerms,
    attainable_flops,
    bottleneck,
    cops_per_dot,
    partial_reduce_cost,
    roofline_terms,
)
from repro.core.topk import approx_max_k, approx_min_k

# The legacy KNN entry points (repro.core.knn) are a deprecated shim over
# repro.search; re-export lazily (PEP 562) so the shim's DeprecationWarning
# fires only when a legacy symbol is actually used — not for everyone who
# imports repro.core.binning / roofline through this package.
_KNN_SHIM = (
    "cosine_nns", "exact_cosine_nns", "exact_l2nns", "exact_mips",
    "half_norms", "l2nns", "mips",
)


def __getattr__(name):
    if name in _KNN_SHIM or name == "knn":
        import importlib

        knn = importlib.import_module("repro.core.knn")
        # `repro.core.knn` itself stays reachable as an attribute, as the
        # old eager import made it.
        return knn if name == "knn" else getattr(knn, name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
