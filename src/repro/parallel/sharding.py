"""Logical-axis sharding rules (MaxText-style) for the model zoo.

Model code annotates activations/params with *logical* axis names; the rules
table maps them to mesh axes.  DP over ("pod", "data"); TP/EP/CP over
"model".  When no mesh is active the constraint is a no-op so smoke tests on
one CPU device run unmodified.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "LOGICAL_RULES",
    "logical_to_spec",
    "shard",
    "param_spec",
    "activation_rules",
    "use_mesh",
    "current_mesh",
    "shard_map_compat",
]


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """shard_map across jax versions, replication checking disabled.

    shard_map moved out of jax.experimental (and ``check_rep`` became
    ``check_vma``) around jax 0.6.  Checking is off because our collectives
    (all_gather over the reduced axis) produce replication the static
    checker cannot infer.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )

# logical axis -> mesh axes (None = replicated).  ("pod","data") only ever
# shards batch-like axes; "model" shards head/ffn/expert/vocab axes.
LOGICAL_RULES: Tuple[Tuple[str, Optional[object]], ...] = (
    ("batch", ("pod", "data")),
    ("seq", None),                  # sequence kept whole for training
    ("cp_seq", "model"),            # context-parallel KV cache sequence
    ("embed", None),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("head_dim", None),
    ("ffn", "model"),
    ("moe_ffn", None),              # EP owns "model"; per-expert FFN unsharded
    ("experts", "model"),           # expert parallelism
    ("vocab", "model"),
    ("kv_lora", None),
    ("ssm_heads", "model"),
    ("ssm_state", None),
    ("lru_width", "model"),
    ("conv_dim", "model"),
    ("group", None),
    ("capacity", None),
    ("fsdp_embed", ("pod", "data")),  # ZeRO/FSDP param sharding for huge archs
)

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def _rules():
    return dict(getattr(_state, "rules", None) or LOGICAL_RULES)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Sequence] = None):
    """Activate a mesh (and optional rule overrides) for model tracing."""
    prev_mesh = getattr(_state, "mesh", None)
    prev_rules = getattr(_state, "rules", None)
    _state.mesh = mesh
    _state.rules = tuple(rules) if rules is not None else None
    try:
        yield
    finally:
        _state.mesh = prev_mesh
        _state.rules = prev_rules


def logical_to_spec(logical_axes: Sequence[Optional[str]]) -> P:
    """Map logical axis names to a PartitionSpec under the active rules."""
    mesh = current_mesh()
    rules = _rules()
    axes = []
    for name in logical_axes:
        if name is None:
            axes.append(None)
            continue
        target = rules.get(name)
        if target is None or mesh is None:
            axes.append(None)
            continue
        # Drop mesh axes that don't exist on this mesh (e.g. "pod" on the
        # single-pod mesh).
        if isinstance(target, tuple):
            present = tuple(a for a in target if a in mesh.axis_names)
            axes.append(present if present else None)
        else:
            axes.append(target if target in mesh.axis_names else None)
    return P(*axes)


def shard(x, *logical_axes):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_spec(*logical_axes) -> P:
    """PartitionSpec for a parameter tensor (used to build in_shardings)."""
    return logical_to_spec(logical_axes)
