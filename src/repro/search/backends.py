"""Backend implementations behind the unified search API.

Three interchangeable executions of the same algorithm (score matmul ->
PartialReduce -> ExactRescoring), all consuming metric-prepared operands and
an additive per-row bias (metric bias + tombstone mask), all returning the
*internal* max-convention first and negating once for distance metrics:

  * ``dense_search``  — pure-XLA reference path (einsum + approx_max_k).
  * ``pallas_search`` — fused Pallas PartialReduce kernel (interpret mode on
    CPU, compiled on TPU); cosine works here too since it is biased MIPS.
  * ``make_sharded_search_fn`` — shard_map over a database axis with
    ``reduction_input_size_override`` recall accounting (paper §7).

``TRACE_COUNTS`` increments once per *trace* of each backend (the body of a
jitted function only runs while tracing), which is how the compile-cache
tests assert "no retrace on same-shape repeat searches".  ``DISPATCH_COUNTS``
increments once per compiled-callable *invocation* from ``Index`` — the
streaming executor's "one dispatch for an 8-block batch" contract is
asserted against it.  Both have ``reset_*`` helpers; tests should reset
rather than do cross-test counter arithmetic.

The steady-state entry points (``dense_search``, ``pallas_search_packed``)
consume pre-packed operands from ``repro.search.packed`` and perform no
database-sized padding or preparation; ``pallas_search`` keeps the one-shot
pack-inside-jit behavior for the functional API and the legacy shims.
"""
from __future__ import annotations

import collections
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.binning import plan_bins, round_up
from repro.core.partial_reduce import partial_reduce_with_plan
from repro.kernels.partial_reduce import (
    partial_reduce_fused,
    partial_reduce_packed,
    partial_reduce_pallas,
)
from repro.parallel.sharding import shard_map_compat
from repro.search.metrics import get_metric
from repro.search import telemetry
from repro.search.stages import (
    MASK_VALUE,
    finalize_values,
    merge_topk,
    pad_queries_to,
    prune_candidates,
    rescore_candidates,
    scan_candidates,
    score_gathered,
    score_rows,
    sentinelize_masked,
)

__all__ = [
    "MASK_VALUE",
    "TRACE_COUNTS",
    "DISPATCH_COUNTS",
    "CompileCache",
    "cluster_search",
    "cluster_search_quant",
    "dense_search",
    "dense_search_quant",
    "pallas_search",
    "pallas_search_packed",
    "pallas_search_packed_quant",
    "prepare_pallas_inputs",
    "make_sharded_search_fn",
    "normalize_db_axes",
    "db_shard_count",
    "default_backend",
    "reset_trace_counts",
    "reset_dispatch_counts",
]

# MASK_VALUE is defined in (and re-exported from) ``repro.search.stages``.

# backend name -> number of jit traces (test observability hook).
# AtomicCounter (repro.search.telemetry): increments are lock-protected
# read-modify-writes, and the global registry adopts the dict so one
# telemetry export / reset_all() covers it.
TRACE_COUNTS = telemetry.AtomicCounter()

# backend name -> number of compiled-callable invocations issued by Index
# (one per device dispatch; the streaming executor issues exactly one for
# an arbitrarily large query batch).
DISPATCH_COUNTS = telemetry.AtomicCounter()

telemetry.registry().register_counter_dict(
    "repro_traces_total", TRACE_COUNTS, "backend",
    "jit traces per backend (steady state: zero growth)",
)
telemetry.registry().register_counter_dict(
    "repro_dispatches_total", DISPATCH_COUNTS, "backend",
    "device dispatches per backend (one per coalesced batch)",
)


def reset_trace_counts() -> None:
    """Zero ``TRACE_COUNTS`` (tests: reset, act, assert — no arithmetic).

    Deprecated thin alias: ``repro.search.telemetry.reset_all()`` zeroes
    this and every other global series in one call."""
    TRACE_COUNTS.clear()


def reset_dispatch_counts() -> None:
    """Zero ``DISPATCH_COUNTS`` (deprecated alias — prefer
    ``repro.search.telemetry.reset_all()``)."""
    DISPATCH_COUNTS.clear()


def default_backend(mesh: Optional[Mesh] = None) -> str:
    """Resolve backend="auto": sharded with a mesh, pallas on TPU, else xla."""
    if mesh is not None:
        return "sharded"
    return "pallas" if jax.default_backend() == "tpu" else "xla"


class CompileCache:
    """Shape/spec-keyed cache of built search callables.

    jax.jit already memoizes traces per callable; this cache additionally
    memoizes the *callables* (closures over static config) so repeat
    searches at the same shape hit the same jitted function — and exposes
    hit/miss counters so tests and users can verify no retracing happens.
    """

    def __init__(self):
        self._fns = {}
        self.hits = 0
        self.misses = 0

    def get(self, key, builder: Callable[[], Callable]) -> Callable:
        fn = self._fns.get(key)
        if fn is None:
            self.misses += 1
            fn = self._fns[key] = builder()
        else:
            self.hits += 1
        return fn

    def info(self) -> dict:
        return {"hits": self.hits, "misses": self.misses, "entries": len(self._fns)}

    def reset_counters(self) -> None:
        """Zero hit/miss counters, keeping the compiled entries."""
        self.hits = 0
        self.misses = 0

    def clear(self) -> None:
        """Drop every entry and zero the counters (forces rebuilds)."""
        self._fns.clear()
        self.reset_counters()


# --- XLA backend ------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=(
        "metric", "k", "recall_target", "reduction_input_size_override",
        "aggregate_to_topk", "use_bitonic",
    ),
)
def dense_search(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    row_bias: Optional[jnp.ndarray] = None,
    *,
    metric: str = "mips",
    k: int = 10,
    recall_target: float = 0.95,
    reduction_input_size_override: int = -1,
    aggregate_to_topk: bool = True,
    use_bitonic: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pure-XLA search: full score matrix + approx_max_k (paper Listings 1/2).

    ``database`` must already be metric-prepared (e.g. normalized for
    cosine); ``row_bias`` carries the metric bias and/or tombstone mask.
    """
    m = get_metric(metric)
    TRACE_COUNTS.inc("xla")
    q = m.prepare_queries(queries)
    scores = score_rows(q, database, row_bias)
    vals, idxs = scan_candidates(
        scores,
        k,
        recall_target=recall_target,
        reduction_input_size_override=reduction_input_size_override,
        aggregate_to_topk=aggregate_to_topk,
        use_bitonic=use_bitonic,
    )
    return finalize_values(vals, m.negate_output), idxs


# --- Quantized two-pass (scan -> exact rescore), repro.search.quant ---------


# Stage alias: the exact second pass lives in ``repro.search.stages``;
# the underscored name predates the stage split and stays for callers
# (and tests) that reached into this module.
_rescore_candidates = rescore_candidates


@functools.partial(
    jax.jit,
    static_argnames=(
        "metric", "k", "k_scan", "recall_target",
        "reduction_input_size_override", "aggregate_to_topk", "use_bitonic",
    ),
)
def dense_search_quant(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    row_bias: Optional[jnp.ndarray],
    scale: Optional[jnp.ndarray],
    rescore_db: Optional[jnp.ndarray],
    rescore_bias: Optional[jnp.ndarray],
    *,
    metric: str,
    k: int,
    k_scan: int,
    recall_target: float = 0.95,
    reduction_input_size_override: int = -1,
    aggregate_to_topk: bool = True,
    use_bitonic: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """XLA two-pass search over a quantized storage tier.

    ``database`` holds the stored (bf16/int8) metric-prepared rows,
    ``scale`` the int8 per-row dequantization scale (None otherwise), and
    ``row_bias`` the fused bias *of the stored values* (metric-bias
    correction + tombstones).  When ``rescore_db`` is given the scan keeps
    the over-fetched candidate set (bins planned for ``k_scan``,
    ``repro.search.quant.scan_k``) and the exact top-k comes from
    re-scoring those candidates against the full-precision tail; without
    it the quantized scan's own scores are returned (approximate values).
    """
    m = get_metric(metric)
    TRACE_COUNTS.inc("xla")
    q = m.prepare_queries(queries)
    scores = score_rows(q, database, row_bias, scale)
    if rescore_db is not None:
        vals, idxs = scan_candidates(
            scores,
            k_scan,
            recall_target=recall_target,
            reduction_input_size_override=reduction_input_size_override,
            aggregate_to_topk=False,
        )
        vals, idxs = rescore_candidates(
            q, vals, idxs, rescore_db, rescore_bias, k, k_scan, use_bitonic
        )
    else:
        vals, idxs = scan_candidates(
            scores,
            k,
            recall_target=recall_target,
            reduction_input_size_override=reduction_input_size_override,
            aggregate_to_topk=aggregate_to_topk,
            use_bitonic=use_bitonic,
        )
    return finalize_values(vals, m.negate_output), idxs


# --- Cluster-pruned scan (repro.search.cluster) ------------------------------


# Stage aliases (see ``repro.search.stages``): the pruning front-end and
# the lane-padding helper moved to the stage layer; the underscored names
# stay for in-repo callers that predate the split.
_cluster_candidates = prune_candidates
_pad_queries_to = pad_queries_to


@functools.partial(
    jax.jit,
    static_argnames=(
        "metric", "k", "probes", "target_scan", "aggregate_to_topk",
        "use_bitonic", "trace_as",
    ),
)
def cluster_search(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    row_bias: jnp.ndarray,
    centroids: jnp.ndarray,
    centroid_bias: jnp.ndarray,
    cluster_rows: jnp.ndarray,
    spill_rows: jnp.ndarray,
    *,
    metric: str,
    k: int,
    probes: int,
    target_scan: float,
    aggregate_to_topk: bool = True,
    use_bitonic: bool = False,
    trace_as: str = "xla",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cluster-pruned search over a packed f32-tier layout (one dispatch).

    Score the C centroids, gather only the top-``probes`` clusters' rows
    (plus the spill block) from the packed database, and reduce those S
    candidates at the planner's inflated ``target_scan`` — the product
    with the cluster-miss budget meets the user's original target
    (``repro.search.cluster``).  Consumes either packed layout: the xla
    (n, d)/(n,) operands or the pallas (n_pad, d_pad)/(1, n_pad) ones —
    gathers are layout-indifferent, which is also why the fused Eq. 20
    kernel is bypassed here: a pruned scan has no sequential database
    stream left to fuse, so both single-device backends share this
    gathered program (``trace_as`` keeps trace accounting under the
    resolved backend's name).  Returned ids are user-space directly — the
    slot tables *are* the permutation map.  Gathered candidates carry the
    fused bias row, so tombstones and masked slots can never surface.
    """
    m_obj = get_metric(metric)
    TRACE_COUNTS.inc(trace_as)
    q = m_obj.prepare_queries(queries)
    idc, valid = prune_candidates(
        q, centroids, centroid_bias, cluster_rows, spill_rows, probes
    )
    qp = pad_queries_to(q, database.shape[1])
    rows = database[idc]                              # (m, S, d) gather
    scores = score_gathered(qp, rows.astype(jnp.float32), row_bias, idc, valid)
    vals, pos = scan_candidates(
        scores, k, recall_target=target_scan,
        aggregate_to_topk=aggregate_to_topk, use_bitonic=use_bitonic,
    )
    idxs = jnp.take_along_axis(idc, pos, axis=-1)
    return finalize_values(vals, m_obj.negate_output), idxs


@functools.partial(
    jax.jit,
    static_argnames=(
        "metric", "k", "k_scan", "probes", "target_scan",
        "aggregate_to_topk", "use_bitonic", "trace_as",
    ),
)
def cluster_search_quant(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    row_bias: jnp.ndarray,
    scale: Optional[jnp.ndarray],
    rescore_db: Optional[jnp.ndarray],
    rescore_bias: Optional[jnp.ndarray],
    centroids: jnp.ndarray,
    centroid_bias: jnp.ndarray,
    cluster_rows: jnp.ndarray,
    spill_rows: jnp.ndarray,
    *,
    metric: str,
    k: int,
    k_scan: int,
    probes: int,
    target_scan: float,
    aggregate_to_topk: bool = True,
    use_bitonic: bool = False,
    trace_as: str = "xla",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cluster-pruned two-pass search over a quantized packed tier.

    The over-fetches stack: the pruned scan ranks the S gathered
    candidates by quantized score with bins planned for ``k_scan``
    (``quant.scan_k``'s confusion budget) at the cluster planner's
    ``target_scan``, then the usual exact second pass re-scores the
    over-fetched winners from the full-precision tail — so the combined
    guarantee is collision(K', S) x miss, both terms budgeted.  Candidate
    ids are user-space, so the rescore gather is identical to the
    unclustered one.
    """
    m_obj = get_metric(metric)
    TRACE_COUNTS.inc(trace_as)
    q = m_obj.prepare_queries(queries)
    idc, valid = prune_candidates(
        q, centroids, centroid_bias, cluster_rows, spill_rows, probes
    )
    qp = pad_queries_to(q, database.shape[1])
    rows = database[idc]
    scores = score_gathered(
        qp, rows.astype(jnp.float32), row_bias, idc, valid, scale
    )
    if rescore_db is not None:
        vals, pos = scan_candidates(
            scores, k_scan, recall_target=target_scan,
            aggregate_to_topk=False,
        )
        idxs = jnp.take_along_axis(idc, pos, axis=-1)
        vals, idxs = rescore_candidates(
            q, vals, idxs, rescore_db, rescore_bias, k, k_scan, use_bitonic
        )
    else:
        vals, pos = scan_candidates(
            scores, k, recall_target=target_scan,
            aggregate_to_topk=aggregate_to_topk, use_bitonic=use_bitonic,
        )
        idxs = jnp.take_along_axis(idc, pos, axis=-1)
    return finalize_values(vals, m_obj.negate_output), idxs


# --- Pallas backend ---------------------------------------------------------


def prepare_pallas_inputs(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    k: int,
    recall_target: float,
    *,
    block_m: int,
    max_block_n: int = 1024,
    row_bias: Optional[jnp.ndarray] = None,
    reduction_input_size_override: int = -1,
):
    """Pad operands to the kernel tiling contract and build the fused bias row.

    The bias row fuses (Appendix A.5) the non-power-of-2 tail mask, the
    metric's additive per-row bias (e.g. -||x||^2/2 for L2), and any
    tombstone mask into a single COP.
    """
    m, d = queries.shape
    n = database.shape[0]
    plan = plan_bins(
        n, k, recall_target,
        reduction_input_size_override=reduction_input_size_override,
    )
    bin_size = plan.bin_size
    block_n = bin_size * max(1, max_block_n // bin_size)
    n_pad = round_up(max(n, block_n), block_n)
    m_pad = round_up(max(m, block_m), block_m)
    d_pad = round_up(d, 128)

    q = jnp.pad(queries, ((0, m_pad - m), (0, d_pad - d)))
    db = jnp.pad(database, ((0, n_pad - n), (0, d_pad - d)))
    bias = jnp.full((n_pad,), MASK_VALUE, jnp.float32)
    body = (
        jnp.zeros((n,), jnp.float32)
        if row_bias is None
        else jnp.maximum(row_bias.astype(jnp.float32), MASK_VALUE)
    )
    bias = bias.at[:n].set(body)
    return q, db, bias[None, :], plan, bin_size, block_n, (m, n)


@functools.partial(
    jax.jit,
    static_argnames=(
        "metric", "k", "recall_target", "block_m", "max_block_n", "interpret",
        "aggregate_to_topk", "use_bitonic", "reduction_input_size_override",
    ),
)
def _pallas_search_jit(
    queries,
    database,
    row_bias,
    *,
    metric,
    k,
    recall_target,
    block_m,
    max_block_n,
    interpret,
    aggregate_to_topk,
    use_bitonic,
    reduction_input_size_override,
):
    m_obj = get_metric(metric)
    TRACE_COUNTS.inc("pallas")
    q = m_obj.prepare_queries(queries)
    q, db, bias, plan, bin_size, block_n, (m, n) = prepare_pallas_inputs(
        q, database, k, recall_target,
        block_m=block_m, max_block_n=max_block_n, row_bias=row_bias,
        reduction_input_size_override=reduction_input_size_override,
    )
    vals, idxs = partial_reduce_pallas(
        q, db, bias, bin_size=bin_size,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    # Masked winners (padded tail) pair -inf with the sentinel index -1:
    # clamping them into [0, n) would let them alias row n-1 and surface
    # as phantom duplicates after merge_topk ties at -inf.
    vals, idxs = vals[:m], sentinelize_masked(vals[:m], idxs[:m], n)
    if aggregate_to_topk:
        vals, idxs = merge_topk(vals, idxs, k, use_bitonic=use_bitonic)
    return finalize_values(vals, m_obj.negate_output), idxs


@functools.partial(
    jax.jit,
    static_argnames=(
        "metric", "k", "n", "bin_size", "block_m", "block_n", "interpret",
        "aggregate_to_topk", "use_bitonic", "fused_select",
    ),
)
def pallas_search_packed(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    row_bias: jnp.ndarray,
    *,
    metric: str,
    k: int,
    n: int,
    bin_size: int,
    block_m: int,
    block_n: int,
    interpret: bool,
    aggregate_to_topk: bool = True,
    use_bitonic: bool = False,
    fused_select: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused-kernel search over pre-packed operands (steady-state path).

    ``database`` (n_pad, d_pad) and ``row_bias`` (1, n_pad) must already
    satisfy the kernel tiling contract — ``repro.search.packed`` builds
    them once at index build/mutation time.  Only the (M, D) query block
    is prepared and padded here, so the per-dispatch memory traffic
    matches the paper's model (I_MEM ~ O(min(M, N)), Eq. 10).  ``n`` is
    the logical row space (packed padding excluded).

    ``fused_select=True`` runs the single-pass scan→select kernel (the
    top-k merge happens in VMEM during the scan; Eq. 20 traffic — only
    the (M, k) result touches HBM).  Requires ``aggregate_to_topk``;
    ``False`` keeps the two-pass bin-winner path, the parity oracle.
    Masked result entries pair -inf values with the sentinel index -1 on
    both paths.
    """
    m_obj = get_metric(metric)
    TRACE_COUNTS.inc("pallas")
    q = m_obj.prepare_queries(queries)
    if fused_select and aggregate_to_topk:
        vals, idxs = partial_reduce_fused(
            q, database, row_bias,
            k_scan=k, bin_size=bin_size, block_m=block_m, block_n=block_n,
            interpret=interpret,
        )
        return finalize_values(vals, m_obj.negate_output), idxs
    vals, idxs = partial_reduce_packed(
        q, database, row_bias,
        bin_size=bin_size, block_m=block_m, block_n=block_n,
        interpret=interpret,
    )
    # Masked winners keep -inf paired with sentinel index -1 through the
    # merge (clamping to n-1 here minted phantom duplicate neighbours).
    idxs = sentinelize_masked(vals, idxs, n)
    if aggregate_to_topk:
        vals, idxs = merge_topk(vals, idxs, k, use_bitonic=use_bitonic)
    return finalize_values(vals, m_obj.negate_output), idxs


@functools.partial(
    jax.jit,
    static_argnames=(
        "metric", "k", "k_scan", "n", "bin_size", "block_m", "block_n",
        "interpret", "aggregate_to_topk", "use_bitonic", "fused_select",
        "int4_packed",
    ),
)
def pallas_search_packed_quant(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    row_bias: jnp.ndarray,
    scale: Optional[jnp.ndarray],
    rescore_db: Optional[jnp.ndarray],
    rescore_bias: Optional[jnp.ndarray],
    *,
    metric: str,
    k: int,
    k_scan: int,
    n: int,
    bin_size: int,
    block_m: int,
    block_n: int,
    interpret: bool,
    aggregate_to_topk: bool = True,
    use_bitonic: bool = False,
    fused_select: bool = False,
    int4_packed: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused-kernel two-pass search over a quantized packed tier.

    Same packed-operand contract as ``pallas_search_packed`` — the kernel
    streams the (n_pad, d_pad) *stored* rows (bf16/int8/int4 HBM bytes,
    dequantized tile-locally in VMEM; ``scale`` is the per-row scale in
    the bias row's (1, n_pad) layout, and ``int4_packed`` marks a
    two-nibbles-per-byte database of stored width d_pad/2).  The
    over-fetched bin winners (the packed layout's bins are planned for
    ``quant.scan_k``) are then exactly re-scored against the
    full-precision gather tail ``rescore_db``/``rescore_bias`` — O(M·L·D)
    second-pass work, inside Eq. 10's O(min(M, N)) budget.

    ``fused_select=True`` replaces the dispatch-level scan→cut with the
    single-pass kernel: the top-``k_scan`` carry is selected in VMEM, so
    the rescore consumes the kernel output directly and the (M, L)
    bin-winner tile never exists in HBM.
    """
    m_obj = get_metric(metric)
    TRACE_COUNTS.inc("pallas")
    q = m_obj.prepare_queries(queries)
    if fused_select and (rescore_db is not None or aggregate_to_topk):
        vals, idxs = partial_reduce_fused(
            q, database, row_bias, scale,
            k_scan=k_scan if rescore_db is not None else k,
            bin_size=bin_size, block_m=block_m, block_n=block_n,
            interpret=interpret, int4_packed=int4_packed,
        )
        if rescore_db is not None:
            vals, idxs = rescore_candidates(
                q, vals, idxs, rescore_db, rescore_bias, k, k_scan,
                use_bitonic,
            )
        return finalize_values(vals, m_obj.negate_output), idxs
    vals, idxs = partial_reduce_packed(
        q, database, row_bias, scale,
        bin_size=bin_size, block_m=block_m, block_n=block_n,
        interpret=interpret, int4_packed=int4_packed,
    )
    # Masked winners keep -inf paired with sentinel index -1 through the
    # merge (clamping to n-1 here minted phantom duplicate neighbours).
    idxs = sentinelize_masked(vals, idxs, n)
    if rescore_db is not None:
        vals, idxs = rescore_candidates(
            q, vals, idxs, rescore_db, rescore_bias, k, k_scan, use_bitonic
        )
    elif aggregate_to_topk:
        vals, idxs = merge_topk(vals, idxs, k, use_bitonic=use_bitonic)
    return finalize_values(vals, m_obj.negate_output), idxs


def pallas_search(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    row_bias: Optional[jnp.ndarray] = None,
    *,
    metric: str = "mips",
    k: int = 10,
    recall_target: float = 0.95,
    block_m: Optional[int] = None,
    max_block_n: Optional[int] = None,
    interpret: Optional[bool] = None,
    aggregate_to_topk: bool = True,
    use_bitonic: bool = False,
    reduction_input_size_override: int = -1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-shot fused-kernel search (paper Alg. 2); packs inside jit.

    Same operand contract as ``dense_search`` (metric-prepared database,
    additive ``row_bias``); all three built-in metrics work here — cosine is
    plain MIPS after preparation, closing the old cosine-only-on-XLA gap.
    Tile sizes left ``None`` come from the kernel planner
    (``repro.search.plan``), sized for this workload and device.

    Every call re-pads the (N, D) database inside the jitted program —
    fine for one-shot functional use and the legacy ``kernels.ops`` shims,
    wrong for a steady-state serving loop.  ``Index`` uses
    ``pallas_search_packed`` over a ``repro.search.packed.PackedState``
    instead.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if block_m is None or max_block_n is None:
        from repro.search import plan as planlib

        p = planlib.plan_search(
            n=database.shape[0], d=queries.shape[1], k=k,
            m=queries.shape[0], metric=metric, recall_target=recall_target,
            # the operand dtype decides the sublane contract the tiles
            # must honour (e.g. bf16 needs block_m % 16 == 0)
            dtype=str(queries.dtype),
            backend="pallas",
            reduction_input_size_override=reduction_input_size_override,
        )
        block_m = block_m or p.block_m
        max_block_n = max_block_n or p.block_n
    return _pallas_search_jit(
        queries, database, row_bias,
        metric=metric, k=k, recall_target=recall_target,
        block_m=block_m, max_block_n=max_block_n, interpret=interpret,
        aggregate_to_topk=aggregate_to_topk, use_bitonic=use_bitonic,
        reduction_input_size_override=reduction_input_size_override,
    )


# --- Sharded backend (paper §7) ---------------------------------------------


def normalize_db_axes(db_axis) -> Tuple[str, ...]:
    """Canonicalize a database-axis spec (``"model"`` or a tuple of mesh
    axis names) into a tuple; the tuple form is a 2-D/N-D database split
    whose shards linearize row-major over the named axes."""
    return (db_axis,) if isinstance(db_axis, str) else tuple(db_axis)


def db_shard_count(mesh: Mesh, db_axis) -> int:
    """Number of database shards: the product of the mesh extents of every
    axis the database rows are split over."""
    count = 1
    for a in normalize_db_axes(db_axis):
        count *= mesh.shape[a]
    return count


def make_sharded_search_fn(
    mesh: Mesh,
    *,
    metric: str = "mips",
    k: int = 10,
    recall_target: float = 0.95,
    db_axis="model",
    batch_axis: Optional[str] = None,
    use_bitonic: bool = False,
    k_scan: Optional[int] = None,
    cluster_probes: Optional[int] = None,
    cluster_target_scan: Optional[float] = None,
):
    """Build (queries, database, row_bias) -> (values, indices) over a mesh.

    database sharded P(db_axis, None); queries replicated over db_axis and
    optionally sharded over ``batch_axis``; ``row_bias`` sharded P(db_axis).
    Each shard PartialReduces its rows with recall accounted against the
    *global* N (``reduction_input_size_override``), the L bin winners are
    all-gathered, and ExactRescoring runs replicated.

    ``db_axis`` may be a single mesh axis name or a *tuple* of names: the
    tuple form splits the database rows over the product of those axes
    (a pod-shaped 2-D mesh folds into one logical row partition), with
    shard ids — and hence the global-id offset arithmetic — linearized
    row-major over the named axes, matching both ``P((a, b), None)``
    placement and the tiled all-gather's concatenation order.  Combining
    a tuple ``db_axis`` with ``batch_axis`` gives full 2-D+ (query x
    database) sharding: per-device work is O(M/batch_shards x
    N/db_shards) and only the O(k_scan) per-shard winners cross the ICI
    (paper §7's traffic contract, priced by ``repro.search.plan`` as the
    ici term in ``Index.explain()``).

    Quantized storage tiers pass the extra sharded operands ``scale``
    (int8 per-row scale, P(db_axis)) and ``rescore_db``/``rescore_bias``
    (the full-precision rescore tail, P(db_axis, None)/P(db_axis)): each
    shard then re-scores its own over-fetched bin winners exactly —
    candidate indices are shard-local, so the gather never crosses shards
    — and the all-gather carries *exact* scores into the final rescoring.
    ``k_scan`` is the over-fetched scan k the bins are planned for
    (default: ``k``).

    Cluster pruning (``cluster_probes``/``cluster_target_scan`` set, plus
    the four side-table operands): the tables are *replicated* — every
    shard ranks the same centroids and derives the same global candidate
    ids — and each shard scores only the candidates its row range owns
    (out-of-range slots mask like empty ones), so the union of shard
    scans covers the candidate set exactly once.  Candidate ids are
    already global user ids, so the offset translation of the dense path
    is skipped; per-shard bins are laid over the S candidate slots at the
    cluster planner's ``target_scan``.
    """
    m_obj = get_metric(metric)
    scan_k = k if k_scan is None else k_scan
    db_axes = normalize_db_axes(db_axis)
    if batch_axis is not None and batch_axis in db_axes:
        raise ValueError(
            f"batch_axis {batch_axis!r} cannot also shard the database "
            f"(db_axis={db_axes!r})"
        )
    n_shards = db_shard_count(mesh, db_axes)

    def searcher(queries, database, row_bias=None, scale=None,
                 rescore_db=None, rescore_bias=None, centroids=None,
                 centroid_bias=None, cluster_rows=None, spill_rows=None):
        global_n = database.shape[0]
        if global_n % n_shards:
            raise ValueError(
                f"database rows {global_n} not divisible by {n_shards} shards"
            )
        TRACE_COUNTS.inc("sharded")
        q = m_obj.prepare_queries(queries)
        bias = (
            row_bias
            if row_bias is not None
            else jnp.zeros((global_n,), jnp.float32)
        )
        qspec = P(batch_axis, None) if batch_axis else P(None, None)

        args = [q, database, bias]
        in_specs = [qspec, P(db_axes, None), P(db_axes)]
        with_scale = scale is not None
        with_rescore = rescore_db is not None
        with_cluster = centroids is not None
        if with_cluster and (
            cluster_probes is None or cluster_target_scan is None
        ):
            raise ValueError(
                "cluster operands passed but make_sharded_search_fn was "
                "built without cluster_probes/cluster_target_scan"
            )
        if with_scale:
            args.append(scale)
            in_specs.append(P(db_axes))
        if with_rescore:
            args.extend([rescore_db, rescore_bias])
            in_specs.extend([P(db_axes, None), P(db_axes)])
        if with_cluster:
            # Side tables replicated: centroid ranking must be identical
            # on every shard for the ownership partition to cover the
            # candidate set exactly once.
            args.extend([centroids, centroid_bias, cluster_rows, spill_rows])
            in_specs.extend([P(None, None), P(None), P(None, None), P(None)])

        def local_fn(q, db, b, *rest):
            # Linearized shard id over the (possibly multi-axis) database
            # split — row-major over db_axes, matching tiled all-gather.
            axis_idx = jax.lax.axis_index(db_axes)
            n_local = db.shape[0]
            offset = axis_idx.astype(jnp.int32) * n_local
            rest = list(rest)
            sc = rest.pop(0) if with_scale else None
            rs_db, rs_bias = (
                (rest.pop(0), rest.pop(0)) if with_rescore else (None, None)
            )
            if with_cluster:
                cents, cbias, crows, srows = rest
                gidc, valid = prune_candidates(
                    q, cents, cbias, crows, srows, cluster_probes
                )
                # Global candidate ids -> this shard's row range; slots
                # another shard owns mask exactly like empty ones.
                local = gidc - offset
                owned = valid & (local >= 0) & (local < n_local)
                lidc = jnp.clip(local, 0, n_local - 1)
                scores = score_gathered(
                    q, db[lidc].astype(jnp.float32), b, lidc, owned, sc
                )
                s_slots = scores.shape[-1]
                plan = plan_bins(
                    s_slots, min(scan_k, s_slots), cluster_target_scan
                )
                vals, pos = partial_reduce_with_plan(scores, plan, mode="max")
                idxs = jnp.take_along_axis(gidc, pos, axis=-1)
                if with_rescore:
                    k_cut = min(scan_k, vals.shape[-1])
                    if k_cut < vals.shape[-1]:
                        vals, sel = jax.lax.top_k(vals, k_cut)
                        pos = jnp.take_along_axis(pos, sel, axis=-1)
                        idxs = jnp.take_along_axis(idxs, sel, axis=-1)
                    lsel = jnp.take_along_axis(lidc, pos, axis=-1)
                    exact = (
                        jnp.einsum("md,mld->ml", q, rs_db[lsel])
                        + rs_bias[lsel]
                    )
                    vals = jnp.where(
                        vals > MASK_VALUE * 0.5, exact, MASK_VALUE
                    )
                # idxs are global user ids already — no offset to add.
            else:
                scores = score_rows(q, db, b, sc)
                plan = plan_bins(
                    n_local, min(scan_k, n_local), recall_target,
                    reduction_input_size_override=global_n,
                )
                vals, idxs = partial_reduce_with_plan(
                    scores, plan, mode="max"
                )
                if with_rescore:
                    # Cut the shard's bin winners to its k_scan best by
                    # quantized score, then exact-rescore only those — the
                    # all-gather then carries exact scores (and ~k_scan
                    # rows per shard instead of L).
                    k_cut = min(scan_k, vals.shape[-1])
                    if k_cut < vals.shape[-1]:
                        vals, sel = jax.lax.top_k(vals, k_cut)
                        idxs = jnp.take_along_axis(idxs, sel, axis=-1)
                    rows = rs_db[idxs]
                    exact = (
                        jnp.einsum("md,mld->ml", q, rows) + rs_bias[idxs]
                    )
                    vals = jnp.where(
                        vals > MASK_VALUE * 0.5, exact, MASK_VALUE
                    )
                idxs = idxs + offset
            # The only cross-device traffic of the whole search: O(k_scan)
            # (value, global id) winners per shard, merged replicated.
            vals = jax.lax.all_gather(vals, db_axes, axis=-1, tiled=True)
            idxs = jax.lax.all_gather(idxs, db_axes, axis=-1, tiled=True)
            top_v, top_i = merge_topk(vals, idxs, k, use_bitonic=use_bitonic)
            return finalize_values(top_v, m_obj.negate_output), top_i

        fn = shard_map_compat(
            local_fn,
            mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=(P(batch_axis, None), P(batch_axis, None)),
        )
        return fn(*args)

    return searcher
