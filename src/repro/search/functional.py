"""Functional one-shot search API (and the engine behind the legacy shims).

Prefer ``repro.search.Index`` for anything called more than once — it
precomputes the metric preparation, owns the compile cache, and supports
in-place updates.  These functions cover the one-shot case and keep the old
``core.knn`` / ``kernels.ops`` signatures alive as thin forwarders.

Value conventions are owned by ``repro.search.metrics`` (module docstring).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
from jax.sharding import Mesh

from repro.search import backends
from repro.search.packed import fuse_bias
from repro.search.metrics import (
    exact_cosine_nns,
    exact_l2nns,
    exact_mips,
    exact_search,
    get_metric,
    half_norms,
)

__all__ = [
    "search",
    "mips",
    "l2nns",
    "cosine_nns",
    "half_norms",
    "exact_mips",
    "exact_l2nns",
    "exact_cosine_nns",
    "exact_search",
]


def search(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    *,
    metric: str = "mips",
    k: int = 10,
    recall_target: float = 0.95,
    backend: str = "auto",
    mesh: Optional[Mesh] = None,
    db_axis: str = "model",
    batch_axis: Optional[str] = None,
    row_bias: Optional[jnp.ndarray] = None,
    reduction_input_size_override: int = -1,
    aggregate_to_topk: bool = True,
    block_m: Optional[int] = None,
    max_block_n: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-shot search of ``queries`` against a raw ``database``.

    The database is metric-prepared (and, on the pallas backend, re-packed
    inside jit) on every call — use ``Index.build`` to amortize that into a
    device-resident ``PackedState`` and get single-dispatch batch streaming.
    Tile sizes left ``None`` are planner-derived (``repro.search.plan``).
    """
    m_obj = get_metric(metric)
    db, metric_bias = m_obj.prepare_database(database)
    if metric_bias is not None:
        # Same finite-mask clamp as the packed path (Appendix A.5 fusion).
        fused = fuse_bias(metric_bias, num_rows=db.shape[0])
        row_bias = fused if row_bias is None else row_bias + fused
    if backend == "auto":
        backend = backends.default_backend(mesh)
    if backend == "xla":
        return backends.dense_search(
            queries, db, row_bias,
            metric=metric, k=k, recall_target=recall_target,
            reduction_input_size_override=reduction_input_size_override,
            aggregate_to_topk=aggregate_to_topk,
        )
    if backend == "pallas":
        return backends.pallas_search(
            queries, db, row_bias,
            metric=metric, k=k, recall_target=recall_target,
            block_m=block_m, max_block_n=max_block_n, interpret=interpret,
            aggregate_to_topk=aggregate_to_topk,
            reduction_input_size_override=reduction_input_size_override,
        )
    if backend == "sharded":
        if mesh is None:
            raise ValueError("backend='sharded' requires a mesh")
        fn = backends.make_sharded_search_fn(
            mesh, metric=metric, k=k, recall_target=recall_target,
            db_axis=db_axis, batch_axis=batch_axis,
        )
        return fn(queries, db, row_bias)
    raise ValueError(f"unknown backend {backend!r}")


# --- Legacy-signature functional entry points -------------------------------


def mips(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    k: int = 10,
    *,
    recall_target: float = 0.95,
    reduction_input_size_override: int = -1,
    aggregate_to_topk: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Maximum inner product search (paper Listing 1)."""
    return backends.dense_search(
        queries, database, None,
        metric="mips", k=k, recall_target=recall_target,
        reduction_input_size_override=reduction_input_size_override,
        aggregate_to_topk=aggregate_to_topk,
    )


def l2nns(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    k: int = 10,
    *,
    db_half_norm: Optional[jnp.ndarray] = None,
    recall_target: float = 0.95,
    reduction_input_size_override: int = -1,
    aggregate_to_topk: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Euclidean NN search (paper Listing 2); values follow the L2 contract
    in ``repro.search.metrics`` (relaxed distances, ascending)."""
    if db_half_norm is None:
        db_half_norm = half_norms(database)
    return backends.dense_search(
        queries, database, -db_half_norm,
        metric="l2", k=k, recall_target=recall_target,
        reduction_input_size_override=reduction_input_size_override,
        aggregate_to_topk=aggregate_to_topk,
    )


def cosine_nns(
    queries: jnp.ndarray,
    database_normalized: jnp.ndarray,
    k: int = 10,
    **kwargs,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cosine search == MIPS on l2-normalized operands (paper §2).

    Legacy contract: ``database_normalized`` rows are already unit-norm;
    queries are normalized here.  ``Index`` with metric="cosine" handles
    raw databases instead.
    """
    q = get_metric("cosine").prepare_queries(queries)
    return mips(q, database_normalized, k, **kwargs)
