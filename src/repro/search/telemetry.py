"""Unified telemetry: metrics registry, request traces, roofline drift.

The paper's claim is an *analytical performance model* (Eq. 4-10, 20)
that configures peak-FLOP/s search — but before this module the model
was only checked at ``Index.explain()`` time, never continuously under
serving traffic.  This is the one observability layer everything reports
through:

  * **Metrics registry** (:class:`MetricsRegistry`, process-global via
    :func:`registry`): counters, gauges and windowed histograms
    (p50/p90/p99 over a bounded sample window), each optionally labeled
    (``backend=...``, ``storage=...``, ``cluster=...``, ``bucket=...``).
    The four legacy counter dicts (``DISPATCH_COUNTS``, ``TRACE_COUNTS``,
    ``PACK_EVENTS``, ``SERVE_EVENTS``) stay importable from their home
    modules — they are :class:`AtomicCounter` instances registered here
    (``register_counter_dict``), so one export carries them too, and one
    :func:`reset_all` replaces the four per-module reset helpers (which
    remain as thin deprecated aliases).  Export formats:
    :func:`export_prometheus` (text exposition format, histograms as
    summaries with ``quantile=`` series) and :func:`export_json`
    (one JSON-serializable snapshot dict); ``scripts/telemetry_dump.py``
    is the CLI.
  * **Request traces** (:class:`RequestTrace` / :class:`Span`): every
    ``SearchServer.submit`` gets a ticket-scoped trace of contiguous
    stage spans (``queue -> coalesce -> stage -> dispatch -> scatter``)
    on the *server's clock* — virtual-clock servers produce exactly
    reproducible span timings.  Completed traces land in a bounded ring
    buffer (``SearchServer.traces(n)``); :func:`chrome_trace` converts
    them to Chrome ``traceEvents`` JSON for flame-graph viewing, and
    :func:`trace_coverage` reports what fraction of measured request
    latency the spans account for (contiguous spans -> ~100% by
    construction; the serve bench asserts >= 95%).
  * **Roofline-drift monitor** (:class:`DriftMonitor`): per bucket, the
    EWMA of measured-dispatch-wall / plan-predicted Eq. 10/20 wall,
    normalized by a warmup-median baseline (absolute model error — e.g.
    running the TPU model on a CPU backend — calibrates out; *drift*
    from the calibrated steady state is what pages an operator).
    Surfaces as ``SearchServer.health()["drift"]``: ``degraded`` when
    the normalized ratio leaves the configured band — the live
    counterpart of ``plan="measure"``.

Thread-safety: serving increments counters from the worker thread while
operator threads read/export — a plain ``Counter[k] += 1`` is a
read-modify-write that loses increments under that interleaving.
:class:`AtomicCounter.inc` and every registry mutator take a lock, and
the hot paths use them (the regression test hammers submit+read
concurrently and asserts exact totals).

Like ``repro.search.faults`` this module is a leaf (stdlib + numpy
only): backends/packed/serve/index/hosttier/plan all import it without
cycles.

>>> reg = MetricsRegistry()
>>> reg.inc("requests_total", 2, backend="xla")
2
>>> reg.counter_value("requests_total", backend="xla")
2
>>> for v in [1.0, 2.0, 3.0, 4.0]:
...     reg.observe("latency_s", v)
>>> reg.histogram_snapshot("latency_s")["count"]
4
>>> 'requests_total{backend="xla"} 2' in reg.export_prometheus()
True
"""
from __future__ import annotations

import collections
import re
import threading
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "AtomicCounter",
    "DriftMonitor",
    "MetricsRegistry",
    "RequestTrace",
    "Span",
    "chrome_trace",
    "export_json",
    "export_prometheus",
    "registry",
    "reset_all",
    "trace_coverage",
]

# Histogram quantiles exported everywhere (the p50/p90/p99 the serve
# bench cross-checks against its own measured latencies).
QUANTILES: Tuple[float, ...] = (0.5, 0.9, 0.99)

_LabelKey = Tuple[Tuple[str, str], ...]


class AtomicCounter(collections.Counter):
    """A ``collections.Counter`` whose increments are atomic.

    ``counter[k] += 1`` is a read-modify-write: two threads interleaving
    it lose increments (the serve worker increments while operator
    threads export).  ``inc`` performs the same update under a lock; the
    class still *is* a Counter, so every existing read/iterate/``dict()``
    call site keeps working unchanged.

    >>> c = AtomicCounter()
    >>> c.inc("batches"), c.inc("batches", 2)
    (1, 3)
    >>> c["batches"]
    3
    """

    def __init__(self, *args, **kwargs):
        self._lock = threading.Lock()
        super().__init__(*args, **kwargs)

    def inc(self, key, n: int = 1) -> int:
        """Atomically add ``n`` to ``key``; returns the new value."""
        with self._lock:
            value = self[key] + n
            dict.__setitem__(self, key, value)
            return value

    def clear(self) -> None:
        with self._lock:
            super().clear()


def _label_key(labels: Mapping[str, object]) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _sanitize(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _prom_labels(key: _LabelKey, extra: Sequence[Tuple[str, str]] = ()) -> str:
    pairs = list(key) + list(extra)
    if not pairs:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in pairs) + "}"


class _Histogram:
    """Windowed histogram: bounded sample deque + lifetime count/sum."""

    __slots__ = ("window", "count", "sum")

    def __init__(self, maxlen: int):
        self.window: collections.deque = collections.deque(maxlen=maxlen)
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        self.window.append(float(value))
        self.count += 1
        self.sum += float(value)

    def snapshot(self) -> dict:
        arr = np.asarray(self.window, dtype=np.float64)
        out = {"count": self.count, "sum": self.sum, "window": int(arr.size)}
        if arr.size:
            out["mean"] = float(arr.mean())
            out["min"] = float(arr.min())
            out["max"] = float(arr.max())
            for q in QUANTILES:
                out[f"p{int(q * 100)}"] = float(np.percentile(arr, q * 100))
        return out


class MetricsRegistry:
    """Labeled counters, gauges and windowed histograms with export.

    One instance is process-global (:func:`registry`); serving, packing,
    the planner and the host tier all report into it.  Every mutator is
    lock-protected (see the module docstring on the ``+=`` race), and
    legacy module-global counter dicts are *adopted* — not copied — via
    :meth:`register_counter_dict`, so exports always read their live
    values and :meth:`reset` clears them too.
    """

    def __init__(self, histogram_window: int = 4096):
        if histogram_window <= 0:
            raise ValueError(
                f"histogram_window must be positive, got {histogram_window}"
            )
        self._lock = threading.RLock()
        self._window = int(histogram_window)
        self._counters: Dict[str, Dict[_LabelKey, float]] = {}
        self._gauges: Dict[str, Dict[_LabelKey, float]] = {}
        self._histograms: Dict[str, Dict[_LabelKey, _Histogram]] = {}
        self._help: Dict[str, str] = {}
        # name -> (mapping, label_name): adopted legacy counter dicts,
        # read live at export/snapshot time.
        self._adopted: Dict[str, Tuple[Mapping, str]] = {}

    # -- mutators ------------------------------------------------------------

    def inc(self, name: str, value: float = 1, **labels) -> float:
        """Atomically add ``value`` to counter ``name`` (labeled series)."""
        key = _label_key(labels)
        with self._lock:
            series = self._counters.setdefault(name, {})
            series[key] = new = series.get(key, 0) + value
            return new

    def set_gauge(self, name: str, value: float, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._gauges.setdefault(name, {})[key] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        """Record one histogram sample (windowed quantiles at snapshot)."""
        key = _label_key(labels)
        with self._lock:
            series = self._histograms.setdefault(name, {})
            hist = series.get(key)
            if hist is None:
                hist = series[key] = _Histogram(self._window)
            hist.observe(value)

    def describe(self, name: str, help_text: str) -> None:
        """Attach a ``# HELP`` line to ``name`` in the Prometheus export."""
        with self._lock:
            self._help[name] = str(help_text)

    def register_counter_dict(
        self, name: str, mapping: Mapping, label: str, help_text: str = ""
    ) -> None:
        """Adopt a legacy module-global counter dict as a labeled series.

        The mapping is read *live* at export time (no double
        bookkeeping) — ``{k: v}`` becomes ``name{label="k"} v`` — and
        :meth:`reset` clears it alongside the native metrics.
        Idempotent per ``name`` (re-registration replaces).
        """
        with self._lock:
            self._adopted[name] = (mapping, str(label))
            if help_text:
                self._help[name] = help_text

    def reset(self) -> None:
        """Zero every native metric AND every adopted counter dict
        (registrations and help text survive — only values clear)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()
            for mapping, _ in self._adopted.values():
                mapping.clear()

    # -- readers -------------------------------------------------------------

    def counter_value(self, name: str, **labels) -> float:
        key = _label_key(labels)
        with self._lock:
            if name in self._adopted:
                mapping, label = self._adopted[name]
                if len(key) == 1 and key[0][0] == label:
                    return mapping.get(key[0][1], 0)
                return 0
            return self._counters.get(name, {}).get(key, 0)

    def gauge_value(self, name: str, **labels) -> Optional[float]:
        with self._lock:
            return self._gauges.get(name, {}).get(_label_key(labels))

    def histogram_snapshot(self, name: str, **labels) -> Optional[dict]:
        with self._lock:
            hist = self._histograms.get(name, {}).get(_label_key(labels))
            return hist.snapshot() if hist is not None else None

    def _collect_locked(self) -> dict:
        counters: Dict[str, List[dict]] = {}
        for name, (mapping, label) in self._adopted.items():
            counters[name] = [
                {"labels": {label: str(k)}, "value": v}
                for k, v in sorted(mapping.items(), key=lambda kv: str(kv[0]))
            ]
        for name, series in self._counters.items():
            counters.setdefault(name, []).extend(
                {"labels": dict(key), "value": v}
                for key, v in sorted(series.items())
            )
        gauges = {
            name: [
                {"labels": dict(key), "value": v}
                for key, v in sorted(series.items())
            ]
            for name, series in self._gauges.items()
        }
        histograms = {
            name: [
                {"labels": dict(key), **hist.snapshot()}
                for key, hist in sorted(series.items())
            ]
            for name, series in self._histograms.items()
        }
        return {
            "counters": counters,
            "gauges": gauges,
            "histograms": histograms,
        }

    def export_json(self) -> dict:
        """One JSON-serializable snapshot of every series (adopted legacy
        dicts included, read live)."""
        with self._lock:
            return self._collect_locked()

    def export_prometheus(self) -> str:
        """Prometheus text exposition format.

        Counters/gauges render one line per labeled series; histograms
        render as summaries — ``name{quantile="0.5"}`` etc. plus
        ``name_count`` / ``name_sum`` — which is what a scrape config
        pointed at ``scripts/telemetry_dump.py`` (or any HTTP wrapper
        around this string) ingests directly.
        """
        with self._lock:
            snap = self._collect_locked()
            helps = dict(self._help)
        lines: List[str] = []

        def emit_header(name: str, mtype: str) -> None:
            if name in helps:
                lines.append(f"# HELP {name} {helps[name]}")
            lines.append(f"# TYPE {name} {mtype}")

        for name, entries in sorted(snap["counters"].items()):
            pname = _sanitize(name)
            emit_header(pname, "counter")
            for entry in entries:
                key = _label_key(entry["labels"])
                lines.append(
                    f"{pname}{_prom_labels(key)} {entry['value']:g}"
                )
        for name, entries in sorted(snap["gauges"].items()):
            pname = _sanitize(name)
            emit_header(pname, "gauge")
            for entry in entries:
                key = _label_key(entry["labels"])
                lines.append(
                    f"{pname}{_prom_labels(key)} {entry['value']:g}"
                )
        for name, entries in sorted(snap["histograms"].items()):
            pname = _sanitize(name)
            emit_header(pname, "summary")
            for entry in entries:
                key = _label_key(entry["labels"])
                for q in QUANTILES:
                    val = entry.get(f"p{int(q * 100)}")
                    if val is not None:
                        lines.append(
                            f"{pname}"
                            f"{_prom_labels(key, [('quantile', str(q))])}"
                            f" {val:g}"
                        )
                lines.append(
                    f"{pname}_count{_prom_labels(key)} {entry['count']:g}"
                )
                lines.append(
                    f"{pname}_sum{_prom_labels(key)} {entry['sum']:g}"
                )
        return "\n".join(lines) + "\n"


# -- per-request tracing ------------------------------------------------------


class Span:
    """One named, closed time interval on the owning server's clock."""

    __slots__ = ("name", "start", "end")

    def __init__(self, name: str, start: float, end: float):
        self.name = name
        self.start = float(start)
        self.end = max(float(end), float(start))

    @property
    def duration_s(self) -> float:
        return self.end - self.start

    def to_dict(self) -> dict:
        return {"name": self.name, "start": self.start, "end": self.end}

    def __repr__(self) -> str:
        return f"Span({self.name!r}, {self.start:.6f}->{self.end:.6f})"


class RequestTrace:
    """Ticket-scoped trace: the stage spans of one served request.

    Spans are appended by the server as the request moves through
    ``queue -> coalesce -> stage -> dispatch -> scatter``; they are
    contiguous on the server's clock (virtual-clock servers therefore
    produce *deterministic* span timings), so the union of spans covers
    the request's measured latency end to end — :func:`trace_coverage`
    over a healthy run reports ~1.0.
    """

    __slots__ = (
        "trace_id", "rows", "k", "bucket", "status", "submitted_at",
        "completed_at", "dispatched_at", "retries", "spans",
    )

    def __init__(self, trace_id: int, rows: int, k: int, submitted_at: float):
        self.trace_id = int(trace_id)
        self.rows = int(rows)
        self.k = int(k)
        self.bucket: Optional[int] = None
        self.status = "pending"
        self.submitted_at = float(submitted_at)
        self.completed_at: Optional[float] = None
        self.dispatched_at: Optional[float] = None
        self.retries = 0
        self.spans: List[Span] = []

    def span(self, name: str, start: float, end: float) -> Span:
        s = Span(name, start, end)
        self.spans.append(s)
        return s

    @property
    def duration_s(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at

    def covered_s(self) -> float:
        """Total span time, as a union of intervals clipped to the
        request's [submit, complete] window (overlaps never double
        count, so coverage is a true fraction)."""
        if self.completed_at is None or not self.spans:
            return 0.0
        lo, hi = self.submitted_at, self.completed_at
        ivals = sorted(
            (max(s.start, lo), min(s.end, hi))
            for s in self.spans
            if s.end > lo and s.start < hi
        )
        covered = 0.0
        cur_lo: Optional[float] = None
        cur_hi = 0.0
        for a, b in ivals:
            if cur_lo is None:
                cur_lo, cur_hi = a, b
            elif a <= cur_hi:
                cur_hi = max(cur_hi, b)
            else:
                covered += cur_hi - cur_lo
                cur_lo, cur_hi = a, b
        if cur_lo is not None:
            covered += cur_hi - cur_lo
        return covered

    def to_dict(self) -> dict:
        return {
            "trace_id": self.trace_id,
            "rows": self.rows,
            "k": self.k,
            "bucket": self.bucket,
            "status": self.status,
            "submitted_at": self.submitted_at,
            "completed_at": self.completed_at,
            "retries": self.retries,
            "spans": [s.to_dict() for s in self.spans],
        }


def trace_coverage(traces: Iterable[RequestTrace]) -> float:
    """Fraction of total measured request latency the spans account for
    (latency-weighted across traces; 1.0 when there is no latency)."""
    covered = 0.0
    total = 0.0
    for tr in traces:
        d = tr.duration_s
        if d is None or d <= 0:
            continue
        total += d
        covered += tr.covered_s()
    return covered / total if total > 0 else 1.0


def chrome_trace(traces: Iterable[RequestTrace]) -> dict:
    """Convert traces to Chrome ``traceEvents`` JSON (open in
    ``chrome://tracing`` / Perfetto; one row per request)."""
    events: List[dict] = []
    for tr in traces:
        for s in tr.spans:
            events.append({
                "name": s.name,
                "cat": "serve",
                "ph": "X",
                "ts": s.start * 1e6,          # microseconds
                "dur": s.duration_s * 1e6,
                "pid": 0,
                "tid": tr.trace_id,
                "args": {
                    "rows": tr.rows,
                    "k": tr.k,
                    "bucket": tr.bucket,
                    "status": tr.status,
                    "retries": tr.retries,
                },
            })
        events.append({
            "name": "thread_name",
            "ph": "M",
            "pid": 0,
            "tid": tr.trace_id,
            "args": {"name": f"request {tr.trace_id} ({tr.rows} rows)"},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


# -- roofline-drift monitor ---------------------------------------------------


class _BucketDrift:
    __slots__ = ("samples", "warmup_ratios", "baseline", "ewma")

    def __init__(self):
        self.samples = 0
        self.warmup_ratios: List[float] = []
        self.baseline: Optional[float] = None
        self.ewma: Optional[float] = None


class DriftMonitor:
    """Live roofline drift: measured dispatch wall vs Eq. 10/20 predicted.

    Per bucket, tracks the EWMA of ``measured_s / predicted_s`` and
    normalizes it by a baseline — the *median* of the first ``warmup``
    ratios.  The baseline calibrates out the constant model-vs-platform
    offset (the analytic prediction is for the planned device; CPU
    interpret runs are orders of magnitude off in absolute terms), so
    the reported ``drift`` is ~1.0 in steady state on any platform and
    moves only when the measured cost *changes relative to the model* —
    exactly the ``plan="measure"`` signal, continuously.  ``degraded``
    when any calibrated bucket's drift leaves ``band``.

    >>> mon = DriftMonitor(band=(0.5, 2.0), warmup=2, alpha=1.0)
    >>> for _ in range(2):
    ...     mon.record("64", measured_s=1e-3, predicted_s=1e-5)
    >>> mon.report()["in_band"]
    True
    >>> mon.record("64", measured_s=10e-3, predicted_s=1e-5)  # 10x slower
    >>> mon.report()["in_band"]
    False
    """

    def __init__(
        self,
        band: Tuple[float, float] = (0.25, 4.0),
        warmup: int = 3,
        alpha: float = 0.25,
    ):
        lo, hi = float(band[0]), float(band[1])
        if not 0.0 < lo < hi:
            raise ValueError(f"band must be 0 < lo < hi, got {band}")
        if warmup < 1:
            raise ValueError(f"warmup must be >= 1, got {warmup}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.band = (lo, hi)
        self.warmup = int(warmup)
        self.alpha = float(alpha)
        self._lock = threading.Lock()
        self._buckets: Dict[str, _BucketDrift] = {}

    def record(
        self, bucket, measured_s: float, predicted_s: float
    ) -> None:
        """Fold one dispatch's (measured, predicted) pair into the EWMA."""
        if measured_s <= 0 or predicted_s <= 0:
            return
        ratio = float(measured_s) / float(predicted_s)
        key = str(bucket)
        with self._lock:
            st = self._buckets.get(key)
            if st is None:
                st = self._buckets[key] = _BucketDrift()
            st.samples += 1
            st.ewma = (
                ratio if st.ewma is None
                else self.alpha * ratio + (1 - self.alpha) * st.ewma
            )
            if st.baseline is None:
                st.warmup_ratios.append(ratio)
                if len(st.warmup_ratios) >= self.warmup:
                    st.baseline = float(np.median(st.warmup_ratios))
                    st.warmup_ratios = []

    def report(self) -> dict:
        """Drift report: headline ``value`` (worst calibrated bucket's
        normalized ratio; 1.0 while still warming up), the ``band``,
        ``in_band``, and the per-bucket evidence."""
        lo, hi = self.band
        with self._lock:
            per_bucket = {}
            worst: Optional[float] = None
            for key, st in sorted(self._buckets.items()):
                drift = (
                    st.ewma / st.baseline
                    if st.baseline not in (None, 0.0) and st.ewma is not None
                    else None
                )
                per_bucket[key] = {
                    "samples": st.samples,
                    "ratio_ewma": st.ewma,
                    "baseline": st.baseline,
                    "drift": drift,
                    "in_band": drift is None or lo <= drift <= hi,
                }
                if drift is not None and (
                    worst is None
                    or abs(np.log(drift)) > abs(np.log(worst))
                ):
                    worst = drift
        value = 1.0 if worst is None else float(worst)
        return {
            "value": value,
            "band": [lo, hi],
            "in_band": lo <= value <= hi,
            "calibrated": worst is not None,
            "per_bucket": per_bucket,
        }

    def reset(self) -> None:
        with self._lock:
            self._buckets.clear()


# -- process-global registry --------------------------------------------------

_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global :class:`MetricsRegistry` every layer reports to."""
    return _REGISTRY


def export_prometheus() -> str:
    """Prometheus text export of the global registry (all series, the
    adopted legacy counter dicts included)."""
    return _REGISTRY.export_prometheus()


def export_json() -> dict:
    """JSON-serializable snapshot of the global registry."""
    return _REGISTRY.export_json()


def reset_all() -> None:
    """Zero every global series AND the four legacy counter dicts
    (``DISPATCH_COUNTS`` / ``TRACE_COUNTS`` / ``PACK_EVENTS`` /
    ``SERVE_EVENTS`` register themselves at import) — the one reset
    tests and benches call instead of four per-module helpers."""
    _REGISTRY.reset()
