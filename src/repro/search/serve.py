"""Concurrent query serving: async micro-batching over one shared ``Index``.

The paper's peak-FLOP/s claim (Eq. 10/20) only materializes when queries
reach the device as large single-dispatch batches — but serving traffic
arrives as many small concurrent requests.  ``SearchServer`` closes that
gap: it accepts per-request queries (each with its own ``k`` sub-budget
against the shared index), coalesces them into planner-sized micro-batches,
executes each coalesced batch as ONE device dispatch over the packed /
streamed steady-state path, and scatters per-request slices back.  Results
are bit-identical to a direct ``Index.search`` of the same rows — padding
to a bucket shape only adds dead rows, it never reorders reductions.

The moving parts, and the contracts tests pin down:

  * **Bucketed batch shapes.**  A coalesced batch is padded up to the
    smallest *bucket* (``SearchSpec.serve_buckets``, planner-derived via
    ``repro.search.plan.plan_buckets``), so the server only ever dispatches
    a small fixed set of pre-compilable shapes — serving traffic never
    retraces.  A request larger than the largest bucket is dispatched solo
    through the streaming executor (still one dispatch), padded to a
    power-of-two multiple of the largest bucket so oversize shapes stay
    bounded too.
  * **Admission / backpressure.**  The queue holds at most
    ``ServeConfig.max_pending_rows`` query rows.  Wall-clock servers block
    ``submit`` (up to ``admission_timeout_s``) until the worker frees
    space; virtual-clock servers raise :class:`QueueFull` immediately
    (there is no concurrent worker to wait for).
  * **Deterministic scheduling mode.**  Pass ``clock=VirtualClock()`` and
    the server runs no threads and never sleeps: the test (or simulator)
    drives it with ``step()`` / ``run_until_idle()``, one micro-batch per
    ``step``, FIFO whole-request coalescing — fully reproducible, and
    latency accounting follows the virtual clock.
  * **Double-buffered staging.**  Each bucket owns two reusable host
    buffers; the next micro-batch is gathered into one while the previous
    dispatch is still in flight on the device, and the previous batch's
    scatter happens after the next dispatch is enqueued.  Host-side
    gather/scatter work therefore overlaps device compute instead of
    serializing with it.
  * **Fault tolerance.**  Every request can carry a deadline
    (``submit(..., deadline_s=...)``): expired tickets fail fast with
    :class:`DeadlineExceeded` and are NEVER dispatched (no dead work on
    the device).  Transient dispatch faults (``repro.search.faults``
    taxonomy, or anything in ``ServeConfig.retryable``) are retried with
    exponential backoff up to ``max_dispatch_retries``; exhausted retries
    fail the batch's tickets with the typed error.  A dead worker (thread
    exception / injected :class:`~repro.search.faults.WorkerDeath`) is
    restarted by a watchdog without dropping queued tickets — the popped
    batch is requeued at the front.  Sustained overload (admission queue
    full past ``overload_grace_s``) sheds load with a structured
    :class:`Overloaded` error carrying a ``retry_after_s`` estimate —
    callers get an explicit backpressure signal, never silent recall
    loss.  ``SearchServer.health()`` reports status
    ("ok" / "degraded" / "overloaded"), worker liveness, the failure
    counters, and the served-query cluster-miss estimate.
  * **Served-query cluster-miss monitor.**  On clustered indexes, every
    ``miss_sample_every``-th batch samples ``miss_sample_rows`` real
    query rows through ``repro.search.cluster.query_miss_rate``; the
    running estimate surfaces in ``health()["cluster_miss"]`` and
    ``Index.explain()["cluster"]["served_miss"]``.  A rate above the
    ``miss_check_threshold`` warn level flags an out-of-distribution
    query stream (the documented ``cluster="off"`` case).

Typical use::

    from repro.search import Index
    from repro.search.serve import SearchServer

    server = SearchServer(Index.build(db, k=10), warmup=True)
    ticket = server.submit(q, deadline_s=0.1)   # from any thread
    values, indices = ticket.result()  # (m_i, k) slices of one big dispatch
    server.close()

``SERVE_EVENTS`` counts batches / coalesced requests / padded rows /
oversize batches — plus the failure taxonomy: "deadline_expired",
"transient_faults", "dispatch_retries", "failed_batches",
"worker_deaths", "worker_restarts", "requeued_tickets", "load_shed",
"miss_sampled_rows" — globally (same taxonomy style as
``DISPATCH_COUNTS`` / ``PACK_EVENTS``); ``SearchServer.stats()`` reports
the per-server view.  ``docs/operations.md`` is the runbook mapping each
counter to its failure mode and operator action.

Telemetry (``repro.search.telemetry``): the global metrics registry
carries these counters plus queue-depth / occupancy gauges and latency
histograms; every submitted request gets a ticket-scoped trace of stage
spans (``queue -> coalesce -> stage -> dispatch -> scatter``) retained
in a bounded ring buffer (``SearchServer.traces(n)``, Chrome-trace
export via ``telemetry.chrome_trace``); and a roofline-drift monitor
compares each dispatch's measured wall against the plan's Eq. 10/20
prediction, degrading ``health()`` when the calibrated ratio leaves
``ServeConfig.drift_band``.  Span timings follow the server's clock, so
virtual-clock servers produce deterministic traces.
"""
from __future__ import annotations

import bisect
import collections
import contextlib
import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.search import cluster as clusterlib
from repro.search import faults as faultslib
from repro.search import telemetry as telemetrylib
from repro.search.index import Index, SearchResult
from repro.search.plan import plan_buckets

try:  # dispatch-path profiler hook; absent on stripped-down jax builds
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - depends on the jax build
    _TraceAnnotation = None

__all__ = [
    "DeadlineExceeded",
    "Overloaded",
    "QueueFull",
    "SERVE_EVENTS",
    "SearchServer",
    "SearchTicket",
    "ServeConfig",
    "VirtualClock",
    "reset_serve_events",
]

# event name -> count across every server (test observability hook, same
# reset-act-assert style as backends.DISPATCH_COUNTS / packed.PACK_EVENTS):
# "batches", "coalesced_requests", "padded_rows", "oversize_batches", plus
# the failure taxonomy listed in the module docstring.
SERVE_EVENTS = telemetrylib.AtomicCounter()
telemetrylib.registry().register_counter_dict(
    "repro_serve_events_total", SERVE_EVENTS, "event",
    "SearchServer lifecycle and failure events (docs/operations.md)",
)


def reset_serve_events() -> None:
    """Zero ``SERVE_EVENTS`` (tests: reset, act, assert — no arithmetic).

    Deprecated thin alias: ``repro.search.telemetry.reset_all()`` zeroes
    this and every other global series in one call."""
    SERVE_EVENTS.clear()


class QueueFull(RuntimeError):
    """Admission control rejected a request: the pending-row queue is full."""


class Overloaded(QueueFull):
    """Sustained-overload load shed: the queue has been full past
    ``ServeConfig.overload_grace_s``.  Subclasses :class:`QueueFull` (old
    handlers keep working) and adds ``retry_after_s`` — the server's
    estimate of when queued work will have drained — so callers can back
    off intelligently instead of hammering a saturated server."""

    def __init__(self, rows_pending: int, retry_after_s: float):
        self.rows_pending = rows_pending
        self.retry_after_s = retry_after_s
        super().__init__(
            f"server overloaded: {rows_pending} rows pending past the "
            f"overload grace window; retry in ~{retry_after_s:.3f}s"
        )


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before its batch was dispatched.

    Raised through ``SearchTicket.result()``.  The contract is strict:
    an expired ticket is failed at batch-formation (or retry) time and
    its rows are NEVER dispatched — deadlines exist to stop dead work
    from reaching the device, not just to time out the caller."""

    def __init__(self, rows: int, deadline: float, now: float):
        self.deadline = deadline
        super().__init__(
            f"deadline {deadline:.6f} passed (now {now:.6f}) before "
            f"dispatch; request of {rows} rows was never dispatched"
        )


class VirtualClock:
    """Deterministic, manually-advanced clock for tests and simulation.

    A server built with ``clock=VirtualClock()`` runs no threads and never
    sleeps; latency accounting (``SearchTicket.latency_s``) reads this
    clock, so a test or a load simulator controls time exactly.

    >>> clock = VirtualClock()
    >>> clock.advance(0.5)
    0.5
    >>> clock.now()
    0.5
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance time backwards (dt={dt})")
        self._now += dt
        return self._now


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Frozen serving policy for one :class:`SearchServer`.

    Attributes:
      max_batch: most query rows one micro-batch holds.  ``None`` defers to
        the planner-resolved ``SearchSpec.query_block`` — the batch size the
        kernel plan was sized for.
      buckets: ascending pre-compiled batch shapes; a coalesced batch pads
        up to the smallest bucket holding it.  ``None`` defers to
        ``SearchSpec.serve_buckets`` (planner-derived ladder), clipped to
        ``max_batch``.
      max_pending_rows: admission bound — most query rows queued (not yet
        dispatched) at once; ``submit`` beyond it blocks (wall clock) or
        raises :class:`QueueFull` (virtual clock).
      max_delay_s: wall-clock coalescing window — how long the worker holds
        an under-full batch open for more arrivals.  Irrelevant under a
        virtual clock (the driver decides when to ``step``).
      admission_timeout_s: longest a wall-clock ``submit`` blocks for queue
        space before raising :class:`QueueFull`.
      max_dispatch_retries: redispatch attempts after a retryable fault
        (0 disables retries); exhausted retries fail the batch's tickets
        with the typed error.
      retry_backoff_s: base backoff before the first retry, doubled per
        attempt.  Wall-clock servers sleep; virtual-clock servers advance
        the clock (so backoff interacts with deadlines deterministically).
      retryable: exception types the retry loop redispatches on.  Default
        :class:`repro.search.faults.TransientFault` — extend with runtime
        exception types known to be transient on your platform.
      overload_grace_s: how long the admission queue must stay full before
        ``submit`` sheds load with :class:`Overloaded` instead of
        blocking/raising :class:`QueueFull`.  0 sheds immediately on a
        full queue.
      miss_sample_every: on clustered indexes, sample the served-query
        cluster-miss rate every Nth dispatched batch (0 disables the
        monitor).
      miss_sample_rows: query rows scored per sample (clipped to the
        batch's live rows).
      trace_buffer: how many completed request traces the ring buffer
        keeps (``SearchServer.traces(n)``); 0 disables per-request
        tracing entirely (no trace objects are allocated).
      drift_band: (lo, hi) band for the roofline-drift monitor's
        normalized measured/predicted ratio; outside it ``health()``
        degrades.  The ratio is baseline-calibrated, so ~1.0 is "on
        model" on any platform.
      drift_warmup: dispatches per bucket used to fix the drift
        baseline (the median of their measured/predicted ratios).
      drift_alpha: EWMA weight of the newest dispatch's ratio.
    """

    max_batch: Optional[int] = None
    buckets: Optional[Tuple[int, ...]] = None
    max_pending_rows: int = 4096
    max_delay_s: float = 0.002
    admission_timeout_s: float = 5.0
    max_dispatch_retries: int = 2
    retry_backoff_s: float = 0.001
    retryable: Tuple[type, ...] = (faultslib.TransientFault,)
    overload_grace_s: float = 0.25
    miss_sample_every: int = 32
    miss_sample_rows: int = 8
    trace_buffer: int = 256
    drift_band: Tuple[float, float] = (0.25, 4.0)
    drift_warmup: int = 3
    drift_alpha: float = 0.25

    def __post_init__(self):
        if self.max_batch is not None and self.max_batch <= 0:
            raise ValueError(f"max_batch must be positive, got {self.max_batch}")
        if self.max_pending_rows <= 0:
            raise ValueError(
                f"max_pending_rows must be positive, got {self.max_pending_rows}"
            )
        if self.max_delay_s < 0 or self.admission_timeout_s < 0:
            raise ValueError("delays/timeouts must be non-negative")
        if self.max_dispatch_retries < 0:
            raise ValueError(
                f"max_dispatch_retries must be >= 0, got "
                f"{self.max_dispatch_retries}"
            )
        if self.retry_backoff_s < 0 or self.overload_grace_s < 0:
            raise ValueError("backoff/grace must be non-negative")
        if self.miss_sample_every < 0 or self.miss_sample_rows <= 0:
            raise ValueError(
                "miss_sample_every must be >= 0 and miss_sample_rows > 0"
            )
        if self.trace_buffer < 0:
            raise ValueError(
                f"trace_buffer must be >= 0, got {self.trace_buffer}"
            )
        lo, hi = self.drift_band
        if not 0.0 < lo < hi:
            raise ValueError(f"drift_band must be 0 < lo < hi, got "
                             f"{self.drift_band}")
        if self.drift_warmup < 1:
            raise ValueError(
                f"drift_warmup must be >= 1, got {self.drift_warmup}"
            )
        if not 0.0 < self.drift_alpha <= 1.0:
            raise ValueError(
                f"drift_alpha must be in (0, 1], got {self.drift_alpha}"
            )
        if self.buckets is not None:
            object.__setattr__(
                self, "buckets", tuple(int(b) for b in self.buckets)
            )


class SearchTicket:
    """Handle for one submitted request; resolves to a ``SearchResult``.

    ``result()`` returns ``(values, indices)`` of shape ``(rows, k)`` — the
    request's slice of its coalesced micro-batch, bit-identical to a direct
    ``Index.search`` of the same query rows.  The arrays are host-side
    numpy views (results cross the device boundary once per micro-batch,
    not once per request).
    """

    __slots__ = (
        "rows", "k", "deadline", "submitted_at", "completed_at", "trace",
        "_queries", "_offset", "_server", "_done", "_event", "_result",
        "_error",
    )

    def __init__(self, server: "SearchServer", queries: np.ndarray, k: int,
                 deadline: Optional[float] = None):
        self._server = server
        self._queries = queries
        self.rows = queries.shape[0]
        self.k = k
        # Absolute deadline on the server's clock; None = no deadline.
        self.deadline = deadline
        self.submitted_at = server._now()
        self.completed_at: Optional[float] = None
        # Ticket-scoped stage trace (None when ServeConfig.trace_buffer=0).
        self.trace: Optional[telemetrylib.RequestTrace] = None
        self._offset = 0
        self._done = False
        # Allocated lazily (under the server lock) only when a thread
        # actually blocks in ``result()``: at thousands of requests per
        # second, per-ticket Event construction is measurable overhead and
        # the virtual-clock mode never waits at all.
        self._event: Optional[threading.Event] = None
        self._result: Optional[SearchResult] = None
        self._error: Optional[BaseException] = None

    @property
    def done(self) -> bool:
        return self._done

    @property
    def latency_s(self) -> Optional[float]:
        """Submit-to-completion latency on the server's clock (None while
        pending)."""
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at

    def result(self, timeout: Optional[float] = None) -> SearchResult:
        """The request's ``(values (rows, k), indices (rows, k))``.

        Wall-clock servers block until the worker completes the request;
        virtual-clock servers drive their own queue to idle (equivalent to
        ``server.run_until_idle()``), so a plain submit-then-result flow
        works in both modes.
        """
        if not self._done and self._server._manual:
            self._server.run_until_idle()
        if not self._done:
            with self._server._lock:  # completion holds the same lock
                event = self._event
                if event is None and not self._done:
                    event = self._event = threading.Event()
            if event is not None and not event.wait(timeout):
                raise TimeoutError(
                    f"request ({self.rows} rows) still pending after "
                    f"{timeout}s"
                )
        if self._error is not None:
            raise self._error
        return self._result

    def _complete(self, result: SearchResult, now: float) -> None:
        """Caller must hold the server lock (see ``result``)."""
        self._result = result
        self.completed_at = now
        self._queries = None  # staging copy done; free the host rows
        self._done = True
        if self.trace is not None:
            self.trace.status = "done"
            self.trace.completed_at = now
            self._server._store_trace(self.trace)
        if self._event is not None:
            self._event.set()

    def _fail(self, error: BaseException, now: float) -> None:
        """Caller must hold the server lock."""
        self._error = error
        self.completed_at = now
        self._queries = None
        self._done = True
        tr = self.trace
        if tr is not None:
            tr.status = "failed"
            tr.completed_at = now
            last = max((sp.end for sp in tr.spans), default=self.submitted_at)
            tr.span("failed", last, now)
            self._server._store_trace(tr)
        if self._event is not None:
            self._event.set()


class SearchServer:
    """Async micro-batching front end over one shared :class:`Index`.

    ``clock=None`` (default) starts a background worker thread that
    coalesces on the wall clock (``ServeConfig.max_delay_s`` window);
    passing a :class:`VirtualClock` selects the deterministic single-
    threaded mode where the caller drives ``step()`` /
    ``run_until_idle()``.  ``warmup=True`` pre-compiles every bucket shape
    before the first request (otherwise each bucket compiles on first use).
    """

    def __init__(
        self,
        index: Index,
        config: Optional[ServeConfig] = None,
        *,
        clock: Optional[VirtualClock] = None,
        warmup: bool = False,
        faults: Optional[faultslib.FaultInjector] = None,
    ):
        self.index = index
        # Per-server injector for the serve.* points; None falls through
        # to the process-global ``faults.active()`` registry.
        self._faults = faults
        self.config = config or ServeConfig()
        spec = index.spec
        if not spec.aggregate_to_topk:
            raise ValueError(
                "SearchServer requires aggregate_to_topk=True: per-request "
                "k budgets are column slices of the coalesced dispatch, "
                "which is only correct over sorted top-k rows — not the "
                "raw unsorted bin winners"
            )
        qb = spec.query_block or 4096
        self.max_batch = self.config.max_batch or qb
        buckets = (
            self.config.buckets
            or spec.serve_buckets
            or plan_buckets(self.max_batch)
        )
        buckets = sorted({int(b) for b in buckets if b <= self.max_batch})
        if not buckets or buckets[-1] != self.max_batch:
            buckets.append(self.max_batch)
        self.buckets: Tuple[int, ...] = tuple(buckets)
        self._qdtype = np.dtype(spec.dtype or index._db.dtype)

        self._clock = clock
        self._manual = clock is not None
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._queue: collections.deque = collections.deque()
        self._pending_rows = 0
        self._closed = False
        # (result, batch, bucket, t_disp0): dispatched, not yet scattered
        # (t_disp0 = perf_counter at dispatch; closes the drift window).
        self._inflight: Optional[tuple] = None
        # Serializes index.search dispatches against out-of-band Index
        # mutations (see ``mutation()``) — Index is not thread-safe.
        self._dispatch_gate = threading.Lock()
        self._staging: Dict[int, list] = {}
        # AtomicCounter: the worker thread increments while operator
        # threads read stats()/health()/exports — see repro.search.telemetry.
        self._stats = telemetrylib.AtomicCounter()
        self._latency_sum = 0.0
        self._worker: Optional[threading.Thread] = None
        # Overload tracking: when the admission queue first went (and
        # stayed) full; None while there is space.
        self._full_since: Optional[float] = None
        # EWMA of wall seconds per service cycle — the Overloaded
        # retry-after estimate's drain rate.
        self._service_ema = 0.0
        self._miss_sample_countdown = self.config.miss_sample_every
        self._started_at = self._now()
        # Completed-trace ring buffer (bounded; None = tracing disabled).
        self._traces: Optional[collections.deque] = (
            collections.deque(maxlen=self.config.trace_buffer)
            if self.config.trace_buffer > 0 else None
        )
        self._trace_seq = 0
        # Roofline-drift monitor: measured dispatch wall vs the plan's
        # Eq. 10/20 prediction per bucket (health()["drift"]).
        self._drift = telemetrylib.DriftMonitor(
            band=self.config.drift_band,
            warmup=self.config.drift_warmup,
            alpha=self.config.drift_alpha,
        )
        self._predicted_cache: Dict[int, Optional[float]] = {}
        self._last_fault: Optional[dict] = None

        if warmup:
            self.precompile()
        if not self._manual:
            self._worker = threading.Thread(
                target=self._worker_main, name="SearchServer", daemon=True
            )
            self._worker.start()

    # -- time / fault plumbing -----------------------------------------------

    def _now(self) -> float:
        return self._clock.now() if self._manual else time.monotonic()

    def _fire(self, point: str) -> None:
        """Hit a serve.* injection point (per-server injector first, then
        the process-global registry; no-op when neither is installed)."""
        inj = self._faults if self._faults is not None else faultslib.active()
        if inj is not None:
            inj.fire(point)

    def _backoff(self, delay: float) -> None:
        """Retry backoff: sleep on the wall clock, advance a virtual one
        (so backoff-vs-deadline interactions stay deterministic in tests)."""
        if delay <= 0:
            return
        if self._manual:
            self._clock.advance(delay)
        else:
            time.sleep(delay)

    # -- admission -----------------------------------------------------------

    @property
    def pending_rows(self) -> int:
        """Query rows admitted but not yet dispatched (the queue depth the
        backpressure bound applies to)."""
        return self._pending_rows

    def submit(self, queries, k: Optional[int] = None,
               deadline_s: Optional[float] = None) -> SearchTicket:
        """Enqueue one request: ``(rows, D)`` (or a single ``(D,)`` row).

        ``k`` is the request's own neighbour budget — it must not exceed
        the index's ``spec.k`` (the coalesced dispatch computes ``spec.k``
        winners once; per-request budgets are slices of that, which is what
        lets requests with different ``k`` share a batch).  ``deadline_s``
        is a relative deadline on the server's clock: if it passes before
        the request's batch dispatches, the ticket fails with
        :class:`DeadlineExceeded` and its rows are never dispatched.
        Returns a :class:`SearchTicket`; raises :class:`QueueFull` when
        admission control rejects the request, or its subclass
        :class:`Overloaded` (with a ``retry_after_s`` estimate) under
        sustained overload.
        """
        q = np.asarray(queries, self._qdtype)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2 or q.shape[0] == 0:
            raise ValueError(f"queries must be (rows>0, D), got {q.shape}")
        if q.shape[1] != self.index.dim:
            raise ValueError(
                f"query dim {q.shape[1]} != index dim {self.index.dim}"
            )
        k = self.index.spec.k if k is None else int(k)
        if not 0 < k <= self.index.spec.k:
            raise ValueError(
                f"per-request k={k} must be in [1, spec.k={self.index.spec.k}]"
                " — build the index with the largest k any request needs"
            )
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be positive, got {deadline_s}")
        rows = q.shape[0]
        with self._lock:
            if self._closed:
                raise RuntimeError("server is closed")
            if rows > self.config.max_pending_rows:
                raise QueueFull(
                    f"request of {rows} rows exceeds the admission capacity "
                    f"({self.config.max_pending_rows} rows)"
                )
            if self._pending_rows + rows > self.config.max_pending_rows:
                now = self._now()
                if self._full_since is None:
                    self._full_since = now
                if now - self._full_since >= self.config.overload_grace_s:
                    self._shed_locked()  # raises Overloaded
                if self._manual:
                    raise QueueFull(
                        f"{self._pending_rows} rows pending; admitting {rows} "
                        f"more exceeds max_pending_rows="
                        f"{self.config.max_pending_rows}"
                    )
                timeout = time.monotonic() + self.config.admission_timeout_s
                while self._pending_rows + rows > self.config.max_pending_rows:
                    remaining = timeout - time.monotonic()
                    if remaining <= 0 or self._closed:
                        raise QueueFull(
                            f"no queue space for {rows} rows within "
                            f"{self.config.admission_timeout_s}s"
                        )
                    self._not_full.wait(remaining)
                    if (
                        self._pending_rows + rows
                        > self.config.max_pending_rows
                        and self._full_since is not None
                        and self._now() - self._full_since
                        >= self.config.overload_grace_s
                    ):
                        # The queue stayed full past the grace window while
                        # this thread waited: fail fast with the structured
                        # signal instead of stacking blocked submitters.
                        self._shed_locked()
                if self._closed:
                    # close() may have drained the queue and retired the
                    # worker while this thread waited for space; enqueueing
                    # now would strand the ticket forever.
                    raise RuntimeError("server is closed")
            deadline = (
                None if deadline_s is None else self._now() + deadline_s
            )
            ticket = SearchTicket(self, q, k, deadline)
            if self._traces is not None:
                self._trace_seq += 1
                tr = telemetrylib.RequestTrace(
                    self._trace_seq, rows, k, ticket.submitted_at
                )
                tr.span("submit", ticket.submitted_at, ticket.submitted_at)
                ticket.trace = tr
            self._queue.append(ticket)
            self._pending_rows += rows
            self._stats["peak_pending_rows"] = max(
                self._stats["peak_pending_rows"], self._pending_rows
            )
            telemetrylib.registry().set_gauge(
                "repro_serve_pending_rows", self._pending_rows
            )
            self._work.notify()
        return ticket

    def search(self, queries, k: Optional[int] = None,
               timeout: Optional[float] = None) -> SearchResult:
        """Synchronous convenience: ``submit`` + ``result`` in one call."""
        return self.submit(queries, k=k).result(timeout=timeout)

    def resolve(self, tickets: Sequence[SearchTicket],
                timeout: Optional[float] = None) -> List[SearchResult]:
        """Resolve many tickets (driving the queue first in virtual mode)."""
        if self._manual:
            self.run_until_idle()
        return [t.result(timeout=timeout) for t in tickets]

    # -- micro-batch formation and dispatch ----------------------------------

    def _shed_locked(self) -> None:
        """Raise :class:`Overloaded` with a drain-time estimate (caller
        must hold the lock)."""
        batches = max(1, -(-self._pending_rows // self.max_batch))
        per_batch = max(
            self._service_ema, self.config.max_delay_s, 1e-3
        )
        self._stats.inc("load_shed")
        SERVE_EVENTS.inc("load_shed")
        raise Overloaded(self._pending_rows, batches * per_batch)

    def _fail_expired_locked(self, t: SearchTicket, now: float) -> None:
        """Fail one deadline-expired ticket (caller must hold the lock)."""
        t._fail(DeadlineExceeded(t.rows, t.deadline, now), now)
        self._stats.inc("deadline_expired")
        SERVE_EVENTS.inc("deadline_expired")

    def _take_batch_locked(self, now: float) -> Optional[List[SearchTicket]]:
        """Pop the next FIFO micro-batch: whole requests only, up to
        ``max_batch`` rows (a request bigger than ``max_batch`` ships solo
        through the streaming executor).  Deadline-expired tickets are
        failed here — popped and skipped, never staged or dispatched."""
        batch: List[SearchTicket] = []
        total = 0
        while self._queue:
            head = self._queue[0]
            if head.deadline is not None and now >= head.deadline:
                self._queue.popleft()
                self._pending_rows -= head.rows
                self._fail_expired_locked(head, now)
                continue
            if batch and total + head.rows > self.max_batch:
                break
            self._queue.popleft()
            batch.append(head)
            total += head.rows
            if total >= self.max_batch:
                break
        self._pending_rows -= total
        if self._pending_rows < self.config.max_pending_rows:
            self._full_since = None
        telemetrylib.registry().set_gauge(
            "repro_serve_pending_rows", self._pending_rows
        )
        return batch or None

    def _expire_batch(
        self, batch: List[SearchTicket], now: float
    ) -> List[SearchTicket]:
        """Drop (and fail) tickets whose deadline passed — re-checked
        before every retry so backoff never redispatches dead work."""
        live = [
            t for t in batch if t.deadline is None or now < t.deadline
        ]
        if len(live) != len(batch):
            with self._lock:
                for t in batch:
                    if t.deadline is not None and now >= t.deadline:
                        self._fail_expired_locked(t, now)
        return live

    def _fail_batch(self, batch: List[SearchTicket],
                    error: BaseException) -> None:
        """Fail every ticket of a batch with one typed error."""
        now = self._now()
        self._last_fault = {
            "error": type(error).__name__,
            "point": getattr(error, "point", None),
            "detail": str(error),
            "at": now,
        }
        with self._lock:
            for t in batch:
                t._fail(error, now)
        self._stats.inc("failed_batches")
        SERVE_EVENTS.inc("failed_batches")

    def _requeue(self, batch: List[SearchTicket]) -> None:
        """Put a popped-but-undispatched batch back at the queue front
        (FIFO order preserved) — the worker-death no-ticket-loss leg."""
        with self._lock:
            for t in reversed(batch):
                self._queue.appendleft(t)
                self._pending_rows += t.rows
        self._stats.inc("requeued_tickets", len(batch))
        SERVE_EVENTS.inc("requeued_tickets", len(batch))

    def _bucket_for(self, rows: int) -> int:
        """Smallest pre-compiled shape holding ``rows``; oversize requests
        double up from ``max_batch`` so even their shapes stay bounded."""
        if rows <= self.max_batch:
            return self.buckets[bisect.bisect_left(self.buckets, rows)]
        bucket = self.max_batch
        while bucket < rows:
            bucket *= 2
        self._stats.inc("oversize_batches")
        SERVE_EVENTS.inc("oversize_batches")
        return bucket

    def _stage(self, bucket: int, batch: List[SearchTicket]) -> np.ndarray:
        """Gather the batch's query rows into a reusable host buffer.

        Two buffers per bucket, used alternately: the buffer being filled
        here is never the one whose device copy the in-flight dispatch was
        fed from, so host gather overlaps device compute (the
        double-buffering leg of the pipeline).
        """
        if bucket > self.max_batch:
            # Oversize batches ship solo and are rare: a transient buffer,
            # never cached — caching would pin two bucket-sized host
            # buffers per oversize shape for the server's lifetime.
            buf = np.zeros((bucket, self.index.dim), self._qdtype)
        else:
            pair = self._staging.get(bucket)
            if pair is None:
                pair = self._staging[bucket] = [
                    np.zeros((bucket, self.index.dim), self._qdtype),
                    np.zeros((bucket, self.index.dim), self._qdtype),
                    0,
                ]
            buf = pair[pair[2]]
            pair[2] ^= 1
            self._stats.inc("staging_swaps")
        offset = 0
        for t in batch:
            buf[offset : offset + t.rows] = t._queries
            t._offset = offset
            offset += t.rows
        buf[offset:] = 0.0  # bucket padding: dead rows, sliced away at scatter
        return buf

    def _service_once(self) -> bool:
        """Dispatch ONE coalesced micro-batch; then scatter the previous.

        Pipeline order is deliberate: stage the new batch (host work) while
        the previous dispatch runs on device, enqueue the new dispatch,
        *then* block on the previous result and scatter it — so the device
        is never idle waiting for host gather/scatter bookkeeping.

        Faults: retryable exceptions (``ServeConfig.retryable``) redispatch
        the batch after exponential backoff, re-checking deadlines each
        attempt; exhausted retries (and non-retryable errors) fail the
        batch's tickets with the typed error.  :class:`WorkerDeath` requeues
        the batch (nothing was dispatched) and propagates — the watchdog /
        ``step()`` restart path handles it without ticket loss.
        """
        # Death here = nothing popped yet; the queue is untouched.
        self._fire("serve.worker")
        cfg = self.config
        t_start = time.perf_counter()
        with self._lock:
            batch = self._take_batch_locked(self._now())
            if batch is not None:
                self._not_full.notify_all()
        if batch is None:
            self._finalize(self._pop_inflight())
            return False
        t_pop = self._now()
        attempt = 0
        while True:
            try:
                # bucket/stage inside the guard too: an allocation failure
                # on a huge oversize request must fail its tickets, not kill
                # the worker thread with the popped batch stranded.
                self._fire("serve.staging_alloc")
                t_coalesced = self._now()
                rows = sum(t.rows for t in batch)
                bucket = self._bucket_for(rows)
                buf = self._stage(bucket, batch)
                self._fire("serve.transfer")
                q = jnp.asarray(buf)
                t_staged = self._now()
                # perf_counter BEFORE the injection point: an injected
                # delay lands inside the drift monitor's measured window.
                t_disp0 = time.perf_counter()
                # Fired OUTSIDE the gate: a death injected here while the
                # main thread holds ``mutation()`` must not deadlock the
                # restarted worker on a gate its dead self never took.
                self._fire("serve.dispatch")
                with self._dispatch_gate:
                    with self._profile_span(f"serve.dispatch[{bucket}]"):
                        result = self.index.search(q)  # ONE dispatch
                break
            except faultslib.WorkerDeath:
                # This thread is about to die; nothing was dispatched for
                # this batch, so hand it back intact for the next worker.
                self._requeue(batch)
                raise
            except cfg.retryable as e:
                self._stats.inc("transient_faults")
                SERVE_EVENTS.inc("transient_faults")
                self._last_fault = {
                    "error": type(e).__name__,
                    "point": getattr(e, "point", None),
                    "detail": str(e),
                    "at": self._now(),
                }
                if attempt >= cfg.max_dispatch_retries:
                    self._fail_batch(batch, e)
                    return True
                attempt += 1
                self._stats.inc("dispatch_retries")
                SERVE_EVENTS.inc("dispatch_retries")
                self._backoff(cfg.retry_backoff_s * (2 ** (attempt - 1)))
                # Deadlines keep ticking through backoff: drop expired
                # tickets rather than dispatch dead work on the retry.
                batch = self._expire_batch(batch, self._now())
                if not batch:
                    return True
            except Exception as e:  # scatter the failure, keep serving
                self._fail_batch(batch, e)
                return True
        t_dispatched = self._now()
        for t in batch:
            tr = t.trace
            if tr is not None:
                # Contiguous stage spans on the server clock: together
                # with "scatter" (closed at completion) they tile the
                # request's [submit, complete] window end to end.
                tr.bucket = bucket
                tr.retries = attempt
                tr.span("queue", t.submitted_at, t_pop)
                tr.span("coalesce", t_pop, t_coalesced)
                tr.span("stage", t_coalesced, t_staged)
                tr.span("dispatch", t_staged, t_dispatched)
                tr.dispatched_at = t_dispatched
        self._stats.inc("batches")
        self._stats.inc("coalesced_requests", len(batch))
        self._stats.inc("dispatched_rows", rows)
        self._stats.inc("padded_rows", bucket - rows)
        SERVE_EVENTS.inc("batches")
        SERVE_EVENTS.inc("coalesced_requests", len(batch))
        SERVE_EVENTS.inc("padded_rows", bucket - rows)
        reg = telemetrylib.registry()
        reg.observe("repro_serve_batch_rows", rows, bucket=bucket)
        live = self._stats["dispatched_rows"] + self._stats["padded_rows"]
        if live:
            reg.set_gauge(
                "repro_serve_occupancy", self._stats["dispatched_rows"] / live
            )
        prev = self._pop_inflight()
        self._inflight = (result, batch, bucket, t_disp0)
        self._finalize(prev)
        self._maybe_sample_miss(buf, rows)
        # EWMA of service time feeds the Overloaded retry-after estimate.
        elapsed = time.perf_counter() - t_start
        self._service_ema = (
            elapsed if self._service_ema == 0.0
            else 0.8 * self._service_ema + 0.2 * elapsed
        )
        return True

    def _pop_inflight(self) -> Optional[tuple]:
        entry, self._inflight = self._inflight, None
        return entry

    def _finalize(self, entry: Optional[tuple]) -> None:
        """Block on a dispatched batch and scatter per-request slices.

        The batch result crosses to the host ONCE (``np.asarray`` — a view
        on CPU, one transfer on accelerators); tickets then receive numpy
        views, not per-request device slices.  Scattering R requests as
        2R device slice programs would cost more than the search itself.
        """
        if entry is None:
            return
        result, batch, bucket, t_disp0 = entry
        try:
            self._fire("serve.scatter")
            result.values.block_until_ready()
            # Dispatch-to-ready wall: the measured side of the roofline
            # drift ratio for this bucket.
            measured_s = time.perf_counter() - t_disp0
            values = np.asarray(result.values)
            indices = np.asarray(result.indices)
        except faultslib.WorkerDeath as e:
            # The dispatch already ran; its device-side work is lost with
            # the dying worker.  Fail the tickets with the typed error
            # (never silently re-dispatch completed work) and let the
            # watchdog restart the worker for the still-queued rest.
            self._fail_batch(batch, e)
            raise
        except Exception as e:
            # Accelerator errors surface asynchronously, at the block — a
            # bare raise here would kill the worker thread and strand every
            # waiter; fail the batch's tickets instead and keep serving.
            self._fail_batch(batch, e)
            return
        now = self._now()
        latencies = []
        with self._lock:  # one acquisition per batch, not per ticket
            for t in batch:
                tr = t.trace
                if tr is not None and tr.dispatched_at is not None:
                    tr.span("scatter", tr.dispatched_at, now)
                t._complete(
                    SearchResult(
                        values[t._offset : t._offset + t.rows, : t.k],
                        indices[t._offset : t._offset + t.rows, : t.k],
                    ),
                    now,
                )
                if t.latency_s is not None:
                    self._latency_sum += t.latency_s
                    latencies.append(t.latency_s)
            self._stats.inc("completed_requests", len(batch))
        reg = telemetrylib.registry()
        for lat in latencies:
            reg.observe("repro_serve_request_latency_seconds", lat)
        reg.observe(
            "repro_serve_dispatch_wall_seconds", measured_s,
            bucket=bucket,
        )
        self._record_drift(bucket, measured_s)

    def _maybe_sample_miss(self, buf: np.ndarray, live_rows: int) -> None:
        """Served-query cluster-miss monitor: every Nth batch, score a few
        real query rows through ``cluster.query_miss_rate`` and fold the
        counts into the ``ClusterState`` accumulators.

        Uses the *live* front of the staging buffer (padding rows would
        bias the estimate toward the all-zeros query).  Best-effort by
        design: the monitor must never take serving down, so any failure
        is swallowed — the signal just stays stale."""
        if self.config.miss_sample_every <= 0:
            return
        pk = getattr(self.index, "_packed", None)
        cs = pk.cluster if pk is not None else None
        if cs is None:
            return
        self._miss_sample_countdown -= 1
        if self._miss_sample_countdown > 0:
            return
        self._miss_sample_countdown = self.config.miss_sample_every
        m = min(self.config.miss_sample_rows, live_rows)
        try:
            rows, bias = pk.exact_rows_bias()
            missed, checked = clusterlib.query_miss_rate(
                cs, jnp.asarray(np.array(buf[:m])), rows, bias,
                self.index.spec.k,
            )
        except Exception:
            return
        cs.served_miss_checked += checked
        cs.served_miss_missed += missed
        rate = cs.served_miss_rate
        if rate is not None:
            telemetrylib.registry().set_gauge(
                "repro_serve_cluster_miss_rate", rate
            )
        self._stats.inc("miss_sampled_rows", m)
        SERVE_EVENTS.inc("miss_sampled_rows", m)

    # -- deterministic (virtual-clock) driving -------------------------------

    def step(self) -> bool:
        """Virtual-clock driver: dispatch one micro-batch (scattering the
        previously dispatched one).  Returns False — after finalizing any
        leftover in-flight batch — once the queue is empty."""
        if not self._manual:
            raise RuntimeError(
                "step() is the virtual-clock driver; wall-clock servers "
                "run their own worker thread"
            )
        try:
            return self._service_once()
        except faultslib.WorkerDeath:
            # The virtual-clock analogue of the wall watchdog: the "worker"
            # (this step) died and is instantly "restarted" — queued tickets
            # were requeued by the dying service pass, so the next step
            # picks them up.  Returns True: there may still be work.
            self._record_restart()
            return True

    def run_until_idle(self) -> None:
        """Drive the queue to empty and scatter everything in flight."""
        while self.step():
            pass

    # -- wall-clock worker ---------------------------------------------------

    def _record_restart(self) -> None:
        self._stats.inc("worker_deaths")
        self._stats.inc("worker_restarts")
        SERVE_EVENTS.inc("worker_deaths")
        SERVE_EVENTS.inc("worker_restarts")
        self._last_fault = {
            "error": "WorkerDeath",
            "point": "serve.worker",
            "detail": "worker died and was restarted by the watchdog",
            "at": self._now(),
        }

    def _worker_main(self) -> None:
        """Watchdog wrapper: restart a dead worker loop in place.

        A worker death (injected :class:`~repro.search.faults.WorkerDeath`
        or any escaped exception) would otherwise strand every queued
        ticket forever.  Restarting *inside the same thread* keeps
        ``close()``'s join working unchanged, and the dying service pass
        already requeued any popped-but-undispatched batch — so no ticket
        is lost across a restart."""
        while True:
            try:
                self._worker_loop()
                return
            except BaseException:
                with self._lock:
                    done = (
                        self._closed
                        and not self._queue
                        and self._inflight is None
                    )
                self._record_restart()
                if done:
                    return

    def _worker_loop(self) -> None:
        cfg = self.config
        while True:
            with self._lock:
                if self._closed and not self._queue:
                    break
                if not self._queue:
                    # idle: scatter any in-flight batch, then sleep on work
                    if self._inflight is None:
                        self._work.wait(0.05)
                else:
                    # coalescing window: hold the batch open for late
                    # arrivals until it fills or the head request's window
                    # expires
                    deadline = (
                        self._queue[0].submitted_at + cfg.max_delay_s
                    )
                    while (
                        self._queue
                        and self._pending_rows < self.max_batch
                        and not self._closed
                    ):
                        remaining = deadline - self._now()
                        if remaining <= 0:
                            break
                        self._work.wait(remaining)
            self._service_once()
        self._finalize(self._pop_inflight())

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Stop accepting requests, drain the queue, join the worker."""
        with self._lock:
            self._closed = True
            self._work.notify_all()
            self._not_full.notify_all()
        if self._worker is not None:
            self._worker.join(timeout)
            self._worker = None
        elif self._manual:
            self.run_until_idle()

    def __enter__(self) -> "SearchServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- out-of-band index mutations -----------------------------------------

    @contextlib.contextmanager
    def mutation(self):
        """Serialize an ``Index`` mutation against in-flight dispatches.

        ``Index`` is not thread-safe, and a wall-clock server's worker
        calls ``index.search`` from its own thread — so ``add`` / ``delete``
        (or anything else that rebinds the packed state) issued while the
        server runs must take this gate::

            with server.mutation():
                server.index.add(rows)

        ``KNNDatastore.extend`` / ``forget`` do this automatically when a
        server is attached.  Already-dispatched batches are unaffected
        (JAX arrays are immutable — updates rebind new buffers, they never
        write into operands a running program reads); the gate only
        excludes the *start* of a dispatch while index state is mid-update.
        Virtual-clock servers are single-threaded, where this is a no-op
        by construction (but still safe to use).
        """
        with self._dispatch_gate:
            yield

    # -- observability -------------------------------------------------------

    def _profile_span(self, name: str):
        """``jax.profiler.TraceAnnotation`` around the coalesced dispatch
        (shows up in device profiles); no-op when the profiler is absent."""
        if _TraceAnnotation is not None:
            return _TraceAnnotation(name)
        return contextlib.nullcontext()

    def _store_trace(self, trace: telemetrylib.RequestTrace) -> None:
        """Push a completed trace into the bounded ring buffer (deque
        append is atomic; callers already hold the server lock)."""
        if self._traces is not None:
            self._traces.append(trace)

    def traces(self, n: Optional[int] = None) -> List[telemetrylib.RequestTrace]:
        """The most recent completed request traces, oldest first (at most
        ``ServeConfig.trace_buffer`` are retained; ``n`` limits further).
        Feed them to ``repro.search.telemetry.chrome_trace`` for a
        flame-graph JSON, or ``trace_coverage`` for the span-coverage
        fraction."""
        if self._traces is None:
            return []
        with self._lock:
            out = list(self._traces)
        return out if n is None else out[-int(n):]

    def drift(self) -> dict:
        """The roofline-drift monitor's report (see ``health()["drift"]``)."""
        return self._drift.report()

    def _predicted_s(self, bucket: int) -> Optional[float]:
        """Plan-predicted wall seconds (Eq. 10/20) for one ``bucket``-row
        dispatch, memoized per bucket; None when the planner cannot price
        this shape (drift recording is then skipped)."""
        if bucket in self._predicted_cache:
            return self._predicted_cache[bucket]
        try:
            plan = self.index.kernel_plan
            if plan.m == bucket:
                pred = plan.predicted_s
            else:
                pred = self.index._replan(
                    n=plan.n, m=bucket, backend=plan.backend, pin_from=plan
                ).predicted_s
            pred = float(pred) if pred and pred > 0 else None
        except Exception:
            pred = None
        self._predicted_cache[bucket] = pred
        return pred

    def _record_drift(self, bucket: int, measured_s: float) -> None:
        predicted = self._predicted_s(bucket)
        if predicted is None or measured_s <= 0:
            return
        self._drift.record(str(bucket), measured_s, predicted)
        telemetrylib.registry().set_gauge(
            "repro_serve_drift", self._drift.report()["value"]
        )

    def precompile(self) -> int:
        """Compile every bucket shape ahead of traffic (one dummy dispatch
        per bucket); returns the number of buckets warmed."""
        for bucket in self.buckets:
            with self._dispatch_gate:  # may be called on a live server
                self.index.search(
                    jnp.zeros((bucket, self.index.dim), self._qdtype)
                ).values.block_until_ready()
        self._stats["precompiled_buckets"] = len(self.buckets)
        return len(self.buckets)

    def stats(self) -> dict:
        """Serving counters: batching efficiency, queue pressure, cache."""
        s = dict(self._stats)
        out = {
            "buckets": self.buckets,
            "max_batch": self.max_batch,
            "batches": s.get("batches", 0),
            "coalesced_requests": s.get("coalesced_requests", 0),
            "completed_requests": s.get("completed_requests", 0),
            "dispatched_rows": s.get("dispatched_rows", 0),
            "padded_rows": s.get("padded_rows", 0),
            "oversize_batches": s.get("oversize_batches", 0),
            "failed_batches": s.get("failed_batches", 0),
            "staging_swaps": s.get("staging_swaps", 0),
            "peak_pending_rows": s.get("peak_pending_rows", 0),
            "precompiled_buckets": s.get("precompiled_buckets", 0),
            "deadline_expired": s.get("deadline_expired", 0),
            "transient_faults": s.get("transient_faults", 0),
            "dispatch_retries": s.get("dispatch_retries", 0),
            "worker_deaths": s.get("worker_deaths", 0),
            "worker_restarts": s.get("worker_restarts", 0),
            "requeued_tickets": s.get("requeued_tickets", 0),
            "load_shed": s.get("load_shed", 0),
            "miss_sampled_rows": s.get("miss_sampled_rows", 0),
            "pending_rows": self._pending_rows,
            "uptime_s": self._now() - self._started_at,
            "traced_requests": len(self._traces) if self._traces else 0,
            "cache": self.index.cache_info(),
        }
        live = out["dispatched_rows"] + out["padded_rows"]
        out["occupancy"] = out["dispatched_rows"] / live if live else 0.0
        done = out["completed_requests"]
        out["mean_latency_s"] = self._latency_sum / done if done else 0.0
        return out

    def health(self) -> dict:
        """Liveness / degradation report for operators and load balancers.

        ``status`` is the headline: ``"ok"``, ``"degraded"`` (dead worker
        on an open server, or the served-query cluster-miss estimate past
        its warn threshold), or ``"overloaded"`` (admission queue full past
        ``overload_grace_s`` — submits are being shed).  The rest is the
        evidence: worker liveness, ``uptime_s``, ``last_fault`` (the most
        recent failure's type/point/time), queue depth, the failure
        counters, the ``drift`` block (roofline-drift monitor: normalized
        measured/predicted dispatch wall per bucket — out of
        ``ServeConfig.drift_band`` degrades), ``expected_recall_live``
        (analytic bin-collision term x the *served* cluster-miss
        estimate), and (clustered indexes) the ``cluster_miss`` block
        mirroring ``Index.explain()["cluster"]["served_miss"]``.  See
        ``docs/operations.md`` for the counter-by-counter runbook.
        """
        with self._lock:
            pending = self._pending_rows
            queued = len(self._queue)
            full_since = self._full_since
            closed = self._closed
        now = self._now()
        worker_alive = self._manual or (
            self._worker is not None and self._worker.is_alive()
        )
        overloaded = (
            full_since is not None
            and now - full_since >= self.config.overload_grace_s
        )
        s = self._stats
        report = {
            "worker_alive": worker_alive,
            "closed": closed,
            "uptime_s": now - self._started_at,
            "last_fault": self._last_fault,
            "pending_rows": pending,
            "queued_requests": queued,
            "deadline_expired": s.get("deadline_expired", 0),
            "transient_faults": s.get("transient_faults", 0),
            "dispatch_retries": s.get("dispatch_retries", 0),
            "failed_batches": s.get("failed_batches", 0),
            "worker_deaths": s.get("worker_deaths", 0),
            "worker_restarts": s.get("worker_restarts", 0),
            "load_shed": s.get("load_shed", 0),
            "requeued_tickets": s.get("requeued_tickets", 0),
        }
        miss_warning = False
        pk = getattr(self.index, "_packed", None)
        cs = pk.cluster if pk is not None else None
        if cs is not None:
            report["cluster_miss"] = cs.served_miss_report()
            miss_warning = report["cluster_miss"]["warning"]
        drift = self._drift.report()
        report["drift"] = drift
        drift_warning = drift["calibrated"] and not drift["in_band"]
        try:
            recall_live = float(self.index.expected_recall_live)
        except Exception:
            recall_live = None
        report["expected_recall_live"] = recall_live
        reg = telemetrylib.registry()
        reg.set_gauge("repro_serve_uptime_seconds", report["uptime_s"])
        if recall_live is not None:
            reg.set_gauge("repro_serve_expected_recall_live", recall_live)
        degraded = (
            (not worker_alive and not closed) or miss_warning or drift_warning
        )
        report["status"] = (
            "overloaded" if overloaded
            else ("degraded" if degraded else "ok")
        )
        return report
