"""Index: the index-free front door of the unified search API.

"Index" in the Faiss sense of the word only — true to the paper there is no
graph/IVF data structure to build or maintain.  ``Index.build`` does the
only precompute the algorithm needs (metric preparation: half norms or row
normalization, O(N) element-wise), so updates are cheap:

  * ``add(rows)``    appends into spare capacity (amortized growth),
  * ``delete(ids)``  tombstones rows via the kernel bias row,
  * bin plans and metric precompute are re-derived lazily on next search —
    no rebuild, the paper's "suitable for frequent updates" claim.

``search`` auto-tiles large query batches (``spec.query_block``) so the
score tile stays bounded, dispatches to the xla / pallas / sharded backend,
and memoizes compiled callables per (shape, dtype, spec) in a
``CompileCache`` — repeat same-shape searches never retrace.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.binning import BinPlan, plan_bins
from repro.search import backends
from repro.search.metrics import Metric, get_metric
from repro.search.spec import SearchSpec

__all__ = ["Index", "SearchResult"]


class SearchResult(NamedTuple):
    """(values, indices), both (M, k); value conventions per the metric
    contract in ``repro.search.metrics``."""

    values: jnp.ndarray
    indices: jnp.ndarray


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


class Index:
    """Searchable database under one ``SearchSpec``.

    Build one with ``Index.build(db, metric=..., k=..., ...)``; never call
    the constructor directly.  All mutating methods (``add``, ``delete``)
    update in place and return ``self`` for chaining.
    """

    def __init__(
        self,
        spec: SearchSpec,
        db: jnp.ndarray,
        live: jnp.ndarray,
        size: int,
        num_live: int,
        *,
        capacity_block: int = 1024,
        mesh: Optional[Mesh] = None,
        db_axis: str = "model",
        batch_axis: Optional[str] = None,
        interpret: Optional[bool] = None,
    ):
        self.spec = spec
        self._db = db
        self._live = live
        self._size = size          # append high-water mark (<= capacity)
        self._num_live = num_live  # live rows (size minus tombstones)
        self._capacity_block = capacity_block
        self._mesh = mesh
        self._db_axis = db_axis
        self._batch_axis = batch_axis
        self._interpret = interpret
        self._db_proc = None       # metric-prepared database (lazy)
        self._metric_bias = None   # metric's additive row bias (lazy)
        self._bias = None          # metric bias + tombstone mask (lazy)
        self._cache = backends.CompileCache()

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls,
        database: jnp.ndarray,
        *,
        metric: str = "mips",
        k: int = 10,
        recall_target: float = 0.95,
        backend: str = "auto",
        spec: Optional[SearchSpec] = None,
        capacity: Optional[int] = None,
        capacity_block: int = 1024,
        interpret: Optional[bool] = None,
        **spec_kwargs,
    ) -> "Index":
        """Create an index over ``database`` rows (N, D).

        ``spec`` overrides the individual (metric, k, ...) arguments when
        given.  ``capacity`` pre-allocates room for ``add`` beyond N;
        ``interpret`` forces Pallas interpret mode (auto: on except on TPU).
        """
        if spec is None:
            spec = SearchSpec(
                metric=metric, k=k, recall_target=recall_target,
                backend=backend, **spec_kwargs,
            )
        get_metric(spec.metric)  # validate eagerly
        database = jnp.asarray(database)
        if database.ndim != 2:
            raise ValueError(f"database must be (N, D), got {database.shape}")
        n = database.shape[0]
        cap = max(n, capacity or n)
        if cap > n:
            cap = _round_up(cap, capacity_block)
            database = jnp.pad(database, ((0, cap - n), (0, 0)))
        live = jnp.zeros((cap,), bool).at[:n].set(True)
        return cls(
            spec, database, live, size=n, num_live=n,
            capacity_block=capacity_block, interpret=interpret,
        )

    # -- introspection -------------------------------------------------------

    @property
    def metric(self) -> Metric:
        return get_metric(self.spec.metric)

    @property
    def capacity(self) -> int:
        return self._db.shape[0]

    @property
    def dim(self) -> int:
        return self._db.shape[1]

    @property
    def size(self) -> int:
        """Number of live (searchable) rows."""
        return self._num_live

    @property
    def num_appended(self) -> int:
        """Rows ever appended (live + tombstoned) — the append high-water
        mark.  Row-aligned side tables (e.g. value tokens) must cover at
        least this many rows."""
        return self._size

    def __len__(self) -> int:
        return self._num_live

    @property
    def plan(self) -> BinPlan:
        """Bin plan (and analytic E[recall], Eq. 13) for the current shape."""
        return plan_bins(
            self.capacity, self.spec.k, self.spec.recall_target,
            reduction_input_size_override=self.spec.reduction_input_size_override,
        )

    @property
    def expected_recall(self) -> float:
        return self.plan.expected_recall

    def cache_info(self) -> dict:
        return self._cache.info()

    def __repr__(self) -> str:
        mesh = f", mesh={dict(self._mesh.shape)}" if self._mesh else ""
        return (
            f"Index(metric={self.spec.metric!r}, k={self.spec.k}, "
            f"backend={self._resolve_backend()!r}, size={self.size}, "
            f"capacity={self.capacity}, dim={self.dim}{mesh})"
        )

    # -- derived state -------------------------------------------------------

    def _resolve_backend(self) -> str:
        b = self.spec.backend
        if b == "auto":
            return backends.default_backend(self._mesh)
        if b == "sharded" and self._mesh is None:
            raise ValueError(
                "backend='sharded' requires a mesh — call "
                ".shard(mesh, db_axis=...) first"
            )
        return b

    def _prepared(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(metric-prepared db, combined bias row) with lazy re-derivation."""
        if self._db_proc is None:
            db = self._db
            if self.spec.dtype is not None:
                db = db.astype(jnp.dtype(self.spec.dtype))
            self._db_proc, self._metric_bias = self.metric.prepare_database(db)
            self._bias = None
        if self._bias is None:
            tomb = jnp.where(self._live, 0.0, backends.MASK_VALUE).astype(
                jnp.float32
            )
            bias = (
                tomb
                if self._metric_bias is None
                else jnp.maximum(
                    tomb + self._metric_bias.astype(jnp.float32),
                    backends.MASK_VALUE,
                )
            )
            self._bias = bias
        return self._db_proc, self._bias

    def _invalidate(self, *, rows_changed: bool):
        if rows_changed:
            self._db_proc = None
            self._metric_bias = None
        self._bias = None

    # -- search --------------------------------------------------------------

    def search(self, queries: jnp.ndarray) -> SearchResult:
        """Top-k neighbours of each query row: (M, D) -> SearchResult (M, k).

        Query batches larger than ``spec.query_block`` are processed in
        equal-shaped tiles (one compiled program) to bound the score tile.
        If fewer than k live rows exist (mass deletes), the tail of each
        result row is filled with sentinel values (float32 min) and
        arbitrary indices of masked rows.
        """
        queries = jnp.asarray(queries)
        if queries.ndim != 2:
            raise ValueError(f"queries must be (M, D), got {queries.shape}")
        if queries.shape[1] != self.dim:
            raise ValueError(
                f"query dim {queries.shape[1]} != index dim {self.dim}"
            )
        if self.spec.dtype is not None:
            queries = queries.astype(jnp.dtype(self.spec.dtype))
        m = queries.shape[0]
        qb = self.spec.query_block
        if m <= qb:
            return SearchResult(*self._search_block(queries))
        m_pad = _round_up(m, qb)
        padded = jnp.pad(queries, ((0, m_pad - m), (0, 0)))
        vals, idxs = [], []
        for start in range(0, m_pad, qb):
            v, i = self._search_block(padded[start : start + qb])
            vals.append(v)
            idxs.append(i)
        return SearchResult(
            jnp.concatenate(vals, axis=0)[:m],
            jnp.concatenate(idxs, axis=0)[:m],
        )

    def _search_block(self, q: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        backend = self._resolve_backend()
        db, bias = self._prepared()
        spec = self.spec
        key = (backend, q.shape, str(q.dtype), self.capacity, spec)

        if backend == "xla":
            def build():
                def fn(q, db, bias):
                    return backends.dense_search(
                        q, db, bias,
                        metric=spec.metric, k=spec.k,
                        recall_target=spec.recall_target,
                        reduction_input_size_override=
                            spec.reduction_input_size_override,
                        aggregate_to_topk=spec.aggregate_to_topk,
                        use_bitonic=spec.use_bitonic,
                    )
                return fn
        elif backend == "pallas":
            interpret = self._interpret
            def build():
                def fn(q, db, bias):
                    return backends.pallas_search(
                        q, db, bias,
                        metric=spec.metric, k=spec.k,
                        recall_target=spec.recall_target,
                        block_m=spec.block_m, max_block_n=spec.max_block_n,
                        interpret=interpret,
                        aggregate_to_topk=spec.aggregate_to_topk,
                        use_bitonic=spec.use_bitonic,
                        reduction_input_size_override=
                            spec.reduction_input_size_override,
                    )
                return fn
        elif backend == "sharded":
            mesh, db_axis = self._mesh, self._db_axis
            batch_axis = self._batch_axis
            if batch_axis is not None and q.shape[0] % mesh.shape[batch_axis]:
                batch_axis = None  # replicate queries that do not divide
            key = key + (id(mesh), db_axis, batch_axis)
            def build():
                searcher = backends.make_sharded_search_fn(
                    mesh, metric=spec.metric, k=spec.k,
                    recall_target=spec.recall_target,
                    db_axis=db_axis, batch_axis=batch_axis,
                    use_bitonic=spec.use_bitonic,
                )
                jitted = jax.jit(searcher)
                qsharding = NamedSharding(mesh, P(batch_axis, None))
                def fn(q, db, bias):
                    return jitted(jax.device_put(q, qsharding), db, bias)
                return fn
        else:
            raise ValueError(f"unknown backend {backend!r}")

        fn = self._cache.get(key, build)
        return fn(q, db, bias)

    # -- updates (the paper's frequent-update path) --------------------------

    def add(self, rows: jnp.ndarray) -> "Index":
        """Append rows; grows capacity in ``capacity_block`` steps.

        No index rebuild: the metric precompute (half norms / row
        normalization, O(N) element-wise) and the bin plan are re-derived
        lazily on the next search.
        """
        rows = jnp.atleast_2d(jnp.asarray(rows))
        if rows.shape[1] != self.dim:
            raise ValueError(f"row dim {rows.shape[1]} != index dim {self.dim}")
        r = rows.shape[0]
        required = self._size + r
        if required > self.capacity:
            # Linear growth in capacity_block steps, not doubling: spare
            # capacity is tombstone-masked but still *scored* on every
            # search, so over-allocation costs FLOPs, not just memory.
            block = self._capacity_block
            if self._mesh is not None:
                block = math.lcm(block, self._mesh.shape[self._db_axis])
            new_cap = _round_up(required, block)
            grow = new_cap - self.capacity
            self._db = jnp.pad(self._db, ((0, grow), (0, 0)))
            self._live = jnp.pad(self._live, (0, grow))
            if self._mesh is not None:
                self._reshard()
        self._db = self._db.at[self._size : required].set(
            rows.astype(self._db.dtype)
        )
        self._live = self._live.at[self._size : required].set(True)
        self._size = required
        self._num_live += r
        self._invalidate(rows_changed=True)
        return self

    def delete(self, ids) -> "Index":
        """Tombstone rows by index: masked out via the kernel bias row.

        Deleted slots are not reclaimed (append-only storage); their ids
        never appear in subsequent search results.
        """
        ids = jnp.atleast_1d(jnp.asarray(ids, jnp.int32))
        self._live = self._live.at[ids].set(False)
        # Recount rather than decrement: ids may repeat (within a call or
        # across calls) and a gather-then-sum would count those twice.
        self._num_live = int(jnp.sum(self._live))
        self._invalidate(rows_changed=False)
        return self

    # -- sharding ------------------------------------------------------------

    def shard(
        self,
        mesh: Mesh,
        *,
        db_axis: str = "model",
        batch_axis: Optional[str] = None,
    ) -> "Index":
        """Return a mesh-sharded copy: rows P(db_axis, None), queries
        optionally sharded over ``batch_axis``.

        Capacity is padded (with tombstoned rows) to a multiple of the shard
        count; recall accounting against the global N is handled by the
        sharded backend internally.
        """
        n_shards = mesh.shape[db_axis]
        cap = _round_up(self.capacity, n_shards)
        db, live = self._db, self._live
        if cap > self.capacity:
            db = jnp.pad(db, ((0, cap - self.capacity), (0, 0)))
            live = jnp.pad(live, (0, cap - self.capacity))
        out = Index(
            self.spec.with_backend("sharded"), db, live,
            size=self._size, num_live=self._num_live,
            capacity_block=self._capacity_block,
            mesh=mesh, db_axis=db_axis, batch_axis=batch_axis,
            interpret=self._interpret,
        )
        out._reshard()
        return out

    def _reshard(self):
        assert self._mesh is not None
        self._db = jax.device_put(
            self._db, NamedSharding(self._mesh, P(self._db_axis, None))
        )
        self._live = jax.device_put(
            self._live, NamedSharding(self._mesh, P(self._db_axis))
        )
