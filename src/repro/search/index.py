"""Index: the index-free front door of the unified search API.

"Index" in the Faiss sense of the word only — true to the paper there is no
graph/IVF data structure to build or maintain.  ``Index.build`` does the
only precompute the algorithm needs (metric preparation + packing into the
backend's native layout, O(N) element-wise), held device-resident in a
``repro.search.packed.PackedState`` so updates are cheap:

  * ``add(rows)``    appends into spare capacity and metric-prepares ONLY
    the appended slice (amortized growth, no O(N) re-derivation),
  * ``delete(ids)``  tombstones rows by patching the packed bias row —
    no host sync, no O(N) work,
  * the bin plan and the padded kernel layout are owned by the packed
    state, rebuilt only on capacity/backend changes —
    no rebuild, the paper's "suitable for frequent updates" claim.

``search`` dispatches pre-packed operands to the xla / pallas / sharded
backend, so the steady-state dispatch never pads or prepares the (N, D)
database (the paper's I_MEM ~ O(min(M, N)) bound, Eq. 10).  Query batches
larger than ``spec.query_block`` run as ONE compiled streaming program
(``lax.map`` over equal-shaped blocks) instead of a Python loop of
dispatches; compiled callables are memoized per (shape, dtype, spec) in a
``CompileCache`` — repeat same-shape searches never retrace.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.binning import BinPlan, plan_bins, round_up
from repro.search import backends, packed as packedlib, plan as planlib
from repro.search import cluster as clusterlib
from repro.search import faults as faultslib
from repro.search import hosttier as hosttierlib
from repro.search import telemetry as telemetrylib
from repro.search import quant
from repro.search.metrics import Metric, get_metric
from repro.search.spec import SearchSpec

__all__ = ["Index", "SNAPSHOT_FORMAT", "SNAPSHOT_VERSION", "SearchResult"]

# Snapshot stamping (Index.save / Index.restore).  The format string guards
# against loading some other repro.checkpoint artifact as an index; the
# version gates forward compatibility — restore refuses snapshots written
# by a NEWER version (older ones are handled field-by-field).
SNAPSHOT_FORMAT = "repro.search.index"
SNAPSHOT_VERSION = 1


class SearchResult(NamedTuple):
    """(values, indices), both (M, k); value conventions per the metric
    contract in ``repro.search.metrics``."""

    values: jnp.ndarray
    indices: jnp.ndarray


class Index:
    """Searchable database under one ``SearchSpec``.

    Build one with ``Index.build(db, metric=..., k=..., ...)``; never call
    the constructor directly.  All mutating methods (``add``, ``delete``)
    update in place and return ``self`` for chaining.
    """

    def __init__(
        self,
        spec: SearchSpec,
        db: jnp.ndarray,
        live: jnp.ndarray,
        size: int,
        num_live: Union[int, jnp.ndarray],
        *,
        capacity_block: int = 1024,
        mesh: Optional[Mesh] = None,
        db_axis: str = "model",
        batch_axis: Optional[str] = None,
        interpret: Optional[bool] = None,
        kernel_plan: Optional[planlib.Plan] = None,
    ):
        self.spec = spec
        self._db = db
        self._live = live
        self._size = size          # append high-water mark (<= capacity)
        self._num_live = num_live  # live rows; int, or a lazy device scalar
        self._capacity_block = capacity_block
        self._mesh = mesh
        self._db_axis = db_axis
        self._batch_axis = batch_axis
        self._interpret = interpret
        self._kernel_plan = kernel_plan
        self._packed: Optional[packedlib.PackedState] = None
        self._cache = backends.CompileCache()

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls,
        database: jnp.ndarray,
        *,
        metric: str = "mips",
        k: int = 10,
        recall_target: float = 0.95,
        backend: str = "auto",
        spec: Optional[SearchSpec] = None,
        capacity: Optional[int] = None,
        capacity_block: int = 1024,
        interpret: Optional[bool] = None,
        plan: Union[str, planlib.Plan] = "model",
        device: Optional[str] = None,
        plan_cache: Optional[planlib.PlanCache] = None,
        hbm_budget_bytes: Optional[float] = None,
        **spec_kwargs,
    ) -> "Index":
        """Create an index over ``database`` rows (N, D).

        ``spec`` overrides the individual (metric, k, ...) arguments when
        given.  ``capacity`` pre-allocates room for ``add`` beyond N;
        ``interpret`` forces Pallas interpret mode (auto: on except on TPU).
        The packed search state (metric precompute, fused bias row, kernel
        layout) is materialized here, at build time — not on first search.

        ``plan`` selects how kernel parameters (tile sizes, query block)
        are chosen for spec fields left ``None``:

          * ``"model"`` (default): analytically, from the paper's
            performance model (``repro.search.plan.plan_search``).
          * ``"measure"``: the model's choice refined by a short on-device
            sweep (``repro.search.plan.tune_plan``), persisted in
            ``plan_cache`` (or the ``REPRO_PLAN_CACHE`` file).
          * a :class:`repro.search.plan.Plan` instance: used as-is.

        Explicit block fields in ``spec``/``spec_kwargs`` always pin the
        corresponding choice.  ``device`` names a hardware profile from
        ``repro.core.roofline.HARDWARE`` (default: auto-detect).

        ``residency="host"`` (a spec field, accepted here as a keyword)
        builds the cold tier: packed operands stay in host RAM and the
        planner sizes the segment waves against ``hbm_budget_bytes``
        (default: the device profile's HBM) — capacity is padded to whole
        segments so every wave shares one compiled program shape.

        >>> import jax.numpy as jnp
        >>> idx = Index.build(jnp.eye(32), metric="mips", k=2)
        >>> idx.spec.resolved and idx.kernel_plan.source == "model"
        True
        """
        if spec is None:
            spec = SearchSpec(
                metric=metric, k=k, recall_target=recall_target,
                backend=backend, **spec_kwargs,
            )
        # Validate eagerly: metric existence AND metric x storage-tier
        # compatibility (covers metrics registered after the spec was
        # built, which SearchSpec's own validation cannot see).
        quant.check_metric_storage(get_metric(spec.metric), spec.storage)
        database = jnp.asarray(database)
        if database.ndim != 2:
            raise ValueError(f"database must be (N, D), got {database.shape}")
        n = database.shape[0]
        cap = max(n, capacity or n)
        if cap > n:
            cap = round_up(cap, capacity_block)
            database = jnp.pad(database, ((0, cap - n), (0, 0)))

        # Resolve the kernel plan over the *capacity* row space — that is
        # what the packed layout (and its bin plan) covers.
        plan_backend = spec.backend
        if plan_backend == "auto":
            plan_backend = backends.default_backend(None)
        if isinstance(plan, planlib.Plan):
            plan_obj = plan
        elif plan in ("model", "measure"):
            plan_obj = planlib.plan_search(
                n=cap, d=database.shape[1], k=spec.k, metric=spec.metric,
                recall_target=spec.recall_target,
                # the planner sizes tiles for the dtype that actually runs:
                # the spec override, else the database's own
                dtype=spec.dtype or str(database.dtype),
                backend=plan_backend, device=device,
                reduction_input_size_override=
                    spec.reduction_input_size_override,
                block_m=spec.block_m, max_block_n=spec.max_block_n,
                query_block=spec.query_block,
                storage=spec.storage, rescore=spec.rescore_enabled,
                cluster=spec.cluster,
                residency=spec.residency, segment_rows=spec.segment_rows,
                hbm_budget_bytes=hbm_budget_bytes,
            )
            if plan == "measure" and plan_obj.source != "user":
                plan_obj = planlib.tune_plan(
                    database, plan_obj, spec=spec, cache=plan_cache,
                    interpret=interpret,
                )
        else:
            raise ValueError(
                f"plan must be 'model', 'measure' or a Plan, got {plan!r}"
            )
        spec = plan_obj.to_spec(spec)

        if spec.residency == "host" and spec.segment_rows:
            # The wave program has one fixed shape; pad capacity (with
            # tombstoned rows) to a whole number of segment waves.
            seg_cap = round_up(cap, spec.segment_rows)
            if seg_cap > cap:
                database = jnp.pad(database, ((0, seg_cap - cap), (0, 0)))
                cap = seg_cap

        live = jnp.zeros((cap,), bool).at[:n].set(True)
        index = cls(
            spec, database, live, size=n, num_live=n,
            capacity_block=capacity_block, interpret=interpret,
            kernel_plan=plan_obj,
        )
        if spec.backend != "sharded":
            # backend="sharded" has no mesh yet; ``shard`` packs instead.
            index.pack()
        return index

    # -- introspection -------------------------------------------------------

    @property
    def metric(self) -> Metric:
        return get_metric(self.spec.metric)

    @property
    def capacity(self) -> int:
        return self._db.shape[0]

    @property
    def dim(self) -> int:
        return self._db.shape[1]

    @property
    def size(self) -> int:
        """Number of live (searchable) rows.

        ``delete`` keeps the live count as a lazy device scalar so the
        dispatch pipeline is never blocked; reading ``size`` (or ``len``)
        is what materializes it.
        """
        if not isinstance(self._num_live, int):
            self._num_live = int(self._num_live)
        return self._num_live

    @property
    def num_appended(self) -> int:
        """Rows ever appended (live + tombstoned) — the append high-water
        mark.  Row-aligned side tables (e.g. value tokens) must cover at
        least this many rows."""
        return self._size

    def __len__(self) -> int:
        return self.size

    @property
    def plan(self) -> BinPlan:
        """Bin plan (and analytic E[recall], Eq. 13) for the current shape.

        Quantized tiers plan for the over-fetched scan k
        (``repro.search.quant.scan_k``), so ``expected_recall`` is the
        conservative ``((L-1)/L)^(K'-1)`` bound the two-pass guarantee
        rests on.
        """
        if self._packed is not None:
            return self._packed.plan
        return plan_bins(
            self.capacity,
            packedlib.scan_k_for(self.spec, self.capacity),
            self.spec.recall_target,
            reduction_input_size_override=self.spec.reduction_input_size_override,
        )

    @property
    def expected_recall(self) -> float:
        cp = self._cluster_plan_in_effect()
        if cp is not None:
            k_scan = packedlib.scan_k_for(self.spec, cp.scan_rows)
            return cp.recall_decomposition(k_scan)["expected_recall"]
        return self.plan.expected_recall

    @property
    def expected_recall_live(self) -> float:
        """Live served-recall proxy: the analytic bin-collision term
        (Eq. 13, over-fetch margin of the quantized tiers already folded
        into ``scan_k``) times the *measured* served-query cluster-miss
        survival rate when the ``SearchServer`` sampler has data —
        falling back to the analytic miss term before any sample, and to
        plain ``expected_recall`` on unclustered indexes.  This is the
        one gauge that moves when real traffic drifts out of the
        distribution the cluster tables were certified on."""
        cp = self._cluster_plan_in_effect()
        if cp is None:
            return float(self.plan.expected_recall)
        k_scan = packedlib.scan_k_for(self.spec, cp.scan_rows)
        decomp = cp.recall_decomposition(k_scan)
        cs = self._packed.cluster if self._packed is not None else None
        rate = cs.served_miss_rate if cs is not None else None
        if rate is None:
            return float(decomp["expected_recall"])
        return float(decomp["collision_term"] * (1.0 - rate))

    def _cluster_plan_in_effect(self):
        """The ClusterPlan the live search path actually prunes with.

        Prefers the plan the packed side-tables were built with (the one
        whose probes/target_scan are baked into the compiled program);
        falls back to the kernel plan's derivation pre-pack.  None when
        pruning is off or rejected by the planner crossover.
        """
        if self._packed is not None:
            cs = self._packed.cluster
            return cs.plan if cs is not None else None
        kp = self._kernel_plan
        if kp is not None and kp.cluster is not None and kp.cluster.enabled:
            return kp.cluster
        return None

    def _replan(
        self,
        *,
        n: Optional[int] = None,
        m: Optional[int] = None,
        backend: Optional[str] = None,
        device: Optional[str] = None,
        pin_from: Optional[planlib.Plan] = None,
        db_shards: Optional[int] = None,
    ) -> planlib.Plan:
        """One re-planning entry point for growth/shard/explain.

        Always carries the spec's recall accounting
        (``reduction_input_size_override``) and the *actual* operand dtype
        (spec override or the database's own), so a derived plan can never
        diverge from the packed layout's bin math.  ``pin_from`` pins the
        tile triple of an existing plan (layout-preserving re-plans);
        otherwise the spec's own (possibly ``None``) fields apply.
        """
        spec = self.spec
        tiles = (
            dict(block_m=pin_from.block_m, max_block_n=pin_from.block_n,
                 query_block=pin_from.query_block)
            if pin_from is not None
            else dict(block_m=spec.block_m, max_block_n=spec.max_block_n,
                      query_block=spec.query_block)
        )
        return planlib.plan_search(
            n=self.capacity if n is None else n, d=self.dim, k=spec.k,
            m=m, metric=spec.metric, recall_target=spec.recall_target,
            dtype=spec.dtype or str(self._db.dtype),
            backend=backend or self._resolve_backend(),
            device=device or (pin_from.device if pin_from else None),
            reduction_input_size_override=spec.reduction_input_size_override,
            storage=spec.storage, rescore=spec.rescore_enabled,
            cluster=spec.cluster,
            db_shards=(
                self._num_db_shards() if db_shards is None else db_shards
            ),
            residency=spec.residency,
            segment_rows=spec.segment_rows,
            **tiles,
        )

    @property
    def kernel_plan(self) -> planlib.Plan:
        """The resolved kernel plan (``repro.search.plan.Plan``) — tile
        sizes, bin layout and the roofline prediction behind them."""
        if self._kernel_plan is None:
            self._kernel_plan = self._replan()
        return self._kernel_plan

    def explain(
        self,
        *,
        m: Optional[int] = None,
        measure: bool = False,
        validate_hlo: bool = False,
    ) -> dict:
        """The plan behind this index, with its predicted roofline position.

        Returns a dict with the resolved ``plan`` (tiles, bin layout,
        provenance), the ``predicted`` roofline placement (attainable
        FLOP/s, binding wall, per-batch wall time — Eq. 4–10), and the
        analytic ``expected_recall`` (Eq. 13).  ``m`` re-evaluates the
        prediction for a specific query-batch size (default: one
        ``query_block``).

        ``measure=True`` additionally times a synthetic batch on the live
        index and reports achieved FLOP/s and the fraction of the model's
        attainable roof actually reached.  ``validate_hlo=True`` (xla
        backend) lowers the search program and cross-checks the model's
        FLOP count against the compiled HLO (``repro.search.plan.hlo_check``).
        """
        plan = self.kernel_plan
        if m is not None and m != plan.m:
            plan = dataclasses.replace(
                self._replan(n=plan.n, m=m, backend=plan.backend,
                             pin_from=plan),
                source=plan.source,
            )
        report = {
            "plan": plan.summary(),
            "backend": self._resolve_backend(),
            "expected_recall": plan.expected_recall,
            "predicted": {
                "device": plan.device,
                "flops": plan.flops,
                "hbm_bytes": plan.hbm_bytes,
                "cops": plan.cops,
                "i_mem": plan.i_mem,
                "i_cop": plan.i_cop,
                "attainable_flops": plan.attainable_flops,
                "bottleneck": plan.bottleneck,
                "wall_s": plan.predicted_s,
                "qps": plan.predicted_qps,
            },
            # Traffic is priced from the dtype actually *stored*, not an
            # assumed 4 bytes/element: quantized tiers stream 2- or 1-byte
            # rows (Eq. 10/20) plus an O(M·L·D) exact rescore pass.
            "storage": {
                "tier": self.spec.storage,
                "db_bytes_per_element": quant.storage_bytes(
                    self.spec.storage
                ),
                "db_resident_bytes": self.capacity * self.dim
                * quant.storage_bytes(self.spec.storage),
                "rescore": self.spec.rescore_enabled,
                "k_scan": plan.k_scan or plan.k,
                # Eq. 20 traffic for one dispatch at this tier: on the
                # fused Pallas path this is db-bytes + O(M·k_scan) with no
                # score-tile round trip — what the bench smoke asserts.
                "predicted_hbm_bytes": plan.hbm_bytes,
                "fused_select": self.spec.fused_select_enabled,
            },
        }
        if self.spec.residency == "host":
            seg = self.spec.segment_rows or plan.segment_rows
            waves = self.capacity // seg if seg else 0
            sbytes = quant.storage_bytes(self.spec.storage)
            # The segment schedule a search will actually run: fixed-shape
            # waves streamed through device HBM, double-buffered one ahead.
            report["residency"] = {
                "tier": "host",
                "segment_rows": seg,
                "num_segments": waves,
                "segment_hbm_bytes": seg * self.dim * sbytes,
                "hbm_budget_bytes": plan.hbm_budget_bytes,
                "schedule": [
                    {"wave": i, "rows": [i * seg, (i + 1) * seg]}
                    for i in range(waves)
                ],
            }
        if self._mesh is not None:
            # The §7 distributed-traffic picture: per-shard scan sizing
            # plus the one collective — the O(k_scan)-per-shard (value,
            # global id) all-gather — priced against the ICI bandwidth.
            report["sharding"] = {
                "db_axes": list(self._db_axes()),
                "batch_axis": self._batch_axis,
                "db_shards": plan.db_shards,
                "per_shard_n": plan.n // max(plan.db_shards, 1),
                "ici_gather_bytes": plan.ici_bytes,
                "ici_s": plan.ici_s,
            }
        cp = self._cluster_plan_in_effect()
        report["cluster"] = {"mode": self.spec.cluster,
                             "enabled": cp is not None}
        if cp is None and plan.cluster is not None:
            # auto mode, rejected by the crossover: record why.
            report["cluster"]["predicted_speedup"] = \
                plan.cluster.predicted_speedup
        rejected_miss = (
            self._packed.cluster_rejected_miss
            if self._packed is not None else None
        )
        if cp is None and rejected_miss is not None:
            # auto mode, planner crossover passed but the build-time
            # empirical check measured a miss rate the decay model can't
            # budget (structureless data): record the measurement.
            report["cluster"].update({
                "rejected_by": "sampled_miss_check",
                "sampled_miss": rejected_miss,
                "miss_budget": plan.cluster.miss_budget
                if plan.cluster is not None else None,
            })
        if cp is not None:
            k_scan = packedlib.scan_k_for(self.spec, cp.scan_rows)
            decomp = cp.recall_decomposition(k_scan)
            report["cluster"].update({
                "num_clusters": cp.num_clusters,
                "probes": cp.probes,
                "rows_per_cluster": cp.rows_per_cluster,
                "spill_capacity": cp.spill_capacity,
                "scan_rows": cp.scan_rows,
                "scanned_fraction": cp.scanned_fraction,
                "predicted_speedup": cp.predicted_speedup,
                # E[recall] = P(no bin collision) * P(no cluster miss):
                # the product the planner certified against the target.
                "collision_term": decomp["collision_term"],
                "miss_term": decomp["miss_term"],
                "expected_recall": decomp["expected_recall"],
            })
            report["expected_recall"] = decomp["expected_recall"]
            cs = self._packed.cluster if self._packed is not None else None
            if cs is not None:
                # Served-query miss monitor (fed by SearchServer sampling):
                # the build-time check used db rows as query proxies; this
                # is the live estimate over *real* traffic, the only signal
                # for out-of-distribution query streams.
                report["cluster"]["served_miss"] = cs.served_miss_report()
        report["expected_recall_live"] = self.expected_recall_live
        if self._packed is not None:
            report["packed"] = {
                "n": self._packed.n,
                "db_shape": tuple(self._packed.db.shape),
                "bin_size": self._packed.bin_size,
                "block_n": self._packed.block_n,
            }
        m_eff = m or plan.m or plan.query_block
        if measure:
            queries = jax.random.normal(
                jax.random.PRNGKey(0), (m_eff, self.dim), self._db.dtype
            )
            wall = planlib.time_search(self, queries, repeats=3)
            # plan.flops is already the backend-correct count for m_eff
            # (padded kernel layout on pallas, raw operands on xla/sharded)
            achieved = plan.flops / wall
            report["measured"] = {
                "wall_s": wall,
                "qps": m_eff / wall,
                "achieved_flops": achieved,
                "roofline_fraction": achieved / plan.attainable_flops,
            }
        if validate_hlo:
            backend = self._resolve_backend()
            if backend != "xla":
                report["hlo"] = {"skipped": f"hlo check is xla-only "
                                 f"(resolved backend {backend!r})"}
            elif cp is not None:
                report["hlo"] = {"skipped": "hlo check models the dense "
                                 "scan; the clustered program gathers a "
                                 "pruned row set instead"}
            else:
                pk = self.pack()
                q = jax.ShapeDtypeStruct(
                    (min(m_eff, self.spec.query_block), self.dim),
                    self._db.dtype,
                )
                if self.spec.storage == "f32":
                    lowered = backends.dense_search.lower(
                        q, pk.db, pk.bias,
                        metric=self.spec.metric, k=self.spec.k,
                        recall_target=self.spec.recall_target,
                        reduction_input_size_override=
                            self.spec.reduction_input_size_override,
                        aggregate_to_topk=self.spec.aggregate_to_topk,
                        use_bitonic=self.spec.use_bitonic,
                    ).compile()
                else:
                    lowered = backends.dense_search_quant.lower(
                        q, pk.db, pk.bias, pk.scale,
                        pk.rescore_db, pk.rescore_bias,
                        metric=self.spec.metric, k=self.spec.k,
                        k_scan=packedlib.scan_k_for(
                            self.spec, pk.n, live=self.size
                        ),
                        recall_target=self.spec.recall_target,
                        reduction_input_size_override=
                            self.spec.reduction_input_size_override,
                        aggregate_to_topk=self.spec.aggregate_to_topk,
                        use_bitonic=self.spec.use_bitonic,
                    ).compile()
                block_plan = plan
                if q.shape[0] != plan.m:
                    block_plan = self._replan(
                        n=plan.n, m=q.shape[0], backend=plan.backend,
                        pin_from=plan,
                    )
                report["hlo"] = planlib.hlo_check(
                    block_plan, lowered.as_text()
                )
        return report

    def cache_info(self) -> dict:
        return self._cache.info()

    def telemetry(self) -> dict:
        """One JSON-serializable telemetry snapshot, index gauges included.

        Refreshes this index's gauges in the process-global registry —
        size/capacity and the recall pair (analytic ``expected_recall``
        and live ``expected_recall_live``), labeled by
        backend/storage/cluster — then returns
        ``repro.search.telemetry.export_json()`` (so the dispatch/trace/
        pack/serve counters and every histogram ride along).  For the
        Prometheus text form, call ``telemetry.export_prometheus()``
        after this.
        """
        reg = telemetrylib.registry()
        labels = {
            "backend": self._resolve_backend(),
            "storage": self.spec.storage,
            "cluster": (
                "on" if self._cluster_plan_in_effect() is not None else "off"
            ),
        }
        reg.set_gauge("repro_index_size", self.size, **labels)
        reg.set_gauge("repro_index_capacity", self.capacity, **labels)
        reg.set_gauge(
            "repro_index_expected_recall", self.expected_recall, **labels
        )
        reg.set_gauge(
            "repro_index_expected_recall_live", self.expected_recall_live,
            **labels,
        )
        return telemetrylib.export_json()

    def __repr__(self) -> str:
        mesh = f", mesh={dict(self._mesh.shape)}" if self._mesh else ""
        return (
            f"Index(metric={self.spec.metric!r}, k={self.spec.k}, "
            f"backend={self._resolve_backend()!r}, size={self.size}, "
            f"capacity={self.capacity}, dim={self.dim}{mesh})"
        )

    # -- packed state --------------------------------------------------------

    def _resolve_backend(self) -> str:
        b = self.spec.backend
        if self.spec.residency == "host":
            # The cold tier scans xla-layout segment waves; "auto" never
            # resolves to pallas/sharded here (spec validation already
            # rejects them explicitly).
            return "xla"
        if b == "auto":
            return backends.default_backend(self._mesh)
        if b == "sharded" and self._mesh is None:
            raise ValueError(
                "backend='sharded' requires a mesh — call "
                ".shard(mesh, db_axis=...) first"
            )
        return b

    def _db_axes(self) -> tuple:
        """Database mesh axes as a tuple (1-D: one name; 2-D: several)."""
        return backends.normalize_db_axes(self._db_axis)

    def _num_db_shards(self) -> int:
        """Database shard count — the product of the db-axis extents."""
        if self._mesh is None:
            return 1
        return backends.db_shard_count(self._mesh, self._db_axis)

    def pack(self) -> packedlib.PackedState:
        """The device-resident packed operands for the resolved backend.

        Built at ``build``/``shard`` time and patched incrementally by
        ``add``/``delete``; a full repack happens only if the resolved
        backend changed under an ``auto`` spec or a non-row-wise metric
        invalidated the state.
        """
        backend = self._resolve_backend()
        if self._packed is None or self._packed.backend != backend:
            self._packed = packedlib.pack_state(
                self._db, self._live, self.metric, self.spec, backend,
                cluster_plan=self.kernel_plan.cluster,
            )
            self._place_packed()
        return self._packed

    def _place_packed(self):
        """Pin packed operands to their residency: host RAM for the cold
        tier, the mesh layout when sharded (no-op for plain hbm)."""
        if self._packed is None:
            return
        if self.spec.residency == "host":
            # The packed arrays live on the host CPU between searches;
            # HostTierSearcher streams segment slices to the hot device.
            cpu = jax.local_devices(backend="cpu")[0]
            pk = self._packed
            pk.db = jax.device_put(pk.db, cpu)
            pk.bias = jax.device_put(pk.bias, cpu)
            if pk.scale is not None:
                pk.scale = jax.device_put(pk.scale, cpu)
            if pk.rescore_db is not None:
                pk.rescore_db = jax.device_put(pk.rescore_db, cpu)
                pk.rescore_bias = jax.device_put(pk.rescore_bias, cpu)
            return
        if self._mesh is None:
            return
        rows = NamedSharding(self._mesh, P(self._db_axis, None))
        per_row = NamedSharding(self._mesh, P(self._db_axis))
        pk = self._packed
        pk.db = jax.device_put(pk.db, rows)
        pk.bias = jax.device_put(pk.bias, per_row)
        if pk.scale is not None:
            pk.scale = jax.device_put(pk.scale, per_row)
        if pk.rescore_db is not None:
            pk.rescore_db = jax.device_put(pk.rescore_db, rows)
            pk.rescore_bias = jax.device_put(pk.rescore_bias, per_row)
        if pk.cluster is not None:
            # Cluster side-tables are small (O(C*d + C*R)) and hold GLOBAL
            # row ids, so they are replicated — every shard probes the same
            # clusters and masks down to the rows it owns.
            repl2 = NamedSharding(self._mesh, P(None, None))
            repl1 = NamedSharding(self._mesh, P(None))
            cs = pk.cluster
            cs.centroids = jax.device_put(cs.centroids, repl2)
            cs.centroid_bias = jax.device_put(cs.centroid_bias, repl1)
            cs.cluster_rows = jax.device_put(cs.cluster_rows, repl2)
            cs.spill_rows = jax.device_put(cs.spill_rows, repl1)

    # -- search --------------------------------------------------------------

    def search(self, queries: jnp.ndarray) -> SearchResult:
        """Top-k neighbours of each query row: (M, D) -> SearchResult (M, k).

        Query batches larger than ``spec.query_block`` are processed in
        equal-shaped tiles to bound the score tile — by default as a
        single compiled streaming program (one device dispatch for the
        whole batch); ``spec.stream=False`` falls back to the per-block
        Python loop (bit-identical, one dispatch per block).  If fewer
        than k live rows exist (mass deletes), the tail of each result row
        is filled with sentinel values (float32 min) and arbitrary indices
        of masked rows.

        >>> import jax.numpy as jnp
        >>> index = Index.build(jnp.eye(16), metric="mips", k=3)
        >>> values, indices = index.search(jnp.eye(16)[:4])
        >>> indices.shape
        (4, 3)
        >>> int(indices[0, 0])  # e_0's best match is row 0
        0
        """
        queries = jnp.asarray(queries)
        if queries.ndim != 2:
            raise ValueError(f"queries must be (M, D), got {queries.shape}")
        if queries.shape[1] != self.dim:
            raise ValueError(
                f"query dim {queries.shape[1]} != index dim {self.dim}"
            )
        if self.spec.dtype is not None:
            queries = queries.astype(jnp.dtype(self.spec.dtype))
        if queries.shape[0] <= self.spec.query_block:
            return SearchResult(*self._search_block(queries))
        if self.spec.stream and self.spec.residency != "host":
            # The host tier's wave driver stages segments from Python, so
            # multi-block batches run the (bit-identical) per-block loop —
            # each block still re-streams the database once.
            return self._search_stream(queries)
        return self._search_loop(queries)

    def _batch_axis_for(self, rows: int) -> Optional[str]:
        """Query batch axis, dropped when it does not divide the block."""
        batch_axis = self._batch_axis
        if batch_axis is not None and rows % self._mesh.shape[batch_axis]:
            return None  # replicate queries that do not divide
        return batch_axis

    def _search_block(self, q: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        backend = self._resolve_backend()
        pk = self.pack()
        if self.spec.residency == "host":
            key = ("host", q.shape, str(q.dtype), self.capacity, self.spec)
            fn = self._cache.get(key, self._build_host_searcher)
            # Dispatch accounting (one per segment wave) lives inside the
            # wave driver.
            return fn(q, pk)
        key = ("block", backend, q.shape, str(q.dtype), self.capacity, self.spec)
        batch_axis = None
        if backend == "sharded":
            batch_axis = self._batch_axis_for(q.shape[0])
            key = key + (id(self._mesh), self._db_axis, batch_axis)
        fn = self._cache.get(
            key, lambda: self._build_block_fn(backend, pk, batch_axis)
        )
        backends.DISPATCH_COUNTS.inc(backend)
        return fn(q, *pk.operands())

    def _build_host_searcher(self) -> hosttierlib.HostTierSearcher:
        pk = self._packed
        return hosttierlib.HostTierSearcher(
            self.spec,
            k_scan=packedlib.scan_k_for(self.spec, pk.n, live=self.size),
            segment_rows=self.spec.segment_rows
            or self.kernel_plan.segment_rows,
        )

    def _search_loop(self, queries: jnp.ndarray) -> SearchResult:
        """Per-block Python loop: one dispatch per tile.

        Kept as the parity oracle for the streaming executor and as the
        benchmark's dispatch-overhead baseline (``spec.stream=False``).
        """
        m = queries.shape[0]
        qb = self.spec.query_block
        m_pad = round_up(m, qb)
        padded = jnp.pad(queries, ((0, m_pad - m), (0, 0)))
        vals, idxs = [], []
        for start in range(0, m_pad, qb):
            v, i = self._search_block(padded[start : start + qb])
            vals.append(v)
            idxs.append(i)
        # stack, not concatenate: on multi-device meshes, concatenating
        # shard_map outputs (check_rep disabled) makes the partitioner
        # treat them as unreduced over the db axis and psum — silently
        # scaling results by the shard count.  stack keeps the replicas.
        k = vals[0].shape[-1]
        return SearchResult(
            jnp.stack(vals).reshape(m_pad, k)[:m],
            jnp.stack(idxs).reshape(m_pad, k)[:m],
        )

    def _search_stream(self, queries: jnp.ndarray) -> SearchResult:
        """Single-program streaming executor: the whole multi-block batch
        is ONE compiled dispatch (``lax.map`` over (B, query_block, D))."""
        backend = self._resolve_backend()
        pk = self.pack()
        m, d = queries.shape
        qb = self.spec.query_block
        num_blocks = -(-m // qb)
        m_pad = num_blocks * qb
        blocks = jnp.pad(queries, ((0, m_pad - m), (0, 0))).reshape(
            num_blocks, qb, d
        )
        key = (
            "stream", backend, blocks.shape, str(blocks.dtype),
            self.capacity, self.spec,
        )
        batch_axis = None
        if backend == "sharded":
            batch_axis = self._batch_axis_for(qb)
            key = key + (id(self._mesh), self._db_axis, batch_axis)
        fn = self._cache.get(
            key, lambda: self._build_stream_fn(backend, pk, batch_axis)
        )
        backends.DISPATCH_COUNTS.inc(backend)
        vals, idxs = fn(blocks, *pk.operands())
        k = vals.shape[-1]
        return SearchResult(
            vals.reshape(m_pad, k)[:m], idxs.reshape(m_pad, k)[:m]
        )

    def _build_block_fn(self, backend, pk, batch_axis=None):
        """(q_block, *packed_operands) -> (values, indices) callable.

        Closes only over static config (spec fields, packed layout
        constants); the packed arrays — ``PackedState.operands()``: (db,
        bias) for the f32 tier, plus (scale, rescore_db, rescore_bias) for
        quantized tiers — are passed as operands so bias/row/scale patches
        never invalidate the compiled program.
        """
        spec = self.spec
        quantized = spec.storage != "f32"
        clustered = pk.cluster is not None
        if clustered:
            # Statics come from the plan the tables were BUILT with (the
            # live ``pk.cluster``), never ``kernel_plan.cluster``: after
            # growth the re-derived plan may disagree with the carried
            # tables until the lazy recluster fires.
            cplan = pk.cluster.plan
            probes, target_scan = cplan.probes, cplan.target_scan
        if backend in ("xla", "pallas") and clustered:
            trace_as = backend
            if not quantized:
                def fn(q, db, bias, ce, cb, cr, sr):
                    return backends.cluster_search(
                        q, db, bias, ce, cb, cr, sr,
                        metric=spec.metric, k=spec.k, probes=probes,
                        target_scan=target_scan,
                        aggregate_to_topk=spec.aggregate_to_topk,
                        use_bitonic=spec.use_bitonic, trace_as=trace_as,
                    )
                return fn
            k_scan = packedlib.scan_k_for(spec, pk.n, live=self.size)
            def fn(q, db, bias, scale, rs_db, rs_bias, ce, cb, cr, sr):
                return backends.cluster_search_quant(
                    q, db, bias, scale, rs_db, rs_bias, ce, cb, cr, sr,
                    metric=spec.metric, k=spec.k, k_scan=k_scan,
                    probes=probes, target_scan=target_scan,
                    aggregate_to_topk=spec.aggregate_to_topk,
                    use_bitonic=spec.use_bitonic, trace_as=trace_as,
                )
            return fn
        if backend == "xla":
            if not quantized:
                def fn(q, db, bias):
                    return backends.dense_search(
                        q, db, bias,
                        metric=spec.metric, k=spec.k,
                        recall_target=spec.recall_target,
                        reduction_input_size_override=
                            spec.reduction_input_size_override,
                        aggregate_to_topk=spec.aggregate_to_topk,
                        use_bitonic=spec.use_bitonic,
                    )
                return fn
            k_scan = packedlib.scan_k_for(spec, pk.n, live=self.size)
            def fn(q, db, bias, scale, rs_db, rs_bias):
                return backends.dense_search_quant(
                    q, db, bias, scale, rs_db, rs_bias,
                    metric=spec.metric, k=spec.k, k_scan=k_scan,
                    recall_target=spec.recall_target,
                    reduction_input_size_override=
                        spec.reduction_input_size_override,
                    aggregate_to_topk=spec.aggregate_to_topk,
                    use_bitonic=spec.use_bitonic,
                )
            return fn
        if backend == "pallas":
            interpret = self._interpret
            if interpret is None:
                interpret = jax.default_backend() != "tpu"
            n, bin_size, block_n = pk.n, pk.bin_size, pk.block_n
            fused = spec.fused_select_enabled
            int4_packed = spec.storage == "int4"
            if not quantized:
                def fn(q, db, bias):
                    return backends.pallas_search_packed(
                        q, db, bias,
                        metric=spec.metric, k=spec.k, n=n,
                        bin_size=bin_size, block_m=spec.block_m,
                        block_n=block_n, interpret=interpret,
                        aggregate_to_topk=spec.aggregate_to_topk,
                        use_bitonic=spec.use_bitonic,
                        fused_select=fused,
                    )
                return fn
            k_scan = packedlib.scan_k_for(spec, pk.n, live=self.size)
            def fn(q, db, bias, scale, rs_db, rs_bias):
                return backends.pallas_search_packed_quant(
                    q, db, bias, scale, rs_db, rs_bias,
                    metric=spec.metric, k=spec.k, k_scan=k_scan, n=n,
                    bin_size=bin_size, block_m=spec.block_m,
                    block_n=block_n, interpret=interpret,
                    aggregate_to_topk=spec.aggregate_to_topk,
                    use_bitonic=spec.use_bitonic,
                    fused_select=fused, int4_packed=int4_packed,
                )
            return fn
        if backend == "sharded":
            mesh, db_axis = self._mesh, self._db_axis
            searcher = backends.make_sharded_search_fn(
                mesh, metric=spec.metric, k=spec.k,
                recall_target=spec.recall_target,
                db_axis=db_axis, batch_axis=batch_axis,
                use_bitonic=spec.use_bitonic,
                k_scan=packedlib.scan_k_for(spec, pk.n, live=self.size)
                if quantized else None,
                cluster_probes=probes if clustered else None,
                cluster_target_scan=target_scan if clustered else None,
            )
            jitted = jax.jit(searcher)
            qsharding = NamedSharding(mesh, P(batch_axis, None))
            if clustered and not quantized:
                # The searcher signature puts the quant operands before the
                # cluster tables, so the f32 clustered operand tuple
                # (db, bias, cents, cbias, crows, srows) must skip them
                # explicitly or the tables would bind to scale/rescore.
                def fn(q, db, bias, ce, cb, cr, sr):
                    return jitted(jax.device_put(q, qsharding),
                                  db, bias, None, None, None,
                                  ce, cb, cr, sr)
                return fn
            def fn(q, *ops):
                return jitted(jax.device_put(q, qsharding), *ops)
            return fn
        raise ValueError(f"unknown backend {backend!r}")

    def _build_stream_fn(self, backend, pk, batch_axis=None):
        """(blocks (B, qb, D), db, bias) -> ((B, qb, k), (B, qb, k)).

        ``lax.map`` streams the blocks through one compiled program; the
        query buffer is donated on accelerators (it is dead after the
        dispatch), never the shared db/bias operands.
        """
        if backend == "sharded":
            mesh, spec = self._mesh, self.spec
            clustered = pk.cluster is not None
            cplan = pk.cluster.plan if clustered else None
            searcher = backends.make_sharded_search_fn(
                mesh, metric=spec.metric, k=spec.k,
                recall_target=spec.recall_target,
                db_axis=self._db_axis, batch_axis=batch_axis,
                use_bitonic=spec.use_bitonic,
                k_scan=packedlib.scan_k_for(spec, pk.n, live=self.size)
                if spec.storage != "f32" else None,
                cluster_probes=cplan.probes if clustered else None,
                cluster_target_scan=cplan.target_scan
                if clustered else None,
            )
            if clustered and spec.storage == "f32":
                # Same positional-binding hazard as the block fn: the f32
                # clustered operand tuple must skip the quant slots.
                def call(q, ops):
                    db, bias, ce, cb, cr, sr = ops
                    return searcher(q, db, bias, None, None, None,
                                    ce, cb, cr, sr)
            else:
                def call(q, ops):
                    return searcher(q, *ops)
            stream = jax.jit(
                lambda blocks, *ops: jax.lax.map(
                    lambda q: call(q, ops), blocks
                )
            )
            qsharding = NamedSharding(mesh, P(None, batch_axis, None))
            def fn(blocks, *ops):
                return stream(jax.device_put(blocks, qsharding), *ops)
            return fn
        block_fn = self._build_block_fn(backend, pk)
        donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
        return jax.jit(
            lambda blocks, *ops: jax.lax.map(
                lambda q: block_fn(q, *ops), blocks
            ),
            donate_argnums=donate,
        )

    # -- updates (the paper's frequent-update path) --------------------------

    def add(self, rows: jnp.ndarray) -> "Index":
        """Append rows; grows capacity in ``capacity_block`` steps.

        No index rebuild: only the appended slice is metric-prepared
        (``Metric.prepare_update``) and patched into the packed state;
        growth re-lays-out the packed operands (one device copy) without
        re-deriving the metric precompute of existing rows.
        """
        faultslib.fire("index.add")  # before any state changes: add is
        # all-or-nothing under injection, so a failed extend never leaves
        # a half-patched packed state behind.
        rows = jnp.atleast_2d(jnp.asarray(rows))
        if rows.shape[1] != self.dim:
            raise ValueError(f"row dim {rows.shape[1]} != index dim {self.dim}")
        r = rows.shape[0]
        required = self._size + r
        had_packed = self._packed is not None
        rowwise = self.metric.rowwise
        if not rowwise:
            # Coupled preparation (e.g. a learned rotation refit): the
            # incremental patches below are undefined, so drop the state
            # now — also skips the pointless growth relayout copy.
            self._packed = None
        if required > self.capacity:
            # Linear growth in capacity_block steps, not doubling: spare
            # capacity is tombstone-masked but still *scored* on every
            # search, so over-allocation costs FLOPs, not just memory.
            block = self._capacity_block
            if self._mesh is not None:
                block = math.lcm(block, self._num_db_shards())
            if self.spec.residency == "host" and self.spec.segment_rows:
                # Capacity stays a whole number of segment waves, so the
                # compiled wave program's shape never changes under growth.
                block = math.lcm(block, self.spec.segment_rows)
            new_cap = round_up(required, block)
            grow = new_cap - self.capacity
            self._db = jnp.pad(self._db, ((0, grow), (0, 0)))
            self._live = jnp.pad(self._live, (0, grow))
            if self._packed is not None:
                self._packed = self._packed.relayout(
                    self._packed.backend, new_cap, self.spec
                )
            if self._kernel_plan is not None:
                # Same pinned tiles, re-planned bins/prediction for the
                # grown row space (mirrors the packed relayout).
                p = self._kernel_plan
                self._kernel_plan = dataclasses.replace(
                    self._replan(n=new_cap, m=p.m or None,
                                 backend=p.backend, pin_from=p),
                    source=p.source,
                )
            if self._mesh is not None:
                self._reshard()
        self._db = self._db.at[self._size : required].set(
            rows.astype(self._db.dtype)
        )
        self._live = self._live.at[self._size : required].set(True)
        if self._packed is not None:
            self._packed.update_rows(self._size, rows, self.metric)
        self._size = required
        self._num_live = self._num_live + r
        if had_packed and self._packed is None:
            self.pack()  # full repack — still at add() time, never at search
        pk = self._packed
        if pk is not None and pk.cluster is not None \
                and pk.cluster.needs_recluster:
            # Lazy recluster: incremental assignment spilled past the
            # planner's imbalance threshold, so rebuild the coarse
            # quantizer for the *current* capacity — at add() time, never
            # at search.  Same capacity => same table shapes => the
            # compiled programs stay valid (zero retraces in steady state).
            cplan = planlib.plan_clusters(
                n=self.capacity,
                k_scan=packedlib.scan_k_for(self.spec, self.capacity),
                recall_target=self.spec.recall_target,
            )
            if cplan.enabled:
                packedlib.rebuild_cluster(pk, self._live, self.metric, cplan)
                self._place_packed()
        return self

    def delete(self, ids) -> "Index":
        """Tombstone rows by index: masked out via the packed bias row.

        Deleted slots are not reclaimed (append-only storage); their ids
        never appear in subsequent search results.  Pure device-side
        patches — no host sync, so a serving loop's dispatch pipeline is
        never blocked (the live count materializes lazily via ``size``).
        """
        faultslib.fire("index.delete")  # before any patch: all-or-nothing
        ids = jnp.atleast_1d(jnp.asarray(ids, jnp.int32))
        self._live = self._live.at[ids].set(False)
        # Recount rather than decrement: ids may repeat (within a call or
        # across calls) and a gather-then-sum would count those twice.
        # Kept as a device scalar; ``size`` turns it into an int on read.
        self._num_live = jnp.sum(self._live)
        if self._packed is not None:
            self._packed.delete_rows(ids)
        return self

    # -- crash-safe snapshots ------------------------------------------------

    def save(self, path: str) -> str:
        """Write a crash-safe snapshot directory; returns the committed path.

        Serializes the raw database + live mask AND the packed search
        state — prepared rows, fused bias, quant scale/rescore tails,
        cluster side tables — via ``repro.checkpoint.save_snapshot``
        (tmp-dir + fsync + atomic-rename commit; an existing snapshot at
        ``path`` is replaced atomically, and a crash mid-save always
        leaves a loadable snapshot behind).  :meth:`restore` therefore
        re-runs *nothing*: no metric preparation, no quantization, no
        k-means — and returns bit-identical search results.

        Meshed (sharded) indexes save their full logical arrays; restore
        always lands unmeshed — call ``.shard(mesh)`` on the restored
        index before searching a ``backend="sharded"`` spec.
        """
        from repro.checkpoint.checkpoint import save_snapshot

        faultslib.fire("index.save")
        telemetrylib.registry().inc("repro_snapshot_saves_total")
        pk = self.pack()
        arrays, pk_meta = packedlib.snapshot_state(pk)
        arrays["db"] = self._db
        arrays["live"] = self._live
        meta = {
            "format": SNAPSHOT_FORMAT,
            "version": SNAPSHOT_VERSION,
            "spec": self.spec.to_json_dict(),
            "size": self._size,
            "num_live": self.size,  # materializes the lazy device scalar
            "capacity_block": self._capacity_block,
            "packed": pk_meta,
        }
        return save_snapshot(path, arrays, meta)

    @classmethod
    def restore(cls, path: str) -> "Index":
        """Load a snapshot written by :meth:`save` — no build work re-run.

        >>> import tempfile, os, jax.numpy as jnp
        >>> idx = Index.build(jnp.eye(32), metric="mips", k=2)
        >>> with tempfile.TemporaryDirectory() as d:
        ...     _ = idx.save(os.path.join(d, "snap"))
        ...     r = Index.restore(os.path.join(d, "snap"))
        >>> r.size == idx.size
        True
        """
        from repro.checkpoint.checkpoint import load_snapshot

        meta, arrays = load_snapshot(path)
        if meta.get("format") != SNAPSHOT_FORMAT:
            raise ValueError(
                f"{path} is not an index snapshot "
                f"(format={meta.get('format')!r})"
            )
        if int(meta.get("version", 0)) > SNAPSHOT_VERSION:
            raise ValueError(
                f"snapshot version {meta['version']} is newer than this "
                f"code's {SNAPSHOT_VERSION} — upgrade to restore it"
            )
        spec = SearchSpec.from_json_dict(meta["spec"])
        index = cls(
            spec,
            jnp.asarray(arrays["db"]),
            jnp.asarray(arrays["live"]),
            size=int(meta["size"]),
            num_live=int(meta["num_live"]),
            capacity_block=int(meta["capacity_block"]),
        )
        index._packed = packedlib.restore_state(arrays, meta["packed"], spec)
        index._place_packed()  # host-resident specs re-pin to host RAM
        telemetrylib.registry().inc("repro_snapshot_restores_total")
        return index

    # -- sharding ------------------------------------------------------------

    def shard(
        self,
        mesh: Mesh,
        *,
        db_axis="model",
        batch_axis: Optional[str] = None,
    ) -> "Index":
        """Return a mesh-sharded copy: rows P(db_axis, None), queries
        optionally sharded over ``batch_axis``.

        ``db_axis`` may be one mesh axis name or a *tuple* of names — the
        tuple form folds a pod-shaped (multi-host-shaped) mesh into one
        logical database split over the product of those axes; pairing it
        (or a single db axis) with ``batch_axis`` gives 2-D query x
        database sharding.  Capacity is padded (with tombstoned rows) to
        a multiple of the shard count; recall accounting against the
        global N is handled by the sharded backend internally.  The
        packed layout — including the metric precompute — is carried over
        (``relayout``), not rebuilt.
        """
        if self.spec.residency != "hbm":
            raise ValueError(
                "host-resident indexes cannot be sharded — the cold tier "
                "streams segments through a single device's HBM; rebuild "
                "with residency='hbm' first"
            )
        n_shards = backends.db_shard_count(mesh, db_axis)
        cap = round_up(self.capacity, n_shards)
        db, live = self._db, self._live
        if cap > self.capacity:
            db = jnp.pad(db, ((0, cap - self.capacity), (0, 0)))
            live = jnp.pad(live, (0, cap - self.capacity))
        sharded_plan = None
        if self._kernel_plan is not None:
            # Same tiles (the packed layout carries over); re-evaluate the
            # prediction for the sharded backend and global capacity.
            p = self._kernel_plan
            sharded_plan = dataclasses.replace(
                self._replan(n=cap, m=p.m or None, backend="sharded",
                             pin_from=p, db_shards=n_shards),
                source=p.source,
            )
        out = Index(
            self.spec.with_backend("sharded"), db, live,
            size=self._size, num_live=self._num_live,
            capacity_block=self._capacity_block,
            mesh=mesh, db_axis=db_axis, batch_axis=batch_axis,
            interpret=self._interpret, kernel_plan=sharded_plan,
        )
        if self._packed is not None:
            out._packed = self._packed.relayout("sharded", cap, out.spec)
        out._reshard()
        out.pack()
        return out

    def _reshard(self):
        assert self._mesh is not None
        self._db = jax.device_put(
            self._db, NamedSharding(self._mesh, P(self._db_axis, None))
        )
        self._live = jax.device_put(
            self._live, NamedSharding(self._mesh, P(self._db_axis))
        )
        self._place_packed()
