"""repro.search — the unified front door for TPU-KNN search.

One API over every backend (paper Listings 1/2, Alg. 2, §7):

    from repro.search import Index

    index = Index.build(db, metric="l2", k=10, recall_target=0.95)
    values, indices = index.search(queries)      # auto backend, one dispatch
    index.add(new_rows).delete(stale_ids)        # index-free updates
    sharded = index.shard(mesh, db_axis="model") # distributed search

Backends: "auto" | "xla" | "pallas" | "sharded" (``SearchSpec.backend``).
Metrics: "mips" | "l2" | "cosine", extensible via ``register_metric``; the
value/sign contract lives in ``repro.search.metrics``.
Storage tiers: "f32" | "bf16" | "int8" (``SearchSpec.storage``,
``repro.search.quant``) — quantized tiers store the packed database at 2
or 1 bytes/element, scan it at reduced precision with an over-fetched
candidate budget (``scan_k``), and exactly rescore the winners against a
full-precision tail, cutting database HBM traffic 2-4x (Eq. 10/20) while
keeping the Eq. 13-14 recall guarantee; "f32" is bit-identical to the
pre-tier path.
Cluster pruning: ``SearchSpec.cluster`` = "auto" | "off"
(``repro.search.cluster``) — above the planner's cost crossover the index
builds a k-means coarse quantizer and each query scans only its top-rho
clusters plus an always-scanned spill block, then reduces the gathered
rows exactly.  Every parameter (C, rho, capacities, the scan budget) is
derived by ``repro.search.plan.plan_clusters`` from (N, k, recall_target)
— there are no user knobs — and the recall guarantee becomes the product
P(no bin collision) x P(no cluster miss), both reported by
``Index.explain()``.  Below the crossover (small N) "auto" builds nothing
and is bit-identical to "off".

Stage pipeline (``repro.search.stages``): every backend is an assembly of
the same scan → rescore → gather stage primitives — ``score_rows``,
``scan_candidates``, ``rescore_candidates``, ``prune_candidates``,
``merge_topk``, ``finalize_values`` (+ ``pad_queries_to`` for lane
padding) — which is what makes layouts bit-comparable: replicated, 1-D /
2-D sharded and host-tiered searches run identical per-row math and
differ only in where rows live.  2-D (query x database) sharding:
``index.shard(mesh, db_axis=("data", "model"), batch_axis=...)`` folds
several mesh axes into one logical database split (pod-shaped meshes,
``normalize_db_axes`` / ``db_shard_count``); only O(k) (value, global id)
winners per shard cross the ICI, which ``Index.explain()`` prices.  Host
cold tier: ``Index.build(..., residency="host")`` keeps packed operands
in host RAM and streams planner-sized segment waves (``plan_segments``,
``SEGMENT_ALIGN``-row aligned) through device HBM via
``repro.search.hosttier`` (``HostTierSearcher`` driving ``wave_program``),
double-buffered one wave ahead — N bounded by host memory, one dispatch
per wave, zero retraces in steady state.

Kernel planning (``repro.search.plan``): every tile size and the bin count
are derived analytically from the paper's performance model (Eq. 4–10) and
recall guarantee (Eq. 13–14) — ``Index.build(plan="model")`` is the default;
``plan="measure"`` refines with a short on-device sweep; ``Index.explain()``
reports the plan and its predicted (vs measured) roofline position.  See
``docs/performance_model.md`` for the equation-to-code map.

Packed search state (the performance-model contract, Eq. 10)
------------------------------------------------------------

``Index`` holds a device-resident ``PackedState`` (``repro.search.packed``):
the metric-prepared database in the backend's native padded layout, plus
one fused bias row carrying the metric bias, tombstone mask, and tail mask.
It is built at ``Index.build`` / ``Index.shard`` time — never during a
search — so the steady-state dispatch touches the (N, D) database exactly
once and pads only the (M, D) query block.  Invalidation rules:

  * ``add(rows)``     — patches the appended slice only; the metric
    precompute runs on the new rows alone (``Metric.prepare_update``).
    Capacity growth re-lays-out the packed arrays (one device copy) but
    never re-prepares existing rows.  Non-row-wise metrics
    (``Metric.rowwise=False``) force a full repack, still at add() time.
  * ``delete(ids)``   — patches only the bias row (O(|ids|)); no host
    sync: the live count stays a lazy device scalar until ``size`` reads.
  * ``shard(mesh)``   — relayouts (copies) the packed operands onto the
    mesh; the metric precompute carries over.
  * a different resolved backend under ``backend="auto"`` — full repack
    on the next ``pack()``.

Multi-block query batches (M > ``SearchSpec.query_block``) execute as one
compiled streaming program (``lax.map``) — a single device dispatch —
unless ``SearchSpec(stream=False)`` selects the per-block loop baseline.

Concurrent serving (``repro.search.serve``): ``SearchServer`` coalesces
many small concurrent requests into planner-sized micro-batches (padded to
a fixed bucket ladder so nothing retraces), dispatches each coalesced
batch once over the packed/streamed path, and scatters per-request slices
back — with admission backpressure, a deterministic virtual-clock mode for
tests, and double-buffered host→device query staging.  Fault tolerance
(``repro.search.faults``, ``docs/operations.md``): per-request deadlines
(``DeadlineExceeded`` — expired tickets are never dispatched), bounded
retry-with-backoff for transient dispatch faults, a worker watchdog that
restarts a dead worker without dropping queued tickets, sustained-overload
shedding (``Overloaded`` with a ``retry_after_s`` estimate), and
``SearchServer.health()`` — all driveable deterministically through the
seeded ``FaultInjector`` (``TransientFault`` / ``FatalFault`` /
``WorkerDeath`` at the named ``INJECTION_POINTS``).

Unified telemetry (``repro.search.telemetry``): one process-global
metrics registry (counters / gauges / windowed p50-p99 histograms,
labeled by backend/storage/cluster/bucket) absorbs the four legacy
counter dicts and exports Prometheus text or a JSON snapshot
(``export_prometheus`` / ``export_json``, ``Index.telemetry()``,
``scripts/telemetry_dump.py``); every served request carries a
ticket-scoped stage trace (``SearchServer.traces``, Chrome-trace JSON
via ``chrome_trace``); and a per-bucket roofline-drift monitor checks
each dispatch's measured wall against the plan's Eq. 10/20 prediction,
degrading ``SearchServer.health()`` when the calibrated ratio leaves
its band.  ``telemetry.reset_all()`` zeroes everything in one call.

Crash-safe snapshots: ``Index.save(path)`` / ``Index.restore(path)``
persist the packed state, cluster tables and quantization artifacts
through ``repro.checkpoint``'s atomic-rename commit (``SNAPSHOT_FORMAT`` /
``SNAPSHOT_VERSION`` stamped) — a restored replica serves bit-identical
results without re-running build/k-means/quantization.

``repro.core.knn``, ``repro.kernels.ops`` and ``repro.core.distributed``
remain as deprecated thin shims over this package.
"""
from repro.core.binning import (  # re-export: planning is part of the API
    BinPlan,
    bins_for_recall,
    expected_recall,
    plan_bins,
)
from repro.core.rescoring import exact_rescoring
from repro.core.topk import approx_max_k, approx_min_k
from repro.search.backends import (
    DISPATCH_COUNTS,
    MASK_VALUE,
    TRACE_COUNTS,
    CompileCache,
    cluster_search,
    cluster_search_quant,
    db_shard_count,
    default_backend,
    dense_search,
    dense_search_quant,
    make_sharded_search_fn,
    normalize_db_axes,
    pallas_search,
    pallas_search_packed,
    pallas_search_packed_quant,
    reset_dispatch_counts,
    reset_trace_counts,
)
from repro.search.hosttier import HostTierSearcher, wave_program
from repro.search.stages import (
    finalize_values,
    merge_topk,
    pad_queries_to,
    prune_candidates,
    rescore_candidates,
    scan_candidates,
    score_rows,
)
from repro.search.functional import (
    cosine_nns,
    exact_cosine_nns,
    exact_l2nns,
    exact_mips,
    exact_search,
    half_norms,
    l2nns,
    mips,
    search,
)
from repro.search.cluster import ClusterPlan, ClusterState, query_miss_rate
from repro.search.faults import (
    INJECTION_POINTS,
    DelayFault,
    FatalFault,
    FaultInjector,
    InjectedFault,
    TransientFault,
    WorkerDeath,
)
from repro.search.index import (
    SNAPSHOT_FORMAT,
    SNAPSHOT_VERSION,
    Index,
    SearchResult,
)
from repro.search.metrics import (
    Metric,
    available_metrics,
    get_metric,
    register_metric,
)
from repro.search.packed import (
    PACK_EVENTS,
    PackedState,
    fuse_bias,
    pack_state,
    reset_pack_events,
    restore_state,
    snapshot_state,
)
from repro.search.quant import (
    STORAGE_TIERS,
    QuantizedRows,
    dequantize_rows,
    pack_int4_rows,
    quantize_rows,
    scan_k,
    storage_bytes,
    storage_dtype,
    unpack_int4_rows,
    validate_restored,
)
from repro.search.plan import (
    SEGMENT_ALIGN,
    Plan,
    PlanCache,
    detect_device,
    hlo_check,
    plan_buckets,
    plan_clusters,
    plan_search,
    plan_segments,
    tune_plan,
)
from repro.search.serve import (
    SERVE_EVENTS,
    DeadlineExceeded,
    Overloaded,
    QueueFull,
    SearchServer,
    SearchTicket,
    ServeConfig,
    VirtualClock,
    reset_serve_events,
)
from repro.search.spec import BACKENDS, SearchSpec
from repro.search.telemetry import (
    AtomicCounter,
    DriftMonitor,
    MetricsRegistry,
    RequestTrace,
    Span,
    chrome_trace,
    export_json,
    export_prometheus,
    registry,
    reset_all,
    trace_coverage,
)

__all__ = [
    # front door
    "Index",
    "SearchResult",
    "SearchSpec",
    "BACKENDS",
    "search",
    # metric registry
    "Metric",
    "register_metric",
    "get_metric",
    "available_metrics",
    # functional + exact baselines
    "mips",
    "l2nns",
    "cosine_nns",
    "half_norms",
    "exact_mips",
    "exact_l2nns",
    "exact_cosine_nns",
    "exact_search",
    # backends
    "default_backend",
    "dense_search",
    "pallas_search",
    "pallas_search_packed",
    "make_sharded_search_fn",
    "normalize_db_axes",
    "db_shard_count",
    "CompileCache",
    "MASK_VALUE",
    # stage primitives (repro.search.stages) — what backends compose
    "score_rows",
    "scan_candidates",
    "rescore_candidates",
    "prune_candidates",
    "merge_topk",
    "finalize_values",
    "pad_queries_to",
    # host-RAM cold tier (repro.search.hosttier)
    "HostTierSearcher",
    "wave_program",
    # packed state
    "PackedState",
    "pack_state",
    "fuse_bias",
    # quantized storage tiers (repro.search.quant)
    "STORAGE_TIERS",
    "QuantizedRows",
    "quantize_rows",
    "dequantize_rows",
    "pack_int4_rows",
    "unpack_int4_rows",
    "storage_bytes",
    "storage_dtype",
    "scan_k",
    "dense_search_quant",
    "pallas_search_packed_quant",
    # cluster-pruned scan front-end (repro.search.cluster)
    "ClusterPlan",
    "ClusterState",
    "plan_clusters",
    "cluster_search",
    "cluster_search_quant",
    # kernel planner (the performance model as a subsystem)
    "Plan",
    "plan_search",
    "plan_buckets",
    "plan_segments",
    "SEGMENT_ALIGN",
    "tune_plan",
    "PlanCache",
    "detect_device",
    "hlo_check",
    # concurrent serving (async micro-batching front end)
    "SearchServer",
    "SearchTicket",
    "ServeConfig",
    "VirtualClock",
    "QueueFull",
    "Overloaded",
    "DeadlineExceeded",
    # fault injection (repro.search.faults)
    "FaultInjector",
    "InjectedFault",
    "TransientFault",
    "FatalFault",
    "WorkerDeath",
    "DelayFault",
    "INJECTION_POINTS",
    # crash-safe snapshots
    "SNAPSHOT_FORMAT",
    "SNAPSHOT_VERSION",
    "snapshot_state",
    "restore_state",
    "validate_restored",
    "query_miss_rate",
    # observability (repro.search.telemetry is the unified layer)
    "TRACE_COUNTS",
    "DISPATCH_COUNTS",
    "PACK_EVENTS",
    "SERVE_EVENTS",
    "reset_trace_counts",
    "reset_dispatch_counts",
    "reset_pack_events",
    "reset_serve_events",
    "MetricsRegistry",
    "AtomicCounter",
    "DriftMonitor",
    "RequestTrace",
    "Span",
    "registry",
    "export_prometheus",
    "export_json",
    "chrome_trace",
    "trace_coverage",
    "reset_all",
    # planning / operator re-exports
    "BinPlan",
    "plan_bins",
    "bins_for_recall",
    "expected_recall",
    "approx_max_k",
    "approx_min_k",
    "exact_rescoring",
]
