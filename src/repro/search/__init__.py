"""repro.search — the unified front door for TPU-KNN search.

One API over every backend (paper Listings 1/2, Alg. 2, §7):

    from repro.search import Index

    index = Index.build(db, metric="l2", k=10, recall_target=0.95)
    values, indices = index.search(queries)      # auto backend, auto-tiled
    index.add(new_rows).delete([3, 17])          # index-free updates
    sharded = index.shard(mesh, db_axis="model") # distributed search

Backends: "auto" | "xla" | "pallas" | "sharded" (``SearchSpec.backend``).
Metrics: "mips" | "l2" | "cosine", extensible via ``register_metric``; the
value/sign contract lives in ``repro.search.metrics``.

``repro.core.knn``, ``repro.kernels.ops`` and ``repro.core.distributed``
remain as deprecated thin shims over this package.
"""
from repro.core.binning import (  # re-export: planning is part of the API
    BinPlan,
    bins_for_recall,
    expected_recall,
    plan_bins,
)
from repro.core.rescoring import exact_rescoring
from repro.core.topk import approx_max_k, approx_min_k
from repro.search.backends import (
    MASK_VALUE,
    CompileCache,
    default_backend,
    dense_search,
    make_sharded_search_fn,
    pallas_search,
)
from repro.search.functional import (
    cosine_nns,
    exact_cosine_nns,
    exact_l2nns,
    exact_mips,
    exact_search,
    half_norms,
    l2nns,
    mips,
    search,
)
from repro.search.index import Index, SearchResult
from repro.search.metrics import (
    Metric,
    available_metrics,
    get_metric,
    register_metric,
)
from repro.search.spec import BACKENDS, SearchSpec

__all__ = [
    # front door
    "Index",
    "SearchResult",
    "SearchSpec",
    "BACKENDS",
    "search",
    # metric registry
    "Metric",
    "register_metric",
    "get_metric",
    "available_metrics",
    # functional + exact baselines
    "mips",
    "l2nns",
    "cosine_nns",
    "half_norms",
    "exact_mips",
    "exact_l2nns",
    "exact_cosine_nns",
    "exact_search",
    # backends
    "default_backend",
    "dense_search",
    "pallas_search",
    "make_sharded_search_fn",
    "CompileCache",
    "MASK_VALUE",
    # planning / operator re-exports
    "BinPlan",
    "plan_bins",
    "bins_for_recall",
    "expected_recall",
    "approx_max_k",
    "approx_min_k",
    "exact_rescoring",
]
