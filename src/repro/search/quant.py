"""Quantized storage tiers: bf16/int8 packed databases with exact rescoring.

The paper's Eq. 10 memory-wall analysis makes per-search cost proportional
to the bytes streamed for the (N, D) database — which means bytes-per-row
directly sets where the roofline knee lands.  This module owns the
``storage`` tier of the search stack:

  * ``"f32"``  — 4 bytes/element, today's exact path (the default; packed
    state, kernels and planner behave bit-identically to before this
    subsystem existed).
  * ``"bf16"`` — 2 bytes/element.  The scan matmul consumes the bf16 rows
    directly (f32 accumulation), halving database HBM traffic.
  * ``"int8"`` — 1 byte/element with a per-row symmetric scale
    (``row ≈ scale * int8``), quartering database HBM traffic.
  * ``"int4"`` — 0.5 bytes/element with a per-row symmetric scale
    (``row ≈ scale * int4``, codes in [-7, 7]).  The *canonical* stored
    form everywhere above the kernel is one int8 code per element (so the
    XLA reference paths, cluster gathers and snapshots stay byte-wise and
    backend-agnostic); the Pallas layout packs two codes per byte
    (:func:`pack_int4_rows`) and the scan kernel unpacks the nibbles in
    VMEM, so the 8x HBM-traffic drop is realized where the memory wall
    actually is.

Quantized tiers run a **two-pass search** mirroring the paper's
score/rescore split: PartialReduce scans the quantized database over all N
rows to produce an *over-fetched* candidate set (see :func:`scan_k`), then
``core.rescoring`` re-scores only those candidates against a full-precision
rescore tail — O(M·L·D) exact work, within Eq. 10's O(min(M, N)) budget.

Over-fetch derivation (why the Eq. 13–14 guarantee survives quantization)
-------------------------------------------------------------------------

With exact scores, a true top-K entry is lost only when a *better* top-K
entry shares its bin — the ball-in-bins argument behind
``E[recall] = ((L-1)/L)^(K-1)`` (Eq. 13).  With quantized scan scores, a
top-K entry can additionally lose its bin to a truly-worse row that
quantization *promotes* past it; that requires the rival's true score to
lie within the quantization band ``2·eps`` of the entry's.  Budget at most
``T`` such in-band rivals per top-K entry and treat each, conservatively,
exactly like a truly-better entry in the bin argument: the scan's candidate
set then contains the true top-K with

    E[recall_scan] >= ((L-1)/L)^(K+T-1)

so planning the bins for an **effective K' = K + T at the original recall
target** (and rescoring the L winners exactly) preserves the guarantee in
expectation.  The per-tier confusion budgets

    T(bf16) = ceil(K/2)        T(int8) = K        T(int4) = 2K

follow from the tiers' relative score-error bounds (bf16 keeps an 8-bit
mantissa, relative error ~2^-8; per-row symmetric int8 bounds the per-entry
error at ``scale/2`` with ``scale = max|row|/127``, a ~0.4 % relative score
error for well-conditioned rows; int4's ``scale = max|row|/7`` widens the
band 16x to a ~7 % relative error, so its in-band rival budget doubles
again) under a bounded near-tie density — they are deliberately
conservative, and ``tests/test_recall_guarantee.py`` validates the
end-to-end recall empirically with a Hoeffding margin.

Nothing here imports the rest of ``repro.search`` — the metric registry,
packed state, planner and backends all build *on* these primitives.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = [
    "STORAGE_TIERS",
    "QuantizedRows",
    "check_metric_storage",
    "dequantize_rows",
    "is_quantized",
    "pack_int4_rows",
    "quantize_rows",
    "scan_k",
    "storage_bytes",
    "storage_dtype",
    "unpack_int4_rows",
    "validate_restored",
]

# The legal ``SearchSpec.storage`` values, in decreasing bytes/element.
STORAGE_TIERS: Tuple[str, ...] = ("f32", "bf16", "int8", "int4")

_BYTES = {"f32": 4, "bf16": 2, "int8": 1, "int4": 0.5}
# Stored container dtype per tier.  int4 codes live in an int8 container:
# unpacked (one code per byte, values in [-7, 7]) in the canonical form,
# two codes per byte in the Pallas layout (pack_int4_rows).
_DTYPES = {
    "f32": jnp.float32,
    "bf16": jnp.bfloat16,
    "int8": jnp.int8,
    "int4": jnp.int8,
}

# Smallest representable per-row scale: keeps all-zero rows quantizing to
# zeros instead of dividing by zero.
_SCALE_FLOOR = 1e-30

_INT8_MAX = 127.0
_INT4_MAX = 7.0

# Tiers that carry a per-row scale table alongside the stored rows.
_SCALED_TIERS = ("int8", "int4")


def is_quantized(storage: str) -> bool:
    """True for tiers that store fewer than 4 bytes per element."""
    return storage_bytes(storage) < 4


def storage_bytes(storage: str) -> float:
    """Bytes per stored database element for a tier.

    Integral for the byte-wise tiers; ``0.5`` for int4, where the Pallas
    layout packs two codes per byte (the XLA reference paths keep one code
    per byte — see :func:`pack_int4_rows`).

    >>> [storage_bytes(s) for s in STORAGE_TIERS]
    [4, 2, 1, 0.5]
    """
    try:
        return _BYTES[storage]
    except KeyError:
        raise ValueError(
            f"unknown storage tier {storage!r}; expected one of "
            f"{STORAGE_TIERS}"
        ) from None


def storage_dtype(storage: str):
    """The jnp dtype rows of a tier are stored in."""
    storage_bytes(storage)  # validate
    return _DTYPES[storage]


def quantize_rows(
    rows: jnp.ndarray, storage: str
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Quantize metric-prepared f32 rows into a tier's stored form.

    Returns ``(stored, scale)`` where ``scale`` is the per-row symmetric
    scale for the scaled tiers (``rows ≈ stored * scale[:, None]``) and
    ``None`` for the others.  Pure per-row math — the property
    ``Index.add`` exploits to quantize only the appended slice.  int4
    returns *unpacked* codes (one int8 per element, values in [-7, 7]) —
    the canonical form; nibble-packing is a Pallas layout concern
    (:func:`pack_int4_rows`).

    >>> import jax.numpy as jnp
    >>> q, s = quantize_rows(jnp.ones((2, 3)), "int8")
    >>> (q.dtype.name, s.shape)
    ('int8', (2,))
    >>> q4, s4 = quantize_rows(jnp.ones((2, 3)), "int4")
    >>> (q4.dtype.name, int(q4.max()), s4.shape)
    ('int8', 7, (2,))
    """
    rows = rows.astype(jnp.float32)
    if storage == "f32":
        return rows, None
    if storage == "bf16":
        return rows.astype(jnp.bfloat16), None
    if storage in _SCALED_TIERS:
        qmax = _INT8_MAX if storage == "int8" else _INT4_MAX
        amax = jnp.max(jnp.abs(rows), axis=-1)
        scale = jnp.maximum(amax / qmax, _SCALE_FLOOR)
        q = jnp.clip(
            jnp.round(rows / scale[:, None]), -qmax, qmax
        ).astype(jnp.int8)
        return q, scale.astype(jnp.float32)
    raise ValueError(
        f"unknown storage tier {storage!r}; expected one of {STORAGE_TIERS}"
    )


def dequantize_rows(
    stored: jnp.ndarray, scale: Optional[jnp.ndarray]
) -> jnp.ndarray:
    """f32 view of stored rows — the values the quantized scan *actually*
    ranks by, used to fold the metric-bias correction into the bias row."""
    rows = stored.astype(jnp.float32)
    if scale is not None:
        rows = rows * scale[:, None]
    return rows


def pack_int4_rows(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack canonical int4 codes (one int8 per element) two-per-byte.

    Column ``2j`` lands in byte ``j``'s low nibble, column ``2j+1`` in its
    high nibble; an odd trailing column is padded with a zero code.  This
    is the on-device layout the Pallas scan kernel streams — half the HBM
    bytes of the canonical form — and :func:`unpack_int4_rows` inverts it
    exactly.

    >>> import jax.numpy as jnp
    >>> codes = jnp.asarray([[-7, 3, 5, -1]], dtype=jnp.int8)
    >>> packed = pack_int4_rows(codes)
    >>> packed.shape
    (1, 2)
    >>> bool((unpack_int4_rows(packed) == codes).all())
    True
    """
    if codes.shape[-1] % 2:
        pad = [(0, 0)] * (codes.ndim - 1) + [(0, 1)]
        codes = jnp.pad(codes, pad)
    lo = codes[..., 0::2].astype(jnp.int32)
    hi = codes[..., 1::2].astype(jnp.int32)
    return ((hi << 4) | (lo & 0xF)).astype(jnp.int8)


def unpack_int4_rows(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_int4_rows`: bytes back to int8 codes.

    The output's trailing dimension is ``2 *`` the packed one; callers
    slice off the odd-``d`` pad column if they need the logical width.
    """
    b = packed.astype(jnp.int32)
    lo = (b << 28) >> 28  # arithmetic shifts sign-extend the low nibble
    hi = b >> 4
    interleaved = jnp.stack([lo, hi], axis=-1)
    return interleaved.reshape(*packed.shape[:-1], -1).astype(jnp.int8)


def scan_k(storage: str, k: int, *, n: Optional[int] = None) -> int:
    """Effective neighbour count the quantized scan plans its bins for.

    Implements the over-fetch derivation in the module docstring:
    ``K' = K + T`` with the tier's confusion budget ``T``.  ``n`` clamps
    the result to the database size (``plan_bins`` requires ``k <= n``).

    >>> scan_k("f32", 10), scan_k("bf16", 10), scan_k("int8", 10)
    (10, 15, 20)
    >>> scan_k("int4", 10)
    30
    """
    if k <= 0:
        raise ValueError(f"k must be positive, got {k}")
    if storage == "bf16":
        k = k + math.ceil(k / 2)
    elif storage == "int8":
        k = 2 * k
    elif storage == "int4":
        k = 3 * k
    else:
        storage_bytes(storage)  # validate the tier name
    if n is not None:
        k = min(k, n)
    return k


def check_metric_storage(metric, storage: str) -> None:
    """Reject unsupported metric × storage combinations, actionably.

    ``metric`` is a ``repro.search.metrics.Metric`` (duck-typed here to
    keep this module import-free).  Metrics declare the tiers their
    prepared rows survive in ``Metric.storage_tiers``; e.g. a raw cosine
    variant whose ``prepare_database`` does *not* normalize rows should
    exclude ``"int8"`` — per-row scales cannot bound its score error, and
    the failure would otherwise surface as a cryptic kernel-level error.
    """
    storage_bytes(storage)  # validate the tier name first
    tiers = getattr(metric, "storage_tiers", STORAGE_TIERS)
    if storage not in tiers:
        raise ValueError(
            f"metric {metric.name!r} does not support storage="
            f"{storage!r} (supported tiers: {tuple(tiers)}).  Either pick "
            "a supported tier, or register the metric with a "
            "quantization-compatible preparation (normalized/bounded rows) "
            "and declare it via Metric(storage_tiers=...)."
        )


def validate_restored(storage: str, db_dtype, has_scale: bool) -> None:
    """Consistency check for a snapshot-restored packed database.

    A snapshot's META names the storage tier and its arrays carry the
    stored rows — if they disagree (truncated write that dodged the
    commit protocol, hand-edited META, version skew) the search kernels
    would fail deep inside a dispatch with a dtype error, or worse,
    silently misinterpret int8 codes.  Fail here instead, actionably.

    >>> import jax.numpy as jnp
    >>> validate_restored("int8", jnp.int8, has_scale=True)
    >>> validate_restored("f32", jnp.float32, has_scale=False)
    """
    expected = storage_dtype(storage)
    if is_quantized(storage) and jnp.dtype(db_dtype) != jnp.dtype(expected):
        raise ValueError(
            f"snapshot claims storage={storage!r} but the stored rows are "
            f"{jnp.dtype(db_dtype).name} (expected "
            f"{jnp.dtype(expected).name}) — corrupt or version-skewed "
            "snapshot; rebuild the index"
        )
    if (storage in _SCALED_TIERS) != has_scale:
        raise ValueError(
            f"snapshot storage={storage!r} "
            + ("is missing its per-row scale table"
               if storage in _SCALED_TIERS
               else "carries an unexpected scale table")
            + " — corrupt or version-skewed snapshot; rebuild the index"
        )


@dataclasses.dataclass
class QuantizedRows:
    """One metric-prepared, tier-quantized row slice (build or ``add``).

    Attributes:
      rows: stored-dtype rows (what the scan matmul consumes; canonical
        unpacked codes for int4).
      scale: per-row f32 scale (int8/int4 tiers) or None.
      bias: metric bias *of the stored values* (the metric-bias correction
        folded into the fused bias row, so quantized scan scores are
        internally consistent), or None.
      exact_rows: full-precision metric-prepared rows — the rescore tail.
      exact_bias: metric bias of ``exact_rows`` (what the rescore pass and
        the f32 path use), or None.
    """

    rows: jnp.ndarray
    scale: Optional[jnp.ndarray]
    bias: Optional[jnp.ndarray]
    exact_rows: jnp.ndarray
    exact_bias: Optional[jnp.ndarray]
