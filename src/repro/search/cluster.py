"""Cluster-pruned scan front-end: a tuning-free k-means coarse quantizer.

The paper's brute-force scan pays ``O(N·D)`` per query no matter what the
data looks like; IVF-style methods win at large N precisely by *not*
scanning everything — at the cost of per-dataset knobs (cluster count,
probe count) the paper's tuning-free stance forbids.  This module closes
that gap the same way ``repro.search.quant`` closed the precision gap:
every cluster parameter is **derived** from (N, k, recall_target), and the
recall guarantee survives as a product of two analytically-budgeted terms.

Layout (side tables — the packed row order never changes)
---------------------------------------------------------

A clustered index keeps the packed database exactly as before (user row
order, fused bias row, incremental add/delete patches all unchanged) and
adds a :class:`ClusterState` of side tables:

  * ``centroids``      (C, d)   metric-prepared k-means centroids,
  * ``centroid_bias``  (C,)     fused metric bias of the centroids (e.g.
    ``-||mu||^2/2`` for L2), so queries rank centroids with the *same*
    biased-MIPS scoring the row scan uses,
  * ``cluster_rows``   (C, R)   user row ids per cluster, ``-1`` = empty
    slot — this is simultaneously the per-cluster row ranges *and* the
    permutation map: gathered candidates are user ids natively, so
    returned indices never need translating,
  * ``spill_rows``     (B,)     an always-scanned overflow block for rows
    whose nearest clusters are full (and for incremental ``add`` bursts).

The pruned scan scores queries against the C centroids, gathers the rows
of the top-``rho`` clusters plus the spill block (S = rho·R + B slots,
empty slots masked to ``MASK_VALUE`` so partially-filled clusters never
leak), and runs the usual bin reduction + exact top-k over those S
candidates only — scanned rows drop from N to S per query.

Derivation (why there are no knobs)
-----------------------------------

With cluster pruning a true top-K entry can be lost two ways: the usual
bin *collision* (Eq. 13–14) inside the scanned set, or a cluster *miss* —
its home cluster is not among the query's top-``rho``.  The guarantee
becomes a product ``E[recall] = collision_term x miss_term`` and the
planner budgets each term separately:

  * miss budget: half the allowed loss, ``p_miss <= (1 - target) / 2``.
  * probe count: under a geometric neighbor-mass decay model — ranked by
    query-centroid affinity, each successive cluster holds at most half
    the remaining true-neighbor mass, so ``p_miss <= 2^-rho`` — the
    budget inverts to ``rho = ceil(log2(2 / (1 - target)))``.
  * inner scan target: the bin layout over the S scanned rows is planned
    at ``target_scan = target / (1 - miss_budget)``, so the product meets
    the original target by construction.
  * cluster count: ``C = 2^ceil(log2(sqrt(N)))`` — the classic IVF
    balance point where centroid scoring (C dots) and cluster scanning
    (N/C rows per probe) cost the same order.
  * cluster capacity: ``R = roundup(1.25 · N/C, 8)`` slots (25 % balance
    headroom over the ideal N/C fill, sublane-aligned).
  * spill block: ``B = roundup(max(64, N/64), 8)`` — bounded incremental
    headroom, always scanned so spilled rows can never be missed.

The decay model is an *assumption about the data*, not a theorem: it
holds when the corpus has cluster structure (the regime real embedding
workloads live in, and the only regime where pruning can win at all) and
fails on structureless data — e.g. i.i.d. Gaussian rows, where a query's
true neighbors spread across many Voronoi cells and no sub-linear probe
schedule can hit them.  The planner's *crossover* is purely a cost
decision (``repro.search.plan.plan_clusters``): pruning is enabled only
when the modeled per-query row cost — C centroid dots plus
gather-penalized S row reads — beats the full scan by at least 2x; it
prices FLOPs, not geometry, so it cannot see the regime.  The geometry
is checked **empirically at build time** instead: after the tables are
built, :func:`sampled_miss_rate` measures the actual cluster-miss rate
of sampled live rows used as query proxies (true top-k from a dense
scored pass vs the clusters the probe schedule would visit), and the
pack layer discards the tables — silently falling back to the dense
scan, bit-identical to ``cluster="off"`` — when the measured rate blows
past :func:`miss_check_threshold`.  That keeps the tuning-free claim
honest on *both* sides: no knobs to enable pruning, and no silent recall
collapse on data the model does not fit.

One assumption no build-time measurement can verify remains: queries
must be drawn from (or near) the database distribution — the proxy check
embodies exactly that premise, and it is the contract every IVF system
carries.  Out-of-distribution query streams land in unprobed clusters at
an unpredictable rate; ``cluster="off"`` is the right build for those.
``tests/test_recall_guarantee.py`` validates the end-to-end guarantee on
clusterable corpora with a Hoeffding margin.

Nothing here imports the rest of ``repro.search`` — like ``quant``, this
is a leaf the planner, packed state and backends build *on*.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binning import plan_bins, round_up

__all__ = [
    "ClusterPlan",
    "ClusterState",
    "KMEANS_ITERS",
    "assign_rows",
    "build_tables",
    "kmeans",
    "miss_budget_for",
    "miss_check_threshold",
    "num_clusters_for",
    "probes_for",
    "query_miss_rate",
    "restore_tables",
    "sampled_miss_rate",
    "snapshot_tables",
    "spill_capacity_for",
]

# Lloyd iterations for the build-time coarse quantizer.  Fixed and small:
# the centroids only need to capture coarse structure (the probe schedule
# and spill block absorb imperfect boundaries), and a deterministic
# iteration count keeps builds bit-reproducible.
KMEANS_ITERS = 8

# Nearest-centroid candidates considered by the capacity-constrained
# assignment before a row falls through to the spill block.
_ASSIGN_CANDIDATES = 8

# Slot padding / empty-slot sentinel in cluster_rows and spill_rows.
EMPTY_SLOT = -1

# Per-cluster capacity headroom over the ideal N/C fill.
_BALANCE_SLACK = 1.25

# Replan trigger: once the spill block is more than half full, the next
# ``add`` asks the planner for fresh centroids (lazy recluster).
_SPILL_REPLAN_FRACTION = 0.5

# Build-time empirical miss check: query proxies sampled from the live
# rows, and the acceptance threshold's slack over the analytical budget.
# The check is a regime detector (clusterable vs structureless data), not
# a certifier — the slack absorbs proxy/sampling noise on corpora the
# model fits, while structureless data overshoots it by an order of
# magnitude.  The floor keeps tight budgets (high recall targets) from
# turning sampling noise into spurious rejections.
_MISS_CHECK_SAMPLES = 256
_MISS_CHECK_SLACK = 2.0
_MISS_CHECK_FLOOR = 0.08


def num_clusters_for(n: int) -> int:
    """Planner-chosen centroid count: ``2^ceil(log2(sqrt(n)))``.

    >>> num_clusters_for(8192), num_clusters_for(16384)
    (128, 128)
    """
    if n <= 1:
        return 1
    return 1 << max(0, math.ceil(math.log2(math.sqrt(n))))


def miss_budget_for(recall_target: float) -> float:
    """Cluster-miss probability budget: half the allowed recall loss."""
    if not 0.0 < recall_target < 1.0:
        raise ValueError(f"recall_target must be in (0, 1), got {recall_target}")
    return (1.0 - recall_target) / 2.0


def probes_for(recall_target: float, num_clusters: int = 128) -> int:
    """Probe count rho from the geometric-decay miss model.

    ``p_miss <= 2^-rho`` inverted against the miss budget, with a
    partition-aware floor of ``C/32`` probes: the decay model prices
    probes in absolute ranks, but the neighbour mass each rank captures
    shrinks as the partition refines (each cluster holds ~1/C of the
    data), so a fixed rho under-probes large C.  The floor keeps the
    probed-mass fraction roughly constant (``rho/C >= 1/32``), which
    bounds the asymptotic scanned fraction at ~1.25/32 of N plus spill —
    the pruning win saturates instead of silently trading recall for it.

    >>> probes_for(0.90), probes_for(0.95), probes_for(0.99)
    (5, 6, 8)
    >>> probes_for(0.95, num_clusters=256)
    8
    """
    budget = miss_budget_for(recall_target)
    decay = max(1, math.ceil(math.log2(1.0 / budget)))
    floor = -(-num_clusters // 32)
    return min(max(1, num_clusters - 1), max(decay, floor))


def spill_capacity_for(n: int) -> int:
    """Always-scanned overflow slots: ``roundup(max(64, n/64), 8)``."""
    return round_up(max(64, n // 64), 8)


def rows_per_cluster_for(n: int, num_clusters: int) -> int:
    """Sublane-aligned per-cluster slot count with 25 % balance headroom."""
    ideal = math.ceil(n / max(1, num_clusters))
    return round_up(max(1, math.ceil(ideal * _BALANCE_SLACK)), 8)


@dataclasses.dataclass(frozen=True)
class ClusterPlan:
    """Frozen, fully-derived cluster-pruning parameters for one row space.

    Built by ``repro.search.plan.plan_clusters`` — never from user knobs.
    ``enabled=False`` records that the planner evaluated pruning for this
    workload and rejected it (below the cost crossover), which is how
    ``cluster="auto"`` stays bit-identical to the full scan at small N.
    """

    n: int
    num_clusters: int
    rows_per_cluster: int
    probes: int
    spill_capacity: int
    miss_budget: float
    target_scan: float
    predicted_speedup: float
    enabled: bool

    @property
    def scan_rows(self) -> int:
        """Candidate slots per query: probed cluster slots + spill block."""
        return self.probes * self.rows_per_cluster + self.spill_capacity

    @property
    def scanned_fraction(self) -> float:
        """Predicted fraction of the row space scanned per query."""
        return min(1.0, self.scan_rows / max(1, self.n))

    def recall_decomposition(self, k_scan: int) -> dict:
        """The product guarantee: collision term (Eq. 13 over the S
        scanned slots at ``target_scan``) times the miss term."""
        bins = plan_bins(
            self.scan_rows, min(k_scan, self.scan_rows), self.target_scan
        )
        # Bin size 1 keeps *every* scanned slot — the reduction is exact,
        # so no collision is possible.  (Eq. 13's ball-in-bins value is
        # meaningless there; S is small enough that this is the common
        # layout for the inner scan.)
        collision = 1.0 if bins.log2_bin_size == 0 else bins.expected_recall
        miss = 1.0 - self.miss_budget
        return {
            "collision_term": collision,
            "miss_term": miss,
            "expected_recall": collision * miss,
        }


@dataclasses.dataclass
class ClusterState:
    """Device side tables + host fill counts for one clustered layout.

    The device arrays are search *operands* (passed per dispatch, like the
    packed bias row, so slot patches never invalidate compiled programs);
    ``counts``/``spill_count`` mirror the fill level on the host so
    incremental assignment never needs a device round-trip per row.
    """

    plan: ClusterPlan
    centroids: jnp.ndarray      # (C, d) metric-prepared, f32
    centroid_bias: jnp.ndarray  # (C,) fused metric bias, f32
    cluster_rows: jnp.ndarray   # (C, R) int32 user row ids, EMPTY_SLOT pad
    spill_rows: jnp.ndarray     # (B,) int32 user row ids, EMPTY_SLOT pad
    counts: np.ndarray          # host (C,) slots used per cluster
    spill_count: int = 0
    spill_baseline: int = 0     # spill level right after (re)build
    # Served-query miss monitor accumulators (repro.search.serve samples a
    # fraction of real served queries through ``query_miss_rate``).  The
    # build-time check above uses db rows as query proxies, so these are
    # the only signal that covers out-of-distribution *query* streams —
    # the one assumption no build-time measurement can verify.
    served_miss_checked: int = 0
    served_miss_missed: int = 0

    def operands(self) -> Tuple[jnp.ndarray, ...]:
        """The positional device operands the pruned scan consumes."""
        return (
            self.centroids, self.centroid_bias,
            self.cluster_rows, self.spill_rows,
        )

    @property
    def served_miss_rate(self) -> Optional[float]:
        """Sampled miss rate of real served queries (None before any
        sample).  Compare against ``miss_check_threshold(plan.miss_budget)``
        — a sustained rate above it means the query stream is out of the
        distribution the tables were certified on (rebuild with
        ``cluster="off"``)."""
        if self.served_miss_checked == 0:
            return None
        return self.served_miss_missed / self.served_miss_checked

    def served_miss_report(self) -> dict:
        """The served-query miss monitor block both ``Index.explain()``
        and ``SearchServer.health()`` report: sampled pairs, the rate,
        the warn threshold, and whether it is breached."""
        rate = self.served_miss_rate
        threshold = miss_check_threshold(self.plan.miss_budget)
        return {
            "sampled_pairs": self.served_miss_checked,
            "miss_rate": rate,
            "warn_threshold": threshold,
            "warning": rate is not None and rate > threshold,
        }

    @property
    def needs_recluster(self) -> bool:
        """Lazy-replan trigger: incremental assignment has GROWN the spill
        block past the planner's imbalance threshold since the tables were
        built.  Growth since build — not the absolute level — is the
        signal: skewed corpora can legitimately fill part of the spill at
        build time (every spilled row is always scanned, so recall is
        unaffected), and reclustering the same data would just reproduce
        that baseline."""
        grown = self.spill_count - self.spill_baseline
        return grown > int(
            self.plan.spill_capacity * _SPILL_REPLAN_FRACTION
        )


def kmeans(rows: jnp.ndarray, num_clusters: int,
           iters: int = KMEANS_ITERS) -> jnp.ndarray:
    """Deterministic Lloyd k-means over metric-prepared rows (device).

    Strided init over the row order (no RNG — builds are bit-reproducible),
    relaxed-L2 assignment (``argmax <x, mu> - ||mu||^2/2``, Eq. 19's trick
    reused), mean update with empty clusters keeping their old centroid.
    O(iters · N · C · D) one-time build cost.

    >>> c = kmeans(jnp.eye(8, 4), 2)
    >>> c.shape
    (2, 4)
    """
    rows = jnp.asarray(rows, jnp.float32)
    n = rows.shape[0]
    if num_clusters > n:
        raise ValueError(f"num_clusters={num_clusters} exceeds rows n={n}")
    cents = rows[(jnp.arange(num_clusters) * n) // num_clusters]
    ones = jnp.ones((n,), jnp.float32)
    for _ in range(iters):
        logits = rows @ cents.T - 0.5 * jnp.sum(cents * cents, -1)[None, :]
        assign = jnp.argmax(logits, -1)
        sums = jax.ops.segment_sum(rows, assign, num_segments=num_clusters)
        cnt = jax.ops.segment_sum(ones, assign, num_segments=num_clusters)
        cents = jnp.where(
            cnt[:, None] > 0, sums / jnp.maximum(cnt, 1.0)[:, None], cents
        )
    return cents


def _nearest_candidates(
    rows: jnp.ndarray,
    centroids: jnp.ndarray,
    centroid_bias: jnp.ndarray,
    width: int,
) -> np.ndarray:
    """Host (r, width) centroid ids per row, best-first, scored with the
    same biased-MIPS affinity the search probes use."""
    width = min(width, centroids.shape[0])
    aff = (
        jnp.asarray(rows, jnp.float32) @ centroids.T
        + centroid_bias[None, :]
    )
    _, cand = jax.lax.top_k(aff, width)
    return np.asarray(cand)


def build_tables(
    rows: jnp.ndarray,
    live: Optional[np.ndarray],
    plan: ClusterPlan,
    prepare: Callable[[jnp.ndarray], Tuple[jnp.ndarray, Optional[jnp.ndarray]]],
) -> ClusterState:
    """Build the full side-table set for ``rows`` (build / lazy recluster).

    ``rows`` are the metric-prepared full-precision rows over the whole
    capacity row space; ``live`` is a host bool mask (None = all live) —
    dead rows (tombstones, unwritten capacity) get no slot, so they are
    structurally absent from every candidate set.  ``prepare`` is the
    metric's ``prepare_database``, re-run on the raw k-means centroids so
    centroid scoring uses the same prepared space + bias convention as the
    row scan (e.g. centroids are re-normalized for cosine, giving
    spherical k-means).

    Capacity-constrained greedy assignment: each live row goes to its
    best-affinity centroid with a free slot (up to ``_ASSIGN_CANDIDATES``
    fallbacks), then the spill block, then — spill full — the emptiest
    cluster (total capacity ``C·R >= 1.25·N`` guarantees a slot exists).
    The per-row Python loop is build-time-only, O(N) host work.
    """
    rows = jnp.asarray(rows)
    if live is None:
        live_idx = np.arange(rows.shape[0])
    else:
        live_idx = np.flatnonzero(np.asarray(live))
    if live_idx.size < plan.num_clusters:
        raise ValueError(
            f"cannot build {plan.num_clusters} clusters from "
            f"{live_idx.size} live rows"
        )
    live_rows = rows[jnp.asarray(live_idx)]
    raw_cents = kmeans(live_rows, plan.num_clusters)
    cents, cent_bias = prepare(raw_cents)
    cents = jnp.asarray(cents, jnp.float32)
    bias = (
        jnp.zeros((plan.num_clusters,), jnp.float32)
        if cent_bias is None
        else jnp.asarray(cent_bias, jnp.float32)
    )
    cand = _nearest_candidates(live_rows, cents, bias, _ASSIGN_CANDIDATES)

    table = np.full(
        (plan.num_clusters, plan.rows_per_cluster), EMPTY_SLOT, np.int32
    )
    spill = np.full((plan.spill_capacity,), EMPTY_SLOT, np.int32)
    counts = np.zeros((plan.num_clusters,), np.int64)
    spill_count = 0
    for rid, cs in zip(live_idx, cand):
        placed = False
        for c in cs:
            if counts[c] < plan.rows_per_cluster:
                table[c, counts[c]] = rid
                counts[c] += 1
                placed = True
                break
        if placed:
            continue
        if spill_count < plan.spill_capacity:
            spill[spill_count] = rid
            spill_count += 1
        else:
            c = int(np.argmin(counts))
            table[c, counts[c]] = rid
            counts[c] += 1
    return ClusterState(
        plan=plan,
        centroids=cents,
        centroid_bias=bias,
        cluster_rows=jnp.asarray(table),
        spill_rows=jnp.asarray(spill),
        counts=counts,
        spill_count=spill_count,
        spill_baseline=spill_count,
    )


def miss_check_threshold(miss_budget: float) -> float:
    """Acceptance threshold for the build-time empirical miss check.

    ``max(2 x budget, 0.08)``: clusterable corpora measure within the
    budget (the slack absorbs the self-query proxy and sampling noise),
    structureless data measures 5-10x above it.

    >>> miss_check_threshold(0.05), miss_check_threshold(0.005)
    (0.1, 0.08)
    """
    return max(_MISS_CHECK_SLACK * miss_budget, _MISS_CHECK_FLOOR)


def sampled_miss_rate(
    state: ClusterState,
    rows: jnp.ndarray,
    bias_row: jnp.ndarray,
    live: Optional[np.ndarray],
    k: int,
) -> float:
    """Empirical cluster-miss rate of built tables, no user queries needed.

    Samples (strided, deterministic) live prepared rows as query proxies —
    the standard IVF self-test, exact for metrics whose prepared database
    rows are valid query vectors (mips trivially, relaxed L2 because
    queries enter Eq. 19 unprepared, cosine because prepared rows are
    already unit-norm) — then measures directly what the decay model only
    assumes: the fraction of each proxy's true top-``k`` (dense scored
    pass over all rows with the fused bias, so tombstones can't count)
    whose home cluster is NOT among the proxy's top-``probes`` centroids
    (spill rows always count as hit — they are always scanned).

    One (m, N) matmul of build-time work; the per-row cluster membership
    is recovered from the tables themselves, so the measurement covers
    exactly the layout the pruned scan will gather from.
    """
    rows = jnp.asarray(rows, jnp.float32)
    capacity = rows.shape[0]
    if live is None:
        live_idx = np.arange(capacity)
    else:
        live_idx = np.flatnonzero(np.asarray(live))
    m = min(_MISS_CHECK_SAMPLES, live_idx.size)
    sample = live_idx[(np.arange(m) * live_idx.size) // m]
    q = rows[jnp.asarray(sample)]
    k_eff = max(1, min(k, live_idx.size))
    missed, checked = _miss_counts(state, q, rows, bias_row, k_eff)
    return missed / checked


def query_miss_rate(
    state: ClusterState,
    queries: jnp.ndarray,
    rows: jnp.ndarray,
    bias_row: jnp.ndarray,
    k: int,
) -> Tuple[int, int]:
    """Cluster-miss counts for *real* query rows — the served-traffic
    monitor behind ``SearchServer.health()``.

    Same measurement as :func:`sampled_miss_rate` (true top-``k`` of a
    dense scored pass vs the clusters the probe schedule visits, spill
    rows always hit) but over caller-supplied queries instead of db-row
    proxies, and returning raw ``(missed, checked)`` neighbour-pair counts
    so a server can accumulate a running estimate across samples.

    ``rows`` / ``bias_row`` must be the *exact* (full-precision) prepared
    rows and fused bias — ``PackedState.exact_rows_bias()`` — so the
    "true" neighbours are the real ones, not tier-rounded ones, and
    tombstoned rows can never count as misses.
    """
    q = jnp.asarray(queries, jnp.float32)
    rows = jnp.asarray(rows, jnp.float32)
    k_eff = max(1, min(k, rows.shape[0]))
    return _miss_counts(state, q, rows, bias_row, k_eff)


def _miss_counts(
    state: ClusterState,
    q: jnp.ndarray,
    rows: jnp.ndarray,
    bias_row: jnp.ndarray,
    k_eff: int,
) -> Tuple[int, int]:
    """Shared miss measurement: of the true top-``k_eff`` neighbour pairs
    of ``q`` (dense scored pass), how many live in clusters the probe
    schedule would NOT visit?  Membership is recovered from the tables
    themselves, so the measurement covers exactly the layout the pruned
    scan gathers from.  Returns host ints ``(missed, checked)``."""
    plan = state.plan
    capacity = rows.shape[0]
    scores = q @ rows.T + jnp.asarray(bias_row, jnp.float32)[None, :]
    _, true_ids = jax.lax.top_k(scores, k_eff)
    caff = q @ state.centroids.T + state.centroid_bias[None, :]
    _, probed = jax.lax.top_k(caff, plan.probes)

    member = np.full((capacity,), -1, np.int64)
    tbl = np.asarray(state.cluster_rows)
    filled = tbl >= 0
    member[tbl[filled]] = np.nonzero(filled)[0]
    in_spill = np.zeros((capacity,), bool)
    sp = np.asarray(state.spill_rows)
    in_spill[sp[sp >= 0]] = True

    true_ids = np.asarray(true_ids)
    probed = np.asarray(probed)
    hit = in_spill[true_ids]
    hit |= (member[true_ids][:, :, None] == probed[:, None, :]).any(-1)
    return int(hit.size - hit.sum()), int(hit.size)


def snapshot_tables(state: ClusterState) -> Tuple[dict, dict]:
    """Serialize a ClusterState into ``(arrays, meta)`` for a snapshot.

    Everything is captured — device tables, host fill counts, spill
    bookkeeping, the frozen plan, the served-miss accumulators — so a
    restored replica resumes the incremental-assignment contract exactly
    where the original left off (no k-means re-run, no slot drift).
    """
    arrays = {
        "cluster/centroids": state.centroids,
        "cluster/centroid_bias": state.centroid_bias,
        "cluster/cluster_rows": state.cluster_rows,
        "cluster/spill_rows": state.spill_rows,
        "cluster/counts": np.asarray(state.counts),
    }
    meta = {
        "plan": dataclasses.asdict(state.plan),
        "spill_count": int(state.spill_count),
        "spill_baseline": int(state.spill_baseline),
        "served_miss_checked": int(state.served_miss_checked),
        "served_miss_missed": int(state.served_miss_missed),
    }
    return arrays, meta


def restore_tables(arrays: dict, meta: dict) -> ClusterState:
    """Inverse of :func:`snapshot_tables` (loud on unknown plan fields —
    the same version-skew contract as ``SearchSpec.from_json_dict``)."""
    plan_dict = dict(meta["plan"])
    known = {f.name for f in dataclasses.fields(ClusterPlan)}
    unknown = sorted(set(plan_dict) - known)
    if unknown:
        raise ValueError(
            f"snapshot cluster plan carries unknown fields {unknown} — "
            "written by a newer version? Rebuild the index or upgrade."
        )
    return ClusterState(
        plan=ClusterPlan(**plan_dict),
        centroids=jnp.asarray(arrays["cluster/centroids"]),
        centroid_bias=jnp.asarray(arrays["cluster/centroid_bias"]),
        cluster_rows=jnp.asarray(arrays["cluster/cluster_rows"]),
        spill_rows=jnp.asarray(arrays["cluster/spill_rows"]),
        counts=np.asarray(arrays["cluster/counts"]),
        spill_count=int(meta["spill_count"]),
        spill_baseline=int(meta["spill_baseline"]),
        served_miss_checked=int(meta.get("served_miss_checked", 0)),
        served_miss_missed=int(meta.get("served_miss_missed", 0)),
    )


def assign_rows(state: ClusterState, rows: jnp.ndarray, start: int) -> None:
    """Incrementally slot appended rows (user ids ``start..start+r``).

    Mirrors the packed ``update_rows`` contract: O(r) work against the
    existing centroids — nearest centroid with a free slot, else the spill
    block, else (spill full) the emptiest cluster.  Patches the device
    tables in place; ``state.needs_recluster`` tells ``Index.add`` when
    the spill pressure says the centroids should be lazily re-derived.
    """
    rows = jnp.atleast_2d(jnp.asarray(rows))
    cand = _nearest_candidates(
        rows, state.centroids, state.centroid_bias, _ASSIGN_CANDIDATES
    )
    tbl_c, tbl_j, tbl_id = [], [], []
    sp_j, sp_id = [], []
    for off, cs in enumerate(cand):
        rid = start + off
        placed = False
        for c in cs:
            if state.counts[c] < state.plan.rows_per_cluster:
                tbl_c.append(c)
                tbl_j.append(int(state.counts[c]))
                tbl_id.append(rid)
                state.counts[c] += 1
                placed = True
                break
        if placed:
            continue
        if state.spill_count < state.plan.spill_capacity:
            sp_j.append(state.spill_count)
            sp_id.append(rid)
            state.spill_count += 1
        else:
            c = int(np.argmin(state.counts))
            tbl_c.append(c)
            tbl_j.append(int(state.counts[c]))
            tbl_id.append(rid)
            state.counts[c] += 1
    if tbl_id:
        state.cluster_rows = state.cluster_rows.at[
            jnp.asarray(tbl_c), jnp.asarray(tbl_j)
        ].set(jnp.asarray(tbl_id, jnp.int32))
    if sp_id:
        state.spill_rows = state.spill_rows.at[jnp.asarray(sp_j)].set(
            jnp.asarray(sp_id, jnp.int32)
        )
