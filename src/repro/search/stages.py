"""Composable search stages: scan -> rescore -> gather/merge.

Every layout this package executes — the replicated xla/pallas programs,
the 1-D and 2-D sharded programs (paper §7), and the host-RAM cold tier's
segment waves — is an assembly of the same four stage primitives over
metric-prepared operands in the *internal max convention* (maximize
``<q', x'> + bias``, negate once at the API boundary):

  * :func:`score_rows`       — the streamed score matmul + fused bias
    (one additive COP carrying metric bias, tombstones and tail mask).
  * :func:`scan_candidates`  — the PartialReduce / ApproxTopK bin scan
    (Eq. 13–14 recall accounting, optionally against a *global* N when
    the operand is one shard or one segment of a larger database).
  * :func:`rescore_candidates` — the exact second pass of the quantized
    two-pass search: cut the bin winners to the ``k_scan`` over-fetch
    budget, gather the full-precision tail, re-score exactly.  Shards and
    host segments run it on *local* candidate ids before any merge, so
    the gather never crosses the interconnect (rescore-before-gather).
  * :func:`merge_topk`       — exact top-k merge of candidate streams
    (the all-gather reduction of the sharded path; the per-wave carry
    merge of the host tier).

:func:`prune_candidates` is the optional cluster-pruning front-end that
replaces the streamed scan's candidate set with gathered slots, and
:func:`finalize_values` applies the metric's single sign flip.

On the Pallas backend the fused kernel
(``repro.kernels.partial_reduce.partial_reduce_fused``) subsumes the
scan → ``merge_topk`` pair: the top-``k_scan`` carry is merged in VMEM
during the scan, so the composed pipeline degenerates to
score+scan+select (one dispatch, Eq. 20 traffic) followed by the same
rescore/finalize stages.  The two-pass composition remains the parity
oracle (``SearchSpec(fused_select=False)``).

These functions are deliberately *pure shape-in/shape-out jax* — no jit,
no counters, no layout knowledge.  ``repro.search.backends`` composes
them into the entry points ``Index`` dispatches (where tracing/dispatch
accounting lives), and the property tests in
``tests/test_packed_invariants.py`` assert that stage composition equals
the monolithic dense reference under arbitrary add/delete interleavings.

History note: these bodies were extracted verbatim from the accreted
dense/packed/quant/cluster × one-pass/two-pass variants in
``backends.py`` — op order is unchanged on purpose, so the refactor is
bit-identical to the pre-stage programs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rescoring import exact_rescoring
from repro.core.topk import approx_max_k

__all__ = [
    "MASK_VALUE",
    "score_rows",
    "scan_candidates",
    "rescore_candidates",
    "prune_candidates",
    "merge_topk",
    "finalize_values",
    "pad_queries_to",
    "sentinelize_masked",
]

# Finite -inf surrogate (float32 min): keeps the MXU/VPU paths free of NaN
# propagation while still losing every comparison against real scores.
MASK_VALUE = float(np.finfo(np.float32).min)

Array = jnp.ndarray


def sentinelize_masked(vals: Array, idxs: Array, n: int) -> Array:
    """Pair masked candidates with the sentinel index ``-1``.

    A masked winner (fully tombstoned bin, padded tail) carries a
    meaningless index; clamping it into ``[0, n)`` — the historical
    behaviour — let it alias row ``n-1`` and surface as a phantom
    duplicate neighbour once ``merge_topk`` tied at ``-inf``.  Keeping the
    ``-inf`` value paired with ``-1`` through the merge makes masked
    entries collision-free; live winners are clamped into range here (the
    only clamp the pipeline applies, at finalize order).  The fused Pallas
    kernel applies the identical rule in VMEM, so fused and two-pass
    outputs agree bitwise.
    """
    return jnp.where(vals > MASK_VALUE * 0.5, jnp.minimum(idxs, n - 1), -1)


def pad_queries_to(q: Array, width: int) -> Array:
    """Zero-pad query lanes up to a packed layout's d_pad (exact for dot
    products — the database's padded lanes are zero too)."""
    if q.shape[1] == width:
        return q
    return jnp.pad(q, ((0, 0), (0, width - q.shape[1])))


# --- stage 1: score -----------------------------------------------------------


def score_rows(
    q: Array,
    database: Array,
    row_bias: Optional[Array] = None,
    scale: Optional[Array] = None,
) -> Array:
    """Streamed biased-MIPS score tile: ``q @ db.T (* scale) + bias``.

    ``q`` must already be metric-prepared; ``database`` holds the stored
    rows of any tier (bf16/int8 rows score through ``scale``, the int8
    per-row dequantization scale).  ``row_bias`` is the fused bias row —
    adding it *after* the scale keeps quantized scan scores internally
    consistent (the bias is computed from the stored values).
    """
    scores = jnp.einsum("ik,jk->ij", q, database)
    if scale is not None:
        scores = scores * scale[None, :]
    if row_bias is not None:
        scores = scores + row_bias[None, :]
    return scores


def score_gathered(
    q: Array,
    rows: Array,
    row_bias: Array,
    ids: Array,
    valid: Array,
    scale: Optional[Array] = None,
) -> Array:
    """Gathered biased-MIPS scores over per-query candidate rows.

    ``rows`` is the (m, S, d) gather ``database[ids]`` (cast to f32 by
    the caller when the tier stores narrower rows); invalid slots (empty
    cluster tails, slots another shard owns) score ``MASK_VALUE`` so they
    can never win a bin.
    """
    scores = jnp.einsum("md,msd->ms", q, rows)
    if scale is not None:
        scores = scores * scale.reshape(-1)[ids]
    scores = scores + row_bias.reshape(-1)[ids]
    return jnp.where(valid, scores, MASK_VALUE)


# --- stage 2: scan (the Eq. 13-14 bin reduction) ------------------------------


def scan_candidates(
    scores: Array,
    k: int,
    *,
    recall_target: float,
    reduction_input_size_override: int = -1,
    aggregate_to_topk: bool = True,
    use_bitonic: bool = False,
) -> Tuple[Array, Array]:
    """PartialReduce the score tile into L bin winners (or the top-k).

    ``reduction_input_size_override`` carries the recall accounting when
    ``scores`` covers only a shard or a host-tier segment of a larger
    database (paper §7): bins are then laid out as if the scan saw the
    global N, which is what makes the per-partition collision terms
    compose into the global Eq. 13 bound.
    """
    return approx_max_k(
        scores,
        k,
        recall_target=recall_target,
        reduction_input_size_override=reduction_input_size_override,
        aggregate_to_topk=aggregate_to_topk,
        use_bitonic=use_bitonic,
    )


# --- stage 3: rescore (exact second pass of the quantized tiers) --------------


def rescore_candidates(q, scan_vals, idxs, rescore_db, rescore_bias, k,
                       k_scan, use_bitonic=False):
    """Exact second pass of the quantized search (internal max convention).

    Two stages, mirroring the paper's score/rescore split with the *scan*
    at reduced precision: first the L bin winners are cut to the
    ``k_scan`` best by quantized score (``k_scan = k + T``, the
    over-fetch budget of ``repro.search.quant.scan_k`` — a true top-k
    entry drops out only past T quantization-promoted rivals, the same
    event the bin over-fetch already insures), then only those O(M·K')
    rows are gathered from the full-precision rescore tail and re-scored
    exactly.  Candidates the scan masked (tombstoned rows, padded bins —
    their clamped indices would otherwise rescore to a live row's true
    score and duplicate it into top-k) stay masked.

    ``idxs`` index ``rescore_db`` directly, so on sharded/host-tiered
    layouts they are *local* (shard- or segment-relative) ids — rescoring
    happens before any offset translation or gather across partitions.
    """
    if k_scan < scan_vals.shape[-1]:
        scan_vals, sel = jax.lax.top_k(scan_vals, k_scan)
        idxs = jnp.take_along_axis(idxs, sel, axis=-1)
    rows = rescore_db[idxs]                           # (m, k_scan, d) gather
    exact = jnp.einsum("md,mld->ml", q, rows)
    exact = exact + rescore_bias[idxs]
    exact = jnp.where(scan_vals > MASK_VALUE * 0.5, exact, MASK_VALUE)
    return exact_rescoring(exact, idxs, k, mode="max", use_bitonic=use_bitonic)


# --- optional front-end: cluster pruning --------------------------------------


def prune_candidates(q, centroids, centroid_bias, cluster_rows,
                     spill_rows, probes):
    """Per-query candidate row ids from the pruning side tables.

    Scores the prepared queries against the (C, d) centroids with the same
    biased-MIPS convention as the row scan, keeps the top-``probes``
    clusters, and concatenates their slot tables with the always-scanned
    spill block.  Returns ``(ids, valid)`` where ``ids`` (m, S) are
    *user-space* row ids clamped to >= 0 and ``valid`` marks real slots —
    empty slots (padded tails of partially-filled clusters, unused spill
    capacity) must be masked by the caller so they can never win a bin.

    The slot order INTERLEAVES the probed clusters (slot j of every
    cluster, then slot j+1, ...) instead of concatenating them whole.
    Eq. 13's collision bound assumes the true top-k land in random bins;
    cluster-contiguous order breaks that badly — a query's winners
    concentrate in its best cluster's slots, adjacent slots share a bin,
    and measured recall falls below the planned collision term.
    Interleaving spreads each cluster across the bin space, restoring the
    random-placement regime the plan prices.
    """
    caff = jnp.einsum("md,cd->mc", q, centroids) + centroid_bias[None, :]
    _, top_c = jax.lax.top_k(caff, probes)
    m = q.shape[0]
    slots = cluster_rows[top_c]                       # (m, probes, R)
    slots = slots.swapaxes(1, 2).reshape(m, -1)       # (m, R * probes)
    spill = jnp.broadcast_to(
        spill_rows[None, :], (m, spill_rows.shape[0])
    )
    ids = jnp.concatenate([slots, spill], axis=1)     # (m, S)
    return jnp.maximum(ids, 0), ids >= 0


# --- stage 4: gather/merge ----------------------------------------------------


def merge_topk(
    vals: Array,
    idxs: Array,
    k: int,
    *,
    extra_vals: Optional[Array] = None,
    extra_idxs: Optional[Array] = None,
    use_bitonic: bool = False,
) -> Tuple[Array, Array]:
    """Exact top-k reduction of one or two candidate streams.

    The merge node every distributed layout ends in: the sharded path
    all-gathers per-shard winners and merges them here; the host tier
    merges each segment wave's winners into the running (m, k) carry.
    Values are compared as-is (internal max convention) — since every
    partition computes a given row's score from identical bits, the merge
    is order-insensitive up to exact-tie placement.
    """
    if extra_vals is not None:
        vals = jnp.concatenate([vals, extra_vals], axis=-1)
        idxs = jnp.concatenate([idxs, extra_idxs], axis=-1)
    return exact_rescoring(vals, idxs, k, mode="max", use_bitonic=use_bitonic)


def finalize_values(vals: Array, negate_output: bool) -> Array:
    """The single internal-max -> public-value sign flip (metric contract
    in ``repro.search.metrics``); every composed pipeline applies it
    exactly once, at the very end."""
    return -vals if negate_output else vals
