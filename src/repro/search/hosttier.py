"""Host-RAM cold tier: segment-wave search over larger-than-HBM databases.

``Index.build(..., residency="host")`` keeps the packed database in host
memory and bounds device HBM to a planner-sized budget
(``repro.search.plan.plan_segments``): each search streams the rows
through the device in fixed-shape *segment waves* — slice segment i+1
out of the host-resident packed arrays and start its async ``device_put``
(the double-buffered prefetch) *before* dispatching the wave program over
segment i, so the copy of the next wave overlaps the scan of the current
one.  N is then bounded by host memory, not one device's HBM, at the cost
of re-streaming the database per query batch — the right trade exactly
when the database dwarfs the query stream.

The wave program is an assembly of the shared stage primitives
(``repro.search.stages``): score the segment, bin-scan it with recall
accounted against the *global* N (``reduction_input_size_override`` —
the same Eq. 13–14 composition argument as a §7 shard), exactly rescore
the quantized tiers' candidates from the segment's own f32 tail (local
ids, before any offset), translate ids by the segment's row offset, and
``merge_topk`` into the running (m, k) carry.  Because the segment
offset is a *traced* scalar operand and every wave has the same shape,
the steady state compiles at most two programs — interior waves and the
final wave (which applies the metric's sign flip) — and then runs one
dispatch per wave with zero retraces, whatever N grows to.

Observability follows the backend convention: ``TRACE_COUNTS["host"]``
per wave-program trace, ``DISPATCH_COUNTS["host"]`` per wave dispatch.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.search import telemetry
from repro.search.backends import DISPATCH_COUNTS, TRACE_COUNTS
from repro.search.metrics import get_metric
from repro.search.stages import (
    MASK_VALUE,
    finalize_values,
    merge_topk,
    rescore_candidates,
    scan_candidates,
    score_rows,
)

__all__ = ["HostTierSearcher", "wave_program"]


@functools.partial(
    jax.jit,
    static_argnames=(
        "metric", "k", "k_scan", "recall_target", "global_n", "rescore",
        "is_last", "use_bitonic",
    ),
)
def wave_program(
    queries: jnp.ndarray,
    seg_db: jnp.ndarray,
    seg_bias: jnp.ndarray,
    seg_scale: Optional[jnp.ndarray],
    seg_rescore_db: Optional[jnp.ndarray],
    seg_rescore_bias: Optional[jnp.ndarray],
    offset: jnp.ndarray,
    carry_vals: jnp.ndarray,
    carry_idxs: jnp.ndarray,
    *,
    metric: str,
    k: int,
    k_scan: int,
    recall_target: float,
    global_n: int,
    rescore: bool,
    is_last: bool,
    use_bitonic: bool,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One segment wave: scan -> (rescore) -> offset -> merge into carry.

    ``offset`` (the segment's first global row id) is a traced int32
    scalar, NOT a static — every interior wave shares one compiled
    program.  ``global_n`` carries the Eq. 13–14 recall accounting: bins
    over this segment are laid out as if the scan saw the whole database,
    so the per-wave collision terms compose into the same global bound a
    resident scan plans for — and the candidate top-k is containment-
    equivalent to the resident oracle's, which is what the layout-parity
    grid asserts bit-exactly.  ``is_last`` folds the metric sign flip
    into the final wave (distance metrics thus trace twice: interior +
    last; MIPS traces once).
    """
    m_obj = get_metric(metric)
    TRACE_COUNTS.inc("host")
    q = m_obj.prepare_queries(queries)
    scores = score_rows(q, seg_db, seg_bias, seg_scale)
    if rescore:
        vals, idxs = scan_candidates(
            scores, k_scan, recall_target=recall_target,
            reduction_input_size_override=global_n, aggregate_to_topk=False,
        )
        vals, idxs = rescore_candidates(
            q, vals, idxs, seg_rescore_db, seg_rescore_bias, k, k_scan,
            use_bitonic,
        )
    else:
        vals, idxs = scan_candidates(
            scores, k, recall_target=recall_target,
            reduction_input_size_override=global_n, aggregate_to_topk=True,
            use_bitonic=use_bitonic,
        )
    idxs = idxs + offset
    vals, idxs = merge_topk(
        carry_vals, carry_idxs, k,
        extra_vals=vals, extra_idxs=idxs, use_bitonic=use_bitonic,
    )
    if is_last:
        vals = finalize_values(vals, m_obj.negate_output)
    return vals, idxs


class HostTierSearcher:
    """Callable ``(queries, packed_state) -> (values, indices)`` that
    drives the segment-wave schedule over a host-resident xla-layout
    ``repro.search.packed.PackedState``.

    Built once per (spec, capacity, query shape) by ``Index`` and cached
    in its ``CompileCache`` — the wave program underneath additionally
    memoizes its traces, so repeat searches at the same shape are pure
    dispatches.
    """

    def __init__(self, spec, *, k_scan: int, segment_rows: int):
        if spec.segment_rows is not None:
            segment_rows = spec.segment_rows
        if segment_rows <= 0:
            raise ValueError(
                f"segment_rows must be positive, got {segment_rows}"
            )
        self.spec = spec
        self.segment_rows = segment_rows
        self.k_scan = k_scan
        # The hot device the waves stream through: the process default
        # (the accelerator when one exists; under tests, the host CPU —
        # same staging code path, trivial copies).
        self.device = jax.devices()[0]

    def num_segments(self, capacity: int) -> int:
        if capacity % self.segment_rows:
            raise ValueError(
                f"capacity {capacity} is not a whole number of "
                f"{self.segment_rows}-row segments — Index.build/add must "
                "pad capacity to whole waves"
            )
        return capacity // self.segment_rows

    def _stage(self, pk, seg: int):
        """Kick off the async host->device copy of one segment's operands
        (slices of the host-resident packed arrays)."""
        lo, hi = seg * self.segment_rows, (seg + 1) * self.segment_rows
        put = lambda a: jax.device_put(a[lo:hi], self.device)
        quantized = pk.scale is not None
        rescoring = pk.rescore_db is not None
        return (
            put(pk.db),
            put(pk.bias),
            put(pk.scale) if quantized else None,
            put(pk.rescore_db) if rescoring else None,
            put(pk.rescore_bias) if rescoring else None,
        )

    def __call__(self, queries, pk) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cap = pk.db.shape[0]
        waves = self.num_segments(cap)
        seg = self.segment_rows
        spec = self.spec
        rescore = pk.rescore_db is not None
        m = queries.shape[0]
        q = jax.device_put(queries, self.device)
        carry_vals = jnp.full((m, spec.k), MASK_VALUE, jnp.float32)
        carry_idxs = jnp.zeros((m, spec.k), jnp.int32)
        telemetry.registry().set_gauge(
            "repro_hosttier_segments", waves, segment_rows=seg
        )
        nxt = self._stage(pk, 0)
        for i in range(waves):
            cur = nxt
            if i + 1 < waves:
                # Double buffer: the next wave's copy is in flight while
                # this wave's program runs.
                nxt = self._stage(pk, i + 1)
            DISPATCH_COUNTS.inc("host")
            # Per-wave host-tier series: the cold tier's wave cadence is
            # its own roofline story (one dispatch per segment streamed).
            telemetry.registry().inc(
                "repro_hosttier_waves_total", segment_rows=seg
            )
            carry_vals, carry_idxs = wave_program(
                q, cur[0], cur[1], cur[2], cur[3], cur[4],
                jnp.int32(i * seg), carry_vals, carry_idxs,
                metric=spec.metric, k=spec.k,
                k_scan=min(self.k_scan, seg),
                recall_target=spec.recall_target,
                global_n=cap, rescore=rescore,
                is_last=(i == waves - 1),
                use_bitonic=spec.use_bitonic,
            )
        return carry_vals, carry_idxs

    def occupancy(self, pk) -> list:
        """Per-segment live-row fraction (benchmark observability: how
        much of each wave's streamed bytes score real rows)."""
        bias = np.asarray(pk.bias)
        out = []
        for s in range(self.num_segments(pk.db.shape[0])):
            blk = bias[s * self.segment_rows : (s + 1) * self.segment_rows]
            out.append(float(np.mean(blk > MASK_VALUE * 0.5)))
        return out
