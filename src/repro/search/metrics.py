"""Metric registry: the single owner of score transforms and sign conventions.

Every backend (xla, pallas, sharded) reduces every metric to ONE internal
problem: *maximize* ``<q', x'> + bias(x')`` where ``q'``/``x'`` are the
metric-prepared queries/database and ``bias`` is an additive per-row term
folded into the kernel's bias row.  The registry entry for a metric supplies
the preparation functions, the bias, and whether the public values are the
negated internal scores.

Value contract (the one place it is documented — shims and kernels refer
here):

  * ``mips``:   values are inner products ``<q, x>``; descending,
                higher is better.
  * ``cosine``: values are cosine similarities (queries and database rows
                l2-normalized); descending, higher is better.
  * ``l2``:     values are the paper's *relaxed distances*
                ``||x||^2/2 - <q, x>`` (Eq. 19) — the query norm is dropped,
                so they are monotone in true Euclidean distance per query
                but are NOT the true distances; ascending, lower is better.
                Internally every backend maximizes ``<q,x> - ||x||^2/2`` and
                negates exactly once at the API boundary, so values agree
                across backends to float tolerance.

``exact`` baselines (Faiss-Flat analogues) follow the same contract and are
what the parity/recall tests compare against.

>>> get_metric("l2").negate_output      # ascending relaxed distances
True
>>> get_metric("mips").negate_output    # descending inner products
False
>>> "cosine" in available_metrics()
True
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.search import quant

__all__ = [
    "Metric",
    "register_metric",
    "get_metric",
    "available_metrics",
    "half_norms",
    "l2_normalize",
    "exact_mips",
    "exact_l2nns",
    "exact_cosine_nns",
    "exact_search",
]

Array = jnp.ndarray


def half_norms(database: Array) -> Array:
    """Precomputed ``||x||^2 / 2`` per database row (Eq. 19)."""
    return 0.5 * jnp.sum(jnp.square(database), axis=-1)


def l2_normalize(x: Array, eps: float = 1e-12) -> Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


@dataclasses.dataclass(frozen=True)
class Metric:
    """One similarity/distance mode, reduced to biased-MIPS form.

    Attributes:
      name: registry key.
      negate_output: True when public values are ascending distances
        (internal max-scores negated once at the API boundary).
      prepare_database: db -> (db', row_bias or None).  Called once at
        ``Index.build`` (the precompute the paper calls "index-free":
        O(N) element-wise work, no data structure).  The cluster-pruned
        front-end (``repro.search.cluster``) reuses the same hook to put
        k-means centroids into metric space, so a query scores centroids
        and database rows under one biased-MIPS contract.
      prepare_queries: q -> q' applied on every search.
      exact: (q, db_raw, k) -> (values, indices) exact baseline obeying the
        same value contract (db_raw is the *unprepared* database).
      rowwise: whether ``prepare_database`` is a pure per-row map, i.e.
        ``prepare_database(db)[i] == prepare_database(db[i:i+1])[0]`` for
        every row.  True for all built-ins (identity, half norms, row
        normalization), and it is what lets ``Index.add`` prepare only the
        appended slice (``prepare_update``) instead of re-deriving O(N)
        state.  A metric whose preparation couples rows (e.g. a learned
        rotation refit over the whole database) must set False, which
        forces a full repack on every ``add``.
      storage_tiers: the ``repro.search.quant`` storage tiers this metric's
        prepared rows survive.  All built-ins support every tier (cosine
        normalizes, so its rows are bounded; l2/mips use per-row int8
        scales).  A metric whose prepared rows defeat per-row scaling —
        e.g. an *unnormalized* cosine variant — should exclude "int8" so
        ``SearchSpec``/``Index.build`` reject the combination with an
        actionable error instead of a kernel-level failure.
    """

    name: str
    negate_output: bool
    prepare_database: Callable[[Array], Tuple[Array, Optional[Array]]]
    prepare_queries: Callable[[Array], Array]
    exact: Callable[[Array, Array, int], Tuple[Array, Array]]
    rowwise: bool = True
    storage_tiers: Tuple[str, ...] = quant.STORAGE_TIERS

    def prepare_update(self, rows: Array) -> Tuple[Array, Optional[Array]]:
        """Incremental preparation of an appended row slice.

        Valid only for ``rowwise`` metrics; callers (``Index.add`` via
        ``repro.search.packed``) must check ``rowwise`` and fall back to a
        full ``prepare_database`` repack otherwise.
        """
        if not self.rowwise:
            raise ValueError(
                f"metric {self.name!r} is not row-wise; incremental "
                "preparation is undefined — repack the full database"
            )
        return self.prepare_database(rows)

    # -- quantize-aware packing (the repro.search.quant storage tiers) ------

    def storage_bias(
        self,
        stored: Array,
        scale: Optional[Array],
        storage: Optional[str] = None,
    ) -> Optional[Array]:
        """Metric bias of the values a quantized tier actually stores.

        The scan ranks by ``<q, x_hat> + bias`` where ``x_hat`` is the
        dequantized stored row — so the bias must be computed *from the
        stored values* (e.g. ``-||x_hat||^2/2`` for L2), not from the
        full-precision rows, or quantized scan scores would be internally
        inconsistent.  Implemented by re-running ``prepare_database`` on
        the dequantized rows and keeping only the bias; a custom metric
        for which that recipe is wrong should exclude the quantized tiers
        via ``storage_tiers``.

        ``storage`` names the tier explicitly (int8 and int4 are
        indistinguishable from the arrays alone — both carry int8 codes
        plus a scale); ``None`` falls back to the legacy scale-based
        inference for pre-int4 callers.
        """
        if storage is None:
            storage = "bf16" if scale is None else "int8"
        quant.check_metric_storage(self, storage)
        _, bias = self.prepare_database(quant.dequantize_rows(stored, scale))
        return bias

    def prepare_storage(
        self, rows: Array, storage: str
    ) -> quant.QuantizedRows:
        """Metric-prepare + tier-quantize ``rows`` (full pack granularity).

        Returns the stored rows, the int8 per-row scale (or None), the
        bias correction for the stored values, and the full-precision
        rescore tail (``exact_rows`` / ``exact_bias``).  For
        ``storage="f32"`` this is exactly ``prepare_database`` — stored
        and exact views alias the same arrays.
        """
        quant.check_metric_storage(self, storage)
        prepped, bias = self.prepare_database(rows)
        if not quant.is_quantized(storage):
            return quant.QuantizedRows(prepped, None, bias, prepped, bias)
        stored, scale = quant.quantize_rows(prepped, storage)
        return quant.QuantizedRows(
            stored,
            scale,
            self.storage_bias(stored, scale, storage),
            prepped,
            bias,
        )

    def prepare_update_storage(
        self, rows: Array, storage: str
    ) -> quant.QuantizedRows:
        """Incremental :meth:`prepare_storage` of an appended row slice.

        Same ``rowwise`` contract as :meth:`prepare_update`; quantization
        itself is per-row (per-row int8 scales), so slice and full packs
        agree exactly.
        """
        if not self.rowwise:
            raise ValueError(
                f"metric {self.name!r} is not row-wise; incremental "
                "preparation is undefined — repack the full database"
            )
        return self.prepare_storage(rows, storage)


_REGISTRY: Dict[str, Metric] = {}


def register_metric(metric: Metric, *, overwrite: bool = False) -> Metric:
    if metric.name in _REGISTRY and not overwrite:
        raise ValueError(f"metric {metric.name!r} already registered")
    _REGISTRY[metric.name] = metric
    return metric


def get_metric(metric) -> Metric:
    if isinstance(metric, Metric):
        return metric
    try:
        return _REGISTRY[metric]
    except KeyError:
        raise ValueError(
            f"unknown metric {metric!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def available_metrics() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# --- Exact baselines (recall evaluation / Faiss-Flat analogue) --------------


def exact_mips(queries, database, k: int = 10):
    scores = jnp.einsum("ik,jk->ij", queries, database)
    return jax.lax.top_k(scores, k)


def exact_l2nns(queries, database, k: int = 10):
    dists = half_norms(database)[None, :] - jnp.einsum(
        "ik,jk->ij", queries, database
    )
    vals, idxs = jax.lax.top_k(-dists, k)
    return -vals, idxs


def exact_cosine_nns(queries, database, k: int = 10):
    scores = jnp.einsum(
        "ik,jk->ij", l2_normalize(queries), l2_normalize(database)
    )
    return jax.lax.top_k(scores, k)


def exact_search(queries, database, k: int = 10, *, metric="mips"):
    """Exact top-k under any registered metric (same value contract)."""
    return get_metric(metric).exact(queries, database, k)


# --- Built-in metrics -------------------------------------------------------

register_metric(
    Metric(
        name="mips",
        negate_output=False,
        prepare_database=lambda db: (db, None),
        prepare_queries=lambda q: q,
        exact=exact_mips,
    )
)

register_metric(
    Metric(
        name="l2",
        negate_output=True,
        # bias = -||x||^2/2: maximizing <q,x> + bias == minimizing the
        # relaxed distance (Eq. 19, one COP folded into the bias row).
        prepare_database=lambda db: (db, -half_norms(db)),
        prepare_queries=lambda q: q,
        exact=exact_l2nns,
    )
)

register_metric(
    Metric(
        name="cosine",
        negate_output=False,
        prepare_database=lambda db: (l2_normalize(db), None),
        prepare_queries=l2_normalize,
        exact=exact_cosine_nns,
    )
)
