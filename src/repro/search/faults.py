"""Deterministic fault injection for the serving and index-mutation paths.

A production claim ("suitable for applications with frequent updates",
paper §1) is only worth what survives failure: allocation errors during
staging, transfer/dispatch errors from the runtime, a worker thread dying
mid-batch, a crash between snapshot writes.  None of those can be tested
by waiting for them to happen — this module makes them *injectable*, the
same way ``DISPATCH_COUNTS`` / ``PACK_EVENTS`` made the traffic contract
*observable*: named injection points threaded through the hot paths, a
seeded registry deciding deterministically which hits fire, and a typed
exception taxonomy the recovery code (retries, watchdog, load-shed) keys
on.  Chaos runs driven from the ``VirtualClock`` serve mode are therefore
fully reproducible: same seed + same schedule -> same failures, every run.

Injection points (``INJECTION_POINTS``)
---------------------------------------

  ==================== ====================================================
  ``serve.worker``     start of each ``SearchServer`` service cycle (the
                       worker-loop heartbeat; a ``WorkerDeath`` here kills
                       the worker *between* batches — queue intact)
  ``serve.staging_alloc`` bucket selection + host staging-buffer gather
  ``serve.transfer``   just before the host->device query copy
  ``serve.dispatch``   just before the coalesced ``index.search`` dispatch
                       (the retry loop's point: ``TransientFault`` here is
                       retried with backoff)
  ``serve.scatter``    before blocking on the device result and scattering
                       per-request slices
  ``index.add``        entry of ``Index.add``
  ``index.delete``     entry of ``Index.delete``
  ``index.save``       entry of ``Index.save`` (before any file is written)
  ``checkpoint.commit`` inside the snapshot writer, after the tmp dir is
                       fully written but *before* the atomic rename — the
                       crash-safety test point (a fault here must leave the
                       previously committed snapshot untouched)
  ==================== ====================================================

Exception taxonomy
------------------

  * :class:`InjectedFault`  — common base (a ``RuntimeError``).
  * :class:`TransientFault` — retryable: the serve retry loop backs off and
    redispatches (bounded by ``ServeConfig.max_dispatch_retries``).
  * :class:`FatalFault`     — non-retryable: fails the affected tickets /
    operation with a typed error; the server keeps serving.
  * :class:`WorkerDeath`    — simulates the worker thread dying.  The
    wall-clock watchdog (and the virtual-clock ``step()``) restarts the
    worker without dropping queued tickets.
  * :class:`DelayFault`     — a *slowdown*, not an error: a hit of kind
    ``"delay"`` sleeps ``FaultInjector.delay_s`` wall seconds and then
    returns normally (nothing is raised).  This is the deterministic way
    to drive the telemetry roofline-drift monitor out of band — the
    dispatch succeeds, it is just slow.  The class itself is the
    taxonomy marker; it is never raised.

Usage::

    from repro.search import faults

    inj = faults.FaultInjector(
        seed=7,
        rates={"serve.dispatch": 0.05},             # 5% of dispatches
        schedule=[("serve.worker", 3, "death")],    # 3rd cycle exactly
    )
    faults.install(inj)          # process-global, or SearchServer(faults=inj)
    try:
        ...                      # drive traffic; faults fire deterministically
    finally:
        faults.uninstall()

Determinism: each point owns an independent ``numpy`` generator seeded
from ``(seed, crc32(point))``, so firing decisions at one point never
perturb another's stream, and hit counters (``hits``) advance only when
the instrumented code path actually executes.  When no injector is
installed every ``fire()`` is a cheap no-op — production pays one dict
read per point.

Like ``repro.search.quant`` and ``cluster``, this module is a leaf:
nothing here imports the rest of ``repro.search``, so the serve/index/
checkpoint layers can all depend on it without cycles.
"""
from __future__ import annotations

import contextlib
import threading
import time
import zlib
from collections import Counter
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "DelayFault",
    "FatalFault",
    "FaultInjector",
    "INJECTION_POINTS",
    "InjectedFault",
    "TransientFault",
    "WorkerDeath",
    "active",
    "fire",
    "injected",
    "install",
    "uninstall",
]

INJECTION_POINTS: Tuple[str, ...] = (
    "serve.worker",
    "serve.staging_alloc",
    "serve.transfer",
    "serve.dispatch",
    "serve.scatter",
    "index.add",
    "index.delete",
    "index.save",
    "checkpoint.commit",
)


class InjectedFault(RuntimeError):
    """Base of every injected failure (``point`` names where it fired)."""

    def __init__(self, point: str, hit: int, detail: str = ""):
        self.point = point
        self.hit = hit
        super().__init__(
            f"injected fault at {point!r} (hit #{hit})"
            + (f": {detail}" if detail else "")
        )


class TransientFault(InjectedFault):
    """Retryable failure (e.g. a transient runtime/transfer error)."""


class FatalFault(InjectedFault):
    """Non-retryable failure: the operation fails with this typed error."""


class WorkerDeath(InjectedFault):
    """Simulated death of the serving worker (watchdog-recoverable)."""


class DelayFault(InjectedFault):
    """Taxonomy marker for the ``"delay"`` kind: a hit of this kind
    *sleeps* ``FaultInjector.delay_s`` and returns — it is never raised.
    Use it to inject a slow (but successful) dispatch, e.g. to drive the
    ``SearchServer`` roofline-drift monitor out of its band."""


_KINDS = {
    "transient": TransientFault,
    "fatal": FatalFault,
    "death": WorkerDeath,
    "delay": DelayFault,
}


def _check_point(point: str) -> None:
    if point not in INJECTION_POINTS:
        raise ValueError(
            f"unknown injection point {point!r}; known points: "
            f"{INJECTION_POINTS}"
        )


class FaultInjector:
    """Seeded, deterministic decision engine for the injection points.

    Args:
      seed: base seed; each point derives an independent RNG stream from
        ``(seed, crc32(point))`` so points never perturb each other.
      rates: ``{point: probability}`` — each hit of ``point`` fires with
        that probability (kind ``rate_kind``, default transient).
      schedule: ``(point, nth_hit, kind)`` triples — the *nth* hit of
        ``point`` (1-based) fires a fault of ``kind`` ("transient" |
        "fatal" | "death").  Exact and rate-independent: the canonical way
        to script a reproducible chaos scenario.
      rate_kind: the exception kind rate-based fires raise.
      delay_s: wall seconds a ``"delay"``-kind hit sleeps before
        returning (delay fires succeed slowly instead of raising).

    >>> inj = FaultInjector(schedule=[("serve.dispatch", 2, "transient")])
    >>> inj.fire("serve.dispatch")   # hit 1: passes
    >>> try:
    ...     inj.fire("serve.dispatch")  # hit 2: fires
    ... except TransientFault as e:
    ...     print(e.point, e.hit)
    serve.dispatch 2
    """

    def __init__(
        self,
        seed: int = 0,
        rates: Optional[Dict[str, float]] = None,
        schedule: Optional[Iterable[Sequence]] = None,
        rate_kind: str = "transient",
        delay_s: float = 0.05,
    ):
        self.seed = int(seed)
        if delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {delay_s}")
        self.delay_s = float(delay_s)
        self.rates: Dict[str, float] = {}
        for point, p in (rates or {}).items():
            _check_point(point)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"rate for {point!r} must be in [0,1], got {p}")
            self.rates[point] = float(p)
        if rate_kind not in _KINDS:
            raise ValueError(f"rate_kind must be one of {sorted(_KINDS)}")
        self.rate_kind = rate_kind
        self.schedule: Dict[Tuple[str, int], str] = {}
        for entry in schedule or ():
            point, nth, kind = entry
            _check_point(point)
            if kind not in _KINDS:
                raise ValueError(
                    f"schedule kind must be one of {sorted(_KINDS)}, "
                    f"got {kind!r}"
                )
            if int(nth) < 1:
                raise ValueError(f"schedule hits are 1-based, got {nth}")
            self.schedule[(point, int(nth))] = kind
        self.hits: Counter = Counter()
        self.fired: Counter = Counter()
        self._lock = threading.Lock()
        self._rngs: Dict[str, np.random.Generator] = {}
        self.reset()

    def reset(self) -> None:
        """Rewind hit counters and RNG streams to the initial state —
        after which the exact same fire pattern replays."""
        with self._lock:
            self.hits.clear()
            self.fired.clear()
            self._rngs = {
                point: np.random.default_rng(
                    [self.seed, zlib.crc32(point.encode())]
                )
                for point in INJECTION_POINTS
            }

    def fire(self, point: str) -> None:
        """Record one hit of ``point``; raise if the seed/schedule says so."""
        _check_point(point)
        with self._lock:
            self.hits[point] += 1
            hit = self.hits[point]
            kind = self.schedule.get((point, hit))
            if kind is None:
                rate = self.rates.get(point, 0.0)
                # Always draw when a rate is configured, even on non-firing
                # hits — the stream position must depend only on the hit
                # count for determinism.
                if rate > 0.0 and self._rngs[point].random() < rate:
                    kind = self.rate_kind
            if kind is None:
                return
            self.fired[point] += 1
        if kind == "delay":
            # A slowdown, not an error: sleep OUTSIDE the lock (other
            # points keep firing) and return without raising.
            time.sleep(self.delay_s)
            return
        raise _KINDS[kind](point, hit)


# -- process-global registry --------------------------------------------------

_ACTIVE: Optional[FaultInjector] = None


def install(injector: FaultInjector) -> FaultInjector:
    """Install ``injector`` process-globally (index/checkpoint points fire
    through this; ``SearchServer(faults=...)`` can override serve.*)."""
    global _ACTIVE
    _ACTIVE = injector
    return injector


def uninstall() -> None:
    """Remove the global injector; every ``fire()`` becomes a no-op."""
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[FaultInjector]:
    """The globally installed injector, or None."""
    return _ACTIVE


def fire(point: str) -> None:
    """Fire ``point`` on the global injector (no-op when none installed)."""
    if _ACTIVE is not None:
        _ACTIVE.fire(point)


@contextlib.contextmanager
def injected(injector: FaultInjector):
    """Scope an injector: installed on entry, uninstalled on exit.

    >>> with injected(FaultInjector()) as inj:
    ...     active() is inj
    True
    """
    prev = _ACTIVE
    install(injector)
    try:
        yield injector
    finally:
        if prev is None:
            uninstall()
        else:
            install(prev)
