"""SearchSpec: the frozen, hashable description of a search problem.

One spec owns everything that was previously scattered across keyword
arguments of five entry points: the metric, k, the recall target, the
backend choice, the compute dtype, and the kernel block sizes.  Because the
spec is frozen and hashable it doubles as (part of) the compile-cache key —
two searches with the same spec and the same operand shapes share one traced
program.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.search import quant

__all__ = ["BACKENDS", "SearchSpec"]

BACKENDS = ("auto", "xla", "pallas", "sharded")


@dataclasses.dataclass(frozen=True)
class SearchSpec:
    """Frozen description of an approximate-KNN search problem.

    Attributes:
      metric: registered metric name ("mips", "l2", "cosine", ...).
      k: number of neighbours returned per query.
      recall_target: analytic E[recall] target used to plan bins (Eq. 14).
      backend: "auto" (sharded if a mesh is attached, else pallas on TPU,
        else xla), or an explicit "xla" | "pallas" | "sharded".
      dtype: optional compute dtype name (e.g. "bfloat16") the operands are
        cast to before the distance matmul; None inherits the input dtype.
      storage: database storage tier — "f32" (exact, the default),
        "bf16", "int8" or "int4" (``repro.search.quant``).  Quantized
        tiers store the metric-prepared database at 2, 1 or 0.5
        bytes/element (per-row scale for int8/int4; int4 packs two codes
        per byte in the Pallas layout), scan it over all N rows, and
        exactly rescore an over-fetched candidate set against a
        full-precision tail, so the Eq. 13–14 recall guarantee holds in
        expectation while database HBM traffic drops 2–8x (Eq. 10/20).
        ``"f32"`` is bit-identical to the pre-quantization path.
      cluster: cluster-pruned scan front-end (``repro.search.cluster``).
        ``"auto"`` (the default) lets the planner decide: above the cost
        crossover the index builds a k-means coarse quantizer and each
        query scans only its top-rho clusters (plus the spill block);
        below the crossover nothing is built and the search is
        bit-identical to ``"off"``.  ``"off"`` never evaluates pruning.
        There are no other values — every cluster parameter (C, rho,
        capacities) is derived by the planner, never supplied by the user.
      rescore: run the exact second pass on quantized tiers.  ``None``
        (default) resolves to True whenever ``storage != "f32"`` and
        ``aggregate_to_topk`` holds; False skips the f32 rescore tail
        (lower footprint, approximate values, no over-fetch).  True is
        invalid for f32 storage (nothing to rescore) and with
        ``aggregate_to_topk=False`` (the raw bin winners are the output).
      block_m / max_block_n: Pallas tile sizes (queries resident per grid
        step / upper bound on the database tile, rounded to the bin size).
        ``None`` (the default) defers the choice to the kernel planner
        (``repro.search.plan``): ``Index.build`` resolves them analytically
        from the workload and device profile.  Explicit values pin the
        tile and are never overridden.
      query_block: `.search` auto-tiles query batches larger than this so
        the (query_block, N) score tile bounds VMEM/host memory.  ``None``
        defers to the planner, same contract as the tile sizes.
      stream: execute multi-block query batches as ONE compiled streaming
        program (``lax.map`` over (num_blocks, query_block, D)) instead of
        a Python loop of per-block dispatches.  False keeps the per-block
        loop — bit-identical results, one dispatch per block — which is
        the benchmark baseline and parity oracle, not a production path.
      aggregate_to_topk: run ExactRescoring (True) or return the raw L bin
        winners (False).
      use_bitonic: rescore with the paper-faithful bitonic network instead
        of ``lax.top_k``.  Off by default: compiling the bitonic network
        inside jit is pathologically slow on CPU XLA (minutes at L=256),
        and ``lax.top_k`` over the L candidates is exact either way.
      fused_select: run the Pallas backend's single-pass scan→select
        kernel (the top-k carry merges in VMEM during the scan — Eq. 20
        traffic: database bytes + O(k), no (M, N/bin_size) score-tile
        round trip).  ``None`` (default) resolves to True on the pallas
        backend whenever selection happens (``aggregate_to_topk`` or an
        enabled rescore); False pins the two-pass scan→merge path, the
        bit-identical parity oracle.  Ignored off the pallas backend and
        by the cluster-pruned front-end (its gathered scan has no
        streaming j-loop to carry state across).
      reduction_input_size_override: recall-accounting N for sharded inputs
        (paper §7); -1 means "use the operand's own N".
      serve_buckets: ascending micro-batch row counts the concurrent
        ``repro.search.serve.SearchServer`` pads coalesced batches to (each
        bucket is one pre-compiled program shape, so serving traffic never
        retraces).  ``None`` defers to the planner, which derives a
        power-of-two ladder up to ``query_block``
        (``repro.search.plan.plan_buckets``) — same contract as the tile
        fields.  Lists are coerced to tuples so the spec stays hashable.
      residency: where the packed database lives between searches —
        ``"hbm"`` (default: device-resident) or ``"host"`` (the cold
        tier: packed operands stay in host RAM and ``search`` streams
        fixed-shape row segments through device HBM, double-buffered one
        wave ahead, so N is bounded by host memory instead of one
        device's HBM).  Host residency runs on the xla backend only
        (``backend="pallas"``/``"sharded"`` are rejected) and disables
        cluster pruning — the pruned gather needs the whole database
        resident.
      segment_rows: rows per host-tier segment wave.  ``None`` defers to
        the planner, which sizes segments against the device HBM budget
        (``repro.search.plan.plan_segments``) — same contract as the
        tile fields.  Unused for ``residency="hbm"``.

    A freshly-constructed spec defers tiling to the planner; the spec held
    by a built ``Index`` is always fully resolved:

    >>> SearchSpec(metric="l2", k=4).resolved
    False
    >>> SearchSpec(k=4, block_m=256, max_block_n=1024,
    ...            query_block=4096).resolved
    True
    """

    metric: str = "mips"
    k: int = 10
    recall_target: float = 0.95
    backend: str = "auto"
    dtype: Optional[str] = None
    storage: str = "f32"
    cluster: str = "auto"
    rescore: Optional[bool] = None
    block_m: Optional[int] = None
    max_block_n: Optional[int] = None
    query_block: Optional[int] = None
    stream: bool = True
    aggregate_to_topk: bool = True
    use_bitonic: bool = False
    fused_select: Optional[bool] = None
    reduction_input_size_override: int = -1
    serve_buckets: Optional[Tuple[int, ...]] = None
    residency: str = "hbm"
    segment_rows: Optional[int] = None

    def __post_init__(self):
        if self.residency not in ("hbm", "host"):
            raise ValueError(
                f'residency must be "hbm" or "host", got {self.residency!r}'
            )
        if self.residency == "host" and self.backend in ("pallas", "sharded"):
            raise ValueError(
                f'residency="host" streams database segments through a '
                f"single device and requires the xla backend; got "
                f"backend={self.backend!r}"
            )
        if self.segment_rows is not None and self.segment_rows <= 0:
            raise ValueError(
                f"segment_rows must be positive, got {self.segment_rows}"
            )
        if self.residency == "host" and not self.aggregate_to_topk:
            raise ValueError(
                'residency="host" merges per-segment top-k carries and '
                "needs aggregate_to_topk=True: the raw bin winners of one "
                "segment wave are not comparable across waves"
            )
        if self.k <= 0:
            raise ValueError(f"k must be positive, got {self.k}")
        if not 0.0 < self.recall_target < 1.0:
            raise ValueError(
                f"recall_target must be in (0, 1), got {self.recall_target}"
            )
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {self.backend!r}"
            )
        quant.storage_bytes(self.storage)  # validate the tier name
        if self.cluster not in ("auto", "off"):
            raise ValueError(
                f'cluster must be "auto" or "off", got {self.cluster!r} — '
                "cluster parameters are planner-derived, not user knobs"
            )
        if self.rescore and self.storage == "f32":
            raise ValueError(
                "rescore=True requires a quantized storage tier "
                '("bf16", "int8" or "int4"); storage="f32" is already '
                "exact"
            )
        if self.fused_select and not self.aggregate_to_topk:
            raise ValueError(
                "fused_select=True needs aggregate_to_topk=True: the "
                "fused kernel's VMEM carry *is* the top-k selection, so "
                "there are no raw bin winners to return.  Use "
                "fused_select=False (or None) for the two-pass scan."
            )
        if self.rescore and not self.aggregate_to_topk:
            raise ValueError(
                "rescore=True needs aggregate_to_topk=True: with "
                "aggregate_to_topk=False the raw bin winners are the "
                "output, so there is no top-k to rescore into.  Use "
                "rescore=False for a raw quantized scan."
            )
        if self.storage != "f32":
            # Metric x storage compatibility, checked here when the metric
            # is already registered (Index.build re-checks eagerly so
            # late-registered metrics are covered too).
            from repro.search.metrics import _REGISTRY

            m = _REGISTRY.get(self.metric)
            if m is not None:
                quant.check_metric_storage(m, self.storage)
        for field in ("block_m", "max_block_n", "query_block"):
            v = getattr(self, field)
            if v is not None and v <= 0:
                raise ValueError(f"{field} must be positive, got {v}")
        if self.serve_buckets is not None:
            buckets = tuple(int(b) for b in self.serve_buckets)
            if not buckets or any(b <= 0 for b in buckets):
                raise ValueError(
                    f"serve_buckets must be positive, got {self.serve_buckets}"
                )
            if list(buckets) != sorted(set(buckets)):
                raise ValueError(
                    "serve_buckets must be strictly ascending, got "
                    f"{self.serve_buckets}"
                )
            object.__setattr__(self, "serve_buckets", buckets)
        # Metric existence is validated lazily by the registry (metrics.py)
        # so user-registered metrics can be referenced before import order
        # would otherwise allow.

    @property
    def rescore_enabled(self) -> bool:
        """Whether the two-pass quantized search runs its exact rescore.

        >>> SearchSpec(storage="int8").rescore_enabled
        True
        >>> SearchSpec(storage="f32").rescore_enabled
        False
        """
        if self.storage == "f32" or not self.aggregate_to_topk:
            return False
        return True if self.rescore is None else self.rescore

    @property
    def fused_select_enabled(self) -> bool:
        """Resolved ``fused_select`` (the pallas backend consults this).

        >>> SearchSpec().fused_select_enabled
        True
        >>> SearchSpec(aggregate_to_topk=False).fused_select_enabled
        False
        >>> SearchSpec(fused_select=False).fused_select_enabled
        False
        """
        if self.fused_select is not None:
            return self.fused_select
        # The fused kernel produces the selected top-k directly, so it
        # needs a selection stage to subsume; raw bin winners
        # (aggregate_to_topk=False) keep the two-pass scan.
        return self.aggregate_to_topk

    @property
    def resolved(self) -> bool:
        """True once every planner-deferred block field holds a value."""
        return not (
            self.block_m is None
            or self.max_block_n is None
            or self.query_block is None
        )

    def with_backend(self, backend: str) -> "SearchSpec":
        return dataclasses.replace(self, backend=backend)

    # -- snapshot (de)serialization ------------------------------------------

    def to_json_dict(self) -> dict:
        """JSON-safe field dict (``Index.save`` stamps it into snapshots).

        >>> SearchSpec.from_json_dict(SearchSpec(k=4).to_json_dict()).k
        4
        """
        d = dataclasses.asdict(self)
        if d["serve_buckets"] is not None:
            d["serve_buckets"] = list(d["serve_buckets"])
        return d

    @classmethod
    def from_json_dict(cls, d: dict) -> "SearchSpec":
        """Inverse of :meth:`to_json_dict`, with loud version-skew errors:
        a snapshot written by a newer code version may carry fields this
        version does not know."""
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(
                f"snapshot spec carries unknown fields {unknown} — written "
                "by a newer version? Rebuild the index or upgrade."
            )
        if d.get("serve_buckets") is not None:
            d["serve_buckets"] = tuple(d["serve_buckets"])
        return cls(**d)
