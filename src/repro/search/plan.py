"""Model-driven kernel planner: the paper's performance model as a subsystem.

The paper's central claim (abstract, §4–5) is that every kernel parameter of
the search can be derived *analytically* — from the accelerator performance
model (Eq. 4–10) and the recall guarantee (Eq. 13–14) — with no empirical
index tuning.  This module is where that happens: ``plan_search`` maps a
workload description ``(M, N, D, k, dtype, metric, recall_target)`` plus a
device profile onto a frozen :class:`Plan` holding

  * the bin layout ``(L, W)`` from the recall guarantee
    (``repro.core.binning``, Eq. 13–14),
  * the kernel tiles ``block_m`` / ``block_n`` sized against the device's
    on-chip memory budget and the MXU/VPU tiling contract,
  * the host-level ``query_block`` (bounding the (query_block, N) score
    tile of the XLA backend) and the ``stream`` decision,
  * roofline predictions — FLOPs, HBM bytes, COPs, the two operational
    intensities, attainable FLOP/s and the binding wall (Eq. 4–6, Eq. 20) —
    via ``repro.core.roofline``.

``Index.build(..., plan="model")`` (the default) consumes a Plan instead of
hard-coded tile sizes; ``plan="measure"`` refines the model's pick with a
short on-device sweep (:func:`tune_plan`, persisted in a :class:`PlanCache`);
``Index.explain()`` reports the plan with predicted — and optionally
measured — roofline position.

The planner is deliberately conservative where the model and the legacy
defaults agree: when the memory budget allows the historical (256, 1024)
tiles, it picks exactly those, so model-planned searches are bit-identical
to the previous hard-coded configuration (tested in ``tests/test_plan.py``).

Doctest — planning is pure math, no device needed:

>>> p = plan_search(n=1_000_000, d=128, k=10, m=10_000, metric="l2",
...                 recall_target=0.95, device="tpu_v4")
>>> p.num_bins >= 10 and p.expected_recall >= 0.95
True
>>> p.block_n % p.bin_size == 0 and p.d_pad % 128 == 0
True
>>> p.bottleneck in ("compute", "memory", "instruction")
True
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Dict, Optional, Tuple

from repro.core.binning import BinPlan, plan_bins, round_up
from repro.core.roofline import (
    HARDWARE,
    Hardware,
    KernelCost,
    attainable_flops,
    bottleneck,
    cops_per_dot,
    partial_reduce_cost,
    partial_reduce_fused_cost,
)
from repro.search import cluster as clusterlib
from repro.search import quant
from repro.search import telemetry
from repro.search.spec import SearchSpec

__all__ = [
    "Plan",
    "PlanCache",
    "plan_search",
    "plan_buckets",
    "plan_clusters",
    "plan_segments",
    "SEGMENT_ALIGN",
    "tune_plan",
    "detect_device",
    "hlo_check",
    "DEFAULT_BLOCK_M",
    "DEFAULT_BLOCK_N",
    "DEFAULT_QUERY_BLOCK",
    "SCORE_TILE_BUDGET",
    "MIN_SERVE_BUCKET",
    "CLUSTER_GATHER_PENALTY",
    "CLUSTER_SPEEDUP_BAR",
]

# The legacy hard-coded tiles, now the *anchors* the model shrinks from when
# the workload or the device budget demands it.  256 query rows keep the
# 128x128 MXU fed across two passes; 1024 database rows per tile is the
# empirically-validated VMEM sweet spot the paper's open-source kernels use.
DEFAULT_BLOCK_M = 256
DEFAULT_BLOCK_N = 1024
DEFAULT_QUERY_BLOCK = 4096

# The XLA backend materializes the (query_block, N) score tile in HBM before
# ApproxTopK consumes it; the planner bounds that tile to this many bytes.
SCORE_TILE_BUDGET = 64 * 2**20

# Smallest serving micro-batch shape the bucket ladder compiles: one sublane
# tile of query rows, so a lone 1-row request is not padded to a full
# query_block.
MIN_SERVE_BUCKET = 8

# Host-tier segment rows round up to this multiple so capacity growth
# (Index.add) lands on whole waves — the compiled wave program's shapes
# never change, keeping the zero-retrace steady state.
SEGMENT_ALIGN = 1024

# Cluster-pruning cost model (repro.search.cluster).  A gathered candidate
# row costs more than a streamed one — the pruned scan trades the fused
# kernel's sequential database stream for random row gathers — so pruned
# rows are priced at this multiple of a full-scan row when deciding the
# crossover.  4x is deliberately pessimistic for HBM gather granularity;
# it keeps the planner from enabling pruning on workloads where the win
# would be marginal.
CLUSTER_GATHER_PENALTY = 4.0

# Pruning is enabled only when the modeled row cost (C centroid dots +
# gather-penalized scanned rows) beats the full scan by at least this
# factor: below it, the bit-identical full scan is the better default.
CLUSTER_SPEEDUP_BAR = 2.0

_DTYPE_BYTES = {
    "float32": 4, "bfloat16": 2, "float16": 2, "int8": 1,
    "float64": 8, "f32": 4, "bf16": 2,
}

# Minimum second-to-last-dim tile (sublane count) per dtype on TPU; the last
# dim is always 128 lanes (see the Pallas tiling contract).  The 0.5 entry is
# the int4 tier: its Pallas layout packs two nibbles per int8 byte, so the
# stored tile is int8-shaped and tiles at 32 sublanes.
_SUBLANE = {4: 8, 2: 16, 1: 32, 8: 8, 0.5: 32}


def _dtype_bytes(dtype: Optional[str]) -> int:
    if dtype is None:
        return 4
    return _DTYPE_BYTES.get(str(dtype), 4)


def detect_device(name: Optional[str] = None) -> str:
    """Resolve a device-profile name against ``repro.core.roofline.HARDWARE``.

    ``None`` auto-detects from the live JAX backend: TPUs map onto the
    closest Table-1 profile by device kind, GPUs onto A100, anything else
    onto the ``"cpu"`` host profile (whose tile budget mirrors the TPU so
    host-planned layouts match device-planned ones).

    >>> detect_device("tpu_v4")
    'tpu_v4'
    """
    if name is not None:
        if name not in HARDWARE:
            raise ValueError(
                f"unknown device profile {name!r}; known: {sorted(HARDWARE)}"
            )
        return name
    import jax

    backend = jax.default_backend()
    if backend == "tpu":
        kind = jax.devices()[0].device_kind.lower()
        # device_kind strings look like "tpu v3", "tpu v4", "tpu v5 lite".
        # Only v5e/v5-lite maps to the v5e profile; other v5 variants
        # (e.g. v5p) have no profile yet and take the generic v4 default
        # rather than v5e's much lower roofline.
        if "v5e" in kind or "v5 lite" in kind or "v5lite" in kind:
            return "tpu_v5e"
        if "v4" in kind:
            return "tpu_v4"
        if "v3" in kind:
            return "tpu_v3"
        return "tpu_v4"
    if backend == "gpu":
        return "a100"
    return "cpu"


@dataclasses.dataclass(frozen=True)
class Plan:
    """The analytically-derived kernel configuration for one search workload.

    Everything ``Index.build`` needs to lay out the packed state and compile
    the search — plus the roofline prediction explaining *why* these numbers
    (Eq. 4–10), so ``Index.explain()`` can report predicted vs measured
    position against the three walls.

    Workload: ``m`` (query batch; 0 = unknown, predictions then assume one
    ``query_block``), ``n`` rows, ``d`` dims, ``k`` neighbours, ``metric``,
    ``dtype``, ``recall_target``, ``backend``, ``device`` (profile name).

    Derived layout: ``num_bins``/``log2_bin_size``/``padded_n`` (Eq. 13–14
    via ``repro.core.binning``), ``d_pad`` (lane padding), ``block_m`` /
    ``block_n`` (kernel tiles), ``query_block``, ``stream``.

    Predictions (per ``query_block``-sized dispatch): ``flops``,
    ``hbm_bytes``, ``cops`` (Appendix A.5), the intensities
    ``i_mem``/``i_cop``, ``attainable_flops`` and the binding ``bottleneck``
    wall (Eq. 6), and ``predicted_s``/``predicted_qps``.  The cost model
    matches the backend that runs: the fused-kernel Eq. 20 traffic model
    over the padded layout for ``pallas``, the unfused Level-3-BLAS shape
    over the raw operands for ``xla``/``sharded``.

    ``source`` records provenance: ``"model"`` (analytic), ``"measure"``
    (refined by :func:`tune_plan`), or ``"user"`` (explicit overrides pinned
    every choice).
    """

    # workload
    m: int
    n: int
    d: int
    k: int
    metric: str
    dtype: str
    recall_target: float
    backend: str
    device: str
    # bin layout (Eq. 13-14)
    num_bins: int
    log2_bin_size: int
    padded_n: int
    expected_recall: float
    # kernel layout
    d_pad: int
    block_m: int
    block_n: int
    query_block: int
    stream: bool
    # roofline prediction (Eq. 4-10)
    flops: float
    hbm_bytes: float
    cops: float
    i_mem: float
    i_cop: float
    attainable_flops: float
    bottleneck: str
    predicted_s: float
    predicted_qps: float
    source: str = "model"
    # recall-accounting N override (paper §7); carried so re-plans (growth,
    # shard, explain) keep the same accounting as the packed layout.
    reduction_input_size_override: int = -1
    # storage tier of the database rows (repro.search.quant): decides the
    # bytes/row the memory-wall terms above were computed with.
    storage: str = "f32"
    # whether the two-pass exact rescore runs (quantized tiers); its
    # O(M·L·D) cost is included in the prediction when True.
    rescore: bool = False
    # the over-fetched k the scan's bin layout was planned for (== k for
    # the f32 tier; quant.scan_k otherwise).
    k_scan: int = 0
    # cluster-pruned front-end (repro.search.cluster): None when the build
    # asked for cluster="off"; a ClusterPlan otherwise — with
    # ``enabled=False`` recording that ``cluster="auto"`` evaluated the
    # crossover and rejected pruning for this N (the bit-identical case).
    # When enabled, ``expected_recall`` above is the *product* bound
    # (collision term over the scanned slots x the cluster-miss term) and
    # the roofline numbers model the gathered pruned program.
    cluster: Optional[clusterlib.ClusterPlan] = None
    # database shard count for backend="sharded" (1 = unsharded/1-device):
    # the scan cost above is then priced per shard — O(min(M, N/shards)),
    # the §7 traffic contract — and the all-gather below is the only
    # cross-device term.
    db_shards: int = 1
    # bytes crossing the ICI per dispatch (each shard contributes its
    # O(k_scan) (f32 value, int32 global id) winners to the all-gather)
    # and the resulting collective wall time at the profile's
    # ici_bandwidth; both 0 when db_shards == 1.
    ici_bytes: float = 0.0
    ici_s: float = 0.0
    # host-RAM cold tier (spec.residency="host"): the segment-wave
    # schedule — fixed segment_rows per wave, num_segments waves per
    # search, two segments HBM-resident at once (scan + double-buffered
    # prefetch) inside hbm_budget_bytes.  All 0 for residency="hbm".
    residency: str = "hbm"
    segment_rows: int = 0
    num_segments: int = 0
    hbm_budget_bytes: float = 0.0

    @property
    def bin_size(self) -> int:
        return 1 << self.log2_bin_size

    @property
    def bin_plan(self) -> BinPlan:
        """The recall-guarantee layout as a ``repro.core.binning.BinPlan``."""
        return BinPlan(
            n=self.n, k=self.k, num_bins=self.num_bins,
            log2_bin_size=self.log2_bin_size, padded_n=self.padded_n,
            expected_recall=self.expected_recall,
        )

    @property
    def cost(self) -> KernelCost:
        return KernelCost(
            flops=self.flops, hbm_bytes=self.hbm_bytes, cops=self.cops
        )

    @property
    def hardware(self) -> Hardware:
        return HARDWARE[self.device]

    @property
    def serve_buckets(self) -> Tuple[int, ...]:
        """Micro-batch bucket ladder for the concurrent serving layer
        (``repro.search.serve``): pre-compiled coalesced-batch shapes up to
        one ``query_block`` — the planner-sized micro-batch."""
        return plan_buckets(self.query_block)

    def to_spec(self, base: Optional[SearchSpec] = None) -> SearchSpec:
        """Materialize a concrete ``SearchSpec`` from this plan.

        Block fields the ``base`` spec already pins (non-``None``) win over
        the plan — explicit user overrides are never silently replaced.
        """
        base = base or SearchSpec(
            metric=self.metric, k=self.k, recall_target=self.recall_target,
            backend=self.backend, storage=self.storage,
            # self.rescore is always resolved (never None) and False for
            # the f32 tier, which SearchSpec accepts — pass it verbatim so
            # an explicit rescore=False footprint plan stays rescore-off.
            rescore=self.rescore,
            residency=self.residency,
        )
        return dataclasses.replace(
            base,
            block_m=base.block_m or self.block_m,
            max_block_n=base.max_block_n or self.block_n,
            query_block=base.query_block or self.query_block,
            serve_buckets=base.serve_buckets
            or plan_buckets(base.query_block or self.query_block),
            segment_rows=base.segment_rows or (self.segment_rows or None),
        )

    def summary(self) -> dict:
        """Flat dict view (what ``Index.explain()`` embeds)."""
        out = dataclasses.asdict(self)
        out["bin_size"] = self.bin_size
        return out


def _vmem_budget(hw: Hardware) -> float:
    """Usable on-chip bytes per grid step: the operand tiles are
    double-buffered but the score/winner scratch is not, so ~3/4 of VMEM
    is the practical ceiling."""
    return 0.75 * hw.vmem_bytes


def _vmem_need(block_m: int, block_n: int, d_pad: int, dtype_bytes: int,
               bin_size: int, db_bytes: Optional[float] = None,
               k_scan: int = 0) -> float:
    """On-chip bytes one (block_m, block_n) grid step holds.

    ``db_bytes`` is the stored database tile's bytes/element (quantized
    tiers stream and hold narrower rows; int4 holds 0.5 — two nibbles per
    stored byte); default: ``dtype_bytes``.  ``k_scan`` charges the fused
    kernel's top-k carry — a persistent (block_m, k_scan) f32-value +
    int32-index scratch pair that lives in VMEM across the whole database
    stream, so it is budgeted alongside the per-step tiles.
    """
    if db_bytes is None:
        db_bytes = dtype_bytes
    return (
        d_pad * (block_m * dtype_bytes + block_n * db_bytes)  # operand tiles
        + block_m * block_n * 4                     # score tile (f32)
        + 2 * block_m * max(1, block_n // bin_size) * 4  # winners (val+idx)
        + 2 * block_m * k_scan * 4                  # fused top-k carry
    )


def _plan_tiles(
    n: int,
    d_pad: int,
    bin_size: int,
    m: Optional[int],
    dtype_bytes: int,
    hw: Hardware,
    *,
    block_m: Optional[int] = None,
    max_block_n: Optional[int] = None,
    db_bytes: Optional[float] = None,
    k_scan: int = 0,
) -> Tuple[int, int]:
    """Initial kernel tile sizes from the on-chip memory model.

    VMEM per grid step holds the query tile (block_m, d_pad), the database
    tile (block_n, d_pad), the score tile (block_m, block_n) and the bin
    winners.  Tiles honour the TPU tiling contract (sublane-multiple rows,
    128-lane columns) and never exceed the data: ``block_n`` stops at the
    bin-aligned database size, so a small database is not padded up to a
    full default tile.  ``block_m`` may subsequently be *escalated* by
    :func:`plan_search` to push the kernel off the memory wall (Eq. 10).
    """
    if db_bytes is None:
        db_bytes = dtype_bytes
    sublane = _SUBLANE.get(dtype_bytes, 8)
    if block_m is None:
        block_m = DEFAULT_BLOCK_M if m is None else min(
            DEFAULT_BLOCK_M, max(sublane, round_up(m, sublane))
        )

    if max_block_n is not None:
        # Pinned: honour it exactly the way the packed layout will
        # (packed._layout derives block_n = bin_size * (max_block_n //
        # bin_size)), so the plan always describes the executed tile.
        return block_m, bin_size * max(1, max_block_n // bin_size)

    budget = _vmem_budget(hw)
    # block_n must be a multiple of the bin size (the kernel's
    # (bm, bn) -> (bm, bins, bin_size) reshape) AND of the *stored*
    # dtype's sublane count (TPU second-to-minor tiling; int8 rows tile at
    # 32 sublanes); both are powers of two, so their lcm is the max.
    db_sublane = _SUBLANE.get(db_bytes, 8)
    unit = max(bin_size, db_sublane)
    n_aligned = round_up(n, unit)
    g_data = max(1, n_aligned // unit)
    g_anchor = max(1, DEFAULT_BLOCK_N // unit)
    g = min(g_data, g_anchor)
    while g > 1 and _vmem_need(
        block_m, g * unit, d_pad, dtype_bytes, bin_size, db_bytes, k_scan
    ) > budget:
        g -= 1
    return block_m, g * unit


def _escalate_block_m(
    block_m: int,
    block_n: int,
    m_eff: int,
    padded_n: int,
    d_pad: int,
    num_bins: int,
    c: float,
    dtype_bytes: int,
    bin_size: int,
    hw: Hardware,
    db_bytes: Optional[float] = None,
    k_scan: int = 0,
) -> int:
    """Grow the query tile until the memory wall clears the other walls.

    The kernel grid streams the full database once per ``block_m`` query
    rows (Eq. 20's ``ib``), so a too-small query tile makes the kernel
    memory-bound regardless of N.  The model doubles ``block_m`` — within
    the VMEM budget (which charges the fused carry at each candidate
    size), the query batch, and a 1024-row cap — until the attainable
    FLOP/s stop being memory-limited.  This is the planner reproducing
    the paper's Fig. 2 reasoning as a *decision* instead of a figure.
    Costs come from the fused single-pass model (the kernel this tile
    actually feeds); ``num_bins`` stays in the signature for the legacy
    two-pass callers in older tests.
    """
    ks = max(1, k_scan)
    cap = min(1024, max(block_m, round_up(m_eff, 8)))
    while block_m < cap:
        cost = partial_reduce_fused_cost(
            m_eff, padded_n, d_pad, ks,
            cops_per_dot=c, block_rows=block_m, dtype_bytes=dtype_bytes,
            db_bytes=db_bytes, block_n=block_n,
            bins_per_block=max(1, block_n // bin_size),
        )
        memory_wall = hw.hbm_bandwidth * cost.i_mem
        other_walls = min(hw.peak_flops, hw.peak_cops * cost.i_cop)
        if memory_wall >= other_walls:
            break
        bigger = min(cap, block_m * 2)
        if _vmem_need(bigger, block_n, d_pad, dtype_bytes, bin_size,
                      db_bytes, ks) > _vmem_budget(hw):
            break
        block_m = bigger
    return block_m


def _dense_cost(m: int, n: int, d: int, l: int, dtype_bytes: int,
                db_bytes: Optional[int] = None) -> KernelCost:
    """Cost of the *unfused* dense path (Remark 1 / Level-3 BLAS shape).

    ``dense_search`` materializes the full (M, N) f32 score matrix in HBM
    before ApproxTopK consumes it, over the unpadded (N, D) operands — so
    its model is operand reads + score write/read + bin winners, not the
    fused kernel's Eq. 20.  This is what makes the dense baseline
    memory-bound at paper scale, i.e. why the fused kernel exists.
    ``db_bytes`` prices the (N, D) operand read at the storage tier's
    bytes/element; the f32 score matrix dominates here regardless, which
    is why quantized tiers pay off most on the fused kernel.
    """
    if db_bytes is None:
        db_bytes = dtype_bytes
    flops = 2.0 * m * n * d
    hbm = (
        dtype_bytes * m * d + db_bytes * n * d
        + 4.0 * (2.0 * m * n + 2.0 * m * l)
    )
    cops = float(m) * n  # the reduction's compare chain
    return KernelCost(flops=flops, hbm_bytes=hbm, cops=cops)


def _rescore_cost(m: int, l: int, k_scan: int, d: int) -> KernelCost:
    """Added cost of the exact second pass (quantized tiers).

    The L bin winners are first cut to the ``k_scan`` best by quantized
    score (a compare chain over L, no HBM gather), then only those
    O(M·K') rows are gathered at full precision and re-scored — so the
    second pass stays O(M), inside Eq. 10's O(min(M, N)) budget, and its
    gather traffic scales with the over-fetch budget, not the bin count.
    """
    flops = 2.0 * m * k_scan * d
    hbm = 4.0 * (m * k_scan * d + 3.0 * m * k_scan)  # rows + bias/vals/idxs
    cops = float(m) * (l + k_scan)  # the cut + the exact compare chain
    return KernelCost(flops=flops, hbm_bytes=hbm, cops=cops)


def plan_clusters(
    *, n: int, k_scan: int, recall_target: float
) -> clusterlib.ClusterPlan:
    """Derive the cluster-pruning parameters — and the enable decision.

    All geometry comes from ``repro.search.cluster``'s closed forms (C =
    2^ceil(log2(sqrt(N))), rho from the geometric-decay miss budget, 25 %
    balance headroom per cluster, an always-scanned spill block); this
    wrapper adds the *cost* decision: per query the pruned path pays C
    centroid dots plus ``CLUSTER_GATHER_PENALTY`` x S gathered-row dots
    against the full scan's N, and pruning is enabled only when that wins
    by ``CLUSTER_SPEEDUP_BAR`` — plus sanity floors (the scanned slot
    count must comfortably hold the over-fetched ``k_scan``, and pruning a
    scan smaller than its own candidate set is never a win).

    >>> plan_clusters(n=8192, k_scan=10, recall_target=0.95).enabled
    True
    >>> plan_clusters(n=2048, k_scan=10, recall_target=0.95).enabled
    False
    """
    num_clusters = clusterlib.num_clusters_for(n)
    rows_per_cluster = clusterlib.rows_per_cluster_for(n, num_clusters)
    probes = clusterlib.probes_for(recall_target, num_clusters)
    spill = clusterlib.spill_capacity_for(n)
    budget = clusterlib.miss_budget_for(recall_target)
    # Inner-scan target so the product (collision x miss) meets the
    # original target: target / (1 - budget) = 2t/(1+t) < 1 always.
    target_scan = recall_target / (1.0 - budget)
    scan_rows = probes * rows_per_cluster + spill
    speedup = n / (num_clusters + CLUSTER_GATHER_PENALTY * scan_rows)
    enabled = (
        speedup >= CLUSTER_SPEEDUP_BAR
        and probes < num_clusters
        and scan_rows < n
        and scan_rows >= 4 * k_scan
    )
    return clusterlib.ClusterPlan(
        n=n, num_clusters=num_clusters, rows_per_cluster=rows_per_cluster,
        probes=probes, spill_capacity=spill, miss_budget=budget,
        target_scan=target_scan, predicted_speedup=speedup, enabled=enabled,
    )


def _cluster_cost(m: int, d: int, l: int, cp: clusterlib.ClusterPlan,
                  dtype_bytes: int, db_bytes: int) -> KernelCost:
    """Cost of the pruned gathered scan (all backends share this program).

    Centroid scoring is a small dense matmul; the candidate rows are then
    *gathered* — every query reads its own S rows with no cross-query
    reuse, so the database term is ``m*S*d`` at the storage tier's width
    (the pruning win is that ``S << N``, not better locality).  The fused
    Eq. 20 kernel is bypassed on this path: a gather-dominated scan has no
    sequential stream to fuse.
    """
    c, s = cp.num_clusters, cp.scan_rows
    flops = 2.0 * m * (c + s) * d
    hbm = (
        dtype_bytes * m * d                    # queries
        + 4.0 * c * d + 4.0 * c               # centroid table + bias
        + 4.0 * m * s                          # gathered candidate ids
        + db_bytes * m * s * d                 # gathered rows, no reuse
        + 4.0 * (2.0 * m * s + 2.0 * m * l)    # score tile + bin winners
    )
    cops = float(m) * (c + s)
    return KernelCost(flops=flops, hbm_bytes=hbm, cops=cops)


def plan_buckets(
    max_batch: int, *, min_bucket: int = MIN_SERVE_BUCKET
) -> Tuple[int, ...]:
    """Micro-batch bucket ladder for the concurrent serving layer.

    A coalesced batch of queries is padded up to the smallest bucket that
    holds it, so the server only ever dispatches one of these shapes — each
    bucket is compiled once and the steady state never retraces.  The
    ladder doubles from ``min_bucket`` (one sublane tile, so a lone tiny
    request is not padded to a full ``query_block``) up to ``max_batch``
    (the planner-sized micro-batch, normally one ``query_block``), which is
    always the last rung.  Padded rows cost FLOPs, so a geometric ladder
    bounds the waste at <2x while keeping the compile count logarithmic.

    >>> plan_buckets(64)
    (8, 16, 32, 64)
    >>> plan_buckets(100)
    (8, 16, 32, 64, 100)
    >>> plan_buckets(4)
    (4,)
    """
    if max_batch <= 0:
        raise ValueError(f"max_batch must be positive, got {max_batch}")
    if min_bucket <= 0:
        raise ValueError(f"min_bucket must be positive, got {min_bucket}")
    out = []
    b = min(min_bucket, max_batch)
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


def _plan_query_block(n: int, backend: str) -> int:
    """Host-level query tiling: bound the XLA backend's (qb, N) score tile.

    The fused Pallas kernel never materializes the full score matrix, so it
    keeps the full default.  So does the sharded backend: its score tile is
    ``(qb, n_local)`` *per shard*, and the shard count is unknown at plan
    time — shrinking against the global N would explode the dispatch count
    on exactly the large-N meshes sharding exists for.  Only the dense
    single-device XLA path, which writes the full ``4*qb*n`` score bytes
    per dispatch, shrinks ``qb`` under ``SCORE_TILE_BUDGET``.
    """
    if backend != "xla":
        return DEFAULT_QUERY_BLOCK
    qb = SCORE_TILE_BUDGET // max(1, 4 * n)
    if qb >= DEFAULT_QUERY_BLOCK:
        return DEFAULT_QUERY_BLOCK
    # Largest power of two under budget, floored at one sublane tile.
    qb = 1 << max(3, int(math.floor(math.log2(max(8, qb)))))
    return min(qb, DEFAULT_QUERY_BLOCK)


def plan_segments(
    *,
    n: int,
    d: int,
    db_bytes: int,
    hbm_budget_bytes: float,
    rescore: bool = False,
    segment_rows: Optional[int] = None,
) -> Tuple[int, int]:
    """Host-tier segment schedule: ``(segment_rows, num_segments)``.

    Two segments are HBM-resident at once — the wave being scanned and
    the double-buffered prefetch of the next — so one segment's bytes
    must fit in *half* of ``hbm_budget_bytes``.  A segment row costs its
    stored width plus the per-row bias/scale vectors, plus the f32
    rescore tail when the quantized two-pass runs.  Rows round up to
    ``SEGMENT_ALIGN`` (whole waves survive capacity growth without a
    shape change), and the returned schedule always covers ``n``:
    ``segment_rows * num_segments >= n`` — ``Index.build`` pads capacity
    up to that product so every wave is the same compiled shape.

    An explicit ``segment_rows`` pins the wave shape (the budget check is
    skipped — the caller owns the consequences), mirroring the tile-field
    contract everywhere else in this module.

    >>> plan_segments(n=4096, d=128, db_bytes=4, hbm_budget_bytes=2**20)
    (1024, 4)
    """
    if n <= 0:
        raise ValueError(f"need positive n, got {n}")
    per_row = float(d * db_bytes) + 8.0            # stored row + bias/scale
    if rescore:
        per_row += 4.0 * d + 4.0                   # f32 rescore tail + bias
    if segment_rows is None:
        if hbm_budget_bytes <= 0:
            raise ValueError(
                f"hbm_budget_bytes must be positive, got {hbm_budget_bytes}"
            )
        fit = int(hbm_budget_bytes / 2.0 / per_row)
        # Align DOWN so the two resident segments stay inside the budget;
        # one SEGMENT_ALIGN wave is the floor regardless (a sub-1024-row
        # wave would thrash the dispatch pipeline for no memory win).
        segment_rows = max(
            SEGMENT_ALIGN, (fit // SEGMENT_ALIGN) * SEGMENT_ALIGN
        )
    num_segments = -(-n // segment_rows)
    return segment_rows, num_segments


def plan_search(
    *,
    n: int,
    d: int,
    k: int,
    m: Optional[int] = None,
    metric: str = "mips",
    recall_target: float = 0.95,
    dtype: Optional[str] = None,
    backend: str = "xla",
    device: Optional[str] = None,
    reduction_input_size_override: int = -1,
    block_m: Optional[int] = None,
    max_block_n: Optional[int] = None,
    query_block: Optional[int] = None,
    storage: str = "f32",
    rescore: Optional[bool] = None,
    cluster: str = "off",
    db_shards: int = 1,
    residency: str = "hbm",
    segment_rows: Optional[int] = None,
    hbm_budget_bytes: Optional[float] = None,
) -> Plan:
    """Derive every kernel parameter analytically (Eq. 4–10 + Eq. 13–14).

    The planner never raises on awkward workloads — k = 1 (bins
    degenerate), N smaller than a database tile, D not a multiple of the
    128-lane contract, recall targets at the guarantee's ceiling — it falls
    back to the nearest valid layout instead (degenerate bins become the
    exact top-k layout; tiles clamp to the data).

    Explicit ``block_m`` / ``max_block_n`` / ``query_block`` overrides pin
    the corresponding choice (the prediction is then computed *for the
    pinned layout*, and ``source`` reports ``"user"`` if every knob was
    pinned).

    ``storage`` is the database's ``repro.search.quant`` tier: it sets the
    bytes/row of the Eq. 10/20 database-stream term (so the memory-wall
    escalation and roofline predictions shift with 2- or 1-byte rows), the
    stored-dtype sublane alignment of ``block_n``, and — when ``rescore``
    (default: on for quantized tiers) — the over-fetched scan k
    (``quant.scan_k``) plus the exact second pass's O(M·L·D) cost.

    ``cluster="auto"`` evaluates the cluster-pruned front-end
    (:func:`plan_clusters`): the returned plan carries a ``ClusterPlan``
    and — when it is past the cost crossover — the roofline prediction
    models the gathered pruned program and ``expected_recall`` becomes the
    product bound (collision over the scanned slots x the miss term).
    ``cluster="off"`` (the default) never evaluates it: ``plan.cluster``
    stays ``None`` and nothing else changes.

    >>> plan_search(n=100, d=8, k=1, device="tpu_v4").num_bins >= 1
    True
    >>> plan_search(n=64, d=7, k=4, device="cpu").d_pad
    128
    >>> p8 = plan_search(n=1 << 20, d=128, k=10, m=256, backend="pallas",
    ...                  device="tpu_v4", storage="int8")
    >>> pf = plan_search(n=1 << 20, d=128, k=10, m=256, backend="pallas",
    ...                  device="tpu_v4")
    >>> p8.hbm_bytes < 0.5 * pf.hbm_bytes  # >=2x less traffic (Eq. 10)
    True
    """
    if n <= 0 or d <= 0:
        raise ValueError(f"need positive n, d; got n={n}, d={d}")
    if k > n:
        raise ValueError(f"k={k} exceeds database size n={n}")
    device = detect_device(device)
    hw = HARDWARE[device]
    dtype_name = str(dtype) if dtype is not None else "float32"
    dbytes = _dtype_bytes(dtype)
    # storage="f32" means "store the compute dtype as-is" (pack_state casts
    # to spec.dtype before preparing), so its rows stream at dbytes; the
    # quantized tiers stream their own narrower width.
    sbytes = dbytes if storage == "f32" else quant.storage_bytes(storage)
    if storage == "int4" and backend != "pallas":
        # Only the Pallas packed layout stores two nibbles per byte; every
        # other backend scores the canonical int8-held codes, so its
        # database streams (and host segments hold) one byte per element.
        sbytes = 1.0
    if rescore and storage == "f32":
        raise ValueError(
            'rescore=True requires a quantized storage tier ("bf16", '
            '"int8" or "int4"); storage="f32" is already exact'
        )
    rescore_on = (storage != "f32") if rescore is None else rescore
    ks = quant.scan_k(storage, k, n=n) if rescore_on else k
    if cluster not in ("auto", "off"):
        raise ValueError(f'cluster must be "auto" or "off", got {cluster!r}')
    if residency not in ("hbm", "host"):
        raise ValueError(
            f'residency must be "hbm" or "host", got {residency!r}'
        )
    if residency == "host" and backend in ("pallas", "sharded"):
        raise ValueError(
            f'residency="host" requires the xla backend, got {backend!r}'
        )
    if db_shards < 1:
        raise ValueError(f"db_shards must be >= 1, got {db_shards}")
    cplan = (
        plan_clusters(n=n, k_scan=ks, recall_target=recall_target)
        # Host residency never evaluates pruning: the pruned program
        # gathers arbitrary rows, which needs the whole database resident.
        if cluster == "auto" and residency != "host" else None
    )
    seg_rows, num_segs, budget = 0, 0, 0.0
    if residency == "host":
        budget = float(hbm_budget_bytes or hw.hbm_bytes)
        seg_rows, num_segs = plan_segments(
            n=n, d=d, db_bytes=sbytes, hbm_budget_bytes=budget,
            rescore=rescore_on, segment_rows=segment_rows,
        )

    bins = plan_bins(
        n, ks, recall_target,
        reduction_input_size_override=reduction_input_size_override,
    )
    d_pad = round_up(d, 128)
    bm, bn = _plan_tiles(
        n, d_pad, bins.bin_size, m, dbytes, hw,
        block_m=block_m, max_block_n=max_block_n, db_bytes=sbytes,
        k_scan=ks,
    )
    # Host residency materializes a (qb, segment_rows) score tile per
    # wave, not (qb, N) — size the query block against the wave shape.
    qb = query_block or _plan_query_block(
        seg_rows if residency == "host" else n, backend
    )

    m_eff = m if m else qb
    flags = dict(
        l2=(metric == "l2"),
        non_pow2_n=(bins.padded_n != n),
        # D is padded with zero lanes at pack time — exact for dot
        # products, so no runtime masking COP; likewise the fused bias row
        # folds the ||x||^2/2 broadcast into the tombstone/tail mask add
        # (Appendix A.5 — this is why the packed layout exists).
        padded_d=False,
        broadcast_norm=False,
    )
    c = cops_per_dot(**flags)
    if backend == "pallas":
        # Only the fused kernel consumes block_m; escalate it off the
        # memory wall (Eq. 10/20) and cost the padded kernel layout.
        if block_m is None:
            bm = _escalate_block_m(
                bm, bn, m_eff, bins.padded_n, d_pad, bins.num_bins, c,
                dbytes, bins.bin_size, hw, db_bytes=sbytes, k_scan=ks,
            )
        # The kernel clamps its query tile to the sublane-rounded batch
        # (kernels.partial_reduce._effective_block_m), so a 1-row search
        # pads to 8 MXU rows, not a full block_m — model the padded shape
        # the kernel actually runs, then price the fused single-pass
        # program: the database streamed once per query block plus the
        # O(M·k_scan) result, with no score-tile HBM round trip.
        sublane_q = _SUBLANE.get(dbytes, 8)
        bm_eff = min(bm, max(sublane_q, round_up(max(m_eff, 1), sublane_q)))
        m_pad = round_up(max(m_eff, 1), bm_eff)
        cost = partial_reduce_fused_cost(
            m_pad, bins.padded_n, d_pad, ks,
            cops_per_dot=c, block_rows=bm_eff, dtype_bytes=dbytes,
            db_bytes=sbytes, block_n=bn,
            bins_per_block=max(1, bn // bins.bin_size),
        )
    else:
        # The dense xla path (and each sharded shard) runs the *unpadded*
        # operands unfused — model the program that actually executes.
        # With db_shards > 1 the shards run concurrently, so the wall is
        # ONE shard's scan over N/shards rows (bins laid against the
        # global N, §7) plus the ICI all-gather priced below.
        n_scan, scan_bins = n, bins.num_bins
        if backend == "sharded" and db_shards > 1:
            n_scan = -(-n // db_shards)
            scan_bins = plan_bins(
                n_scan, min(ks, n_scan), recall_target,
                reduction_input_size_override=n,
            ).num_bins
        cost = _dense_cost(m_eff, n_scan, d, scan_bins, dbytes, sbytes)
    expected = bins.expected_recall
    if cplan is not None and cplan.enabled:
        # The pruned gathered program replaces the scan cost wholesale,
        # and the guarantee becomes the collision x miss product over the
        # S scanned slots (the full-scan bin fields above still describe
        # the packed layout, which clustering leaves untouched).
        cost = _cluster_cost(m_eff, d, bins.num_bins, cplan, dbytes, sbytes)
        expected = cplan.recall_decomposition(ks)["expected_recall"]
    if rescore_on:
        extra = _rescore_cost(m_eff, bins.num_bins, ks, d)
        cost = KernelCost(
            flops=cost.flops + extra.flops,
            hbm_bytes=cost.hbm_bytes + extra.hbm_bytes,
            cops=cost.cops + extra.cops,
        )
    att = attainable_flops(cost, hw)
    predicted_s = cost.flops / att
    ici_bytes = ici_s = 0.0
    if backend == "sharded" and db_shards > 1:
        # The §7 collective: every shard all-gathers its O(k_scan) (f32
        # value, int32 global id) winners to every other shard — 8 bytes
        # per candidate, shards x candidates rows total.  This is the
        # ONLY cross-device traffic of a search, which is the whole
        # traffic-contract argument.
        # Rescore cuts each shard's contribution to k_scan rows; the
        # plain dense path all-gathers its L bin winners.
        cand = ks if rescore_on else scan_bins
        ici_bytes = 8.0 * m_eff * cand * db_shards
        ici_s = ici_bytes / hw.ici_bandwidth
        predicted_s = predicted_s + ici_s
    pinned = all(v is not None for v in (block_m, max_block_n, query_block))
    return Plan(
        m=m or 0, n=n, d=d, k=k, metric=metric, dtype=dtype_name,
        recall_target=recall_target, backend=backend, device=device,
        num_bins=bins.num_bins, log2_bin_size=bins.log2_bin_size,
        padded_n=bins.padded_n, expected_recall=expected,
        d_pad=d_pad, block_m=bm, block_n=bn, query_block=qb,
        stream=True,
        flops=cost.flops, hbm_bytes=cost.hbm_bytes, cops=cost.cops,
        i_mem=cost.i_mem, i_cop=cost.i_cop,
        attainable_flops=att, bottleneck=bottleneck(cost, hw),
        predicted_s=predicted_s, predicted_qps=m_eff / predicted_s,
        source="user" if pinned else "model",
        reduction_input_size_override=reduction_input_size_override,
        storage=storage, rescore=rescore_on, k_scan=ks, cluster=cplan,
        db_shards=db_shards, ici_bytes=ici_bytes, ici_s=ici_s,
        residency=residency, segment_rows=seg_rows, num_segments=num_segs,
        hbm_budget_bytes=budget,
    )


# --- measured refinement (subsumes the old hillclimb loop) -------------------


def time_search(index, queries, *, repeats: int = 3, passes: int = 2
                ) -> float:
    """Wall seconds per ``index.search(queries)``, compile excluded.

    One warmup dispatch (triggers trace + compile), then the best-of-
    ``passes`` mean over ``repeats`` searches — the same protocol as
    ``benchmarks/bench_search.py``, shared here so ``tune_plan`` and
    ``Index.explain(measure=True)`` cannot drift apart.
    """
    index.search(queries).values.block_until_ready()
    best = float("inf")
    for _ in range(passes):
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = index.search(queries)
        out.values.block_until_ready()
        best = min(best, (time.perf_counter() - t0) / repeats)
    # The plan="measure" signal is telemetry too: explain(measure=True) /
    # tune_plan refinements land next to the serve-path drift series.
    telemetry.registry().observe(
        "repro_plan_measured_wall_seconds", best,
        rows=queries.shape[0],
    )
    return best


def _with_measured_tiles(plan: Plan, bm: int, bn: int, qb: int) -> Plan:
    """Re-derive the plan for the measured tile triple.

    A plain ``dataclasses.replace`` of the tiles would leave the roofline
    prediction (flops/bytes/bottleneck/predicted_s) describing the *old*
    tiles; re-running ``plan_search`` with the winners pinned keeps the
    prediction consistent with the configuration it describes.
    """
    refreshed = plan_search(
        n=plan.n, d=plan.d, k=plan.k, m=plan.m or None, metric=plan.metric,
        recall_target=plan.recall_target, dtype=plan.dtype,
        backend=plan.backend, device=plan.device,
        reduction_input_size_override=plan.reduction_input_size_override,
        block_m=bm, max_block_n=bn, query_block=qb,
        storage=plan.storage, rescore=plan.rescore,
        cluster="auto" if plan.cluster is not None else "off",
        db_shards=plan.db_shards, residency=plan.residency,
        segment_rows=plan.segment_rows or None,
        hbm_budget_bytes=plan.hbm_budget_bytes or None,
    )
    return dataclasses.replace(refreshed, source="measure")


class PlanCache:
    """Persistent store of measured plan refinements.

    Keys are the full workload signature (device, backend, metric, dtype,
    shapes, recall target); values are the winning tile triple plus the
    measured wall time.  Backed by a JSON file when ``path`` is given (or
    the ``REPRO_PLAN_CACHE`` environment variable is set); in-memory
    otherwise.  Corrupt or missing files are treated as empty.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path or os.environ.get("REPRO_PLAN_CACHE")
        self._entries: Dict[str, dict] = {}
        if self.path and os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    self._entries = json.load(f)
            except (OSError, ValueError):
                self._entries = {}

    @staticmethod
    def key(plan: Plan, spec: Optional[SearchSpec] = None) -> str:
        base = (
            f"{plan.device}/{plan.backend}/{plan.metric}/{plan.dtype}"
            f"/m{plan.m}/n{plan.n}/d{plan.d}/k{plan.k}/r{plan.recall_target}"
        )
        if plan.storage != "f32":
            # Tiers tile and cost differently; never serve a measured f32
            # layout to a quantized build (or vice versa).
            base += f"/st-{plan.storage}" + ("" if plan.rescore else "-raw")
        if plan.cluster is not None and plan.cluster.enabled:
            # The pruned gathered program times nothing like the full
            # scan; keep its measurements in their own bucket.
            base += "/cl"
        if plan.db_shards > 1:
            base += f"/sh{plan.db_shards}"
        if plan.residency != "hbm":
            # Segment waves time nothing like a resident scan.
            base += f"/host{plan.segment_rows}"
        if spec is not None and not (
            spec.block_m is None
            and spec.max_block_n is None
            and spec.query_block is None
        ):
            # User-pinned knobs constrain the sweep, so results measured
            # under pins must not be served to unpinned workloads.
            base += f"/pin{spec.block_m}-{spec.max_block_n}-{spec.query_block}"
        return base

    def get(self, plan: Plan, spec: Optional[SearchSpec] = None
            ) -> Optional[dict]:
        return self._entries.get(self.key(plan, spec))

    def put(self, plan: Plan, entry: dict,
            spec: Optional[SearchSpec] = None) -> None:
        self._entries[self.key(plan, spec)] = entry
        if self.path:
            with open(self.path, "w") as f:
                json.dump(self._entries, f, indent=1, sort_keys=True)

    def __len__(self) -> int:
        return len(self._entries)


def _tile_candidates(plan: Plan, spec: Optional[SearchSpec] = None) -> list:
    """Small neighbourhood sweep around the model's pick.

    Halved/doubled tiles, clamped to validity (sublane floor, bin-size
    multiples, never beyond the data) — the refinement is a *local* check
    of the model, not a grid search; anything further from the model's
    optimum than 2x is the model being wrong, which is a bug to fix in the
    model, not something to tune around.  Only knobs the sweep may
    legitimately move are varied: user-pinned ``spec`` fields stay fixed,
    and the dense XLA / sharded paths ignore the Pallas tiles, so for them
    only ``query_block`` varies.
    """
    sublane = _SUBLANE.get(_dtype_bytes(plan.dtype), 8)
    # the database tile is stored-dtype (int8 tiles at 32 sublanes); the
    # query tile (block_m) follows the compute dtype — same split as
    # _plan_tiles, or the sweep would propose Mosaic-mistiled candidates.
    sbytes = (
        _dtype_bytes(plan.dtype) if plan.storage == "f32"
        else quant.storage_bytes(plan.storage)
    )
    unit = max(plan.bin_size, _SUBLANE.get(sbytes, 8))
    n_aligned = round_up(plan.n, unit)

    def clamp_bm(v):
        return max(sublane, min(1024, round_up(v, sublane)))

    def clamp_bn(v):
        return max(unit, min(n_aligned, round_up(v, unit)))

    def clamp_qb(v):
        return max(8, min(8192, round_up(v, 8)))

    pallas = plan.backend == "pallas"
    m_factors = (1, 0.5, 2) if pallas and (
        spec is None or spec.block_m is None) else (1,)
    n_factors = (1, 0.5, 2) if pallas and (
        spec is None or spec.max_block_n is None) else (1,)
    q_factors = (1, 0.5, 2) if (
        spec is None or spec.query_block is None) else (1,)
    cands = []
    for fm in m_factors:
        for fn in n_factors:
            for fq in q_factors:
                c = (
                    clamp_bm(int(plan.block_m * fm)),
                    clamp_bn(int(plan.block_n * fn)),
                    clamp_qb(int(plan.query_block * fq)),
                )
                if c not in cands:
                    cands.append(c)
    return cands


def tune_plan(
    database,
    plan: Plan,
    *,
    spec: Optional[SearchSpec] = None,
    cache: Optional[PlanCache] = None,
    repeats: int = 3,
    interpret: Optional[bool] = None,
) -> Plan:
    """Refine a model plan with a short on-device sweep (``plan="measure"``).

    Builds a throwaway index per candidate tile triple, times a
    ``query_block``-sized synthetic batch, and returns the plan rewritten
    with the fastest configuration (``source="measure"``).  Results persist
    in ``cache`` so the sweep runs once per workload signature per device.

    ``spec`` is the workload's real ``SearchSpec``: candidates are built by
    replacing only its tile fields, so the sweep times the exact program
    the index will run (same dtype, rescoring mode, recall accounting) and
    user-pinned tile fields are never varied.  Pinned sweeps are cached
    under a distinct key so their result is not served to unpinned builds.

    This subsumes the old per-config hillclimb harness for search kernels:
    the model proposes, one bounded measurement disposes.
    """
    import jax
    from repro.search.index import Index  # deferred: index imports plan

    if cache is None:  # NOT ``or``: an empty PlanCache is len()==0/falsy
        cache = PlanCache()
    base_spec = spec if spec is not None else SearchSpec(
        metric=plan.metric, k=plan.k, recall_target=plan.recall_target,
        backend=plan.backend, dtype=None if plan.dtype == "float32"
        else plan.dtype, storage=plan.storage, rescore=plan.rescore,
    )
    hit = cache.get(plan, spec)
    if hit is not None:
        return _with_measured_tiles(
            plan, hit["block_m"], hit["block_n"], hit["query_block"]
        )

    m_eff = plan.m or plan.query_block
    queries = jax.random.normal(
        jax.random.PRNGKey(0), (min(m_eff, 2 * plan.query_block), plan.d)
    )
    best, best_wall = None, float("inf")
    last_error: Optional[Exception] = None
    for bm, bn, qb in _tile_candidates(plan, spec):
        cand = dataclasses.replace(
            base_spec, block_m=bm, max_block_n=bn, query_block=qb,
        )
        try:
            # The fully-pinned spec makes plan="model" a no-op passthrough,
            # so candidate builds never recurse into another sweep.
            index = Index.build(
                database, spec=cand, plan="model", interpret=interpret
            )
            wall = time_search(index, queries, repeats=repeats, passes=1)
        except Exception as e:  # invalid candidate on this backend — skip
            last_error = e
            continue
        if wall < best_wall:
            best, best_wall = (bm, bn, qb), wall
    if best is None:
        # Every candidate failed: keep the model's answer, but loudly —
        # a systemic build/search error here would bite real searches too.
        import warnings

        warnings.warn(
            "plan measurement failed for every candidate; keeping the "
            f"unmeasured model plan (last error: {last_error!r})",
            RuntimeWarning,
            stacklevel=2,
        )
        return plan
    cache.put(plan, {
        "block_m": best[0], "block_n": best[1], "query_block": best[2],
        "wall_s": best_wall, "source": "measure",
    }, spec)
    return _with_measured_tiles(plan, *best)


# --- HLO cross-check (absorbing analysis.hlo_cost into the planner) ----------


def hlo_check(plan: Plan, lowered_text: str) -> dict:
    """Compare the plan's analytic cost against compiler-reported HLO cost.

    ``lowered_text`` is optimized HLO (``jax.jit(f).lower(...).compile()
    .as_text()``).  Returns the analytic and HLO FLOP counts plus their
    ratio — the planner's self-audit that Eq. 4–10 describe the program XLA
    actually built (the matmul FLOPs must agree; byte models are
    fusion-granularity estimates on both sides, so only reported).
    """
    from repro.analysis.hlo_cost import analyze_hlo

    hlo = analyze_hlo(lowered_text)
    return {
        "model_flops": plan.flops,
        "hlo_dot_flops": hlo.dot_flops,
        "flops_ratio": hlo.dot_flops / max(plan.flops, 1e-30),
        "model_hbm_bytes": plan.hbm_bytes,
        "hlo_hbm_bytes": hlo.hbm_bytes,
        "hlo_hbm_bytes_bounds": (hlo.hbm_bytes_lo, hlo.hbm_bytes_hi),
        "model_cops": plan.cops,
        "hlo_cop_count": hlo.cop_count,
    }
