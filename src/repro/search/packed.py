"""PackedState: device-resident, backend-layout search operands.

The paper's performance model (Eq. 10) bounds memory traffic at
``I_MEM ~ O(min(M, N))`` — which only holds if the (N, D) database is
touched *once* per search, not re-padded / re-prepared inside every
dispatch.  ``PackedState`` is the layer that guarantees it: at
``Index.build`` / mutation time (never at search time) it materializes

  * the metric-prepared, dtype-cast database in the resolved backend's
    native layout (Pallas: padded to the kernel tiling contract —
    D to a multiple of 128, N to a multiple of ``block_n``),
  * the fused bias row — metric bias (e.g. ``-||x||^2/2`` for L2),
    tombstone mask, and non-power-of-2 tail mask in one additive COP
    (paper Appendix A.5),
  * the bin plan the layout was derived from,

and hands backends pre-packed operands so the steady-state search
dispatch only ever pads the (M, D) *query* block.

Mutation contract (what patches what — the invalidation rules):

  * ``update_rows``  (``Index.add`` without growth): metric-prepares only
    the appended row slice (``Metric.prepare_update``) and patches the db
    rows + bias entries in place — O(r·D), zero O(N·D) work.
  * ``delete_rows``  (``Index.delete``): patches the bias row entries to
    ``MASK_VALUE`` — O(|ids|), the db rows are untouched.
  * ``relayout``     (capacity growth / resharding / backend switch): one
    O(N·D) device-side copy into the new layout, but *no* metric
    re-preparation of existing rows.
  * ``pack_state``   (build / spec change / non-rowwise metric): the only
    full pack — dtype cast + ``Metric.prepare_database`` over all rows.

``PACK_EVENTS`` counts these by name ("full_pack", "relayout",
"rows_updated", "bias_patched", "restore" — plus, on clustered indexes
only, "cluster_built" / "cluster_assigned" / "recluster") so tests and
benchmarks can assert the steady state performs none of them (and that a
snapshot restore performs *only* "restore" — no pack, no k-means).

Clustered indexes (``repro.search.cluster``) add a :class:`ClusterState`
of *side tables* — centroids, per-cluster row-id slots, a spill block —
while the packed arrays above stay in user row order, byte-identical to
the unclustered layout.  The tables are search operands like the bias
row, so slot patches never invalidate compiled programs; deletes need no
cluster work at all (the pruned scan gathers the fused bias row, which
already carries the tombstones).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.binning import BinPlan, plan_bins, round_up
from repro.search import cluster as clusterlib
from repro.search import quant
from repro.search import telemetry
from repro.search.backends import MASK_VALUE
from repro.search.metrics import Metric
from repro.search.spec import SearchSpec

__all__ = [
    "PACK_EVENTS",
    "PackedState",
    "fuse_bias",
    "pack_state",
    "rebuild_cluster",
    "reset_pack_events",
    "restore_state",
    "scan_k_for",
    "snapshot_state",
]

# event name -> count of packing work performed (test observability hook;
# see module docstring for the event taxonomy).  AtomicCounter + registry
# adoption: see ``repro.search.telemetry``.
PACK_EVENTS = telemetry.AtomicCounter()
telemetry.registry().register_counter_dict(
    "repro_pack_events_total", PACK_EVENTS, "event",
    "packing/cluster/restore work performed (repro.search.packed)",
)


def reset_pack_events() -> None:
    """Zero ``PACK_EVENTS`` (use in tests instead of counter arithmetic).

    Deprecated thin alias: ``repro.search.telemetry.reset_all()`` zeroes
    this and every other global series in one call."""
    PACK_EVENTS.clear()


def fuse_bias(
    metric_bias: Optional[jnp.ndarray],
    live: Optional[jnp.ndarray] = None,
    *,
    num_rows: Optional[int] = None,
) -> jnp.ndarray:
    """Fuse metric bias and tombstone mask into one additive (n,) f32 row.

    ``live=None`` means every row is live (the functional one-shot path).
    The ``maximum(..., MASK_VALUE)`` clamp keeps the row finite so the
    MXU/VPU paths stay NaN-free while still losing every comparison.
    """
    if live is None:
        if metric_bias is None:
            return jnp.zeros((num_rows,), jnp.float32)
        return jnp.maximum(metric_bias.astype(jnp.float32), MASK_VALUE)
    tomb = jnp.where(live, 0.0, MASK_VALUE).astype(jnp.float32)
    if metric_bias is None:
        return tomb
    return jnp.maximum(tomb + metric_bias.astype(jnp.float32), MASK_VALUE)


@dataclasses.dataclass
class PackedState:
    """Device-resident operands for one (backend, capacity, spec) layout.

    Attributes:
      backend: "xla" | "pallas" | "sharded" — decides the layout.
      db: metric-prepared database.  (n, d) for xla/sharded; padded
        (n_pad, d_pad) for pallas (tiling contract of the fused kernel).
      bias: fused bias row.  (n,) f32 for xla/sharded; (1, n_pad) for
        pallas with the tail positions pre-masked to ``MASK_VALUE``.
      n: logical row space covered (== Index.capacity when packed).
      d: logical feature dim (before lane padding).
      plan: the BinPlan the pallas layout was derived from.  For quantized
        tiers the plan is laid out for the over-fetched scan k
        (``repro.search.quant.scan_k``), not the user's k.
      bin_size / block_n: pallas kernel tile parameters (block_n == 0 for
        non-pallas layouts).
      storage: the ``repro.search.quant`` tier ``db`` is stored in.  For
        ``"int4"`` the pallas layout stores two codes per byte (db shape
        (n_pad, d_pad/2) int8); other backends keep the canonical one
        code per byte.
      scale: per-row dequantization scale (int8/int4 tiers) — (n,) f32,
        or (1, n_pad) for the pallas layout; None for unscaled tiers.
      rescore_db: full-precision metric-prepared rows (n, d) — the exact
        rescore tail the two-pass search gathers candidates from; None
        when rescoring is disabled or storage is "f32".
      rescore_bias: fused f32 bias row (n,) for the rescore pass — the
        *exact* metric bias plus the same tombstone mask as ``bias``, so
        rescoring can never resurrect a deleted (or padded) row.
    """

    backend: str
    db: jnp.ndarray
    bias: jnp.ndarray
    n: int
    d: int
    plan: BinPlan
    bin_size: int
    block_n: int
    storage: str = "f32"
    scale: Optional[jnp.ndarray] = None
    rescore_db: Optional[jnp.ndarray] = None
    rescore_bias: Optional[jnp.ndarray] = None
    # cluster-pruning side tables (repro.search.cluster); None on
    # unclustered layouts — in which case nothing below changes shape,
    # content or operand order (the bit-identical guarantee).
    cluster: Optional[clusterlib.ClusterState] = None
    # set when the planner enabled pruning but the build-time empirical
    # miss check measured this rate and rejected the tables (structureless
    # data the decay model does not fit); the layout then behaves exactly
    # like cluster="off".  Surfaced by Index.explain().
    cluster_rejected_miss: Optional[float] = None
    # dtype the database was cast to before preparation/quantization;
    # incremental updates must repeat the same cast-then-prepare order so
    # slice and full packs agree exactly (db.dtype itself is the *stored*
    # dtype on quantized tiers, which is not the same thing).
    compute_dtype: str = "float32"

    # -- logical views --------------------------------------------------------

    def rows(self) -> jnp.ndarray:
        """The prepared rows without layout padding: (n, d).

        Always the *canonical* stored form — for the pallas int4 layout
        (two codes per byte on device) the nibbles are unpacked back to
        one int8 code per element, so relayout/snapshot consumers never
        see the packed width.
        """
        if self.storage == "int4" and self.backend == "pallas":
            return quant.unpack_int4_rows(self.db[: self.n])[:, : self.d]
        return self.db[: self.n, : self.d]

    def bias_row(self) -> jnp.ndarray:
        """The fused bias without layout padding: (n,)."""
        flat = self.bias[0] if self.bias.ndim == 2 else self.bias
        return flat[: self.n]

    def scale_row(self) -> Optional[jnp.ndarray]:
        """The int8 per-row scale without layout padding: (n,) or None."""
        if self.scale is None:
            return None
        flat = self.scale[0] if self.scale.ndim == 2 else self.scale
        return flat[: self.n]

    def exact_rows_bias(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full-precision prepared rows + fused bias, (n, d) / (n,).

        The exact scoring source the monitors and the lazy recluster use:
        the f32 tier's own rows, a quantized tier's rescore tail, or —
        rescore disabled — the dequantized stored rows (coarse structure
        only, fine for centroid geometry and miss sampling).
        """
        if self.storage == "f32":
            return self.rows(), self.bias_row()
        if self.rescore_db is not None:
            return self.rescore_db[: self.n], self.rescore_bias[: self.n]
        return (
            quant.dequantize_rows(self.rows(), self.scale_row()),
            self.bias_row(),
        )

    def operands(self) -> Tuple[Optional[jnp.ndarray], ...]:
        """The positional device operands a search dispatch consumes.

        ``(db, bias)`` for the f32 tier (today's exact call shape);
        ``(db, bias, scale, rescore_db, rescore_bias)`` for quantized
        tiers (entries may be None — e.g. bf16 has no scale).  Clustered
        layouts append the four side tables (centroids, centroid_bias,
        cluster_rows, spill_rows) after either shape.  Passing these as
        *operands* rather than closure captures is what lets bias/row/
        scale/slot patches leave compiled programs valid.
        """
        if self.storage == "f32":
            base: Tuple[Optional[jnp.ndarray], ...] = (self.db, self.bias)
        else:
            base = (
                self.db, self.bias, self.scale,
                self.rescore_db, self.rescore_bias,
            )
        if self.cluster is not None:
            return base + self.cluster.operands()
        return base

    # -- in-place patches (the cheap mutations) -------------------------------

    @staticmethod
    def _patch_row(arr: jnp.ndarray, start: int, values: jnp.ndarray
                   ) -> jnp.ndarray:
        """Write a slice into a per-row array in either layout — (n,) for
        xla/sharded or (1, n_pad) for pallas (bias and scale alike)."""
        if arr.ndim == 2:
            return arr.at[0, start : start + values.shape[0]].set(values)
        return arr.at[start : start + values.shape[0]].set(values)

    def update_rows(self, start: int, rows: jnp.ndarray, metric: Metric):
        """Patch an appended row slice: prepare only the slice, O(r·D).

        ``rows`` are raw (unprepared) and are cast to the packed compute
        dtype before preparation — the same cast-then-prepare(-then-
        quantize) order as the full pack, so incremental and full packs
        are numerically identical (quantization is per-row, see
        ``Metric.prepare_update_storage``).
        """
        if self.storage == "f32":
            prepped, metric_bias = metric.prepare_update(
                rows.astype(self.db.dtype)
            )
        else:
            qr = metric.prepare_update_storage(
                rows.astype(jnp.dtype(self.compute_dtype)), self.storage
            )
            prepped, metric_bias = qr.rows, qr.bias
        r = prepped.shape[0]
        slice_bias = fuse_bias(metric_bias, num_rows=r)
        # Exact prepared slice (pre-padding) for cluster assignment below:
        # the same space the centroids were derived in.
        exact_slice = (
            prepped if self.storage == "f32" else qr.exact_rows
        )
        if self.storage == "int4" and self.backend == "pallas":
            # Canonical codes -> the on-device nibble-packed width: pad
            # lanes to the logical d_pad (2x the stored byte width), then
            # pack two codes per byte.  Same order as the full pack.
            prepped = quant.pack_int4_rows(
                jnp.pad(
                    prepped,
                    ((0, 0), (0, 2 * self.db.shape[1] - prepped.shape[1])),
                )
            )
        elif prepped.shape[1] < self.db.shape[1]:  # pallas lane padding
            prepped = jnp.pad(
                prepped, ((0, 0), (0, self.db.shape[1] - prepped.shape[1]))
            )
        self.db = self.db.at[start : start + r].set(prepped)
        self.bias = self._patch_row(self.bias, start, slice_bias)
        if self.storage != "f32":
            if self.scale is not None:
                self.scale = self._patch_row(self.scale, start, qr.scale)
            if self.rescore_db is not None:
                self.rescore_db = self.rescore_db.at[
                    start : start + r
                ].set(qr.exact_rows.astype(self.rescore_db.dtype))
                self.rescore_bias = self.rescore_bias.at[
                    start : start + r
                ].set(fuse_bias(qr.exact_bias, num_rows=r))
        if self.cluster is not None:
            # Incremental nearest-centroid slotting (spill block absorbs
            # overflow); O(r·C) — no repack, no table reshape, so the
            # compiled pruned program stays valid.
            clusterlib.assign_rows(self.cluster, exact_slice, start)
            PACK_EVENTS.inc("cluster_assigned")
        PACK_EVENTS.inc("rows_updated")

    def delete_rows(self, ids: jnp.ndarray):
        """Tombstone rows: patch only the bias entries, O(|ids|).

        Quantized tiers patch the rescore bias row too — the exact second
        pass recomputes true scores, so it must carry its own tombstone
        mask or rescoring would resurrect deleted rows.
        """
        if self.bias.ndim == 2:
            self.bias = self.bias.at[0, ids].set(MASK_VALUE)
        else:
            self.bias = self.bias.at[ids].set(MASK_VALUE)
        if self.rescore_bias is not None:
            self.rescore_bias = self.rescore_bias.at[ids].set(MASK_VALUE)
        PACK_EVENTS.inc("bias_patched")

    # -- layout changes (copy, but never metric re-preparation) ---------------

    def relayout(
        self, backend: str, new_n: int, spec: SearchSpec
    ) -> "PackedState":
        """Re-layout for a new capacity and/or backend, reusing prepared rows.

        One O(N·D) device copy; the grown region is dead (bias
        ``MASK_VALUE``) until ``update_rows`` writes it.  This is what
        capacity growth and ``Index.shard`` use so the packed layout — and
        the metric precompute in it — survives the transition.
        """
        rows = self.rows()
        bias = self.bias_row()
        scale = self.scale_row()
        rescore_db, rescore_bias = self.rescore_db, self.rescore_bias
        if new_n > self.n:
            grow = new_n - self.n
            rows = jnp.pad(rows, ((0, grow), (0, 0)))
            bias = jnp.pad(bias, (0, grow), constant_values=MASK_VALUE)
            if scale is not None:
                scale = jnp.pad(scale, (0, grow))
            if rescore_db is not None:
                rescore_db = jnp.pad(rescore_db, ((0, grow), (0, 0)))
                rescore_bias = jnp.pad(
                    rescore_bias, (0, grow), constant_values=MASK_VALUE
                )
        PACK_EVENTS.inc("relayout")
        out = _layout(
            backend, rows, bias, new_n, self.d, spec,
            scale=scale, rescore_db=rescore_db, rescore_bias=rescore_bias,
            compute_dtype=self.compute_dtype,
        )
        # The side tables hold user row ids, which a relayout never
        # renumbers — carry them verbatim (grown rows are slotted by the
        # update_rows that writes them; a stale-geometry table is caught
        # by Index.add's lazy-recluster trigger).
        out.cluster = self.cluster
        return out


def scan_k_for(
    spec: SearchSpec, n: int, live: Optional[int] = None
) -> int:
    """The k the scan's bin layout is planned for.

    Quantized tiers with rescoring over-fetch (``quant.scan_k``) so the
    exact second pass can restore the Eq. 13–14 guarantee; everything else
    plans for the user's k exactly as before.

    ``live`` caps the over-fetch at the current live-row count (floored at
    ``spec.k`` — the rescore still needs k outputs): after heavy deletes
    an uncapped ``k_scan > live_n`` made the rescore gather read rows that
    could only be tombstones.  The cap binds when the search program is
    built; later deletes are handled by the sentinel/mask propagation
    (masked candidates carry index -1 and can never surface), so no
    retrace is ever needed.
    """
    if spec.rescore_enabled:
        ks = quant.scan_k(spec.storage, spec.k, n=n)
        if live is not None:
            ks = max(spec.k, min(ks, max(int(live), 0)))
        return ks
    return spec.k


def _layout(
    backend: str,
    rows: jnp.ndarray,
    bias: jnp.ndarray,
    n: int,
    d: int,
    spec: SearchSpec,
    *,
    scale: Optional[jnp.ndarray] = None,
    rescore_db: Optional[jnp.ndarray] = None,
    rescore_bias: Optional[jnp.ndarray] = None,
    compute_dtype: str = "float32",
) -> PackedState:
    """Lay prepared (rows, bias) out in the backend's native shape.

    The rescore tail stays in gather layout — (n, d) rows, (n,) bias —
    on every backend: the second pass reads O(M·L) candidates by index,
    never a tiled stream, so it has no kernel layout to satisfy.
    """
    plan = plan_bins(
        n, scan_k_for(spec, n), spec.recall_target,
        reduction_input_size_override=spec.reduction_input_size_override,
    )
    bin_size = plan.bin_size
    if backend == "pallas":
        # Specs built via Index.build are always planner-resolved; direct
        # pack_state callers may pass an unresolved spec, which gets the
        # planner's anchor tile (repro.search.plan owns the real model).
        from repro.search.plan import DEFAULT_BLOCK_N

        max_bn = spec.max_block_n or DEFAULT_BLOCK_N
        block_n = bin_size * max(1, max_bn // bin_size)
        n_pad = round_up(max(n, block_n), block_n)
        if spec.storage == "int4":
            # Two codes per byte on device: pad the logical lanes to a
            # 256-multiple so the packed byte width stays a 128-lane
            # multiple, then nibble-pack (zero pad codes dequantize to 0,
            # exact for dot products like zero lanes).
            d_pad = round_up(d, 256)
            rows = quant.pack_int4_rows(
                jnp.pad(rows, ((0, n_pad - n), (0, d_pad - d)))
            )
        else:
            d_pad = round_up(d, 128)
            rows = jnp.pad(rows, ((0, n_pad - n), (0, d_pad - d)))
        full = jnp.full((n_pad,), MASK_VALUE, jnp.float32).at[:n].set(bias)
        if scale is not None:
            # Padded-tail scale is 0: tail scores become 0*dot + MASK.
            scale = jnp.zeros((n_pad,), jnp.float32).at[:n].set(scale)[None, :]
        return PackedState(
            backend=backend, db=rows, bias=full[None, :], n=n, d=d,
            plan=plan, bin_size=bin_size, block_n=block_n,
            storage=spec.storage, scale=scale,
            rescore_db=rescore_db, rescore_bias=rescore_bias,
            compute_dtype=compute_dtype,
        )
    return PackedState(
        backend=backend, db=rows, bias=bias, n=n, d=d,
        plan=plan, bin_size=bin_size, block_n=0,
        storage=spec.storage, scale=scale,
        rescore_db=rescore_db, rescore_bias=rescore_bias,
        compute_dtype=compute_dtype,
    )


def pack_state(
    database: jnp.ndarray,
    live: Optional[jnp.ndarray],
    metric: Metric,
    spec: SearchSpec,
    backend: str,
    cluster_plan: Optional[clusterlib.ClusterPlan] = None,
) -> PackedState:
    """Full pack: dtype cast + metric preparation over all rows + layout.

    The only entry point that runs ``Metric.prepare_database`` on the
    whole database — everything after build goes through the incremental
    patches above.

    ``cluster_plan``: an *enabled* ``repro.search.cluster.ClusterPlan``
    builds the pruning side tables over the live prepared rows (k-means +
    capacity-constrained assignment); ``None`` — or a plan the planner
    left disabled — packs exactly as before.

    >>> import jax.numpy as jnp
    >>> from repro.search.metrics import get_metric
    >>> from repro.search.spec import SearchSpec
    >>> st = pack_state(jnp.ones((10, 4)), None, get_metric("mips"),
    ...                 SearchSpec(k=2), "xla")
    >>> (st.backend, st.n, st.d, st.rows().shape)
    ('xla', 10, 4, (10, 4))
    """
    n, d = database.shape
    db = database
    if spec.dtype is not None:
        db = db.astype(jnp.dtype(spec.dtype))
    if spec.storage == "f32":
        db, metric_bias = metric.prepare_database(db)
        bias = fuse_bias(metric_bias, live, num_rows=n)
        PACK_EVENTS.inc("full_pack")
        state = _layout(backend, db, bias, n, d, spec)
        _attach_cluster(state, db, bias, live, metric, cluster_plan, spec.k)
        return state
    # Quantized tier: metric-prepare, quantize, fold the bias correction
    # (metric bias of the *stored* values) into the fused scan bias, and
    # optionally keep the full-precision rescore tail with its own fused
    # (exact-bias + tombstone) row.
    qr = metric.prepare_storage(db, spec.storage)
    bias = fuse_bias(qr.bias, live, num_rows=n)
    rescore_db = rescore_bias = None
    if spec.rescore_enabled:
        rescore_db = qr.exact_rows.astype(jnp.float32)
        rescore_bias = fuse_bias(qr.exact_bias, live, num_rows=n)
    PACK_EVENTS.inc("full_pack")
    state = _layout(
        backend, qr.rows, bias, n, d, spec,
        scale=qr.scale, rescore_db=rescore_db, rescore_bias=rescore_bias,
        compute_dtype=str(db.dtype),
    )
    exact_fused = (
        rescore_bias
        if rescore_bias is not None
        else fuse_bias(qr.exact_bias, live, num_rows=n)
    )
    _attach_cluster(
        state, qr.exact_rows, exact_fused, live, metric, cluster_plan, spec.k
    )
    return state


def _attach_cluster(
    state: PackedState,
    exact_rows: jnp.ndarray,
    fused_bias: jnp.ndarray,
    live: Optional[jnp.ndarray],
    metric: Metric,
    cluster_plan: Optional[clusterlib.ClusterPlan],
    k: int,
) -> None:
    """Build, validate and attach the pruning side tables (enabled plans).

    ``exact_rows`` are the metric-prepared full-precision rows — the space
    queries score in, so centroids derived here rank clusters exactly the
    way the pruned scan will; ``fused_bias`` is the matching fused
    (metric + tombstone) bias row.

    The planner's crossover prices FLOPs, not geometry, so the decay
    model's clusterable-data assumption is checked empirically here:
    ``sampled_miss_rate`` measures the actual miss rate of the built
    tables on sampled live rows, and a measurement past
    ``miss_check_threshold`` discards them — the layout falls back to the
    dense scan (bit-identical to ``cluster="off"``) instead of silently
    trading recall for speed on data the model does not fit.
    """
    if cluster_plan is None or not cluster_plan.enabled:
        return
    cs = clusterlib.build_tables(
        exact_rows, live, cluster_plan, metric.prepare_database
    )
    miss = clusterlib.sampled_miss_rate(cs, exact_rows, fused_bias, live, k)
    if miss > clusterlib.miss_check_threshold(cluster_plan.miss_budget):
        state.cluster_rejected_miss = miss
        PACK_EVENTS.inc("cluster_rejected")
        return
    state.cluster = cs
    PACK_EVENTS.inc("cluster_built")


def rebuild_cluster(
    state: PackedState,
    live: Optional[jnp.ndarray],
    metric: Metric,
    cluster_plan: clusterlib.ClusterPlan,
) -> None:
    """Lazy recluster: re-derive centroids + tables from the packed rows.

    Triggered by ``Index.add`` when ``ClusterState.needs_recluster`` says
    spill pressure is past the planner threshold (the cluster analogue of
    the lazy bin replan).  O(N·C·D) device k-means plus O(N) host
    assignment — but *no* repack: the packed rows/bias/scale arrays are
    reused as-is, and at unchanged capacity the new tables keep their
    shapes, so compiled pruned programs stay valid (zero retrace).

    Quantized tiers recluster from the exact rescore tail when present,
    else from the dequantized stored rows — centroid geometry only needs
    coarse structure, so tier rounding is immaterial.

    No miss re-check here: the data passed the build-time check (the
    clustered path only exists because it did), and dropping the tables
    mid-life would change the compiled program's operand shape — a
    retrace the steady-state contract forbids.
    """
    rows, _ = state.exact_rows_bias()
    state.cluster = clusterlib.build_tables(
        rows, live, cluster_plan, metric.prepare_database
    )
    PACK_EVENTS.inc("recluster")


# -- crash-safe snapshots (Index.save / Index.restore) ------------------------

def snapshot_state(state: PackedState) -> Tuple[dict, dict]:
    """Serialize a PackedState into ``(arrays, meta)`` for a snapshot.

    Captures everything a bit-identical restore needs *without* re-running
    any build work: the laid-out device arrays verbatim (including pallas
    padding — so the restored operands are byte-identical to the saved
    ones), the layout constants, and the cluster side tables.  The BinPlan
    is NOT serialized: ``plan_bins`` is deterministic in (n, k_scan,
    recall_target), so :func:`restore_state` recomputes it and *verifies*
    the recomputed bin size against the recorded one — which doubles as a
    version-skew detector for the binning math itself.
    """
    arrays = {"packed/db": state.db, "packed/bias": state.bias}
    if state.scale is not None:
        arrays["packed/scale"] = state.scale
    if state.rescore_db is not None:
        arrays["packed/rescore_db"] = state.rescore_db
        arrays["packed/rescore_bias"] = state.rescore_bias
    meta = {
        "backend": state.backend,
        "n": state.n,
        "d": state.d,
        "bin_size": state.bin_size,
        "block_n": state.block_n,
        "storage": state.storage,
        "compute_dtype": state.compute_dtype,
        "cluster_rejected_miss": state.cluster_rejected_miss,
        "cluster": None,
    }
    if state.cluster is not None:
        cl_arrays, cl_meta = clusterlib.snapshot_tables(state.cluster)
        arrays.update(cl_arrays)
        meta["cluster"] = cl_meta
    return arrays, meta


def restore_state(arrays: dict, meta: dict, spec: SearchSpec) -> PackedState:
    """Rebuild a PackedState from :func:`snapshot_state` output.

    No metric preparation, no quantization, no k-means — the arrays land
    on device exactly as saved, which is what makes restored search
    results bit-identical to the original replica's.
    """
    n = int(meta["n"])
    plan = plan_bins(
        n, scan_k_for(spec, n), spec.recall_target,
        reduction_input_size_override=spec.reduction_input_size_override,
    )
    if plan.bin_size != meta["bin_size"]:
        raise ValueError(
            f"snapshot bin_size={meta['bin_size']} but this version plans "
            f"bin_size={plan.bin_size} for the same (n, k, target) — the "
            "binning math changed since the snapshot was written; rebuild "
            "the index"
        )
    scale = arrays.get("packed/scale")
    quant.validate_restored(
        meta["storage"], arrays["packed/db"].dtype, has_scale=scale is not None
    )
    rescore_db = arrays.get("packed/rescore_db")
    state = PackedState(
        backend=meta["backend"],
        db=jnp.asarray(arrays["packed/db"]),
        bias=jnp.asarray(arrays["packed/bias"]),
        n=n,
        d=int(meta["d"]),
        plan=plan,
        bin_size=int(meta["bin_size"]),
        block_n=int(meta["block_n"]),
        storage=meta["storage"],
        scale=None if scale is None else jnp.asarray(scale),
        rescore_db=None if rescore_db is None else jnp.asarray(rescore_db),
        rescore_bias=(
            None if rescore_db is None
            else jnp.asarray(arrays["packed/rescore_bias"])
        ),
        cluster_rejected_miss=meta.get("cluster_rejected_miss"),
        compute_dtype=meta.get("compute_dtype", "float32"),
    )
    if meta.get("cluster") is not None:
        state.cluster = clusterlib.restore_tables(arrays, meta["cluster"])
    PACK_EVENTS.inc("restore")
    return state
