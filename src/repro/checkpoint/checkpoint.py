"""Fault-tolerant checkpointing: atomic step directories, async writer,
restore-with-remesh (elastic restarts on a different device count).

Layout:
  <dir>/step_000123.tmp/   -> written, fsynced, then renamed to
  <dir>/step_000123/       (rename is the commit point)
      arrays.npz           flat {path: np.ndarray} of the full logical state
      META.json            {"step": int, "leaf_paths": [...]}

Arrays are stored as *full logical* values (gathered), so a restore may build
NamedShardings for any mesh — this is what makes elastic re-scale trivial:
the array is simply re-sharded by device_put on load.  For multi-host
production each host would write its addressable shards plus a metadata
merge; the commit protocol (tmp dir + rename + MANIFEST) is identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, List, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "AsyncCheckpointer",
    "save_snapshot",
    "load_snapshot",
]


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, state) -> str:
    """Synchronous atomic save. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten_with_paths(state)
    arrays = {k: np.asarray(v) for k, v in leaves}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "META.json"), "w") as f:
        json.dump({"step": step, "leaf_paths": [k for k, _ in leaves]}, f)
    # Commit.
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            meta = os.path.join(directory, name, "META.json")
            if os.path.exists(meta):  # only committed checkpoints count
                steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``like``.

    ``shardings``: optional matching pytree of NamedShardings — arrays are
    device_put directly to their (possibly different-sized) target mesh,
    which is the elastic-restart path.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like = _flatten_with_paths(like)
    treedef = jax.tree_util.tree_structure(like)
    flat_shardings = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    leaves = []
    for i, (key, ref) in enumerate(flat_like):
        arr = data[key]
        if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
            arr = arr.astype(ref.dtype)
        if flat_shardings is not None:
            leaves.append(jax.device_put(arr, flat_shardings[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


# -- named snapshots (single logical state, e.g. Index.save/restore) ---------

def _encode_array(a) -> Tuple[np.ndarray, str]:
    """npz-safe encoding.  ``ml_dtypes`` types (bfloat16) do not survive a
    npz round-trip (they load back as raw void records), so they are
    stored as same-width unsigned bit patterns plus the logical dtype
    name; everything numpy-native passes through unchanged."""
    a = np.asarray(a)
    if a.dtype.name == "bfloat16":
        return a.view(np.uint16), "bfloat16"
    return a, a.dtype.name


def _decode_array(a: np.ndarray, logical: str) -> np.ndarray:
    if logical == "bfloat16":
        import ml_dtypes  # ships with jax

        return a.view(ml_dtypes.bfloat16)
    return a


def _fsync_dir_contents(path: str) -> None:
    for name in os.listdir(path):
        fd = os.open(os.path.join(path, name), os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)


def save_snapshot(path: str, arrays: dict, meta: dict) -> str:
    """Atomically write one named snapshot directory.

    Protocol (crash-safe at every step):
      1. write ``<path>.tmp/`` (arrays.npz + META.json), fsync the files;
      2. move any existing committed ``<path>`` aside to ``<path>.old``
         (POSIX rename cannot replace a non-empty directory);
      3. rename ``<path>.tmp`` -> ``<path>``  — the commit point;
      4. delete ``<path>.old``.

    A crash before step 3 leaves the old snapshot committed (the ``.tmp``
    is garbage, ignored by readers); a crash between 2 and 3 leaves
    ``.old``, which :func:`load_snapshot` falls back to.  The
    ``checkpoint.commit`` fault point fires between 1 and 2, so chaos
    tests can assert exactly this invariant.
    """
    from repro.search import faults  # leaf module; lazy to avoid cycles

    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp, old = path + ".tmp", path + ".old"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    encoded, logical = {}, {}
    for key, value in arrays.items():
        encoded[key], logical[key] = _encode_array(value)
    meta = dict(meta, array_dtypes=logical)
    np.savez(os.path.join(tmp, "arrays.npz"), **encoded)
    with open(os.path.join(tmp, "META.json"), "w") as f:
        json.dump(meta, f)
    _fsync_dir_contents(tmp)
    faults.fire("checkpoint.commit")
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(path):
        os.rename(path, old)
    os.rename(tmp, path)  # commit
    if os.path.exists(old):
        shutil.rmtree(old, ignore_errors=True)
    return path


def load_snapshot(path: str) -> Tuple[dict, dict]:
    """Load a committed snapshot: returns ``(meta, arrays)``.

    Falls back to ``<path>.old`` when only the aside copy exists (a crash
    landed between the move-aside and the commit rename); ``.tmp`` dirs
    are never read — they are by definition uncommitted.
    """
    path = os.path.abspath(path)
    if not os.path.exists(os.path.join(path, "META.json")):
        old = path + ".old"
        if os.path.exists(os.path.join(old, "META.json")):
            path = old
        else:
            raise FileNotFoundError(f"no committed snapshot at {path}")
    with open(os.path.join(path, "META.json")) as f:
        meta = json.load(f)
    logical = meta.get("array_dtypes", {})
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = {
            key: _decode_array(data[key], logical.get(key, ""))
            for key in data.files
        }
    return meta, arrays


class AsyncCheckpointer:
    """Background-thread checkpoint writer with at-most-one in flight.

    ``save`` snapshots to host memory synchronously (cheap vs HBM->disk) and
    commits on the worker thread, so the train loop blocks only for the
    device->host copy.  ``wait()`` joins outstanding work (call before exit).
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pending: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    def save(self, step: int, state):
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        self.wait()

        def worker():
            save_checkpoint(self.directory, step, host_state)
            self._gc()

        with self._lock:
            self._pending = threading.Thread(target=worker, daemon=True)
            self._pending.start()

    def wait(self):
        with self._lock:
            t = self._pending
        if t is not None:
            t.join()

    def _gc(self):
        steps = sorted(
            int(n[5:])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
