"""Deterministic data pipeline: per-host sharded synthetic LM token streams
(and vector datasets for the KNN benchmarks), with double-buffered prefetch.

Real deployments swap ``SyntheticTokenSource`` for a file-backed source with
the same iterator protocol; everything downstream (sharding, prefetch,
checkpointable cursor) is production-shaped:

  * each host draws only its shard of the global batch (host_id/host_count),
  * the stream is stateless-resumable: batch i is a pure function of
    (seed, step) so restarts after failure reproduce the exact stream,
  * ``Prefetcher`` overlaps host-side batch synthesis with device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["SyntheticTokenSource", "Prefetcher", "make_vector_dataset"]


class SyntheticTokenSource:
    """Zipf-ish token stream; batch(step) is deterministic in (seed, step)."""

    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        *,
        seed: int = 0,
        host_id: int = 0,
        host_count: int = 1,
        input_mode: str = "tokens",
        d_model: int = 0,
        enc_seq: int = 0,
        mrope: bool = False,
    ):
        if global_batch % host_count:
            raise ValueError(
                f"global_batch {global_batch} not divisible by hosts {host_count}"
            )
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.local_batch = global_batch // host_count
        self.seed = seed
        self.host_id = host_id
        self.input_mode = input_mode
        self.d_model = d_model
        self.enc_seq = enc_seq
        self.mrope = mrope

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.host_id, step])
        )
        b, s = self.local_batch, self.seq_len
        # Zipf-like marginal over the vocab, cheap to draw.
        u = rng.random((b, s + 1))
        tokens = ((self.vocab_size - 1) * u ** 3).astype(np.int32)
        out: Dict[str, np.ndarray] = {"labels": tokens[:, 1:]}
        if self.input_mode == "embeddings":
            out["embeddings"] = rng.standard_normal(
                (b, s, self.d_model), dtype=np.float32
            )
        else:
            out["tokens"] = tokens[:, :-1]
        if self.enc_seq:
            out["tokens"] = tokens[:, :-1]
            out["enc_embeds"] = rng.standard_normal(
                (b, self.enc_seq, self.d_model), dtype=np.float32
            )
        if self.mrope:
            pos = np.arange(s, dtype=np.int32)
            out["mrope_positions"] = np.stack([pos, pos, pos])
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread double buffering over a batch(step) source."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self._source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        # Drain so the worker unblocks.
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def make_vector_dataset(
    n: int, d: int, *, seed: int = 0, metric: str = "mips", clusters: int = 64
):
    """Synthetic clustered vector DB (Glove/Sift stand-in for benchmarks)."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((clusters, d)).astype(np.float32) * 2.0
    assign = rng.integers(0, clusters, size=n)
    x = centers[assign] + rng.standard_normal((n, d)).astype(np.float32)
    if metric == "cosine":
        x /= np.linalg.norm(x, axis=-1, keepdims=True)
    return x
