"""Serving driver: batched requests through the ServingEngine with the
paper's approx-top-k vocabulary sampler (and optional KNN attention).

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b-smoke \
      --batch 4 --max-seq 128 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--knn-attention", action="store_true")
    ap.add_argument("--greedy", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    params = tfm.init_model(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(
        cfg, params, batch=args.batch, max_seq=args.max_seq,
        use_knn=args.knn_attention,
        sample="greedy" if args.greedy else "approx_topk",
    )
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for i in range(args.batch)
    ]
    engine.admit(reqs)
    t0 = time.time()
    engine.run(args.new_tokens)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"[serve] {total} tokens in {dt:.2f}s "
          f"({1e3 * dt / max(args.new_tokens, 1):.1f} ms/step, batch={args.batch})")
    for r in reqs:
        print(f"  req {r.rid}: {r.generated}")


if __name__ == "__main__":
    main()
