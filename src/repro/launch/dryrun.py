import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (into --out, default benchmarks/results/dryrun):
  {arch}_{shape}_{mesh}.json with
    * compiled cost analysis (FLOPs, bytes),
    * memory analysis (per-device argument/output/temp/peak bytes),
    * collective wire bytes parsed from the post-SPMD HLO,
    * the three §Roofline terms for TPU v5e,
    * MODEL_FLOPS = 6*N(_active)*D and the useful-compute ratio.

Usage:
  python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.analysis.hlo import collective_bytes, op_census
from repro.analysis.hlo_cost import analyze_hlo
from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.roofline import HARDWARE
from repro.launch import shardspecs as SS
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import transformer as tfm
from repro.parallel.sharding import use_mesh


def _knn_attn_for_cell(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k uses the paper's knn top-k attention for KV-cache archs."""
    if shape.name != "long_500k":
        return False
    kinds = set(cfg.layer_kinds())
    return any(k in kinds for k in ("dense", "moe", "mla_dense", "mla_moe", "dec"))


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6 * N(_active) * tokens (+ attention KV term on decode)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens  # forward only
    # decode: one token/sequence + attention reads of the cache
    tokens = shape.global_batch
    attn = 0.0
    hd = cfg.resolved_head_dim
    for kind in cfg.layer_kinds():
        if kind in ("dense", "moe", "dec", "enc"):
            attn += 4.0 * cfg.num_heads * hd * shape.seq_len
        elif kind.startswith("mla"):
            attn += 4.0 * cfg.num_heads * cfg.kv_lora_rank * shape.seq_len
        elif kind == "local_attn":
            attn += 4.0 * cfg.num_heads * hd * min(cfg.local_window, shape.seq_len)
    return (2.0 * n + attn) * tokens


def ideal_memory_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Unavoidable global HBM traffic per step (roofline denominator).

    train:   read f32 params + m + v, write all three, plus one bf16
             read/write of activations at the layer boundaries.
    prefill: read bf16 params once + write the KV cache.
    decode:  read bf16 active params + read the whole cache once.
    """
    n = cfg.active_param_count()
    n_total = cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        act = 2.0 * tokens * cfg.d_model * max(
            len(cfg.layer_kinds()), 1
        ) * 2  # save + reload once per layer boundary
        return 6.0 * 4.0 * n_total + act
    from repro.serving.kvcache import cache_bytes_per_token

    cache = cache_bytes_per_token(cfg) * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_total + cache
    return 2.0 * n + cache


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Build abstract args + shardings and lower the right step function."""
    specs = M.input_specs(cfg, shape)
    if shape.kind == "train":
        step = M.make_train_step(cfg, microbatches=cfg.train_microbatches)
        state_abs = jax.eval_shape(
            functools.partial(M.init_train_state, cfg=cfg), jax.random.PRNGKey(0)
        )
        state_sh = SS.sanitize_tree(
            SS.train_state_shardings(cfg, mesh, shape), state_abs, mesh
        )
        batch_sh = SS.sanitize_tree(
            SS.batch_shardings(cfg, shape, mesh), specs, mesh
        )
        fn = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        return fn.lower(state_abs, specs)
    if shape.kind == "prefill":
        step = M.make_prefill_step(cfg)
        params_abs = jax.eval_shape(
            functools.partial(tfm.init_model, cfg=cfg), jax.random.PRNGKey(0)
        )
        fn = jax.jit(
            step,
            in_shardings=(
                SS.sanitize_tree(SS.param_shardings(cfg, mesh, shape), params_abs, mesh),
                SS.sanitize_tree(SS.batch_shardings(cfg, shape, mesh), specs, mesh),
            ),
        )
        return fn.lower(params_abs, specs)
    # decode
    use_knn = _knn_attn_for_cell(cfg, shape)
    step = M.make_decode_step(cfg, use_knn=use_knn)
    params_abs = jax.eval_shape(
        functools.partial(tfm.init_model, cfg=cfg), jax.random.PRNGKey(0)
    )
    arg_sh = SS.decode_arg_shardings(cfg, shape, mesh)
    arg_sh["params"] = SS.sanitize_tree(arg_sh["params"], params_abs, mesh)
    arg_sh["caches"] = SS.sanitize_tree(arg_sh["caches"], specs["caches"], mesh)
    if "cross_kv" in arg_sh:
        arg_sh["cross_kv"] = SS.sanitize_tree(arg_sh["cross_kv"], specs["cross_kv"], mesh)
    args = [params_abs, specs["tokens"], specs["caches"], specs["cur_index"], specs["rng"]]
    shardings = [arg_sh["params"], arg_sh["tokens"], arg_sh["caches"],
                 arg_sh["cur_index"], arg_sh["rng"]]
    if cfg.is_encoder_decoder:
        args.append(specs["cross_kv"])
        shardings.append(arg_sh["cross_kv"])
        fn = jax.jit(
            step,
            in_shardings=tuple(shardings),
            out_shardings=(None, None, arg_sh["caches"]),
            donate_argnums=(2,),
        )
        return fn.lower(*args)
    fn = jax.jit(
        step,
        in_shardings=tuple(shardings),
        out_shardings=(None, None, arg_sh["caches"]),
        donate_argnums=(2,),
    )
    return fn.lower(*args)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             hw_name: str = "tpu_v5e") -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = 512 if multi else 256
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "knn_attention": _knn_attn_for_cell(cfg, shape),
    }
    t0 = time.time()
    rules = SS.cell_rules(cfg, shape, mesh)
    with use_mesh(mesh, rules=rules):
        lowered = lower_cell(cfg, shape, mesh)
    result["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t1, 2)

    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    except Exception as e:  # pragma: no cover
        ca = {}
        result["cost_analysis_error"] = str(e)
    # XLA:CPU cost_analysis counts while bodies ONCE (scan undercount); kept
    # for reference only.  The roofline uses the trip-count-aware HLO walk.
    result["xla_cost_analysis"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }

    try:
        ma = compiled.memory_analysis()
        result["memory"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": int(
                getattr(ma, "peak_memory_in_bytes",
                        getattr(ma, "temp_size_in_bytes", 0))
            ),
        }
    except Exception as e:  # pragma: no cover
        result["memory_error"] = str(e)

    hlo = compiled.as_text()
    t2 = time.time()
    cost = analyze_hlo(hlo)  # per-partition program: all quantities per-device
    coll_total, coll_kinds = collective_bytes(hlo)
    result["analyze_s"] = round(time.time() - t2, 2)
    result["hlo_flops_per_device"] = cost.dot_flops
    result["hlo_bytes_per_device"] = cost.hbm_bytes
    result["hlo_cops_per_device"] = cost.cop_count
    result["hlo_flops"] = cost.dot_flops * chips
    result["hlo_bytes"] = cost.hbm_bytes * chips
    result["while_trips"] = cost.while_trips
    result["collective_bytes"] = coll_total
    result["collective_breakdown"] = coll_kinds
    census = op_census(hlo)
    result["collective_counts"] = {
        k: v for k, v in census.items()
        if any(s in k for s in ("all-", "reduce-scatter", "collective"))
    }

    hw = HARDWARE[hw_name]
    compute_s = cost.dot_flops / hw.peak_flops
    memory_s = cost.hbm_bytes / hw.hbm_bandwidth
    collective_s = coll_total / hw.ici_bandwidth
    instruction_s = cost.cop_count / hw.peak_cops  # the paper's third wall
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s, "instruction": instruction_s}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())
    mf = model_flops(cfg, shape)
    mf_per_device = mf / chips
    # Ideal step time: the better of the compute roofline and the
    # unavoidable-traffic memory roofline — decode is *supposed* to be
    # memory-bound, so MFU alone would misgrade it.
    ideal_bytes_dev = ideal_memory_bytes(cfg, shape) / chips
    t_ideal = max(mf_per_device / hw.peak_flops, ideal_bytes_dev / hw.hbm_bandwidth)
    result["roofline"] = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "instruction_s": instruction_s,
        "dominant": dominant,
        "step_time_s": step_time,
        "model_flops": mf,
        "ideal_bytes_per_device": ideal_bytes_dev,
        "ideal_step_s": t_ideal,
        "useful_ratio": mf_per_device / cost.dot_flops if cost.dot_flops else 0.0,
        "mfu_bound": (mf_per_device / hw.peak_flops) / step_time if step_time else 0.0,
        "roofline_fraction": t_ideal / step_time if step_time else 0.0,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list(ASSIGNED_ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = os.path.join(args.out, f"{arch}_{shape}_{mesh_kind}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {path}")
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_kind} ...", flush=True)
                try:
                    res = run_cell(arch, shape, mesh_kind)
                    dom = res["roofline"]["dominant"]
                    print(
                        f"  ok: compile={res['compile_s']}s flops={res['hlo_flops']:.3e} "
                        f"coll={res['collective_bytes']:.3e}B dominant={dom}",
                        flush=True,
                    )
                except Exception as e:
                    failures += 1
                    res = {
                        "arch": arch, "shape": shape, "mesh": mesh_kind,
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    print(f"  FAIL: {type(e).__name__}: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
