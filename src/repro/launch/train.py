"""Production-shaped training driver.

Wires together: config registry -> data pipeline (prefetched, per-host
sharded) -> jitted train_step (sharded via shardspecs when a mesh is given)
-> async checkpointing -> auto-resume -> straggler tracking.

CPU-runnable end to end with the smoke configs:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b-smoke \
      --steps 50 --seq 64 --global-batch 8 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
)
from repro.configs import get_config
from repro.data.pipeline import Prefetcher, SyntheticTokenSource
from repro.ft.straggler import StragglerPolicy
from repro.launch.mesh import make_host_mesh
from repro.launch import shardspecs as SS
from repro.models import model as M
from repro.optim.adamw import cosine_schedule
from repro.parallel.sharding import use_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_host_mesh(args.model_parallel)
    sched = cosine_schedule(args.lr, args.warmup, args.steps)
    step_fn = M.make_train_step(
        cfg, learning_rate=sched,
        grad_dtype="bfloat16" if args.grad_compression else None,
    )

    src = SyntheticTokenSource(
        cfg.vocab_size, args.seq, args.global_batch, seed=args.seed,
        input_mode=cfg.input_mode if not cfg.is_encoder_decoder else "tokens",
        d_model=cfg.d_model,
        enc_seq=cfg.encoder_seq if cfg.is_encoder_decoder else 0,
        mrope=cfg.mrope,
    )

    with use_mesh(mesh):
        state = M.init_train_state(jax.random.PRNGKey(args.seed), cfg)
        state_sh = SS.sanitize_tree(
            SS.train_state_shardings(cfg, mesh), jax.eval_shape(lambda: state), mesh
        )
        state = jax.tree.map(jax.device_put, state, state_sh)
        train_step = jax.jit(
            step_fn, in_shardings=(state_sh, None), out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )

        start = 0
        ck = None
        if args.ckpt_dir:
            ck = AsyncCheckpointer(args.ckpt_dir)
            at = latest_step(args.ckpt_dir)
            if at is not None:
                like = jax.eval_shape(
                    lambda: M.init_train_state(jax.random.PRNGKey(args.seed), cfg)
                )
                restored, start = restore_checkpoint(
                    args.ckpt_dir, like, shardings=state_sh
                )
                state = M.TrainState(*restored)
                print(f"[train] resumed from step {start}")

        pf = Prefetcher(src, start_step=start)
        policy = StragglerPolicy()
        t_last = time.time()
        try:
            for _ in range(start, args.steps):
                step_i, host_batch = pf.next()
                batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
                with use_mesh(mesh):
                    state, metrics = train_step(state, batch)
                if (step_i + 1) % args.log_every == 0:
                    loss = float(metrics["loss"])
                    dt = time.time() - t_last
                    t_last = time.time()
                    print(
                        f"[train] step={step_i + 1} loss={loss:.4f} "
                        f"gnorm={float(metrics['grad_norm']):.3f} "
                        f"{dt / args.log_every:.3f}s/step"
                    )
                    act = policy.observe({0: dt / args.log_every})
                    if act.kind != "none":
                        print(f"[ft] straggler action: {act}")
                if ck and (step_i + 1) % args.ckpt_every == 0:
                    ck.save(step_i + 1, state)
            if ck:
                ck.save(args.steps, state)
                ck.wait()
        finally:
            pf.close()
        print(f"[train] done at step {args.steps}, final loss "
              f"{float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
