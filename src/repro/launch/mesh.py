"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips.
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips; the "pod"
axis carries only data parallelism (gradient all-reduce crosses the DCN/ICI
pod boundary once per step).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def _make_mesh(shape, axes):
    # jax.sharding.AxisType (explicit-sharding meshes) only exists in newer
    # jax; Auto is the default either way, so omit it when unavailable.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    mp = model_parallel
    while mp > 1 and n % mp:
        mp //= 2
    return _make_mesh((n // mp, mp), ("data", "model"))
