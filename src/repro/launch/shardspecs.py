"""Build NamedSharding pytrees for every (arch x shape x mesh) cell.

Policy (DESIGN.md §6):
  * params: Megatron TP over "model" (heads/ffn/experts/vocab); archs with
    ``fsdp_params`` additionally shard the embed dim over ("pod","data")
    (ZeRO-3-style, all-gathered per layer inside the scan).
  * train batch: sharded over ("pod","data").
  * decode caches: kv-heads over "model" when divisible, else the cache
    sequence is context-parallel over "model"; long_500k (batch=1) shards
    the sequence over every mesh axis.
  * optimizer state: exactly like params (partitioned optimizer for free).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.models.model import TrainState
from repro.optim.adamw import AdamWState
from repro.parallel.sharding import LOGICAL_RULES, logical_to_spec, use_mesh

__all__ = [
    "cell_rules",
    "param_shardings",
    "train_state_shardings",
    "batch_shardings",
    "cache_shardings",
    "decode_arg_shardings",
    "sanitize_tree",
]


def _sanitize_spec(sharding: NamedSharding, aval, mesh: Mesh) -> NamedSharding:
    """Drop mesh axes whose product doesn't divide the tensor dim.

    E.g. kv_heads=8 over a 16-way "model" axis falls back to replication
    (Megatron's GQA convention when kv < TP degree)."""
    if not hasattr(aval, "shape"):
        return sharding
    spec = sharding.spec
    new_axes = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(aval.shape):
            new_axes.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        prod = 1
        for a in axes:
            if aval.shape[i] % (prod * mesh.shape[a]) == 0:
                kept.append(a)
                prod *= mesh.shape[a]
        new_axes.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return NamedSharding(mesh, P(*new_axes))


def sanitize_tree(shardings, abstract, mesh: Mesh):
    """Apply _sanitize_spec leaf-wise (shardings tree must match abstract)."""
    return jax.tree.map(
        lambda s, a: _sanitize_spec(s, a, mesh) if isinstance(s, NamedSharding) else s,
        shardings,
        abstract,
    )

_AXES_LEAF = lambda x: isinstance(x, tuple) and all(
    e is None or isinstance(e, str) for e in x
)


def _dp_size(mesh: Mesh) -> int:
    n = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    return n


def cell_rules(cfg: ModelConfig, shape: Optional[ShapeConfig], mesh: Mesh):
    """Logical rule table adjusted for this cell."""
    rules = dict(LOGICAL_RULES)
    if shape is not None and shape.kind == "decode" and shape.global_batch < _dp_size(mesh):
        # batch too small to shard (long_500k): context-parallel everything.
        rules["batch"] = None
        rules["cp_seq"] = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    return tuple(rules.items())


def _param_rules(cfg: ModelConfig, base_rules):
    rules = dict(base_rules)
    if cfg.fsdp_params:
        rules["embed"] = ("pod", "data")
    return tuple(rules.items())


def _spec_tree(axes_tree, mesh: Mesh, rules):
    with use_mesh(mesh, rules=rules):
        return jax.tree.map(
            lambda axes: NamedSharding(mesh, logical_to_spec(axes)),
            axes_tree,
            is_leaf=_AXES_LEAF,
        )


def param_shardings(cfg: ModelConfig, mesh: Mesh, shape=None):
    rules = _param_rules(cfg, cell_rules(cfg, shape, mesh))
    return _spec_tree(tfm.model_axes(cfg), mesh, rules)


def train_state_shardings(cfg: ModelConfig, mesh: Mesh, shape=None) -> TrainState:
    p = param_shardings(cfg, mesh, shape)
    repl = NamedSharding(mesh, P())
    return TrainState(step=repl, params=p, opt_state=AdamWState(m=p, v=p))


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = NamedSharding(mesh, P(dp))
    b2 = NamedSharding(mesh, P(dp, None))
    b3 = NamedSharding(mesh, P(dp, None, None))
    repl = NamedSharding(mesh, P())
    out = {}
    if shape.kind in ("train", "prefill"):
        if cfg.input_mode == "embeddings" and not cfg.is_encoder_decoder:
            out["embeddings"] = b3
        else:
            out["tokens"] = b2
        if shape.kind == "train":
            out["labels"] = b2
        if cfg.is_encoder_decoder:
            out["enc_embeds"] = b3
        if cfg.mrope:
            out["mrope_positions"] = repl
    return out


def _cache_axes_for_kind(cfg: ModelConfig, kind: str, shape: ShapeConfig, mesh: Mesh):
    model_n = mesh.shape.get("model", 1)
    kv_shardable = (
        cfg.num_kv_heads % model_n == 0 and cfg.num_kv_heads >= model_n
        and not cfg.use_mla
    )
    small_batch = shape.global_batch < _dp_size(mesh)
    if kind == "ssm":
        from repro.models.ssm import SSMCache

        return SSMCache(
            state=("layers", "batch", "ssm_heads", None, None),
            conv=("layers", "batch", None, "conv_dim"),
        )
    if kind == "rglru":
        from repro.models.rglru import RGLRUCache

        return RGLRUCache(
            state=("layers", "batch", "lru_width"),
            conv=("layers", "batch", None, "lru_width"),
        )
    if kind == "local_attn":
        return tfm.LocalKVCache(
            k=("layers", "batch", None, None, None),
            v=("layers", "batch", None, None, None),
            pos=("layers", None),
        )
    if kind.startswith("mla"):
        from repro.models.attention import MLACache

        return MLACache(
            c_kv=("layers", "batch", "cp_seq", None),
            k_rope=("layers", "batch", "cp_seq", None),
        )
    from repro.models.attention import KVCache

    if kv_shardable and not small_batch:
        axes = ("layers", "batch", None, "kv_heads", None)
    else:
        axes = ("layers", "batch", "cp_seq", None, None)
    return KVCache(k=axes, v=axes)


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    rules = cell_rules(cfg, shape, mesh)
    axes = [
        _cache_axes_for_kind(cfg, kind, shape, mesh) for kind, _ in tfm.runs_of(cfg)
    ]
    return _spec_tree(axes, mesh, rules)


def decode_arg_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Shardings for decode_step(params, tokens, caches, cur_index, rng[, cross_kv])."""
    rules = cell_rules(cfg, shape, mesh)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    small_batch = shape.global_batch < _dp_size(mesh)
    bspec = NamedSharding(mesh, P(None if small_batch else dp, None))
    repl = NamedSharding(mesh, P())
    args = {
        "params": param_shardings(cfg, mesh, shape),
        "tokens": bspec,
        "caches": cache_shardings(cfg, shape, mesh),
        "cur_index": repl,
        "rng": repl,
    }
    if cfg.is_encoder_decoder:
        cross = []
        for kind, _ in tfm.runs_of(cfg):
            if kind != "dec":
                cross.append(None)
                continue
            from repro.models.attention import KVCache

            ax = KVCache(
                k=("layers", "batch", None, "heads", None),
                v=("layers", "batch", None, "heads", None),
            )
            cross.append(_spec_tree(ax, mesh, rules))
        args["cross_kv"] = cross
    return args
