"""Elastic scaling: rebuild the mesh for whatever devices survive and
re-shard state from the last checkpoint.

Because checkpoints store full logical arrays and all sharding is derived
from logical axis rules (parallel.sharding), a restart at a different chip
count is: pick the new mesh shape -> rebuild NamedShardings -> device_put.
``choose_mesh_shape`` keeps the model axis fixed when possible (TP degree is
baked into kernel efficiency) and shrinks the data axis, which only changes
the gradient all-reduce span — the train step lowers unchanged.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.parallel.sharding import logical_to_spec, use_mesh

__all__ = ["choose_mesh_shape", "remesh_state", "survivors_mesh"]


def choose_mesh_shape(
    n_devices: int, *, model_parallel: int = 16, multi_pod_threshold: int = 512
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest usable mesh for n_devices, preferring to keep TP width."""
    mp = model_parallel
    while mp > 1 and n_devices % mp:
        mp //= 2
    dp = n_devices // mp
    if n_devices >= multi_pod_threshold:
        pods = n_devices // multi_pod_threshold
        while dp % pods:
            pods //= 2
        return (pods, dp // pods, mp), ("pod", "data", "model")
    return (dp, mp), ("data", "model")


def survivors_mesh(devices: Optional[Sequence] = None, *, model_parallel: int = 16) -> Mesh:
    devices = list(devices if devices is not None else jax.devices())
    shape, axes = choose_mesh_shape(len(devices), model_parallel=model_parallel)
    usable = 1
    for s in shape:
        usable *= s
    import numpy as np

    arr = np.array(devices[:usable]).reshape(shape)
    return Mesh(arr, axes)


def remesh_state(state, axes_tree, new_mesh: Mesh):
    """Re-shard a (host or device) state pytree onto a new mesh."""
    with use_mesh(new_mesh):
        shardings = jax.tree.map(
            lambda axes: NamedSharding(new_mesh, logical_to_spec(axes)),
            axes_tree,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(e is None or isinstance(e, str) for e in x),
        )
    return jax.tree.map(jax.device_put, state, shardings)
