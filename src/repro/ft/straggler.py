"""Straggler detection & mitigation policy.

On a real multi-pod fleet the controller feeds per-host step times in;
the policy decides when a host is persistently slow (EWMA > k x fleet
median) and emits a mitigation action.  The brief's mitigations:
  * "hot spare": swap the slow host for a standby and restart from the
    latest checkpoint (cheap because checkpoints are atomic + elastic),
  * "shrink": drop the host and re-mesh (ft.elastic) when no spare exists.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

__all__ = ["StragglerPolicy", "Action"]


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str           # "none" | "swap" | "shrink"
    host: Optional[int] = None
    reason: str = ""


class StragglerPolicy:
    def __init__(self, *, threshold: float = 1.5, ewma: float = 0.2,
                 grace_steps: int = 10, min_steps: int = 5):
        self.threshold = threshold
        self.ewma = ewma
        self.grace_steps = grace_steps
        self.min_steps = min_steps
        self._t: Dict[int, float] = {}
        self._slow_streak: Dict[int, int] = {}
        self._steps = 0

    def observe(self, step_times: Dict[int, float]) -> Action:
        """Feed one step of per-host wall times; returns the action to take."""
        self._steps += 1
        for host, t in step_times.items():
            prev = self._t.get(host, t)
            self._t[host] = (1 - self.ewma) * prev + self.ewma * t
        if self._steps < self.min_steps or len(self._t) < 2:
            return Action("none")
        med = float(np.median(list(self._t.values())))
        worst_host, worst = max(self._t.items(), key=lambda kv: kv[1])
        if worst > self.threshold * med:
            streak = self._slow_streak.get(worst_host, 0) + 1
            self._slow_streak = {worst_host: streak}
            if streak >= self.grace_steps:
                return Action(
                    "swap", host=worst_host,
                    reason=f"ewma {worst:.3f}s > {self.threshold}x median {med:.3f}s "
                           f"for {streak} steps",
                )
        else:
            self._slow_streak = {}
        return Action("none")
