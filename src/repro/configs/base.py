"""Config system: frozen model/run configs + the architecture registry."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "register", "get_config", "list_configs", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | vlm | audio | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0
    moe_group_size: int = 1024
    moe_capacity_factor: float = 1.5
    router_topk_impl: str = "exact"   # "exact" | "approx" (paper op)
    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # --- hybrid (recurrentgemma) ---
    block_pattern: Tuple[str, ...] = ()   # per-layer kinds; () -> uniform
    local_window: int = 0
    lru_width: int = 0
    lru_gate_blocks: int = 0   # 0 = dense gates; >0 = block-diagonal (Griffin)
    lru_scan_impl: str = "associative"   # "associative" | "linear" (chunked)
    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500           # whisper: 30s audio -> 1500 frames
    # --- modality frontend stub ---
    input_mode: str = "tokens"        # "tokens" | "embeddings" (stubbed frontend)
    # --- position / norm / act ---
    rope_theta: float = 10000.0
    mrope: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"
    gated_mlp: bool = True
    use_layer_norm: bool = False      # False -> RMSNorm
    tie_embeddings: bool = False
    # --- paper integration ---
    knn_attention_k: int = 128        # top-k keys for knn decode attention
    knn_recall_target: float = 0.95
    decode_sample_k: int = 40         # approx_max_k vocab sampling
    # --- numerics / partitioning ---
    dtype: str = "bfloat16"
    attn_scores_dtype: str = "float32"  # "bfloat16" halves score-tile traffic
    q_chunk: int = 512                # query-chunked attention block
    remat: str = "dots"               # "none" | "dots" | "full"
    train_microbatches: int = 1       # gradient accumulation chunks
    fsdp_params: bool = False         # shard params over DP axes too (>=20B)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a multiple of 128 so TP vocab-sharding divides."""
        return ((self.vocab_size + 127) // 128) * 128

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind list driving the scan-run grouping."""
        if self.block_pattern:
            reps = -(-self.num_layers // len(self.block_pattern))
            return (self.block_pattern * reps)[: self.num_layers]
        if self.is_encoder_decoder:
            return ("dec",) * self.num_layers
        if self.family == "ssm":
            return ("ssm",) * self.num_layers
        if self.num_experts:
            dense = ("mla_dense" if self.use_mla else "dense",) * self.first_k_dense
            moe = ("mla_moe" if self.use_mla else "moe",) * (
                self.num_layers - self.first_k_dense
            )
            return dense + moe
        kind = "mla_dense" if self.use_mla else "dense"
        return (kind,) * self.num_layers

    def param_count(self) -> int:
        """Approximate parameter count (for 6ND roofline accounting)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d * (1 if self.tie_embeddings else 2)
        for kind in self.layer_kinds():
            if kind == "ssm":
                di = self.ssm_expand * d
                nh = di // self.ssm_head_dim
                conv = di + 2 * self.ssm_state
                total += d * (2 * di + 2 * self.ssm_state + nh)
                total += 4 * conv + 3 * nh + di + di * d
                continue
            if kind == "rglru":
                lw = self.lru_width or d
                total += 2 * d * lw + 2 * lw * lw + lw * d + 7 * lw
                continue
            # attention part
            if kind.startswith("mla"):
                r = self.kv_lora_rank
                total += d * (r + self.qk_rope_dim)
                total += r * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                if self.q_lora_rank:
                    total += d * self.q_lora_rank + self.q_lora_rank * self.num_heads * (
                        self.qk_nope_dim + self.qk_rope_dim
                    )
                else:
                    total += d * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                total += self.num_heads * self.v_head_dim * d
            else:
                total += d * hd * (self.num_heads + 2 * self.num_kv_heads)
                total += self.num_heads * hd * d
            # ffn part
            if kind.endswith("moe"):
                total += d * self.num_experts
                total += self.num_experts * 3 * d * self.moe_d_ff
                total += self.num_shared_experts * 3 * d * self.moe_d_ff
            elif kind in ("dense", "mla_dense", "local_attn", "attn", "dec", "enc"):
                total += (3 if self.gated_mlp else 2) * d * self.d_ff
        if self.is_encoder_decoder:
            # encoder self-attn + ffn, decoder cross-attn (self+ffn counted above)
            enc = self.encoder_layers * (
                4 * d * self.num_heads * hd + (3 if self.gated_mlp else 2) * d * self.d_ff
            )
            cross = self.num_layers * 4 * d * self.num_heads * hd
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-to experts count)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        inactive = (self.num_experts - self.experts_per_token) * 3 * d * self.moe_d_ff
        total -= inactive * (self.num_layers - self.first_k_dense)
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str   # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # Import side-effect registration.
        import repro.configs  # noqa: F401

        if name not in _REGISTRY:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
