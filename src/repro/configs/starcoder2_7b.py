"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.

GQA + RoPE.  [arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig, register

FULL = register(ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    gated_mlp=False,
    act="gelu",
))

SMOKE = register(ModelConfig(
    name="starcoder2-7b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    gated_mlp=False,
    act="gelu",
    q_chunk=32,
))
