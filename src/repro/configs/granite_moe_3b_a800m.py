"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) MoE 40e top-8.

Per-expert d_ff=512, vocab 49155.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ModelConfig, register

FULL = register(ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    moe_d_ff=512,
    moe_group_size=2048,
))

SMOKE = register(ModelConfig(
    name="granite-moe-3b-a800m-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=64,
    vocab_size=256,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=32,
    moe_group_size=64,
    q_chunk=32,
))
