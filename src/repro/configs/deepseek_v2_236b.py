"""deepseek-v2-236b [moe]: 60L d_model=5120 128H (MLA) MoE 160e top-6.

MLA kv_lora=512, 2 shared + 160 routed experts top-6, per-expert d_ff=1536,
first layer dense.  [arXiv:2405.04434; hf]
"""
from repro.configs.base import ModelConfig, register

FULL = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,       # MLA: logical heads; cache is the kv_lora latent
    head_dim=128,
    d_ff=12288,             # dense (first_k_dense) layers
    vocab_size=102400,
    num_experts=160,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1536,
    first_k_dense=1,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    fsdp_params=True,
    moe_group_size=2048,
))

SMOKE = register(ModelConfig(
    name="deepseek-v2-236b-smoke",
    family="moe",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    num_experts=8,
    experts_per_token=2,
    num_shared_experts=1,
    moe_d_ff=32,
    first_k_dense=1,
    use_mla=True,
    kv_lora_rank=32,
    q_lora_rank=48,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    moe_group_size=64,
    q_chunk=32,
))


# Optimized variant (EXPERIMENTS.md §Perf cell B): smaller MoE dispatch
# groups (dispatch einsum cost is linear in group size), tighter capacity,
# full remat + 8-way gradient accumulation so the cell fits HBM.
OPT = register(ModelConfig(
    **{**{f.name: getattr(FULL, f.name) for f in __import__("dataclasses").fields(FULL)},
       "name": "deepseek-v2-236b-opt", "moe_group_size": 512,
       "moe_capacity_factor": 1.25, "remat": "full", "train_microbatches": 8},
))
