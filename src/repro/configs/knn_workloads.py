"""The paper's own benchmark workloads (Table 2): Glove1.2M and Sift1M."""
from __future__ import annotations

import dataclasses
from typing import Dict

__all__ = ["KNNConfig", "KNN_WORKLOADS"]


@dataclasses.dataclass(frozen=True)
class KNNConfig:
    name: str
    n: int                  # database size
    d: int                  # dimension (pre-padding)
    d_padded: int           # dimension after padding to 128
    m: int                  # query batch
    metric: str             # "cosine" | "l2"
    k: int = 10
    recall_target: float = 0.95
    # Appendix A.5 COP accounting flags
    non_pow2_n: bool = True
    broadcast_norm: bool = False

    @property
    def cops_per_dot(self) -> int:
        c = 3                       # PartialReduce
        c += int(self.metric == "l2")       # relaxed distance
        c += int(self.non_pow2_n)           # masking
        c += int(self.broadcast_norm)       # broadcasting ||x||^2/2
        return c

    def plan(self, device: str = "tpu_v4", backend: str = "pallas"):
        """The analytical kernel plan for this workload on ``device``.

        Thin hook into ``repro.search.plan.plan_search`` so benchmark and
        figure scripts derive every kernel parameter the same way the live
        ``Index.build`` path does (imported lazily: configs must stay
        importable without pulling the search stack in).
        """
        from repro.search.plan import plan_search

        return plan_search(
            n=self.n, d=self.d, k=self.k, m=self.m, metric=self.metric,
            recall_target=self.recall_target, device=device, backend=backend,
        )


KNN_WORKLOADS: Dict[str, KNNConfig] = {
    "glove1.2m": KNNConfig(
        name="glove1.2m", n=1_183_514, d=100, d_padded=128, m=10_000,
        metric="cosine", non_pow2_n=True, broadcast_norm=False,
    ),
    "sift1m": KNNConfig(
        name="sift1m", n=1_000_000, d=128, d_padded=128, m=10_000,
        metric="l2", non_pow2_n=True, broadcast_norm=True,
    ),
}
