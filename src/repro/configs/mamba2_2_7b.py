"""mamba2-2.7b [ssm]: 64L d_model=2560 attn-free vocab=50280 ssm_state=128.

SSD (state-space duality).  [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig, register

FULL = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=1,            # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
))

SMOKE = register(ModelConfig(
    name="mamba2-2.7b-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=1,
    num_kv_heads=1,
    head_dim=16,
    d_ff=0,
    vocab_size=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=16,
))
