"""Architecture registry: importing this package registers all configs."""
from repro.configs import (  # noqa: F401
    deepseek_v2_236b,
    granite_20b,
    granite_moe_3b_a800m,
    internlm2_1_8b,
    knn_workloads,
    mamba2_2_7b,
    qwen2_vl_2b,
    recurrentgemma_9b,
    stablelm_1_6b,
    starcoder2_7b,
    whisper_medium,
)
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    list_configs,
    register,
)
from repro.configs.knn_workloads import KNN_WORKLOADS, KNNConfig  # noqa: F401

ASSIGNED_ARCHS = (
    "deepseek-v2-236b",
    "granite-moe-3b-a800m",
    "granite-20b",
    "internlm2-1.8b",
    "starcoder2-7b",
    "stablelm-1.6b",
    "mamba2-2.7b",
    "qwen2-vl-2b",
    "whisper-medium",
    "recurrentgemma-9b",
)
