"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.

[arXiv:2403.17297; hf]
"""
from repro.configs.base import ModelConfig, register

FULL = register(ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
))

SMOKE = register(ModelConfig(
    name="internlm2-1.8b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    q_chunk=32,
))
