"""whisper-medium [audio]: 24+24L enc-dec d_model=1024 16H d_ff=4096 vocab=51865.

Conv audio frontend is a STUB — input_specs() provides precomputed frame
embeddings (B, 1500, d).  Sinusoidal positions (rope_theta=0).
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig, register

FULL = register(ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_layers=24,
    encoder_seq=1500,
    input_mode="embeddings",
    rope_theta=0.0,
    gated_mlp=False,
    act="gelu",
))

SMOKE = register(ModelConfig(
    name="whisper-medium-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    is_encoder_decoder=True,
    encoder_layers=2,
    encoder_seq=48,
    input_mode="embeddings",
    rope_theta=0.0,
    gated_mlp=False,
    act="gelu",
    q_chunk=32,
))
