"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

M-RoPE, dynamic resolution; the vision patch-embedding frontend is a STUB —
input_specs() provides precomputed patch embeddings.  [arXiv:2409.12191; hf]
"""
from repro.configs.base import ModelConfig, register

FULL = register(ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    input_mode="embeddings",
))

SMOKE = register(ModelConfig(
    name="qwen2-vl-2b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    mrope=True,
    input_mode="embeddings",
    q_chunk=32,
))
