"""stablelm-1.6b [dense]: 24L d_model=2048 32H (MHA kv=32) d_ff=5632 vocab=100352.

[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.configs.base import ModelConfig, register

FULL = register(ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
))

SMOKE = register(ModelConfig(
    name="stablelm-1.6b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    q_chunk=32,
))
