"""granite-20b [dense]: 52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.

Llama-arch code model.  [arXiv:2405.04324; hf]
"""
from repro.configs.base import ModelConfig, register

FULL = register(ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    gated_mlp=False,
    act="gelu",
    fsdp_params=True,
))

SMOKE = register(ModelConfig(
    name="granite-20b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    gated_mlp=False,
    act="gelu",
    q_chunk=32,
))
