"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288.

RG-LRU + local attention, pattern (recurrent, recurrent, local_attn);
window 2048, lru_width 4096, vocab 256000.  [arXiv:2402.19427; unverified]
"""
from repro.configs.base import ModelConfig, register

FULL = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=2048,
    lru_width=4096,
    act="gelu",
    fsdp_params=True,
))

SMOKE = register(ModelConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=16,
    lru_width=64,
    act="gelu",
    q_chunk=32,
))


# Optimized variant (EXPERIMENTS.md §Perf cell A): block-diagonal RG-LRU
# gates (the Griffin paper's own design) remove one f32 (B,S,lru) all-reduce
# per gate per layer under tensor parallelism.
OPT = register(ModelConfig(
    **{**{f.name: getattr(FULL, f.name) for f in __import__("dataclasses").fields(FULL)},
       "name": "recurrentgemma-9b-opt", "lru_gate_blocks": 16},
))
