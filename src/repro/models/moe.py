"""Mixture-of-Experts layer: token-choice top-k routing, GShard-style grouped
capacity dispatch (TPU-native: all einsums, EP-sharded over "model" mesh axis),
plus deepseek-style shared experts.

Router top-k is ``exact`` (lax.top_k) by default; ``approx`` switches to the
paper's approx_max_k.  Note (DESIGN.md §Arch-applicability): for E <= a few
hundred experts the Eq. 14 bin budget L ~ (K-1)/(1-r) is comparable to E, so
approx routing buys nothing — it exists for completeness and for very large
expert counts.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.topk import approx_max_k
from repro.models.params import ParamDef
from repro.parallel.sharding import shard

__all__ = ["moe_defs", "moe_apply"]


def moe_defs(
    d_model: int,
    moe_d_ff: int,
    num_experts: int,
    *,
    num_shared_experts: int = 0,
):
    defs = {
        "router": ParamDef((d_model, num_experts), ("embed", None)),
        "wi": ParamDef((num_experts, d_model, moe_d_ff), ("experts", "embed", "moe_ffn")),
        "wg": ParamDef((num_experts, d_model, moe_d_ff), ("experts", "embed", "moe_ffn")),
        "wo": ParamDef((num_experts, moe_d_ff, d_model), ("experts", "moe_ffn", "embed")),
    }
    if num_shared_experts:
        shared_ff = num_shared_experts * moe_d_ff
        defs["shared_wi"] = ParamDef((d_model, shared_ff), ("embed", "ffn"))
        defs["shared_wg"] = ParamDef((d_model, shared_ff), ("embed", "ffn"))
        defs["shared_wo"] = ParamDef((shared_ff, d_model), ("ffn", "embed"))
    return defs


def _router_topk(logits, k, routing: str, recall_target: float):
    if routing == "approx" and k > 1 and logits.shape[-1] >= 2 * k:
        return approx_max_k(logits, k, recall_target=recall_target)
    vals, idx = jax.lax.top_k(logits, k)
    return vals, idx


def moe_apply(
    params: Dict,
    x: jnp.ndarray,                  # (B, S, d)
    *,
    experts_per_token: int,
    num_experts: int,
    capacity_factor: float = 1.5,
    group_size: int = 1024,
    routing: str = "exact",          # "exact" | "approx"
    recall_target: float = 0.95,
    router_scale: Optional[float] = None,
) -> jnp.ndarray:
    """Grouped-capacity MoE forward.

    Tokens are reshaped to (G, g); each group independently dispatches to
    (E, Cap) slots via one-hot einsums — the canonical TPU MoE lowering whose
    all-to-all GSPMD generates when experts are sharded over "model".
    """
    b, s, d = x.shape
    k = experts_per_token
    tokens = b * s
    g = min(group_size, tokens)
    assert tokens % g == 0, f"tokens {tokens} not divisible by group {g}"
    n_groups = tokens // g
    cap = int(min(g, max(k, round(g * k / num_experts * capacity_factor))))
    xt = x.reshape(n_groups, g, d)

    logits = jnp.einsum("Gtd,de->Gte", xt, params["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = _router_topk(probs, k, routing, recall_target)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalise
    if router_scale:
        top_p = top_p * router_scale

    # Position of each (token, slot) within its expert queue.
    sel = jax.nn.one_hot(top_e, num_experts, dtype=jnp.int32)     # (G, t, k, E)
    pos_in_expert = jnp.cumsum(sel.reshape(n_groups, g * k, num_experts), axis=1)
    pos_in_expert = pos_in_expert.reshape(n_groups, g, k, num_experts) * sel - 1
    keep = (pos_in_expert >= 0) & (pos_in_expert < cap)            # (G, t, k, E)
    slot = jnp.where(keep, pos_in_expert, 0)

    # Build dispatch/combine (G, t, E, Cap) with a python loop over the k
    # slots so the 5-D (G,t,k,E,Cap) tensor never materialises (k is 2..8).
    dispatch = jnp.zeros((n_groups, g, num_experts, cap), x.dtype)
    combine = jnp.zeros((n_groups, g, num_experts, cap), x.dtype)
    for kk in range(k):
        e_k = top_e[:, :, kk]                                       # (G, t)
        slot_k = jnp.take_along_axis(slot[:, :, kk], e_k[..., None], -1)[..., 0]
        keep_k = jnp.take_along_axis(keep[:, :, kk], e_k[..., None], -1)[..., 0]
        e_oh = jax.nn.one_hot(e_k, num_experts, dtype=x.dtype)
        e_oh = e_oh * keep_k[..., None].astype(x.dtype)             # drop overflow
        c_oh = jax.nn.one_hot(slot_k, cap, dtype=x.dtype)
        pair = jnp.einsum("GtE,Gtc->GtEc", e_oh, c_oh)
        dispatch = dispatch + pair
        combine = combine + pair * top_p[:, :, kk, None, None].astype(x.dtype)
    dispatch = shard(dispatch, "batch", None, "experts", None)
    combine = shard(combine, "batch", None, "experts", None)

    # Gather expert inputs, run the expert FFNs, scatter back.
    expert_in = jnp.einsum("GtEc,Gtd->GEcd", dispatch, xt)
    expert_in = shard(expert_in, "batch", "experts", None, None)
    h = jnp.einsum("GEcd,Edf->GEcf", expert_in, params["wi"])
    gate = jnp.einsum("GEcd,Edf->GEcf", expert_in, params["wg"])
    h = jax.nn.silu(gate) * h
    h = shard(h, "batch", "experts", None, "moe_ffn")
    expert_out = jnp.einsum("GEcf,Efd->GEcd", h, params["wo"])
    y = jnp.einsum("GtEc,GEcd->Gtd", combine, expert_out)

    if "shared_wi" in params:
        sh = jax.nn.silu(jnp.einsum("Gtd,df->Gtf", xt, params["shared_wg"]))
        sh = sh * jnp.einsum("Gtd,df->Gtf", xt, params["shared_wi"])
        y = y + jnp.einsum("Gtf,fd->Gtd", sh, params["shared_wo"])
    return y.reshape(b, s, d)
