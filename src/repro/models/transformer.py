"""Model assembly: per-layer defs/apply for every layer kind, scan-over-layers
with homogeneous runs, init + logical axes, train/prefill/decode forwards.

Layer kinds:
  dense      GQA attention + gated MLP
  moe        GQA attention + MoE FFN (+ shared experts)
  mla_dense  MLA attention + gated MLP        (deepseek-v2)
  mla_moe    MLA attention + MoE FFN
  local_attn GQA attention with sliding window + MLP   (recurrentgemma)
  rglru      RG-LRU recurrent block + MLP
  ssm        Mamba-2 SSD block (no separate MLP)
  enc        bidirectional attention + MLP    (whisper encoder)
  dec        causal self-attn + cross-attn + MLP (whisper decoder)

Consecutive identical kinds form a "run" whose params are stacked on a
leading layers axis and executed with jax.lax.scan — keeping HLO size O(#runs)
instead of O(#layers), which is what makes 60-layer 236B configs lower in
seconds.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    embed_defs,
    mlp_apply,
    mlp_defs,
    rms_norm,
)
from repro.models.params import ParamDef, init_params, param_axes, stack_axes
from repro.parallel.sharding import shard

__all__ = [
    "runs_of",
    "model_defs",
    "init_model",
    "model_axes",
    "forward_train",
    "forward_prefill",
    "forward_decode",
    "init_caches",
    "LocalKVCache",
]


class LocalKVCache(NamedTuple):
    """Ring-buffer KV cache for sliding-window attention."""

    k: jnp.ndarray      # (B, W, KV, hd)
    v: jnp.ndarray      # (B, W, KV, hd)
    pos: jnp.ndarray    # (W,) absolute position stored in each slot (-1 empty)


def runs_of(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """Group consecutive identical layer kinds: [(kind, count), ...]."""
    runs: List[Tuple[str, int]] = []
    for kind in cfg.layer_kinds():
        if runs and runs[-1][0] == kind:
            runs[-1] = (kind, runs[-1][1] + 1)
        else:
            runs.append((kind, 1))
    return runs


# --------------------------------------------------------------------------
# Per-layer parameter definitions
# --------------------------------------------------------------------------


def _norm_def(cfg: ModelConfig):
    return ParamDef((cfg.d_model,), ("embed",), "ones")


def layer_defs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    if kind == "ssm":
        return {
            "pre_norm": _norm_def(cfg),
            "ssm": ssm_lib.ssm_defs(
                d, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                n_state=cfg.ssm_state,
            ),
        }
    if kind == "rglru":
        return {
            "pre_norm": _norm_def(cfg),
            "rglru": rglru_lib.rglru_defs(
                d, cfg.lru_width or d, gate_blocks=cfg.lru_gate_blocks
            ),
            "mlp_norm": _norm_def(cfg),
            "mlp": mlp_defs(d, cfg.d_ff, gated=cfg.gated_mlp),
        }
    defs: Dict[str, Any] = {"pre_norm": _norm_def(cfg)}
    if kind.startswith("mla"):
        defs["attn"] = attn.mla_defs(
            d, cfg.num_heads,
            q_lora_rank=cfg.q_lora_rank, kv_lora_rank=cfg.kv_lora_rank,
            qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
            v_head_dim=cfg.v_head_dim,
        )
    else:
        defs["attn"] = attn.attn_defs(d, cfg.num_heads, cfg.num_kv_heads, hd)
    if kind == "dec":
        defs["cross_norm"] = _norm_def(cfg)
        defs["cross"] = attn.cross_attn_defs(d, cfg.num_heads, hd)
    defs["mlp_norm"] = _norm_def(cfg)
    if kind.endswith("moe"):
        defs["moe"] = moe_lib.moe_defs(
            d, cfg.moe_d_ff, cfg.num_experts,
            num_shared_experts=cfg.num_shared_experts,
        )
    else:
        defs["mlp"] = mlp_defs(d, cfg.d_ff, gated=cfg.gated_mlp)
    return defs


# --------------------------------------------------------------------------
# Per-layer apply (train / full-sequence)
# --------------------------------------------------------------------------


def _apply_attn_train(params, x, positions, cfg: ModelConfig, kind: str,
                      return_cache: bool, enc_out=None, mrope_positions=None):
    h = rms_norm(x, params["pre_norm"], cfg.norm_eps)
    cache = None
    if kind.startswith("mla"):
        out = attn.mla_train(
            params["attn"], h, positions,
            num_heads=cfg.num_heads, kv_lora_rank=cfg.kv_lora_rank,
            qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
            rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
            return_cache=return_cache,
        )
    else:
        out = attn.attention_train(
            params["attn"], h, positions,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            rope_theta=cfg.rope_theta,
            causal=(kind != "enc"),
            window=cfg.local_window if kind == "local_attn" else None,
            mrope=cfg.mrope, mrope_positions=mrope_positions,
            q_chunk=cfg.q_chunk, return_cache=return_cache,
        )
    if return_cache:
        out, cache = out
    x = x + out
    if kind == "dec":
        h = rms_norm(x, params["cross_norm"], cfg.norm_eps)
        enc_kv = attn.encode_cross_kv(params["cross"], enc_out)
        x = x + attn.cross_attention(
            params["cross"], h, enc_kv, num_heads=cfg.num_heads, q_chunk=cfg.q_chunk
        )
    h = rms_norm(x, params["mlp_norm"], cfg.norm_eps)
    if kind.endswith("moe"):
        y = moe_lib.moe_apply(
            params["moe"], h,
            experts_per_token=cfg.experts_per_token, num_experts=cfg.num_experts,
            capacity_factor=cfg.moe_capacity_factor, group_size=cfg.moe_group_size,
            routing=cfg.router_topk_impl, recall_target=cfg.knn_recall_target,
        )
    else:
        y = mlp_apply(params["mlp"], h, act=cfg.act)
    return x + y, cache


def layer_train(params, x, positions, cfg: ModelConfig, kind: str,
                return_cache: bool = False, enc_out=None, mrope_positions=None):
    if kind == "ssm":
        h = rms_norm(x, params["pre_norm"], cfg.norm_eps)
        y = ssm_lib.ssm_train(
            params["ssm"], h,
            expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
            n_state=cfg.ssm_state, chunk=cfg.ssm_chunk,
            return_cache=return_cache,
        )
        cache = None
        if return_cache:
            y, cache = y
        return x + y, cache
    if kind == "rglru":
        h = rms_norm(x, params["pre_norm"], cfg.norm_eps)
        y = rglru_lib.rglru_train(
            params["rglru"], h, return_cache=return_cache,
            scan_impl=cfg.lru_scan_impl,
        )
        cache = None
        if return_cache:
            y, cache = y
        x = x + y
        h = rms_norm(x, params["mlp_norm"], cfg.norm_eps)
        return x + mlp_apply(params["mlp"], h, act=cfg.act), cache
    x, cache = _apply_attn_train(
        params, x, positions, cfg, kind, return_cache, enc_out, mrope_positions
    )
    if return_cache and kind == "local_attn":
        cache = _to_ring_cache(cache, positions, cfg)
    return x, cache


def _to_ring_cache(cache: attn.KVCache, positions, cfg: ModelConfig) -> LocalKVCache:
    """Convert a full prefill KV cache to the sliding-window ring buffer."""
    s = cache.k.shape[1]
    w = min(cfg.local_window, s)
    k_tail, v_tail = cache.k[:, -w:], cache.v[:, -w:]
    pos_tail = positions[-w:]
    # Roll so that slot j holds the position p with p % w == j.
    shift = int(s % w) if isinstance(s, int) else s % w
    k_tail = jnp.roll(k_tail, shift, axis=1)
    v_tail = jnp.roll(v_tail, shift, axis=1)
    pos_tail = jnp.roll(pos_tail, shift, axis=0)
    return LocalKVCache(k=k_tail, v=v_tail, pos=pos_tail.astype(jnp.int32))


# --------------------------------------------------------------------------
# Per-layer apply (single-token decode)
# --------------------------------------------------------------------------


def layer_decode(params, x, cache, cur_index, cfg: ModelConfig, kind: str,
                 use_knn: bool, cross_kv: Optional[attn.KVCache] = None):
    h = rms_norm(x, params["pre_norm"], cfg.norm_eps)
    knn_k = cfg.knn_attention_k if use_knn else 0
    if kind == "ssm":
        y, cache = ssm_lib.ssm_decode(
            params["ssm"], h,
            cache, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
            n_state=cfg.ssm_state,
        )
        return x + y, cache
    if kind == "rglru":
        y, cache = rglru_lib.rglru_decode(params["rglru"], h, cache)
        x = x + y
        h = rms_norm(x, params["mlp_norm"], cfg.norm_eps)
        return x + mlp_apply(params["mlp"], h, act=cfg.act), cache
    if kind.startswith("mla"):
        y, cache = attn.mla_decode(
            params["attn"], h, cache, cur_index,
            num_heads=cfg.num_heads, kv_lora_rank=cfg.kv_lora_rank,
            qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
            rope_theta=cfg.rope_theta,
            knn_k=knn_k, knn_recall_target=cfg.knn_recall_target,
        )
    elif kind == "local_attn":
        y, cache = _local_attn_decode(params["attn"], h, cache, cur_index, cfg)
    else:
        y, cache = attn.attention_decode(
            params["attn"], h, cache, cur_index,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            rope_theta=cfg.rope_theta, mrope=cfg.mrope,
            knn_k=knn_k, knn_recall_target=cfg.knn_recall_target,
        )
    x = x + y
    if kind == "dec":
        h = rms_norm(x, params["cross_norm"], cfg.norm_eps)
        x = x + attn.cross_attention(
            params["cross"], h, cross_kv, num_heads=cfg.num_heads,
            q_chunk=cfg.q_chunk,
        )
    h = rms_norm(x, params["mlp_norm"], cfg.norm_eps)
    if kind.endswith("moe"):
        y = moe_lib.moe_apply(
            params["moe"], h,
            experts_per_token=cfg.experts_per_token, num_experts=cfg.num_experts,
            capacity_factor=cfg.moe_capacity_factor,
            group_size=min(cfg.moe_group_size, h.shape[0] * h.shape[1]),
            routing=cfg.router_topk_impl, recall_target=cfg.knn_recall_target,
        )
    else:
        y = mlp_apply(params["mlp"], h, act=cfg.act)
    return x + y, cache


def _local_attn_decode(params, x, cache: LocalKVCache, cur_index, cfg: ModelConfig):
    """Sliding-window decode on a ring-buffer cache (W slots)."""
    b, _, d = x.shape
    w = cache.k.shape[1]
    positions = jnp.full((1,), cur_index, jnp.int32)
    q, k_new, v_new = attn._qkv(
        params, x, positions, rope_theta=cfg.rope_theta, mrope=False,
        mrope_positions=None,
    )
    slot = jnp.mod(cur_index, w)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, slot, 0, 0))
    pos = jax.lax.dynamic_update_slice(cache.pos, positions, (slot,))
    new_cache = LocalKVCache(k=k, v=v, pos=pos)
    groups = cfg.num_heads // cfg.num_kv_heads
    ke, ve = attn._repeat_kv(k, groups), attn._repeat_kv(v, groups)
    scores = jnp.einsum("bhd,bkhd->bhk", q[:, 0], ke) * (q.shape[-1] ** -0.5)
    valid = (pos >= 0) & (pos <= cur_index) & (cur_index - pos < cfg.local_window)
    scores = jnp.where(valid[None, None], scores, attn._NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
    out = jnp.einsum("bhk,bkhd->bhd", probs, ve)
    y = jnp.einsum("bhk,hkd->bd", out, params["wo"])[:, None]
    return y, new_cache


# --------------------------------------------------------------------------
# Cache construction
# --------------------------------------------------------------------------


def _cache_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    dt = _cache_dtype(cfg)
    hd = cfg.resolved_head_dim
    if kind == "ssm":
        return ssm_lib.ssm_init_cache(
            batch, cfg.d_model, expand=cfg.ssm_expand,
            head_dim=cfg.ssm_head_dim, n_state=cfg.ssm_state, dtype=dt,
        )
    if kind == "rglru":
        return rglru_lib.rglru_init_cache(batch, cfg.lru_width or cfg.d_model, dtype=dt)
    if kind == "local_attn":
        w = min(cfg.local_window, max_seq)
        return LocalKVCache(
            k=jnp.zeros((batch, w, cfg.num_kv_heads, hd), dt),
            v=jnp.zeros((batch, w, cfg.num_kv_heads, hd), dt),
            pos=jnp.full((w,), -1, jnp.int32),
        )
    if kind.startswith("mla"):
        return attn.MLACache(
            c_kv=jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dt),
            k_rope=jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dt),
        )
    return attn.KVCache(
        k=jnp.zeros((batch, max_seq, cfg.num_kv_heads, hd), dt),
        v=jnp.zeros((batch, max_seq, cfg.num_kv_heads, hd), dt),
    )


def init_caches(cfg: ModelConfig, batch: int, max_seq: int):
    """Stacked per-run decode caches: [(kind, stacked_cache), ...]."""
    caches = []
    for kind, count in runs_of(cfg):
        one = init_layer_cache(cfg, kind, batch, max_seq)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), one
        )
        caches.append(stacked)
    return caches


# --------------------------------------------------------------------------
# Whole-model defs / init / axes
# --------------------------------------------------------------------------


def model_defs(cfg: ModelConfig):
    defs: Dict[str, Any] = {}
    # Embedding is always needed (decode consumes tokens even in stub-modality
    # archs); vocab is padded to a 128 multiple so TP sharding divides.
    defs["embed"] = embed_defs(cfg.padded_vocab, cfg.d_model)
    defs["final_norm"] = _norm_def(cfg)
    if not cfg.tie_embeddings:
        defs["lm_head"] = {
            "embedding": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"))
        }
    if cfg.is_encoder_decoder:
        defs["enc_final_norm"] = _norm_def(cfg)
    return defs


def init_model(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32):
    keys = jax.random.split(key, 2 + len(runs_of(cfg)) + cfg.encoder_layers)
    params: Dict[str, Any] = init_params(keys[0], model_defs(cfg), dtype)
    layers = []
    for i, (kind, count) in enumerate(runs_of(cfg)):
        defs = layer_defs(cfg, kind)
        lkeys = jax.random.split(keys[1 + i], count)
        stacked = jax.vmap(lambda k: init_params(k, defs, dtype))(lkeys)
        layers.append(stacked)
    params["layers"] = layers
    if cfg.is_encoder_decoder:
        defs = layer_defs(cfg, "enc")
        ekeys = jax.random.split(keys[-1], cfg.encoder_layers)
        params["encoder"] = jax.vmap(lambda k: init_params(k, defs, dtype))(ekeys)
    return params


def model_axes(cfg: ModelConfig):
    axes: Dict[str, Any] = param_axes(model_defs(cfg))
    axes["layers"] = [
        stack_axes(param_axes(layer_defs(cfg, kind))) for kind, _ in runs_of(cfg)
    ]
    if cfg.is_encoder_decoder:
        axes["encoder"] = stack_axes(param_axes(layer_defs(cfg, "enc")))
    return axes


# --------------------------------------------------------------------------
# Forwards
# --------------------------------------------------------------------------


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )


def _cast_params(params, cfg: ModelConfig):
    """Cast master (f32) params to the compute dtype (norms upcast internally)."""
    dt = _cache_dtype(cfg)
    return jax.tree.map(lambda p: p.astype(dt) if p.dtype == jnp.float32 else p, params)


def _sinusoid(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    half = d // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / half))
    ang = positions[:, None].astype(jnp.float32) * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _embed_in(params, cfg: ModelConfig, tokens_or_embeds, positions):
    if tokens_or_embeds.dtype in (jnp.int32, jnp.int64):
        x = jnp.take(params["embed"]["embedding"], tokens_or_embeds, axis=0)
    else:
        x = tokens_or_embeds  # stubbed modality frontend output
    x = x.astype(_cache_dtype(cfg))
    if cfg.rope_theta == 0:  # absolute sinusoidal (whisper-style)
        x = x + _sinusoid(positions, cfg.d_model)[None].astype(x.dtype)
    return shard(x, "batch", None, None)


def _unembed(params, cfg: ModelConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = (
        params["embed"]["embedding"]
        if cfg.tie_embeddings
        else params["lm_head"]["embedding"]
    )
    logits = jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
    return shard(logits, "batch", None, "vocab")


def _encode(params, cfg: ModelConfig, enc_embeds):
    """Whisper encoder: bidirectional scan over stacked 'enc' layers."""
    s = enc_embeds.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    x = enc_embeds.astype(_cache_dtype(cfg))
    x = x + _sinusoid(positions, cfg.d_model)[None].astype(x.dtype)

    def body(h, layer_params):
        h2, _ = layer_train(layer_params, h, positions, cfg, "enc")
        return h2.astype(h.dtype), None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["encoder"])
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def forward_train(
    params,
    cfg: ModelConfig,
    tokens_or_embeds,
    *,
    enc_embeds=None,
    positions=None,
    mrope_positions=None,
):
    """Full-sequence forward -> logits (B, S, V)."""
    attn.set_scores_dtype(
        jnp.bfloat16 if cfg.attn_scores_dtype == "bfloat16" else jnp.float32
    )
    params = _cast_params(params, cfg)
    s = tokens_or_embeds.shape[1]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    x = _embed_in(params, cfg, tokens_or_embeds, positions)
    enc_out = _encode(params, cfg, enc_embeds) if cfg.is_encoder_decoder else None

    for (kind, count), stacked in zip(runs_of(cfg), params["layers"]):
        def body(h, layer_params, _kind=kind):
            h2, _ = layer_train(
                layer_params, h, positions, cfg, _kind,
                enc_out=enc_out, mrope_positions=mrope_positions,
            )
            return h2.astype(h.dtype), None

        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, stacked)
    return _unembed(params, cfg, x)


def forward_prefill(
    params,
    cfg: ModelConfig,
    tokens_or_embeds,
    *,
    enc_embeds=None,
    positions=None,
):
    """Prefill: full forward that also emits per-run stacked KV caches."""
    attn.set_scores_dtype(
        jnp.bfloat16 if cfg.attn_scores_dtype == "bfloat16" else jnp.float32
    )
    params = _cast_params(params, cfg)
    s = tokens_or_embeds.shape[1]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    x = _embed_in(params, cfg, tokens_or_embeds, positions)
    enc_out = _encode(params, cfg, enc_embeds) if cfg.is_encoder_decoder else None

    caches = []
    for (kind, count), stacked in zip(runs_of(cfg), params["layers"]):
        def body(h, layer_params, _kind=kind):
            h2, cache = layer_train(
                layer_params, h, positions, cfg, _kind,
                return_cache=True, enc_out=enc_out,
            )
            return h2.astype(h.dtype), cache

        x, run_cache = jax.lax.scan(_maybe_remat(body, cfg), x, stacked)
        caches.append(run_cache)
    logits = _unembed(params, cfg, x[:, -1:])
    return logits, caches


def forward_decode(
    params,
    cfg: ModelConfig,
    tokens,                 # (B, 1) int32
    caches,                 # from init_caches / forward_prefill
    cur_index,              # scalar int32
    *,
    use_knn: bool = False,
    cross_kv=None,          # stacked (L, ...) whisper cross KV
):
    """Single-token decode step -> (logits (B, 1, V), new caches)."""
    params = _cast_params(params, cfg)
    positions = jnp.full((1,), cur_index, jnp.int32)
    x = _embed_in(params, cfg, tokens, positions)

    new_caches = []
    for i, ((kind, count), stacked) in enumerate(zip(runs_of(cfg), params["layers"])):
        run_cross = cross_kv[i] if cross_kv is not None else None

        def body(h, pc, _kind=kind, _has_cross=(run_cross is not None)):
            if _has_cross:
                layer_params, layer_cache, ck = pc
            else:
                (layer_params, layer_cache), ck = pc, None
            h2, new_cache = layer_decode(
                layer_params, h, layer_cache, cur_index, cfg, _kind,
                use_knn=use_knn, cross_kv=ck,
            )
            h2 = h2.astype(h.dtype)
            new_cache = jax.tree.map(
                lambda n, o: n.astype(o.dtype), new_cache, layer_cache
            )
            return h2, new_cache

        xs = (
            (stacked, caches[i])
            if run_cross is None
            else (stacked, caches[i], run_cross)
        )
        x, run_cache = jax.lax.scan(body, x, xs)
        new_caches.append(run_cache)
    return _unembed(params, cfg, x), new_caches


def build_cross_kv(params, cfg: ModelConfig, enc_out):
    """Per-run stacked cross-attention KV from the encoder output (whisper)."""
    out = []
    for (kind, count), stacked in zip(runs_of(cfg), params["layers"]):
        if kind != "dec":
            out.append(None)
            continue
        kv = jax.vmap(
            lambda cp: attn.encode_cross_kv(cp, enc_out)
        )(stacked["cross"])
        out.append(kv)
    return out
