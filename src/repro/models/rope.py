"""Rotary position embeddings: standard RoPE, partial-rotary, and M-RoPE.

M-RoPE (qwen2-vl): head_dim channels are split into (temporal, height,
width) sections, each rotated by its own position stream.  For text tokens
all three streams coincide, recovering standard RoPE.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["rope_freqs", "apply_rope", "apply_mrope", "default_mrope_positions"]


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """Inverse frequencies for a (possibly partial) rotary dim."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(
    x: jnp.ndarray,                # (..., seq, heads, head_dim)
    positions: jnp.ndarray,        # (..., seq)
    *,
    theta: float = 10000.0,
    rotary_dim: Optional[int] = None,
) -> jnp.ndarray:
    head_dim = x.shape[-1]
    rd = rotary_dim or head_dim
    freqs = rope_freqs(rd, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, rd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    rot, rest = x[..., :rd], x[..., rd:]
    rot = _rotate(rot.astype(jnp.float32), cos, sin).astype(x.dtype)
    return jnp.concatenate([rot, rest], axis=-1) if rd < head_dim else rot


def default_mrope_positions(positions: jnp.ndarray) -> jnp.ndarray:
    """Text-only M-RoPE positions: all three streams equal (..., seq) -> (3, ..., seq)."""
    return jnp.stack([positions, positions, positions], axis=0)


def apply_mrope(
    x: jnp.ndarray,                # (..., seq, heads, head_dim)
    positions3: jnp.ndarray,       # (3, ..., seq): (t, h, w) streams
    *,
    theta: float = 10000.0,
    sections: Tuple[int, int, int] = (2, 1, 1),  # fractions of rd/2 (t,h,w) in 4ths
) -> jnp.ndarray:
    head_dim = x.shape[-1]
    half = head_dim // 2
    s_t = half * sections[0] // 4
    s_h = half * sections[1] // 4
    freqs = rope_freqs(head_dim, theta)  # (half,)
    # Select which position stream drives each frequency channel.
    ch = jnp.arange(half)
    stream = jnp.where(ch < s_t, 0, jnp.where(ch < s_t + s_h, 1, 2))
    pos = jnp.take(positions3, stream, axis=0)  # (half, ..., seq) -> move axis
    pos = jnp.moveaxis(pos, 0, -1)              # (..., seq, half)
    angles = pos.astype(jnp.float32) * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)
