"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block = (linear -> short conv -> RG-LRU) ⊙ (linear -> GeLU), then out-proj.
The RG-LRU diagonal recurrence h_t = a_t ⊙ h_{t-1} + sqrt(1-a_t²) ⊙ (i_t ⊙ x_t)
is evaluated with an associative scan in training (log-depth, O(S) work — the
reason long_500k is native for this family) and one step in decode.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef
from repro.parallel.sharding import shard

__all__ = ["rglru_defs", "rglru_train", "rglru_decode", "RGLRUCache", "rglru_init_cache"]

CONV_W = 4
_C = 8.0  # the paper's fixed recurrence temperature


class RGLRUCache(NamedTuple):
    state: jnp.ndarray   # (B, lru_width) recurrent state
    conv: jnp.ndarray    # (B, CONV_W - 1, lru_width)


def rglru_defs(d_model: int, lru_width: int, *, gate_blocks: int = 0):
    """RG-LRU parameters.

    ``gate_blocks > 0`` uses block-diagonal input/recurrence gates (the
    Griffin/RecurrentGemma design): W is (blocks, lru/blocks, lru/blocks),
    sharded on the block dim — the gate matmul then never contracts across
    the TP shard, removing one f32 (B,S,lru) all-reduce per gate per layer.
    ``gate_blocks == 0`` keeps dense gates (this repo's original baseline;
    see EXPERIMENTS.md §Perf cell A).
    """
    defs = {
        "wx": ParamDef((d_model, lru_width), ("embed", "lru_width")),
        "wy": ParamDef((d_model, lru_width), ("embed", "lru_width")),
        "conv_w": ParamDef((CONV_W, lru_width), (None, "lru_width")),
        "conv_b": ParamDef((lru_width,), ("lru_width",), "zeros"),
        "b_input_gate": ParamDef((lru_width,), ("lru_width",), "zeros"),
        "b_rec_gate": ParamDef((lru_width,), ("lru_width",), "zeros"),
        # Lambda init so a = sigmoid(L)^(c*r) starts near 0.9..0.999.
        "lam": ParamDef((lru_width,), ("lru_width",), 0.8),
        "wo": ParamDef((lru_width, d_model), ("lru_width", "embed")),
    }
    if gate_blocks:
        blk = lru_width // gate_blocks
        defs["w_input_gate"] = ParamDef(
            (gate_blocks, blk, blk), ("lru_width", None, None)
        )
        defs["w_rec_gate"] = ParamDef(
            (gate_blocks, blk, blk), ("lru_width", None, None)
        )
    else:
        defs["w_input_gate"] = ParamDef((lru_width, lru_width), ("lru_width", None))
        defs["w_rec_gate"] = ParamDef((lru_width, lru_width), ("lru_width", None))
    return defs


def _gate_matmul(x, w):
    if w.ndim == 3:  # block-diagonal (blocks, blk, blk)
        blocks, blk, _ = w.shape
        xb = x.reshape(x.shape[:-1] + (blocks, blk))
        return jnp.einsum("...hk,hkl->...hl", xb, w).reshape(x.shape)
    return jnp.einsum("...k,kl->...l", x, w)


def _gates(params, x):
    r = jax.nn.sigmoid(
        _gate_matmul(x, params["w_rec_gate"]) + params["b_rec_gate"]
    )
    i = jax.nn.sigmoid(
        _gate_matmul(x, params["w_input_gate"]) + params["b_input_gate"]
    )
    log_a = -_C * r * jax.nn.softplus(params["lam"])   # log a_t  (<= 0)
    a = jnp.exp(log_a)
    gated_x = i * x
    # sqrt(1 - a^2) input normaliser.
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return a.astype(jnp.float32), (beta * gated_x).astype(jnp.float32)


def _conv(params, x, s):
    x_pad = jnp.pad(x, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    return sum(
        x_pad[:, i : i + s] * params["conv_w"][i] for i in range(CONV_W)
    ) + params["conv_b"]


def rglru_train(params: Dict, u: jnp.ndarray, *, return_cache: bool = False,
                scan_impl: str = "associative", scan_chunk: int = 256):
    """RG-LRU over a full sequence.

    scan_impl:
      * "associative" — log-depth jax.lax.associative_scan: minimal latency
        but materialises O(log S) full (B, S, lru) f32 intermediates.
      * "linear" — chunked sequential scan (what Griffin's own Pallas kernel
        does): intra-chunk associative scan + sequential chunk recurrence,
        so the big intermediates are O(B, chunk, lru) and HBM traffic drops
        by ~S/chunk per stage (EXPERIMENTS.md §Perf cell A, iteration 2).
    """
    b, s, d = u.shape
    x_raw = jnp.einsum("bsd,dk->bsk", u, params["wx"])
    x_raw = shard(x_raw, "batch", None, "lru_width")
    x = _conv(params, x_raw, s)
    a, bx = _gates(params, x)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    if scan_impl == "linear" and s > scan_chunk and s % scan_chunk == 0:
        nc = s // scan_chunk
        lru = a.shape[-1]
        ar = a.reshape(b, nc, scan_chunk, lru).transpose(1, 0, 2, 3)
        br = bx.reshape(b, nc, scan_chunk, lru).transpose(1, 0, 2, 3)

        def chunk_body(h0, inp):
            a_c, b_c = inp
            # intra-chunk associative scan (small: (B, chunk, lru))
            pa, pb = jax.lax.associative_scan(combine, (a_c, b_c), axis=1)
            h_c = pb + pa * h0[:, None, :]
            return h_c[:, -1], h_c

        h0 = jnp.zeros((b, lru), jnp.float32)
        _, hs = jax.lax.scan(chunk_body, h0, (ar, br))
        h = hs.transpose(1, 0, 2, 3).reshape(b, s, lru)
    else:
        _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    gate = jax.nn.gelu(jnp.einsum("bsd,dk->bsk", u, params["wy"]))
    out = jnp.einsum("bsk,kd->bsd", h.astype(u.dtype) * gate, params["wo"])
    if return_cache:
        cache = RGLRUCache(state=h[:, -1], conv=x_raw[:, -(CONV_W - 1):])
        return out, cache
    return out


def rglru_init_cache(batch: int, lru_width: int, dtype=jnp.float32) -> RGLRUCache:
    return RGLRUCache(
        state=jnp.zeros((batch, lru_width), jnp.float32),
        conv=jnp.zeros((batch, CONV_W - 1, lru_width), dtype),
    )


def rglru_decode(
    params: Dict, u: jnp.ndarray, cache: RGLRUCache
) -> Tuple[jnp.ndarray, RGLRUCache]:
    b, _, d = u.shape
    x = jnp.einsum("bsd,dk->bsk", u, params["wx"])[:, 0]
    window = jnp.concatenate([cache.conv, x[:, None]], axis=1)
    x = jnp.einsum("bwk,wk->bk", window, params["conv_w"]) + params["conv_b"]
    a, bx = _gates(params, x)
    h = a * cache.state + bx
    gate = jax.nn.gelu(jnp.einsum("bsd,dk->bsk", u, params["wy"])[:, 0])
    y = jnp.einsum("bk,kd->bd", h.astype(u.dtype) * gate, params["wo"])[:, None]
    return y, RGLRUCache(state=h, conv=window[:, 1:])
