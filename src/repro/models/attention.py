"""Attention variants: GQA/MQA/MHA, local-window, MLA (deepseek-v2),
cross-attention, and the paper-integrated KNN top-k decode attention.

Training/prefill use a query-chunked exact attention (scan over query blocks)
so the (S, S) score matrix never materialises — the same "never write O(MN)
bytes" principle the paper applies to KNN scoring.

``knn_decode_attention`` treats the KV cache as the paper's database: scores
are one MXU matmul, PartialReduce selects the top-k keys (Eq. 13 recall
guarantee), and exact softmax runs over the k survivors.  This is Listing 1
with keys as the database, and is our sub-quadratic long-context path.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.topk import approx_max_k
from repro.models.params import ParamDef
from repro.models.rope import apply_mrope, apply_rope
from repro.parallel.sharding import shard

__all__ = [
    "attn_defs",
    "mla_defs",
    "cross_attn_defs",
    "attention_train",
    "attention_decode",
    "mla_train",
    "mla_decode",
    "cross_attention",
    "knn_decode_attention",
    "KVCache",
    "MLACache",
]

_NEG_INF = -1e30  # finite mask value: avoids NaN from (-inf) - (-inf)


class KVCache(NamedTuple):
    k: jnp.ndarray      # (B, S, KV, hd)
    v: jnp.ndarray      # (B, S, KV, hd)


class MLACache(NamedTuple):
    c_kv: jnp.ndarray   # (B, S, kv_lora)
    k_rope: jnp.ndarray  # (B, S, qk_rope)


# --------------------------------------------------------------------------
# Parameter definitions
# --------------------------------------------------------------------------


def attn_defs(d_model: int, num_heads: int, num_kv_heads: int, head_dim: int):
    return {
        "wq": ParamDef((d_model, num_heads, head_dim), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d_model, num_kv_heads, head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d_model, num_kv_heads, head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((num_heads, head_dim, d_model), ("heads", "head_dim", "embed")),
    }


def mla_defs(
    d_model: int,
    num_heads: int,
    *,
    q_lora_rank: int,
    kv_lora_rank: int,
    qk_nope_dim: int = 128,
    qk_rope_dim: int = 64,
    v_head_dim: int = 128,
):
    defs = {
        "wkv_a": ParamDef((d_model, kv_lora_rank + qk_rope_dim), ("embed", "kv_lora")),
        "kv_norm": ParamDef((kv_lora_rank,), ("kv_lora",), "ones"),
        "wk_b": ParamDef((kv_lora_rank, num_heads, qk_nope_dim), ("kv_lora", "heads", "head_dim")),
        "wv_b": ParamDef((kv_lora_rank, num_heads, v_head_dim), ("kv_lora", "heads", "head_dim")),
        "wo": ParamDef((num_heads, v_head_dim, d_model), ("heads", "head_dim", "embed")),
    }
    if q_lora_rank:
        defs["wq_a"] = ParamDef((d_model, q_lora_rank), ("embed", None))
        defs["q_norm"] = ParamDef((q_lora_rank,), (None,), "ones")
        defs["wq_b"] = ParamDef(
            (q_lora_rank, num_heads, qk_nope_dim + qk_rope_dim),
            (None, "heads", "head_dim"),
        )
    else:
        defs["wq"] = ParamDef(
            (d_model, num_heads, qk_nope_dim + qk_rope_dim),
            ("embed", "heads", "head_dim"),
        )
    return defs


def cross_attn_defs(d_model: int, num_heads: int, head_dim: int):
    return attn_defs(d_model, num_heads, num_heads, head_dim)


# --------------------------------------------------------------------------
# Core attend helpers
# --------------------------------------------------------------------------


def _repeat_kv(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, KV*groups, hd) by repetition (GQA)."""
    if groups == 1:
        return x
    b, s, kv, hd = x.shape
    return jnp.broadcast_to(
        x[:, :, :, None, :], (b, s, kv, groups, hd)
    ).reshape(b, s, kv * groups, hd)


def _attend_chunked(
    q: jnp.ndarray,              # (B, Sq, H, hd)
    k: jnp.ndarray,              # (B, Skv, H, hd)  (already GQA-expanded)
    v: jnp.ndarray,              # (B, Skv, H, hd)
    q_positions: jnp.ndarray,    # (Sq,)
    kv_positions: jnp.ndarray,   # (Skv,)
    *,
    causal: bool,
    window: Optional[int],
    chunk: int = 512,
) -> jnp.ndarray:
    """Exact attention, scanned over query chunks (scores stay O(chunk*Skv))."""
    b, sq, h, hd = q.shape
    vd = v.shape[-1]  # value head dim may differ from qk dim (MLA)
    scale = hd ** -0.5
    if sq % chunk:
        # Largest power-of-two divisor of sq not exceeding the request;
        # degenerate seqs fall back to a single block.
        c = 1
        while c * 2 <= chunk and sq % (c * 2) == 0:
            c *= 2
        chunk = c if c >= 16 else sq
    if sq <= chunk:
        return _attend_block(q, k, v, q_positions, kv_positions, scale, causal, window)
    n_chunks = sq // chunk
    qs = q.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pos = q_positions.reshape(n_chunks, chunk)

    def body(_, qp):
        qc, pc = qp
        return None, _attend_block(qc, k, v, pc, kv_positions, scale, causal, window)

    _, out = jax.lax.scan(body, None, (qs, pos))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, vd)


_SCORES_DTYPE = jnp.float32  # set via set_scores_dtype (hillclimb cell B)


def set_scores_dtype(dtype):
    """Storage dtype for attention score/exp tiles.

    bf16 tiles halve the O(S_q x S_kv) HBM traffic of unfused attention
    (reductions still accumulate in f32) — the paper's "don't write O(MN)
    bytes" pressure applied to the training attention path.  See
    EXPERIMENTS.md §Perf cell B.
    """
    global _SCORES_DTYPE
    _SCORES_DTYPE = dtype


def _attend_block(q, k, v, q_pos, kv_pos, scale, causal, window):
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    scores = shard(scores, "batch", "heads", None, None)
    mask = jnp.ones((q_pos.shape[-1], kv_pos.shape[-1]), bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - kv_pos[None, :] < window
    scores = jnp.where(mask[None, None], scores, _NEG_INF)
    if _SCORES_DTYPE == jnp.bfloat16:
        s16 = scores.astype(jnp.bfloat16)
        m = jnp.max(s16, axis=-1, keepdims=True)
        e = jnp.exp((s16 - m).astype(jnp.float32)).astype(jnp.bfloat16)
        denom = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
        probs = (e / denom.astype(jnp.bfloat16)).astype(q.dtype)
    else:
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# --------------------------------------------------------------------------
# Standard (GQA) attention
# --------------------------------------------------------------------------


def _qkv(params, x, positions, *, rope_theta, mrope, mrope_positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    if mrope:
        pos3 = (
            mrope_positions
            if mrope_positions is not None
            else jnp.stack([positions] * 3, axis=0)
        )
        q = apply_mrope(q, pos3, theta=rope_theta)
        k = apply_mrope(k, pos3, theta=rope_theta)
    elif rope_theta:
        q = apply_rope(q, positions, theta=rope_theta)
        k = apply_rope(k, positions, theta=rope_theta)
    return q, k, v


def attention_train(
    params: Dict,
    x: jnp.ndarray,                 # (B, S, d)
    positions: jnp.ndarray,         # (S,)
    *,
    num_heads: int,
    num_kv_heads: int,
    rope_theta: float = 10000.0,
    causal: bool = True,
    window: Optional[int] = None,
    mrope: bool = False,
    mrope_positions: Optional[jnp.ndarray] = None,
    q_chunk: int = 512,
    return_cache: bool = False,
):
    """Full-sequence self attention (training / prefill)."""
    q, k, v = _qkv(
        params, x, positions,
        rope_theta=rope_theta, mrope=mrope, mrope_positions=mrope_positions,
    )
    groups = num_heads // num_kv_heads
    ke, ve = _repeat_kv(k, groups), _repeat_kv(v, groups)
    out = _attend_chunked(
        q, ke, ve, positions, positions, causal=causal, window=window, chunk=q_chunk
    )
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if return_cache:
        return y, KVCache(k=k, v=v)
    return y


def attention_decode(
    params: Dict,
    x: jnp.ndarray,                 # (B, 1, d)
    cache: KVCache,
    cur_index: jnp.ndarray,         # scalar int32: position being generated
    *,
    num_heads: int,
    num_kv_heads: int,
    rope_theta: float = 10000.0,
    window: Optional[int] = None,
    mrope: bool = False,
    knn_k: int = 0,
    knn_recall_target: float = 0.95,
) -> Tuple[jnp.ndarray, KVCache]:
    """Single-token decode with KV cache update.

    With ``knn_k > 0`` key selection runs through the paper's PartialReduce
    (``knn_decode_attention``) instead of full softmax over S.
    """
    b, _, d = x.shape
    positions = jnp.full((1,), cur_index, jnp.int32)
    q, k_new, v_new = _qkv(
        params, x, positions, rope_theta=rope_theta, mrope=mrope, mrope_positions=None
    )
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, cur_index, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, cur_index, 0, 0))
    # Pin the cache layout after the in-place update: without this GSPMD may
    # re-shard (gather) the whole O(S) cache at the next consumer.
    k = shard(k, "batch", "cp_seq", None, None)
    v = shard(v, "batch", "cp_seq", None, None)
    new_cache = KVCache(k=k, v=v)
    groups = num_heads // num_kv_heads

    q1 = q[:, 0]                    # (B, H, hd)
    s = k.shape[1]
    kv_pos = jnp.arange(s, dtype=jnp.int32)
    valid = kv_pos <= cur_index
    if window is not None:
        valid &= cur_index - kv_pos < window
    if knn_k:
        # raw (unexpanded) cache: the GQA expansion happens group-wise inside
        # so the O(S) cache is never rematerialised at H width.
        out = knn_decode_attention(
            q1, k, v, valid, k=knn_k, recall_target=knn_recall_target,
            kv_groups=groups,
        )
    else:
        ke, ve = _repeat_kv(k, groups), _repeat_kv(v, groups)
        scores = jnp.einsum("bhd,bkhd->bhk", q1, ke) * (q1.shape[-1] ** -0.5)
        scores = jnp.where(valid[None, None], scores, _NEG_INF)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q1.dtype)
        out = jnp.einsum("bhk,bkhd->bhd", probs, ve)
    y = jnp.einsum("bhk,hkd->bd", out, params["wo"])[:, None]
    return y, new_cache


def knn_decode_attention(
    q: jnp.ndarray,        # (B, H, hd)
    keys: jnp.ndarray,     # (B, S, KV, hd)  raw (kv_groups expands to H)
    values: jnp.ndarray,   # (B, S, KV, hd)
    valid: jnp.ndarray,    # (S,) bool
    *,
    k: int,
    recall_target: float = 0.95,
    kv_groups: int = 1,
) -> jnp.ndarray:
    """Paper-technique attention over a KV cache.

    When the cache sequence is context-parallel (the "cp_seq" logical axis is
    mapped to mesh axes for this cell), this runs the paper's §7 distributed
    algorithm with shard_map: PartialReduce per shard (recall accounted
    against the global S), all-gather only the L bin winners *with their
    value vectors*, ExactRescore + softmax globally.  The wire cost is
    O(L x hd) per query instead of the O(S)-scores gather GSPMD would emit.
    """
    from repro.parallel.sharding import current_mesh, logical_to_spec

    mesh = current_mesh()
    cp = None
    if mesh is not None:
        spec = logical_to_spec(("cp_seq",))[0]
        if spec is not None:
            cp = spec if isinstance(spec, tuple) else (spec,)
    if cp:
        return _knn_decode_attention_cp(
            q, keys, values, valid, k=k, recall_target=recall_target,
            mesh=mesh, cp_axes=cp, kv_groups=kv_groups,
        )
    return _knn_decode_attention_local(
        q, _repeat_kv(keys, kv_groups), _repeat_kv(values, kv_groups), valid,
        k=k, recall_target=recall_target,
    )


def _knn_decode_attention_local(q, keys, values, valid, *, k, recall_target,
                                global_s: int = -1, index_offset=None):
    b, h, hd = q.shape
    scale = hd ** -0.5
    # MXU: all scores, one matmul (the paper's einsum).
    scores = jnp.einsum("bhd,bkhd->bhk", q, keys) * scale
    scores = jnp.where(valid[None, None], scores, _NEG_INF)
    # PartialReduce + rescoring: top-k keys with E[recall] per Eq. 13.
    top_scores, top_idx = approx_max_k(scores, k, recall_target=recall_target)
    # Exact softmax over the k survivors only.
    probs = jax.nn.softmax(top_scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    # Gather the selected values: (B, H, k, hd).
    v_bhsd = values.transpose(0, 2, 1, 3)  # (B, H, S, hd)
    sel = jnp.take_along_axis(v_bhsd, top_idx[..., None], axis=2)
    return jnp.einsum("bhk,bhkd->bhd", probs, sel)


def _knn_decode_attention_cp(q, keys, values, valid, *, k, recall_target,
                             mesh, cp_axes, kv_groups=1):
    """Distributed KNN attention (paper §7) over a sequence-sharded cache."""
    from jax.sharding import PartitionSpec as P

    global_s = keys.shape[1]

    def local_fn(q, keys_l, values_l, valid_l):
        b, s_l, kv, hd = keys_l.shape
        h = kv * kv_groups
        scale = hd ** -0.5
        # group-wise scores: no H-wide expansion of the O(S) cache.
        qg = q.reshape(b, kv, kv_groups, hd)
        scores = jnp.einsum(
            "bkgd,bskd->bkgs", qg, keys_l
        ).reshape(b, h, s_l) * scale
        values_l = _repeat_kv(values_l, kv_groups)  # (B, s_l, H, hd)
        scores = jnp.where(valid_l[None, None], scores, _NEG_INF)
        # Local PartialReduce: bin budget scaled by the global S (§7 /
        # reduction_input_size_override), keep bin winners only.
        vals, idxs = approx_max_k(
            scores, min(k, s_l), recall_target=recall_target,
            reduction_input_size_override=global_s,
            aggregate_to_topk=False,
        )
        # Attach the value vectors of the local winners: (B, H, L_loc, hd);
        # payloads travel in bf16 (scores stay f32 for the rescoring).
        v_bhsd = values_l.transpose(0, 2, 1, 3)
        sel_v = jnp.take_along_axis(v_bhsd, idxs[..., None], axis=2)
        sel_v = sel_v.astype(jnp.bfloat16)
        # All-gather candidates + payloads along the cp axes (tiny: O(L*hd)).
        for ax in cp_axes:
            vals = jax.lax.all_gather(vals, ax, axis=2, tiled=True)
            sel_v = jax.lax.all_gather(sel_v, ax, axis=2, tiled=True)
        # Global ExactRescoring + softmax over the k survivors.
        top_vals, top_pos = jax.lax.top_k(vals, k)
        probs = jax.nn.softmax(top_vals.astype(jnp.float32), -1).astype(q.dtype)
        top_v = jnp.take_along_axis(sel_v, top_pos[..., None], axis=2)
        return jnp.einsum("bhk,bhkd->bhd", probs, top_v)

    cp_spec = tuple(cp_axes) if len(cp_axes) > 1 else cp_axes[0]
    from repro.parallel.sharding import shard_map_compat

    fn = shard_map_compat(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(),
            P(None, cp_spec, None, None),
            P(None, cp_spec, None, None),
            P(cp_spec),
        ),
        out_specs=P(),
    )
    return fn(q, keys, values, valid)


# --------------------------------------------------------------------------
# MLA (deepseek-v2 multi-head latent attention)
# --------------------------------------------------------------------------


def _mla_q(params, x, positions, *, qk_nope_dim, qk_rope_dim, rope_theta):
    if "wq_a" in params:
        from repro.models.layers import rms_norm

        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["wq_a"]), params["q_norm"])
        q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope, q_rope = q[..., :qk_nope_dim], q[..., qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, theta=rope_theta)
    return q_nope, q_rope


def mla_train(
    params: Dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    num_heads: int,
    kv_lora_rank: int,
    qk_nope_dim: int = 128,
    qk_rope_dim: int = 64,
    rope_theta: float = 10000.0,
    q_chunk: int = 512,
    return_cache: bool = False,
):
    from repro.models.layers import rms_norm

    b, s, d = x.shape
    q_nope, q_rope = _mla_q(
        params, x, positions,
        qk_nope_dim=qk_nope_dim, qk_rope_dim=qk_rope_dim, rope_theta=rope_theta,
    )
    kv_a = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv = rms_norm(kv_a[..., :kv_lora_rank], params["kv_norm"])
    k_rope = apply_rope(
        kv_a[..., None, kv_lora_rank:], positions, theta=rope_theta
    )  # (B, S, 1, rope_dim) shared across heads
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"])
    value = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"])
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, num_heads, qk_rope_dim))], axis=-1
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = _attend_chunked(
        q_full, k_full, value, positions, positions,
        causal=True, window=None, chunk=q_chunk,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if return_cache:
        return y, MLACache(c_kv=c_kv, k_rope=k_rope[:, :, 0, :])
    return y


def mla_decode(
    params: Dict,
    x: jnp.ndarray,                # (B, 1, d)
    cache: MLACache,
    cur_index: jnp.ndarray,
    *,
    num_heads: int,
    kv_lora_rank: int,
    qk_nope_dim: int = 128,
    qk_rope_dim: int = 64,
    rope_theta: float = 10000.0,
    knn_k: int = 0,
    knn_recall_target: float = 0.95,
) -> Tuple[jnp.ndarray, MLACache]:
    """Absorbed-matmul MLA decode: attends in the compressed kv_lora space.

    Cache holds (c_kv, k_rope) — (512+64) floats/token instead of
    2*H*head_dim; score = q_nopeᵀ(W_kb c) + q_ropeᵀ k_rope computed by
    absorbing W_kb into the query.
    """
    from repro.models.layers import rms_norm

    b = x.shape[0]
    positions = jnp.full((1,), cur_index, jnp.int32)
    q_nope, q_rope = _mla_q(
        params, x, positions,
        qk_nope_dim=qk_nope_dim, qk_rope_dim=qk_rope_dim, rope_theta=rope_theta,
    )
    kv_a = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_new = rms_norm(kv_a[..., :kv_lora_rank], params["kv_norm"])
    kr_new = apply_rope(kv_a[..., None, kv_lora_rank:], positions, theta=rope_theta)[:, :, 0]
    c_kv = jax.lax.dynamic_update_slice(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), (0, cur_index, 0)
    )
    k_rope = jax.lax.dynamic_update_slice(
        cache.k_rope, kr_new.astype(cache.k_rope.dtype), (0, cur_index, 0)
    )
    new_cache = MLACache(c_kv=c_kv, k_rope=k_rope)

    # Absorb W_kb into q: (B, H, kv_lora).
    q_c = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], params["wk_b"])
    scale = (qk_nope_dim + qk_rope_dim) ** -0.5
    scores = (
        jnp.einsum("bhr,bsr->bhs", q_c, c_kv)
        + jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], k_rope)
    ) * scale
    s = c_kv.shape[1]
    valid = jnp.arange(s, dtype=jnp.int32) <= cur_index
    scores = jnp.where(valid[None, None], scores, _NEG_INF)

    if knn_k:
        top_scores, top_idx = approx_max_k(
            scores, knn_k, recall_target=knn_recall_target
        )
        probs = jax.nn.softmax(top_scores.astype(jnp.float32), -1).astype(x.dtype)
        sel = jnp.take_along_axis(
            jnp.broadcast_to(c_kv[:, None], (b, num_heads, s, kv_lora_rank)),
            top_idx[..., None],
            axis=2,
        )
        attn_c = jnp.einsum("bhk,bhkr->bhr", probs, sel)
    else:
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
        attn_c = jnp.einsum("bhs,bsr->bhr", probs, c_kv)
    out = jnp.einsum("bhr,rhk->bhk", attn_c, params["wv_b"])
    y = jnp.einsum("bhk,hkd->bd", out, params["wo"])[:, None]
    return y, new_cache


# --------------------------------------------------------------------------
# Cross attention (whisper decoder)
# --------------------------------------------------------------------------


def cross_attention(
    params: Dict,
    x: jnp.ndarray,                # (B, Sq, d)
    enc_kv: KVCache,               # precomputed from encoder output
    *,
    num_heads: int,
    q_chunk: int = 512,
):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    sq = x.shape[1]
    out = _attend_chunked(
        q, enc_kv.k, enc_kv.v,
        jnp.arange(sq), jnp.arange(enc_kv.k.shape[1]),
        causal=False, window=None, chunk=q_chunk,
    )
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def encode_cross_kv(params: Dict, enc_out: jnp.ndarray) -> KVCache:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    return KVCache(k=k, v=v)
