"""Common layers: norms, gated MLP, embedding / unembedding."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef
from repro.parallel.sharding import shard

__all__ = [
    "rms_norm",
    "layer_norm",
    "mlp_defs",
    "mlp_apply",
    "embed_defs",
    "embed_apply",
    "unembed_apply",
]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp_defs(d_model: int, d_ff: int, *, gated: bool = True) -> Dict[str, ParamDef]:
    defs = {
        "wi": ParamDef((d_model, d_ff), ("embed", "ffn")),
        "wo": ParamDef((d_ff, d_model), ("ffn", "embed")),
    }
    if gated:
        defs["wg"] = ParamDef((d_model, d_ff), ("embed", "ffn"))
    return defs


def mlp_apply(params, x, *, act: str = "silu"):
    h = jnp.einsum("...d,df->...f", x, params["wi"])
    if "wg" in params:
        h = _act(act)(jnp.einsum("...d,df->...f", x, params["wg"])) * h
    else:
        h = _act(act)(h)
    h = shard(h, "batch", None, "ffn")
    return jnp.einsum("...f,fd->...d", h, params["wo"])


def embed_defs(vocab: int, d_model: int) -> Dict[str, ParamDef]:
    return {"embedding": ParamDef((vocab, d_model), ("vocab", "embed"), 1.0)}


def embed_apply(params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed_apply(params, x: jnp.ndarray) -> jnp.ndarray:
    """Final-hidden x unembedding -> logits (the MIPS of paper Listing 1)."""
    logits = jnp.einsum("...d,vd->...v", x, params["embedding"])
    return shard(logits, "batch", None, "vocab")
