"""Parameter definition helpers: one source of truth for shape + logical axes.

A module describes its parameters as ``{name: ParamDef(shape, axes, scale)}``;
``init_params`` materialises them, ``param_axes`` returns the matching
logical-axes tree (used to build NamedShardings for pjit), and both stay in
sync by construction.  Stacked (scanned) layers prepend a "layers" axis.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ParamDef", "init_params", "param_axes", "stack_axes"]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    # None -> fan-in scaled normal; float -> explicit stddev; "zeros"/"ones".
    init: object = None

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape/axes rank mismatch: {self.shape} vs {self.axes}")


def _stddev(shape: Tuple[int, ...]) -> float:
    fan_in = shape[0] if len(shape) == 1 else math.prod(shape[:-1])
    return 1.0 / math.sqrt(max(fan_in, 1))


def init_params(key: jax.Array, defs, dtype=jnp.float32):
    """Materialise a (possibly nested) tree of ParamDefs."""
    flat, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, len(flat))
    leaves = []
    for k, d in zip(keys, flat):
        if d.init == "zeros":
            leaves.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            leaves.append(jnp.ones(d.shape, dtype))
        elif isinstance(d.init, float):
            leaves.append(d.init * jax.random.normal(k, d.shape, dtype))
        else:
            leaves.append(_stddev(d.shape) * jax.random.normal(k, d.shape, dtype))
    return jax.tree.unflatten(treedef, leaves)


def param_axes(defs):
    """Logical-axes tree with the same structure as ``init_params`` output."""
    return jax.tree.map(
        lambda d: d.axes, defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )


def stack_axes(axes_tree):
    """Prepend the scanned-layers axis to every leaf of an axes tree."""
    return jax.tree.map(
        lambda a: ("layers",) + tuple(a),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x
        ),
    )
