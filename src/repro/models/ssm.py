"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Training uses the chunked SSD algorithm: intra-chunk attention-like einsums
plus an inter-chunk recurrence over the (H, P, N) state — O(S) in sequence
length.  Decode is a single recurrent state update, O(1) per token, which is
why long_500k runs natively for this family.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef
from repro.parallel.sharding import shard

__all__ = ["ssm_defs", "ssm_train", "ssm_decode", "SSMCache", "ssm_init_cache"]

CONV_W = 4  # short causal conv window


class SSMCache(NamedTuple):
    state: jnp.ndarray       # (B, H, P, N) recurrent SSM state
    conv: jnp.ndarray        # (B, CONV_W - 1, conv_dim) conv tail


def ssm_dims(d_model: int, *, expand: int = 2, head_dim: int = 64, n_state: int = 128):
    d_inner = expand * d_model
    num_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * n_state  # x, B, C go through the conv
    return d_inner, num_heads, conv_dim


def ssm_defs(d_model: int, *, expand: int = 2, head_dim: int = 64, n_state: int = 128):
    d_inner, num_heads, conv_dim = ssm_dims(
        d_model, expand=expand, head_dim=head_dim, n_state=n_state
    )
    return {
        # order: [z (gate), x, B, C, dt]
        "in_proj": ParamDef(
            (d_model, 2 * d_inner + 2 * n_state + num_heads), ("embed", "conv_dim")
        ),
        "conv_w": ParamDef((CONV_W, conv_dim), (None, "conv_dim")),
        "conv_b": ParamDef((conv_dim,), ("conv_dim",), "zeros"),
        "a_log": ParamDef((num_heads,), ("ssm_heads",), 0.5),
        "d_skip": ParamDef((num_heads,), ("ssm_heads",), "ones"),
        "dt_bias": ParamDef((num_heads,), ("ssm_heads",), "zeros"),
        "norm": ParamDef((d_inner,), ("conv_dim",), "ones"),
        "out_proj": ParamDef((d_inner, d_model), ("conv_dim", "embed")),
    }


def _split_proj(proj, d_inner, n_state, num_heads):
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner : 2 * d_inner + 2 * n_state]
    dt = proj[..., 2 * d_inner + 2 * n_state :]
    return z, xbc, dt


def _gated_norm(y, z, scale, eps=1e-5):
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def ssm_train(
    params: Dict,
    u: jnp.ndarray,          # (B, S, d_model)
    *,
    expand: int = 2,
    head_dim: int = 64,
    n_state: int = 128,
    chunk: int = 256,
    return_cache: bool = False,
):
    b, s, d_model = u.shape
    d_inner, nh, conv_dim = ssm_dims(
        d_model, expand=expand, head_dim=head_dim, n_state=n_state
    )
    p = head_dim
    proj = jnp.einsum("bsd,dk->bsk", u, params["in_proj"])
    z, xbc, dt = _split_proj(proj, d_inner, n_state, nh)
    # Short causal conv over (x, B, C).
    xbc_pad = jnp.pad(xbc, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    conv = sum(
        xbc_pad[:, i : i + s] * params["conv_w"][i] for i in range(CONV_W)
    ) + params["conv_b"]
    conv = jax.nn.silu(conv)
    x = conv[..., :d_inner].reshape(b, s, nh, p)
    x = shard(x, "batch", None, "ssm_heads", None)
    B = conv[..., d_inner : d_inner + n_state]            # (B, S, N), 1 group
    C = conv[..., d_inner + n_state :]
    dt = jax.nn.softplus(dt + params["dt_bias"])          # (B, S, H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))     # (H,) negative
    da = dt.astype(jnp.float32) * a                       # (B, S, H) log-decay

    nc = s // chunk
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    xr = x.reshape(b, nc, chunk, nh, p)
    Br = B.reshape(b, nc, chunk, n_state)
    Cr = C.reshape(b, nc, chunk, n_state)
    dar = da.reshape(b, nc, chunk, nh)
    dtr = dt.reshape(b, nc, chunk, nh)

    # Intra-chunk cumulative decays.
    cum = jnp.cumsum(dar, axis=2)                          # (B, nc, c, H)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B, nc, c, c, H) log decay i<-j
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # Diagonal (intra-chunk) term: Y_intra = (C Bᵀ ⊙ decay ⊙ dt) X
    cb = jnp.einsum("bcin,bcjn->bcij", Cr, Br)             # (B, nc, c, c)
    w = cb[..., None] * decay * dtr[:, :, None, :, :]      # (B, nc, c, c, H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(x.dtype), xr)

    # Chunk-final states: S_n = sum_j exp(cum_end - cum_j) dt_j B_j x_jᵀ
    end_decay = jnp.exp(cum[:, :, -1:, :] - cum)           # (B, nc, c, H)
    contrib = jnp.einsum(
        "bcjh,bcjn,bcjhp->bchpn",
        (end_decay * dtr).astype(x.dtype), Br, xr,
    )                                                      # (B, nc, H, P, N)

    # Inter-chunk recurrence over chunk states.
    chunk_decay = jnp.exp(jnp.sum(dar, axis=2))            # (B, nc, H)

    def scan_body(state, inp):
        contrib_n, decay_n = inp
        new = state * decay_n[..., None, None] + contrib_n
        return new, state                                   # emit state *before* chunk

    init = jnp.zeros((b, nh, p, n_state), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_body,
        init,
        (contrib.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (B, nc, H, P, N)

    # Inter-chunk term: Y_inter[i] = C_i · (decay_to_i * prev_state)
    in_decay = jnp.exp(cum)                                 # decay from chunk start
    y_inter = jnp.einsum(
        "bcin,bchpn,bcih->bcihp",
        Cr, prev_states.astype(x.dtype), in_decay.astype(x.dtype),
    )

    y = (y_intra + y_inter).reshape(b, s, nh, p)
    y = y + x * params["d_skip"][None, None, :, None].astype(x.dtype)
    y = _gated_norm(y.reshape(b, s, d_inner), z, params["norm"])
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    if return_cache:
        cache = SSMCache(state=final_state, conv=xbc[:, -(CONV_W - 1):])
        return out, cache
    return out


def ssm_init_cache(batch: int, d_model: int, *, expand=2, head_dim=64, n_state=128, dtype=jnp.float32):
    d_inner, nh, conv_dim = ssm_dims(d_model, expand=expand, head_dim=head_dim, n_state=n_state)
    return SSMCache(
        state=jnp.zeros((batch, nh, head_dim, n_state), jnp.float32),
        conv=jnp.zeros((batch, CONV_W - 1, conv_dim), dtype),
    )


def ssm_decode(
    params: Dict,
    u: jnp.ndarray,          # (B, 1, d_model)
    cache: SSMCache,
    *,
    expand: int = 2,
    head_dim: int = 64,
    n_state: int = 128,
) -> Tuple[jnp.ndarray, SSMCache]:
    b, _, d_model = u.shape
    d_inner, nh, conv_dim = ssm_dims(
        d_model, expand=expand, head_dim=head_dim, n_state=n_state
    )
    p = head_dim
    proj = jnp.einsum("bsd,dk->bsk", u, params["in_proj"])[:, 0]
    z, xbc, dt = _split_proj(proj, d_inner, n_state, nh)
    window = jnp.concatenate([cache.conv, xbc[:, None]], axis=1)  # (B, W, conv)
    conv = jnp.einsum("bwk,wk->bk", window, params["conv_w"]) + params["conv_b"]
    conv = jax.nn.silu(conv)
    x = conv[:, :d_inner].reshape(b, nh, p)
    B = conv[:, d_inner : d_inner + n_state]
    C = conv[:, d_inner + n_state :]
    dt = jax.nn.softplus(dt + params["dt_bias"])            # (B, H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt.astype(jnp.float32) * a)             # (B, H)
    new_state = (
        cache.state * decay[..., None, None]
        + jnp.einsum("bh,bn,bhp->bhpn", dt, B, x).astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhpn->bhp", C, new_state.astype(x.dtype))
    y = y + x * params["d_skip"][None, :, None].astype(x.dtype)
    y = _gated_norm(y.reshape(b, d_inner), z, params["norm"])
    out = jnp.einsum("bk,kd->bd", y, params["out_proj"])[:, None]
    return out, SSMCache(state=new_state, conv=window[:, 1:])
