"""Step functions + abstract input specs for every (arch x shape) cell.

``train_step`` / ``prefill_step`` / ``decode_step`` are the exact callables
the launcher jits and the dry-run lowers.  ``input_specs`` produces
ShapeDtypeStruct stand-ins (no allocation) for each shape kind; modality
frontends (whisper audio conv, qwen2-vl vision patches) are stubs that
surface as precomputed embedding inputs, per the brief.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.topk import approx_max_k
from repro.models import transformer as tfm
from repro.optim.adamw import adamw_init, adamw_update
from repro.parallel.sharding import shard

__all__ = [
    "loss_fn",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "input_specs",
    "init_train_state",
    "TrainState",
]


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any


def _model_inputs(cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    use_embeds = cfg.input_mode == "embeddings" and not cfg.is_encoder_decoder
    main = batch["embeddings"] if use_embeds else batch["tokens"]
    kwargs = {}
    if cfg.is_encoder_decoder:
        kwargs["enc_embeds"] = batch["enc_embeds"]
    if cfg.mrope and "mrope_positions" in batch:
        kwargs["mrope_positions"] = batch["mrope_positions"]
    return main, kwargs


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """Next-token cross entropy (labels provided explicitly)."""
    main, kwargs = _model_inputs(cfg, batch)
    logits = tfm.forward_train(params, cfg, main, **kwargs)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = logits - 1e9 * pad_mask
    logp = jax.nn.log_softmax(logits, axis=-1)
    take = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = -(take * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss


def make_train_step(cfg: ModelConfig, *, learning_rate: float = 3e-4,
                    weight_decay: float = 0.1, grad_clip: float = 1.0,
                    grad_dtype: Optional[str] = None, microbatches: int = 1):
    """Build train_step(state, batch) -> (state, metrics).

    ``grad_dtype="bfloat16"`` enables compressed gradient all-reduce: grads
    are cast before the (GSPMD-inserted) data-parallel reduction and
    re-expanded inside the optimizer.

    ``microbatches > 1`` scans the global batch in chunks with f32 gradient
    accumulation — peak activation residency drops by the microbatch factor
    (the knob that makes the 236B train_4k cell fit v5e HBM; see
    EXPERIMENTS.md §Perf cell B).
    """

    def _grads(params, batch):
        if microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, cfg, batch)

        bsz = batch["labels"].shape[0]
        split_keys = {
            k for k, v in batch.items()
            if hasattr(v, "shape") and v.ndim >= 1 and v.shape[0] == bsz
        }
        static = {k: v for k, v in batch.items() if k not in split_keys}
        mb = {
            k: batch[k].reshape(
                (microbatches, bsz // microbatches) + batch[k].shape[1:]
            )
            for k in split_keys
        }

        def body(acc, micro):
            loss_sum, g_acc = acc
            loss, g = jax.value_and_grad(loss_fn)(params, cfg, {**static, **micro})
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g
            )
            return (loss_sum + loss, g_acc), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (loss_sum, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), mb)
        inv = 1.0 / microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(state: TrainState, batch):
        loss, grads = _grads(state.params, batch)
        if grad_dtype == "bfloat16":
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        params, opt_state = adamw_update(
            state.params, grads, state.opt_state,
            step=state.step, learning_rate=learning_rate,
            weight_decay=weight_decay,
        )
        metrics = {"loss": loss, "grad_norm": gnorm, "step": state.step}
        return TrainState(step=state.step + 1, params=params, opt_state=opt_state), metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, dtype=jnp.float32) -> TrainState:
    params = tfm.init_model(key, cfg, dtype)
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt_state=adamw_init(params),
    )


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        main, kwargs = _model_inputs(cfg, batch)
        enc = kwargs.get("enc_embeds")
        if cfg.is_encoder_decoder:
            enc_out = tfm._encode(params, cfg, enc)
            logits, caches = tfm.forward_prefill(params, cfg, main, enc_embeds=enc)
            cross_kv = tfm.build_cross_kv(params, cfg, enc_out)
            return logits, caches, cross_kv
        logits, caches = tfm.forward_prefill(params, cfg, main)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, use_knn: bool = False,
                     sample: str = "approx_topk", temperature: float = 0.8):
    """decode_step(params, tokens, caches, cur_index, rng[, cross_kv]).

    Sampling runs the paper's op over the vocabulary: approx_max_k picks the
    top ``cfg.decode_sample_k`` logits (MIPS against the unembedding), then a
    gumbel draw over those candidates.
    """

    def sample_tokens(logits, rng):
        logits = logits[:, -1].astype(jnp.float32)  # (B, V)
        if cfg.padded_vocab != cfg.vocab_size:
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = logits - 1e9 * pad_mask
        if sample == "greedy":
            return jnp.argmax(logits, -1)[:, None]
        vals, idxs = approx_max_k(
            logits, cfg.decode_sample_k, recall_target=cfg.knn_recall_target
        )
        g = jax.random.gumbel(rng, vals.shape)
        choice = jnp.argmax(vals / temperature + g, axis=-1)
        return jnp.take_along_axis(idxs, choice[:, None], axis=-1)

    def decode_step(params, tokens, caches, cur_index, rng, cross_kv=None):
        logits, caches = tfm.forward_decode(
            params, cfg, tokens, caches, cur_index,
            use_knn=use_knn, cross_kv=cross_kv,
        )
        next_tokens = sample_tokens(logits, rng)
        return next_tokens.astype(jnp.int32), logits, caches

    return decode_step


# --------------------------------------------------------------------------
# Abstract input specs (ShapeDtypeStruct, no allocation)
# --------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract inputs for one (arch x shape) cell.

    train/prefill: token (or stub-embedding) batch + labels.
    decode: single token + fully-populated caches + cur_index + rng.
    """
    b, s = shape.global_batch, shape.seq_len
    f = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if shape.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {"labels": _sds((b, s), jnp.int32)}
        if cfg.input_mode == "embeddings" and not cfg.is_encoder_decoder:
            batch["embeddings"] = _sds((b, s, cfg.d_model), f)
        else:
            batch["tokens"] = _sds((b, s), jnp.int32)
        if cfg.is_encoder_decoder:
            batch["enc_embeds"] = _sds((b, cfg.encoder_seq, cfg.d_model), f)
        if cfg.mrope:
            batch["mrope_positions"] = _sds((3, s), jnp.int32)
        if shape.kind == "prefill":
            batch.pop("labels")
        return batch
    # decode: cache stand-ins via eval_shape over init_caches (b, s static)
    caches = jax.eval_shape(lambda: tfm.init_caches(cfg, b, s))
    spec = {
        "tokens": _sds((b, 1), jnp.int32),
        "caches": caches,
        "cur_index": _sds((), jnp.int32),
        "rng": jax.ShapeDtypeStruct((2,), jnp.uint32),
    }
    if cfg.is_encoder_decoder:
        from repro.models.attention import KVCache

        hd = cfg.resolved_head_dim
        spec["cross_kv"] = [
            KVCache(
                k=_sds((count, b, cfg.encoder_seq, cfg.num_heads, hd), f),
                v=_sds((count, b, cfg.encoder_seq, cfg.num_heads, hd), f),
            )
            if kind == "dec"
            else None
            for kind, count in tfm.runs_of(cfg)
        ]
    return spec
