"""Distributed kNN-LM datastore on the unified search API.

The datastore is a ``repro.search.Index`` over (key, value-token) pairs —
optionally mesh-sharded (paper §7: local PartialReduce with global-N recall
accounting, all-gather, global ExactRescoring) — plus the kNN-LM
interpolation head.  Because the Index is index-free, the datastore supports
frequent updates: ``extend`` appends new pairs and ``forget`` tombstones old
ones with no rebuild.

Steady-state serving contract (inherited from the packed search state):
``lookup`` never prepares or pads the (N, D) key matrix — that happened
once at construction / ``extend`` time — and a multi-block query batch is
one device dispatch (the streaming executor), so datastore QPS tracks the
kernel roofline rather than dispatch overhead.

Under concurrent traffic (many decode streams sharing one datastore),
``attach_server`` puts a ``repro.search.serve.SearchServer`` in front of
the index: lookups from independent callers coalesce into planner-sized
micro-batches — one dispatch per batch — instead of issuing one small
dispatch each.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.search import Index
from repro.search import telemetry
from repro.search.backends import DISPATCH_COUNTS
from repro.search.packed import PACK_EVENTS
from repro.search.serve import SearchServer, ServeConfig

__all__ = ["KNNDatastore", "knn_lm_logits"]


class KNNDatastore:
    def __init__(
        self,
        keys: jnp.ndarray,           # (N, D) retrieval keys
        value_tokens: jnp.ndarray,   # (N,) token id each key predicts
        mesh: Optional[Mesh] = None,
        *,
        k: int = 32,
        recall_target: float = 0.95,
        db_axis: str = "model",
        batch_axis: Optional[str] = "data",
        metric: str = "mips",
        capacity: Optional[int] = None,
    ):
        # Pre-allocating ``capacity`` keeps ``extend`` on the cheap path:
        # append-slice patches only, no packed-layout growth copies.
        # With a mesh, build backend="sharded" so no throwaway unmeshed
        # packed layout is materialized before shard() packs the real one.
        self.index = Index.build(
            keys, metric=metric, k=k, recall_target=recall_target,
            capacity=capacity,
            backend="sharded" if mesh is not None else "auto",
        )
        if mesh is not None:
            self.index = self.index.shard(
                mesh, db_axis=db_axis, batch_axis=batch_axis
            )
        self.mesh = mesh
        self.k = k
        self.value_tokens = jnp.asarray(value_tokens)
        self.server: Optional[SearchServer] = None

    @property
    def keys(self) -> jnp.ndarray:
        return self.index._db

    def __len__(self) -> int:
        return len(self.index)

    def attach_server(
        self,
        server: Optional[SearchServer] = None,
        *,
        config: Optional[ServeConfig] = None,
        **server_kwargs,
    ) -> SearchServer:
        """Route ``lookup`` through a coalescing ``SearchServer``.

        Builds one over this datastore's index (``config`` / keyword
        arguments forwarded to ``SearchServer``) unless an existing
        ``server`` — which must already serve this index — is handed in,
        e.g. one shared across several datastore views.  Returns the
        attached server so callers can ``submit`` directly or ``close`` it.
        """
        if server is None:
            server = SearchServer(self.index, config, **server_kwargs)
        elif server.index is not self.index:
            raise ValueError("server serves a different Index instance")
        self.server = server
        return server

    def lookup(self, queries: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """-> (scores (M, k), neighbour value tokens (M, k)).

        With an attached server the batch rides the coalescing queue
        (other concurrent callers may share its dispatch); otherwise it is
        a direct index search.  Results are bit-identical either way.
        """
        if self.server is not None:
            vals, idxs = self.server.search(queries)
        else:
            vals, idxs = self.index.search(queries)
        return vals, jnp.take(self.value_tokens, idxs, axis=0)

    # -- frequent updates (the paper's "no index maintenance" claim) ---------

    def _mutation_gate(self):
        """The attached server's mutation gate, or a no-op without one:
        index updates must never interleave with a worker-thread dispatch
        (``SearchServer.mutation``)."""
        if self.server is not None:
            return self.server.mutation()
        import contextlib

        return contextlib.nullcontext()

    def extend(self, keys: jnp.ndarray, value_tokens: jnp.ndarray) -> "KNNDatastore":
        """Append (key, token) pairs in place; no rebuild."""
        keys = jnp.atleast_2d(jnp.asarray(keys))
        value_tokens = jnp.atleast_1d(jnp.asarray(value_tokens))
        if keys.shape[0] != value_tokens.shape[0]:
            raise ValueError(
                f"{keys.shape[0]} keys vs {value_tokens.shape[0]} tokens"
            )
        with self._mutation_gate():
            start = self.index.num_appended
            self.index.add(keys)
            # Keep value_tokens aligned with the append-only row space.
            pad = self.index.capacity - self.value_tokens.shape[0]
            if pad > 0:
                self.value_tokens = jnp.pad(self.value_tokens, (0, pad))
            self.value_tokens = self.value_tokens.at[
                start : start + value_tokens.shape[0]
            ].set(value_tokens.astype(self.value_tokens.dtype))
        return self

    def forget(self, ids) -> "KNNDatastore":
        """Tombstone datastore rows by index (e.g. stale documents).

        Device-side bias patch only — never blocks the decode loop on a
        host sync (``len(datastore)`` is what materializes the count).
        """
        with self._mutation_gate():
            self.index.delete(ids)
        return self

    def stats(self) -> dict:
        """Compile-cache and packing observability for serving dashboards.

        ``telemetry`` carries the global dispatch/trace counter series
        (``repro.search.telemetry`` snapshot of the adopted legacy
        dicts); the full registry export is ``self.index.telemetry()``.
        """
        info = dict(self.index.cache_info())
        info["capacity"] = self.index.capacity
        info["appended"] = self.index.num_appended
        reg = telemetry.registry()
        info["telemetry"] = {
            "dispatches": dict(DISPATCH_COUNTS),
            "pack_events": dict(PACK_EVENTS),
            "latency": reg.histogram_snapshot(
                "repro_serve_request_latency_seconds"
            ),
        }
        if self.server is not None:
            info["server"] = self.server.stats()
        return info


def knn_lm_logits(
    lm_logits: jnp.ndarray,        # (M, V)
    knn_scores: jnp.ndarray,       # (M, k) inner-product scores
    knn_tokens: jnp.ndarray,       # (M, k)
    *,
    lam: float = 0.25,
    temperature: float = 1.0,
) -> jnp.ndarray:
    """Interpolate p_LM with the neighbour distribution (Khandelwal et al.)."""
    vocab = lm_logits.shape[-1]
    w = jax.nn.softmax(knn_scores / temperature, axis=-1)
    p_knn = jax.vmap(
        lambda wk, tk: jnp.zeros((vocab,)).at[tk].add(wk)
    )(w, knn_tokens)
    p_lm = jax.nn.softmax(lm_logits, axis=-1)
    return jnp.log((1 - lam) * p_lm + lam * p_knn + 1e-20)
