"""Distributed kNN-LM datastore: the paper's §7 multi-chip extension as a
retrieval service for language models.

The datastore holds (key, value-token) pairs sharded over the mesh's model
axis.  A lookup is the paper's distributed MIPS: local PartialReduce on each
shard (recall accounted against the *global* N via
reduction_input_size_override), all-gather of the L bin winners, global
ExactRescoring.  ``knn_lm_logits`` turns neighbour distances into the
classic kNN-LM interpolation distribution.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.distributed import make_sharded_searcher

__all__ = ["KNNDatastore", "knn_lm_logits"]


class KNNDatastore:
    def __init__(
        self,
        keys: jnp.ndarray,           # (N, D) retrieval keys
        value_tokens: jnp.ndarray,   # (N,) token id each key predicts
        mesh: Optional[Mesh] = None,
        *,
        k: int = 32,
        recall_target: float = 0.95,
        db_axis: str = "model",
        batch_axis: Optional[str] = "data",
    ):
        self.mesh = mesh
        self.k = k
        self.value_tokens = value_tokens
        if mesh is not None:
            self.keys = jax.device_put(
                keys, NamedSharding(mesh, P(db_axis, None))
            )
            self._search = make_sharded_searcher(
                mesh, k=k, recall_target=recall_target,
                db_axis=db_axis, batch_axis=batch_axis, metric="mips",
            )
        else:
            self.keys = keys
            from repro.core.knn import mips

            self._search = lambda q, db: mips(
                q, db, k, recall_target=recall_target
            )

    def lookup(self, queries: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """-> (scores (M, k), neighbour value tokens (M, k))."""
        vals, idxs = self._search(queries, self.keys)
        return vals, jnp.take(self.value_tokens, idxs, axis=0)


def knn_lm_logits(
    lm_logits: jnp.ndarray,        # (M, V)
    knn_scores: jnp.ndarray,       # (M, k) inner-product scores
    knn_tokens: jnp.ndarray,       # (M, k)
    *,
    lam: float = 0.25,
    temperature: float = 1.0,
) -> jnp.ndarray:
    """Interpolate p_LM with the neighbour distribution (Khandelwal et al.)."""
    vocab = lm_logits.shape[-1]
    w = jax.nn.softmax(knn_scores / temperature, axis=-1)
    p_knn = jax.vmap(
        lambda wk, tk: jnp.zeros((vocab,)).at[tk].add(wk)
    )(w, knn_tokens)
    p_lm = jax.nn.softmax(lm_logits, axis=-1)
    return jnp.log((1 - lam) * p_lm + lam * p_knn + 1e-20)
