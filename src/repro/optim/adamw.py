"""AdamW optimizer + LR schedules, pure pytree implementation.

Optimizer state shards exactly like the parameters (the NamedShardings built
from model_axes apply to m/v too), so on FSDP-sharded archs this is
ZeRO-style partitioned optimizer state for free.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule", "linear_warmup"]


class AdamWState(NamedTuple):
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(m=jax.tree.map(zeros, params), v=jax.tree.map(zeros, params))


def adamw_update(
    params,
    grads,
    state: AdamWState,
    *,
    step,
    learning_rate=3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    """One AdamW step.  ``learning_rate`` may be a float or callable(step)."""
    lr = learning_rate(step) if callable(learning_rate) else learning_rate
    t = (step + 1).astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        update = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        p2 = p.astype(jnp.float32) - lr * (update + weight_decay * p.astype(jnp.float32))
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(m=new_m, v=new_v)


def linear_warmup(base_lr: float, warmup_steps: int):
    def sched(step):
        return base_lr * jnp.minimum(1.0, (step + 1) / warmup_steps)

    return sched


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1):
    def sched(step):
        warm = jnp.minimum(1.0, (step + 1) / warmup_steps)
        frac = jnp.clip(
            (step - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0
        )
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(math.pi * frac))
        return base_lr * warm * cos

    return sched
