"""Pallas TPU kernels for the paper's compute hot-spot (PartialReduce)."""
from repro.kernels.partial_reduce import partial_reduce_packed, partial_reduce_pallas
from repro.kernels.ref import partial_reduce_ref


# repro.kernels.ops is a deprecated shim over repro.search; re-export its
# entry points lazily (PEP 562) so the shim's DeprecationWarning fires only
# on actual use, not for importers of the Pallas kernels themselves.
def __getattr__(name):
    if name in ("l2_topk", "mips_topk", "ops"):
        import importlib

        ops = importlib.import_module("repro.kernels.ops")
        # `repro.kernels.ops` itself stays reachable as an attribute, as
        # the old eager import made it.
        return ops if name == "ops" else getattr(ops, name)
    raise AttributeError(f"module 'repro.kernels' has no attribute {name!r}")
