"""Pallas TPU kernels for the paper's compute hot-spot (PartialReduce)."""
from repro.kernels.ops import l2_topk, mips_topk
from repro.kernels.partial_reduce import partial_reduce_packed, partial_reduce_pallas
from repro.kernels.ref import partial_reduce_ref
