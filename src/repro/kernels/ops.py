"""DEPRECATED shim — use ``repro.search`` instead.

``mips_topk`` / ``l2_topk`` forward to the Pallas backend of the unified
search API (``repro.search.backends.pallas_search``), which also owns the
padding/bias preprocessing these wrappers used to implement
(``prepare_pallas_inputs``).  Original signatures are preserved.

Note one behavior change inherited from the unified backend: candidate
rescoring defaults to ``lax.top_k`` rather than the bitonic network (results
are identical — both are exact over the L candidates — but compiling the
bitonic sort inside jit is pathologically slow on CPU XLA).  Pass the
paper-faithful path via ``repro.search.SearchSpec(use_bitonic=True)``.
The old -> new mapping is tabulated in ``docs/migration.md``.
"""
from __future__ import annotations

import warnings

from typing import Optional, Tuple

import jax.numpy as jnp

warnings.warn(
    "repro.kernels.ops is a deprecated shim; use repro.search "
    "(Index.build(db, backend='pallas', ...)) — see docs/migration.md",
    DeprecationWarning,
    stacklevel=2,
)

__all__ = ["mips_topk", "l2_topk", "prepare_inputs"]

# repro.search.backends imports repro.kernels.partial_reduce, which executes
# this package's __init__ (and thus this module) first — so the backend
# import must be deferred past module load time.


def _backends():
    from repro.search import backends

    return backends


def prepare_inputs(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    k: int,
    recall_target: float,
    *,
    block_m: int,
    max_block_n: int = 1024,
    half_norms: Optional[jnp.ndarray] = None,
    reduction_input_size_override: int = -1,
):
    """Legacy padding front-end (half-norm convention): see
    ``repro.search.backends.prepare_pallas_inputs`` for the generic version."""
    return _backends().prepare_pallas_inputs(
        queries, database, k, recall_target,
        block_m=block_m, max_block_n=max_block_n,
        row_bias=None if half_norms is None else -half_norms,
        reduction_input_size_override=reduction_input_size_override,
    )


def mips_topk(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    k: int = 10,
    recall_target: float = 0.95,
    *,
    block_m: int = 256,
    max_block_n: int = 1024,
    interpret: bool = False,
    aggregate_to_topk: bool = True,
    reduction_input_size_override: int = -1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused-kernel MIPS (paper Listing 1, via the Pallas PartialReduce)."""
    return _backends().pallas_search(
        queries, database, None,
        metric="mips", k=k, recall_target=recall_target,
        block_m=block_m, max_block_n=max_block_n, interpret=interpret,
        aggregate_to_topk=aggregate_to_topk,
        reduction_input_size_override=reduction_input_size_override,
    )


def l2_topk(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    k: int = 10,
    recall_target: float = 0.95,
    *,
    half_norms: Optional[jnp.ndarray] = None,
    block_m: int = 256,
    max_block_n: int = 1024,
    interpret: bool = False,
    aggregate_to_topk: bool = True,
    reduction_input_size_override: int = -1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused-kernel Euclidean NN (paper Listing 2 / Eq. 19).

    Values follow the L2 contract in ``repro.search.metrics``: relaxed
    distances ``||x||^2/2 - <q,x>``, ascending.
    """
    if half_norms is None:
        half_norms = 0.5 * jnp.sum(jnp.square(database), axis=-1)
    return _backends().pallas_search(
        queries, database, -half_norms,
        metric="l2", k=k, recall_target=recall_target,
        block_m=block_m, max_block_n=max_block_n, interpret=interpret,
        aggregate_to_topk=aggregate_to_topk,
        reduction_input_size_override=reduction_input_size_override,
    )
