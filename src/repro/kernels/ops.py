"""jit'd front-ends for the fused PartialReduce Pallas kernel.

Handles the paper's preprocessing (Appendix A.5):
  * pad D to a multiple of 128 ("Padded to 128" row of Table 2),
  * pad N to the tile grid and mask the tail via the bias row
    (the non-power-of-2 masking COP),
  * fold the L2 halved norm into the same bias row (Eq. 19),
then plans bins for the recall target and runs kernel + ExactRescoring.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binning import plan_bins
from repro.core.rescoring import exact_rescoring
from repro.kernels.partial_reduce import partial_reduce_pallas

__all__ = ["mips_topk", "l2_topk", "prepare_inputs"]

_NEG_INF = float(np.finfo(np.float32).min)  # finite -inf surrogate: keeps the
# MXU path free of NaN propagation from 0 * -inf on padded dims.


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def prepare_inputs(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    k: int,
    recall_target: float,
    *,
    block_m: int,
    max_block_n: int = 1024,
    half_norms: Optional[jnp.ndarray] = None,
    reduction_input_size_override: int = -1,
):
    """Pad inputs to the tiling contract and build the fused bias row."""
    m, d = queries.shape
    n = database.shape[0]
    plan = plan_bins(
        n, k, recall_target,
        reduction_input_size_override=reduction_input_size_override,
    )
    bin_size = plan.bin_size
    block_n = bin_size * max(1, max_block_n // bin_size)
    n_pad = _round_up(max(n, block_n), block_n)
    m_pad = _round_up(max(m, block_m), block_m)
    d_pad = _round_up(d, 128)

    q = jnp.pad(queries, ((0, m_pad - m), (0, d_pad - d)))
    db = jnp.pad(database, ((0, n_pad - n), (0, d_pad - d)))
    bias = jnp.full((n_pad,), _NEG_INF, jnp.float32)
    body = (
        jnp.zeros((n,), jnp.float32)
        if half_norms is None
        else -half_norms.astype(jnp.float32)
    )
    bias = bias.at[:n].set(body)
    return q, db, bias[None, :], plan, bin_size, block_n, (m, n)


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "recall_target", "block_m", "max_block_n", "interpret",
        "aggregate_to_topk", "reduction_input_size_override",
    ),
)
def mips_topk(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    k: int = 10,
    recall_target: float = 0.95,
    *,
    block_m: int = 256,
    max_block_n: int = 1024,
    interpret: bool = False,
    aggregate_to_topk: bool = True,
    reduction_input_size_override: int = -1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused-kernel MIPS (paper Listing 1, via the Pallas PartialReduce)."""
    q, db, bias, plan, bin_size, block_n, (m, n) = prepare_inputs(
        queries, database, k, recall_target,
        block_m=block_m, max_block_n=max_block_n,
        reduction_input_size_override=reduction_input_size_override,
    )
    vals, idxs = partial_reduce_pallas(
        q, db, bias, bin_size=bin_size,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    vals, idxs = vals[:m], jnp.minimum(idxs[:m], n - 1)
    if not aggregate_to_topk:
        return vals, idxs
    return exact_rescoring(vals, idxs, k, mode="max")


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "recall_target", "block_m", "max_block_n", "interpret",
        "aggregate_to_topk", "reduction_input_size_override",
    ),
)
def l2_topk(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    k: int = 10,
    recall_target: float = 0.95,
    *,
    half_norms: Optional[jnp.ndarray] = None,
    block_m: int = 256,
    max_block_n: int = 1024,
    interpret: bool = False,
    aggregate_to_topk: bool = True,
    reduction_input_size_override: int = -1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused-kernel Euclidean NN (paper Listing 2 / Eq. 19).

    Maximizes <q,x> - ||x||^2/2; returned values are the relaxed distances
    ||x||^2/2 - <q,x> (negated kernel output), monotone in true L2.
    """
    if half_norms is None:
        half_norms = 0.5 * jnp.sum(jnp.square(database), axis=-1)
    q, db, bias, plan, bin_size, block_n, (m, n) = prepare_inputs(
        queries, database, k, recall_target,
        block_m=block_m, max_block_n=max_block_n, half_norms=half_norms,
        reduction_input_size_override=reduction_input_size_override,
    )
    vals, idxs = partial_reduce_pallas(
        q, db, bias, bin_size=bin_size,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    vals, idxs = vals[:m], jnp.minimum(idxs[:m], n - 1)
    if not aggregate_to_topk:
        return -vals, idxs
    top_v, top_i = exact_rescoring(vals, idxs, k, mode="max")
    return -top_v, top_i
