"""Pallas TPU kernel: fused score + PartialReduce (paper Alg. 2).

One grid step computes a (block_m, block_n) tile of the query-database score
matrix on the MXU and immediately reduces it to the top-1 (value, index) of
each bin of size 2**W — the O(M*N) score tile never leaves VMEM, which is
the whole point of the paper (I_MEM ~ O(min(M, N)), Eq. 10).

Two selection back-ends share that scan:

  * **Two-pass** (``partial_reduce_packed``): every grid step writes its
    (block_m, bins_per_block) bin-winner tile to HBM and the caller merges
    the (M, N/bin_size) winners with ``lax.top_k``.  Simple, and the
    parity oracle for the fused path.
  * **Fused** (``partial_reduce_fused``): a (block_m, k_scan) candidate
    buffer (values + global indices) lives in VMEM scratch and is carried
    across the sequential j-loop; each grid step merges its tile's bin
    winners into the carry, and only the final (M, k_scan) result is ever
    written to HBM (Eq. 20: database bytes + O(k), no score-tile term).
    Masked winners (tombstones, padded tail) carry the sentinel index -1
    alongside their -inf value, so they can never collide with a live row
    after the merge.

COP accounting (Appendix A.5): the in-tile reduction uses exactly 3
coefficient-wise ops per score (compare/select for the running max, the
iota compare, and the index min) = the paper's C=3.  The bias row fuses both
the non-power-of-2 masking COP and the L2 halved-norm COP into one add.
The fused merge adds O((k_scan + bins_per_block) * k_scan) vector ops per
tile — amortized over block_n database rows, a lower-order COP term.

Tiling contract (enforced by ops.py / repro.search.packed):
  * D is padded to a multiple of 128 (MXU lane width; 256 for the packed
    int4 tier so the two-codes-per-byte rows stay lane-aligned),
  * block_n is a multiple of the bin size 2**W,
  * N is padded to a multiple of block_n (bias = -inf on the padding),
  * block_m rows of queries are resident in VMEM across the j-loop
    (temporal locality of Alg. 2 line 1).  block_m is clamped to the
    sublane-rounded M, so an M=1 serving dispatch no longer pays a full
    block of wasted MXU rows.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.binning import round_up

__all__ = [
    "partial_reduce_fused",
    "partial_reduce_fused_pallas",
    "partial_reduce_packed",
    "partial_reduce_pallas",
]

# Same sentinel the search stages use (stages.MASK_VALUE); redeclared here
# so the kernel layer stays import-free of repro.search.
_MASK = float(jnp.finfo(jnp.float32).min)


def _effective_block_m(m: int, block_m: int, dtype) -> int:
    """Clamp the query tile to the sublane-rounded batch size.

    The planner's block_m targets throughput batches; a small serving
    batch (M=1) padded all the way to it would compute block_m rows of
    wasted MXU work per tile.  The sublane floor (8 f32 / 16 bf16 rows)
    is the hardware minimum.
    """
    sublane = 16 if jnp.dtype(dtype) == jnp.bfloat16 else 8
    return min(block_m, round_up(max(m, 1), sublane))


def partial_reduce_packed(
    queries: jnp.ndarray,   # (m, d) — any m, d <= database's lane-padded d
    database: jnp.ndarray,  # (n_pad, d_pad) pre-packed to the tiling contract
    bias: jnp.ndarray,      # (1, n_pad) f32, tail already masked
    scale: jnp.ndarray = None,  # (1, n_pad) f32 per-row scale (int8/int4)
    *,
    bin_size: int,
    block_m: int = 256,
    block_n: int = 1024,
    interpret: bool = False,
    int4_packed: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Query-side front half of the tiling contract over packed operands.

    The database and bias must already be packed (D padded to a lane
    multiple, N padded to ``block_n`` with masked tail) — see
    ``repro.search.packed``.  Only the (m, d) query block is padded here,
    so repeated searches against the same database perform zero
    database-sized copies.  ``database`` may be stored in a reduced-
    precision tier (bf16/int8/int4 — dequantized tile-locally in VMEM, so
    HBM streams the reduced bytes); ``scale`` carries the per-row scale,
    and ``int4_packed`` marks a two-codes-per-byte database whose logical
    width is twice its stored width.
    Returns (values, indices) with the query padding already stripped:
    both (m, n_pad // bin_size).
    """
    m, d = queries.shape
    d_pad = database.shape[1] * (2 if int4_packed else 1)
    if d > d_pad:
        raise ValueError(f"query dim {d} exceeds packed dim {d_pad}")
    bm = _effective_block_m(m, block_m, queries.dtype)
    m_pad = round_up(m, bm)
    q = jnp.pad(queries, ((0, m_pad - m), (0, d_pad - d)))
    vals, idxs = partial_reduce_pallas(
        q, database, bias, scale,
        bin_size=bin_size, block_m=bm, block_n=block_n,
        interpret=interpret, int4_packed=int4_packed,
    )
    return vals[:m], idxs[:m]


def partial_reduce_fused(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    bias: jnp.ndarray,
    scale: jnp.ndarray = None,
    *,
    k_scan: int,
    bin_size: int,
    block_m: int = 256,
    block_n: int = 1024,
    interpret: bool = False,
    int4_packed: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-pass scan→select over packed operands (Eq. 20 fused path).

    Same operand contract as :func:`partial_reduce_packed`, but selection
    is fused into the scan: the per-query top-``k_scan`` candidate buffer
    is carried in VMEM across the database stream and only the final
    (m, k_scan) result touches HBM.  Returns (values, indices), values
    sorted descending per row; masked entries (fewer than k_scan live
    candidates) hold ``-inf`` values and the sentinel index ``-1``.
    """
    m, d = queries.shape
    d_pad = database.shape[1] * (2 if int4_packed else 1)
    if d > d_pad:
        raise ValueError(f"query dim {d} exceeds packed dim {d_pad}")
    bm = _effective_block_m(m, block_m, queries.dtype)
    m_pad = round_up(m, bm)
    q = jnp.pad(queries, ((0, m_pad - m), (0, d_pad - d)))
    vals, idxs = partial_reduce_fused_pallas(
        q, database, bias, scale,
        k_scan=k_scan, bin_size=bin_size, block_m=bm, block_n=block_n,
        interpret=interpret, int4_packed=int4_packed,
    )
    return vals[:m], idxs[:m]


def _load_db_tile(x_ref, q_dtype, int4_packed: bool):
    """VMEM view of one database tile in the compute dtype.

    For the packed int4 tier the HBM stream carried two two's-complement
    nibbles per byte; unpack them here (arithmetic shifts sign-extend) so
    only the halved byte count ever crossed the memory wall.  Byte j holds
    logical column 2j in its low nibble and 2j+1 in its high nibble —
    matching ``quant.pack_int4_rows``.
    """
    x = x_ref[...]
    if int4_packed:
        xb = x.astype(jnp.int32)
        lo = (xb << 28) >> 28
        hi = xb >> 4
        x = jnp.stack([lo, hi], axis=-1).reshape(x.shape[0], -1)
    if x.dtype != q_dtype:
        # Reduced-precision storage tier: dequantize the tile in VMEM
        # before it hits the MXU (per-row scales apply to the scores).
        x = x.astype(q_dtype)
    return x


def _tile_winners(q_ref, x_ref, scale_ref, bias_ref,
                  *, block_n: int, bin_size: int, int4_packed: bool):
    """One grid step's bin-wise top-1: (values, global indices)."""
    block_m = q_ref.shape[0]
    bins_per_block = block_n // bin_size
    j = pl.program_id(1)

    q = q_ref[...]
    x = _load_db_tile(x_ref, q.dtype, int4_packed)
    # MXU: one (block_m, d) x (d, block_n) matmul, f32 accumulation.
    scores = jax.lax.dot_general(
        q,
        x,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if scale_ref is not None:
        scores = scores * scale_ref[...]  # per-row dequant scale
    scores = scores + bias_ref[...]  # fused mask / halved-norm (1 COP)

    # Bin-wise top-1: reshape puts each bin in the minor (lane) dimension.
    binned = scores.reshape(block_m, bins_per_block, bin_size)
    vmax = jnp.max(binned, axis=-1)                        # COP 1: running max
    lane = jax.lax.broadcasted_iota(jnp.int32, binned.shape, 2)
    hit = jnp.where(binned == vmax[..., None], lane, bin_size)  # COP 2: cmp+sel
    amax = jnp.min(hit, axis=-1)                           # COP 3: index min

    base = j * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (block_m, bins_per_block), 1
    ) * bin_size
    return vmax, base + amax


def _reduce_tile(q_ref, x_ref, scale_ref, bias_ref, v_ref, a_ref,
                 *, block_n: int, bin_size: int, int4_packed: bool = False):
    vmax, idx = _tile_winners(
        q_ref, x_ref, scale_ref, bias_ref,
        block_n=block_n, bin_size=bin_size, int4_packed=int4_packed,
    )
    v_ref[...] = vmax
    a_ref[...] = idx


def _merge_topk_carry(cv, ci, tv, ti, k_scan: int):
    """Merge a tile's bin winners into the running top-k_scan carry.

    Iterative first-lane max extraction over the concatenated
    (k_scan + bins_per_block) lanes: ties resolve to the lowest lane, and
    because the carry (earlier database tiles, itself extraction-ordered)
    precedes the tile winners (bin-ordered), tie order matches what
    ``lax.top_k`` over the full two-pass winner row would produce.  No
    ``lax.top_k``/gather inside the kernel — Mosaic only needs max, iota
    compares and masked sums.
    """
    v = jnp.concatenate([cv, tv], axis=1)
    i = jnp.concatenate([ci, ti], axis=1)
    lanes = v.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.int32, v.shape, 1)
    out_v, out_i = [], []
    for _ in range(k_scan):
        best = jnp.max(v, axis=1, keepdims=True)
        hit = jnp.where(v == best, lane, lanes)
        pos = jnp.min(hit, axis=1, keepdims=True)
        sel = lane == pos
        out_v.append(best)
        out_i.append(jnp.sum(jnp.where(sel, i, 0), axis=1, keepdims=True))
        # Retire BOTH halves of the extracted lane.  Masking only the value
        # would let the lane win a later -inf tie with its stale index — on
        # an all-tombstoned tile the first winner's index would then leak
        # into every masked output slot (the phantom-duplicate bug this
        # kernel exists to fix, resurfacing in VMEM).
        v = jnp.where(sel, _MASK, v)
        i = jnp.where(sel, -1, i)
    return (
        jnp.concatenate(out_v, axis=1),
        jnp.concatenate(out_i, axis=1),
    )


def _fused_tile(q_ref, x_ref, scale_ref, bias_ref, v_ref, a_ref,
                cv_ref, ci_ref,
                *, block_n: int, bin_size: int, k_scan: int,
                int4_packed: bool):
    block_m = q_ref.shape[0]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        # Fresh carry per query block: -inf values, sentinel indices.
        cv_ref[...] = jnp.full((block_m, k_scan), _MASK, jnp.float32)
        ci_ref[...] = jnp.full((block_m, k_scan), -1, jnp.int32)

    vmax, idx = _tile_winners(
        q_ref, x_ref, scale_ref, bias_ref,
        block_n=block_n, bin_size=bin_size, int4_packed=int4_packed,
    )
    # A fully-masked bin's winner is meaningless — pair its -inf value
    # with the sentinel index in-kernel so it can never alias a live row.
    idx = jnp.where(vmax > _MASK * 0.5, idx, -1)
    cv, ci = _merge_topk_carry(cv_ref[...], ci_ref[...], vmax, idx, k_scan)
    cv_ref[...] = cv
    ci_ref[...] = ci

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        v_ref[...] = cv_ref[...]
        a_ref[...] = ci_ref[...]


def _partial_reduce_kernel(
    q_ref,      # (block_m, d)      VMEM
    x_ref,      # (block_n, d)      VMEM
    bias_ref,   # (1, block_n)      VMEM: -inf mask and/or -||x||^2/2
    v_ref,      # (block_m, bins_per_block) VMEM out
    a_ref,      # (block_m, bins_per_block) VMEM out
    *,
    block_n: int,
    bin_size: int,
    int4_packed: bool,
):
    _reduce_tile(q_ref, x_ref, None, bias_ref, v_ref, a_ref,
                 block_n=block_n, bin_size=bin_size, int4_packed=int4_packed)


def _partial_reduce_kernel_scaled(
    q_ref,      # (block_m, d)      VMEM
    x_ref,      # (block_n, d) VMEM int8 (or packed int4 nibbles)
    scale_ref,  # (1, block_n)      VMEM f32 per-row scale
    bias_ref,   # (1, block_n)      VMEM
    v_ref,
    a_ref,
    *,
    block_n: int,
    bin_size: int,
    int4_packed: bool,
):
    _reduce_tile(q_ref, x_ref, scale_ref, bias_ref, v_ref, a_ref,
                 block_n=block_n, bin_size=bin_size, int4_packed=int4_packed)


def _fused_kernel(q_ref, x_ref, bias_ref, v_ref, a_ref, cv_ref, ci_ref,
                  *, block_n, bin_size, k_scan, int4_packed):
    _fused_tile(q_ref, x_ref, None, bias_ref, v_ref, a_ref, cv_ref, ci_ref,
                block_n=block_n, bin_size=bin_size, k_scan=k_scan,
                int4_packed=int4_packed)


def _fused_kernel_scaled(q_ref, x_ref, scale_ref, bias_ref, v_ref, a_ref,
                         cv_ref, ci_ref,
                         *, block_n, bin_size, k_scan, int4_packed):
    _fused_tile(q_ref, x_ref, scale_ref, bias_ref, v_ref, a_ref,
                cv_ref, ci_ref,
                block_n=block_n, bin_size=bin_size, k_scan=k_scan,
                int4_packed=int4_packed)


def _validate_tiling(queries, database, *, block_m, block_n, bin_size,
                     int4_packed):
    m, d = queries.shape
    n, w = database.shape
    d_db = 2 * w if int4_packed else w
    if d != d_db:
        raise ValueError(f"dim mismatch: {d} vs {d_db}")
    if d % 128 or m % block_m or n % block_n or block_n % bin_size:
        raise ValueError(
            f"tiling contract violated: m={m} d={d} n={n} "
            f"block_m={block_m} block_n={block_n} bin_size={bin_size}"
        )


@functools.partial(
    jax.jit,
    static_argnames=(
        "bin_size", "block_m", "block_n", "interpret", "int4_packed",
    ),
)
def partial_reduce_pallas(
    queries: jnp.ndarray,   # (m, d)  m % block_m == 0, d % 128 == 0
    database: jnp.ndarray,  # (n, d)  n % block_n == 0
    bias: jnp.ndarray,      # (1, n)  f32
    scale: jnp.ndarray = None,  # (1, n) f32 per-row scale, or None
    *,
    bin_size: int,
    block_m: int = 256,
    block_n: int = 1024,
    interpret: bool = False,
    int4_packed: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused score+reduce. Returns (values, indices), both (m, n // bin_size).

    Shapes must already satisfy the tiling contract — use
    ``repro.kernels.ops`` for the padding/planning front-end.  ``database``
    may be a reduced-precision storage tier (bf16/int8/int4); ``scale`` is
    the scaled tiers' per-row dequantization scale, applied to the score
    tile in VMEM.
    """
    _validate_tiling(queries, database, block_m=block_m, block_n=block_n,
                     bin_size=bin_size, int4_packed=int4_packed)
    m, d = queries.shape
    n, w = database.shape
    num_bins = n // bin_size
    bins_per_block = block_n // bin_size
    grid = (m // block_m, n // block_n)

    in_specs = [
        pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
        pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
        pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
    ]
    kw = dict(block_n=block_n, bin_size=bin_size, int4_packed=int4_packed)
    if scale is None:
        kernel = functools.partial(_partial_reduce_kernel, **kw)
        operands = (queries, database, bias)
    else:
        kernel = functools.partial(_partial_reduce_kernel_scaled, **kw)
        # scale rides the same (1, block_n) tiling as the bias row.
        in_specs.insert(2, pl.BlockSpec((1, block_n), lambda i, j: (0, j)))
        operands = (queries, database, scale, bias)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_m, bins_per_block), lambda i, j: (i, j)),
            pl.BlockSpec((block_m, bins_per_block), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, num_bins), jnp.float32),
            jax.ShapeDtypeStruct((m, num_bins), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)


@functools.partial(
    jax.jit,
    static_argnames=(
        "k_scan", "bin_size", "block_m", "block_n", "interpret",
        "int4_packed",
    ),
)
def partial_reduce_fused_pallas(
    queries: jnp.ndarray,   # (m, d)  m % block_m == 0, d % 128 == 0
    database: jnp.ndarray,  # (n, d)  n % block_n == 0
    bias: jnp.ndarray,      # (1, n)  f32
    scale: jnp.ndarray = None,  # (1, n) f32 per-row scale, or None
    *,
    k_scan: int,
    bin_size: int,
    block_m: int = 256,
    block_n: int = 1024,
    interpret: bool = False,
    int4_packed: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-pass scan→select: (values, indices), both (m, k_scan).

    The top-k_scan carry lives in VMEM scratch across the sequential
    j-loop (TPU grids iterate the last axis innermost), so per search the
    only HBM traffic is the query block, the database stream and the
    final (m, k_scan) result — the paper's Eq. 20 contract.  Values come
    out sorted descending; masked entries hold (-inf, -1).
    """
    _validate_tiling(queries, database, block_m=block_m, block_n=block_n,
                     bin_size=bin_size, int4_packed=int4_packed)
    if k_scan <= 0:
        raise ValueError(f"k_scan must be positive, got {k_scan}")
    m, d = queries.shape
    n, w = database.shape
    grid = (m // block_m, n // block_n)

    in_specs = [
        pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
        pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
        pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
    ]
    kw = dict(block_n=block_n, bin_size=bin_size, k_scan=k_scan,
              int4_packed=int4_packed)
    if scale is None:
        kernel = functools.partial(_fused_kernel, **kw)
        operands = (queries, database, bias)
    else:
        kernel = functools.partial(_fused_kernel_scaled, **kw)
        in_specs.insert(2, pl.BlockSpec((1, block_n), lambda i, j: (0, j)))
        operands = (queries, database, scale, bias)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_m, k_scan), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, k_scan), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k_scan), jnp.float32),
            jax.ShapeDtypeStruct((m, k_scan), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_m, k_scan), jnp.float32),
            pltpu.VMEM((block_m, k_scan), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
