"""Pallas TPU kernel: fused score + PartialReduce (paper Alg. 2).

One grid step computes a (block_m, block_n) tile of the query-database score
matrix on the MXU and immediately reduces it to the top-1 (value, index) of
each bin of size 2**W — the O(M*N) score tile never leaves VMEM, which is
the whole point of the paper (I_MEM ~ O(min(M, N)), Eq. 10).

COP accounting (Appendix A.5): the in-tile reduction uses exactly 3
coefficient-wise ops per score (compare/select for the running max, the
iota compare, and the index min) = the paper's C=3.  The bias row fuses both
the non-power-of-2 masking COP and the L2 halved-norm COP into one add.

Tiling contract (enforced by ops.py):
  * D is padded to a multiple of 128 (MXU lane width),
  * block_n is a multiple of the bin size 2**W,
  * N is padded to a multiple of block_n (bias = -inf on the padding),
  * block_m rows of queries are resident in VMEM across the j-loop
    (temporal locality of Alg. 2 line 1).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.binning import round_up

__all__ = ["partial_reduce_packed", "partial_reduce_pallas"]


def partial_reduce_packed(
    queries: jnp.ndarray,   # (m, d) — any m, d <= database's lane-padded d
    database: jnp.ndarray,  # (n_pad, d_pad) pre-packed to the tiling contract
    bias: jnp.ndarray,      # (1, n_pad) f32, tail already masked
    scale: jnp.ndarray = None,  # (1, n_pad) f32 per-row scale (int8 tier)
    *,
    bin_size: int,
    block_m: int = 256,
    block_n: int = 1024,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Query-side front half of the tiling contract over packed operands.

    The database and bias must already be packed (D padded to a lane
    multiple, N padded to ``block_n`` with masked tail) — see
    ``repro.search.packed``.  Only the (m, d) query block is padded here,
    so repeated searches against the same database perform zero
    database-sized copies.  ``database`` may be stored in a reduced-
    precision tier (bf16/int8 — dequantized tile-locally in VMEM, so HBM
    streams the reduced bytes); ``scale`` carries the int8 per-row scale.
    Returns (values, indices) with the query padding already stripped:
    both (m, n_pad // bin_size).
    """
    m, d = queries.shape
    d_pad = database.shape[1]
    if d > d_pad:
        raise ValueError(f"query dim {d} exceeds packed dim {d_pad}")
    m_pad = round_up(max(m, block_m), block_m)
    q = jnp.pad(queries, ((0, m_pad - m), (0, d_pad - d)))
    vals, idxs = partial_reduce_pallas(
        q, database, bias, scale,
        bin_size=bin_size, block_m=block_m, block_n=block_n,
        interpret=interpret,
    )
    return vals[:m], idxs[:m]


def _reduce_tile(q_ref, x_ref, scale_ref, bias_ref, v_ref, a_ref,
                 *, block_n: int, bin_size: int):
    block_m = q_ref.shape[0]
    bins_per_block = block_n // bin_size
    j = pl.program_id(1)

    q = q_ref[...]
    x = x_ref[...]
    if x.dtype != q.dtype:
        # Reduced-precision storage tier: the HBM stream carried the
        # narrow dtype; dequantize the tile in VMEM before it hits the
        # MXU (per-row int8 scales apply to the scores below).
        x = x.astype(q.dtype)
    # MXU: one (block_m, d) x (d, block_n) matmul, f32 accumulation.
    scores = jax.lax.dot_general(
        q,
        x,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if scale_ref is not None:
        scores = scores * scale_ref[...]  # int8 per-row dequant scale
    scores = scores + bias_ref[...]  # fused mask / halved-norm (1 COP)

    # Bin-wise top-1: reshape puts each bin in the minor (lane) dimension.
    binned = scores.reshape(block_m, bins_per_block, bin_size)
    vmax = jnp.max(binned, axis=-1)                        # COP 1: running max
    lane = jax.lax.broadcasted_iota(jnp.int32, binned.shape, 2)
    hit = jnp.where(binned == vmax[..., None], lane, bin_size)  # COP 2: cmp+sel
    amax = jnp.min(hit, axis=-1)                           # COP 3: index min

    base = j * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (block_m, bins_per_block), 1
    ) * bin_size
    v_ref[...] = vmax
    a_ref[...] = base + amax


def _partial_reduce_kernel(
    q_ref,      # (block_m, d)      VMEM
    x_ref,      # (block_n, d)      VMEM
    bias_ref,   # (1, block_n)      VMEM: -inf mask and/or -||x||^2/2
    v_ref,      # (block_m, bins_per_block) VMEM out
    a_ref,      # (block_m, bins_per_block) VMEM out
    *,
    block_n: int,
    bin_size: int,
):
    _reduce_tile(q_ref, x_ref, None, bias_ref, v_ref, a_ref,
                 block_n=block_n, bin_size=bin_size)


def _partial_reduce_kernel_scaled(
    q_ref,      # (block_m, d)      VMEM
    x_ref,      # (block_n, d)      VMEM int8
    scale_ref,  # (1, block_n)      VMEM f32 per-row scale
    bias_ref,   # (1, block_n)      VMEM
    v_ref,
    a_ref,
    *,
    block_n: int,
    bin_size: int,
):
    _reduce_tile(q_ref, x_ref, scale_ref, bias_ref, v_ref, a_ref,
                 block_n=block_n, bin_size=bin_size)


@functools.partial(
    jax.jit,
    static_argnames=(
        "bin_size", "block_m", "block_n", "interpret",
    ),
)
def partial_reduce_pallas(
    queries: jnp.ndarray,   # (m, d)  m % block_m == 0, d % 128 == 0
    database: jnp.ndarray,  # (n, d)  n % block_n == 0
    bias: jnp.ndarray,      # (1, n)  f32
    scale: jnp.ndarray = None,  # (1, n) f32 per-row scale, or None
    *,
    bin_size: int,
    block_m: int = 256,
    block_n: int = 1024,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused score+reduce. Returns (values, indices), both (m, n // bin_size).

    Shapes must already satisfy the tiling contract — use
    ``repro.kernels.ops`` for the padding/planning front-end.  ``database``
    may be a reduced-precision storage tier (bf16/int8); ``scale`` is the
    int8 tier's per-row dequantization scale, applied to the score tile
    in VMEM.
    """
    m, d = queries.shape
    n, d2 = database.shape
    if d != d2:
        raise ValueError(f"dim mismatch: {d} vs {d2}")
    if d % 128 or m % block_m or n % block_n or block_n % bin_size:
        raise ValueError(
            f"tiling contract violated: m={m} d={d} n={n} "
            f"block_m={block_m} block_n={block_n} bin_size={bin_size}"
        )
    num_bins = n // bin_size
    bins_per_block = block_n // bin_size
    grid = (m // block_m, n // block_n)

    in_specs = [
        pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
        pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
    ]
    if scale is None:
        kernel = functools.partial(
            _partial_reduce_kernel, block_n=block_n, bin_size=bin_size
        )
        operands = (queries, database, bias)
    else:
        kernel = functools.partial(
            _partial_reduce_kernel_scaled, block_n=block_n, bin_size=bin_size
        )
        # scale rides the same (1, block_n) tiling as the bias row.
        in_specs.insert(2, pl.BlockSpec((1, block_n), lambda i, j: (0, j)))
        operands = (queries, database, scale, bias)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_m, bins_per_block), lambda i, j: (i, j)),
            pl.BlockSpec((block_m, bins_per_block), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, num_bins), jnp.float32),
            jax.ShapeDtypeStruct((m, num_bins), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
