"""Pure-jnp oracle for the fused PartialReduce kernel.

Mirrors ``partial_reduce_pallas`` semantics exactly (same padding, same bias
fusion, same lowest-index tie-break) so kernel tests can assert_allclose.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = ["partial_reduce_ref"]


def partial_reduce_ref(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    bin_size: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    m, d = queries.shape
    n = database.shape[0]
    scores = (
        jnp.einsum(
            "ik,jk->ij", queries, database, preferred_element_type=jnp.float32
        )
        + bias
    )
    num_bins = n // bin_size
    binned = scores.reshape(m, num_bins, bin_size)
    vals = jnp.max(binned, axis=-1)
    args = jnp.argmax(binned, axis=-1)  # first occurrence == lowest index
    offsets = jnp.arange(num_bins, dtype=jnp.int32) * bin_size
    return vals, offsets[None, :] + args.astype(jnp.int32)
