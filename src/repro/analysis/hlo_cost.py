"""Trip-count-aware cost analysis over optimized HLO text.

XLA:CPU's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers program is undercounted by ~num_layers.  This module walks
the HLO call graph (entry -> fusions/calls/whiles) multiplying while bodies
by their trip counts, and reports:

  * dot_flops:   2 * prod(result_dims) * contracted_dim per dot — i.e. MXU
                 flops only, which is exactly the numerator the compute
                 roofline term wants,
  * hbm_bytes:   sum of (operands + result) sizes over top-level ops of each
                 executed computation (the standard fusion-boundary traffic
                 approximation),
  * cop_count:   element-count of non-dot, non-copy top-level ops — a VPU
                 COP estimate for the paper's third roofline term.

Trip counts are parsed from each while condition's compare-against-constant.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

from repro.analysis.hlo import DTYPE_BYTES

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\](?:{[^}]*})?")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body)=%?([\w\.\-]+)"
)
_COND_ATTR_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')


def _shape_elems(dtype: str, dims: str) -> Tuple[int, int]:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n, n * DTYPE_BYTES.get(dtype, 4)


@dataclass
class _Op:
    kind: str
    result_bytes: int
    result_elems: int
    operand_bytes: int
    flops: float
    callees: List[str] = field(default_factory=list)
    cond: Optional[str] = None
    trip: Optional[int] = None
    update_bytes: int = 0


@dataclass
class HloCost:
    dot_flops: float
    hbm_bytes: float        # geometric mean of the hi/lo traffic models
    hbm_bytes_hi: float     # fusion-boundary model (CPU-granularity upper bound)
    hbm_bytes_lo: float     # perfect-fusion model (dots/reduces/slices only)
    cop_count: float
    while_trips: Dict[str, int]


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]+?\)?)\s+"
    r"([a-z][a-z0-9\-]*)\((.*)$"
)
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")

_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy-start", "copy-done", "after-all", "partition-id", "replica-id",
}
_SKIP_COPS = _SKIP_BYTES | {
    "dot", "copy", "transpose", "reshape", "broadcast", "iota", "convert",
    "slice", "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "while", "fusion", "call", "conditional",
    "custom-call", "rng-bit-generator", "gather", "scatter",
}


_DNUMS_RE = re.compile(
    r"lhs_contracting_dims=\{([0-9,]*)\}.*?rhs_contracting_dims=\{([0-9,]*)\}"
)
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


def _dims_of(text: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def _dot_flops_precise(result_text: str, rest: str, shapes_by_name) -> float:
    """flops = 2 * result_elems * prod(lhs contracted dims).

    Operand shapes are resolved through the per-computation name->dims map
    (optimized HLO prints operand names, not shapes)."""
    op_end = rest.find(")")
    operand_names = _OPERAND_NAME_RE.findall(rest[: op_end if op_end >= 0 else len(rest)])
    lhs_dims = shapes_by_name.get(operand_names[0]) if operand_names else None
    if lhs_dims is None:
        return 0.0
    m = _DNUMS_RE.search(rest)
    contract = 1
    if m and m.group(1):
        for ci in m.group(1).split(","):
            ci = int(ci)
            if ci < len(lhs_dims):
                contract *= lhs_dims[ci]
    rm = _SHAPE_RE.search(result_text)
    if not rm:
        return 0.0
    relems, _ = _shape_elems(rm.group(1), rm.group(2))
    return 2.0 * relems * contract


def _parse(hlo: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    # (computation, op_name, result_text, kind, rest) records + shape map.
    records = []
    shapes_by_name: Dict[str, List[int]] = {}
    bytes_by_name: Dict[str, int] = {}
    cur: Optional[str] = None
    entry_name: Optional[str] = None
    comment_re = re.compile(r"/\*.*?\*/")
    for line in hlo.splitlines():
        if "/*" in line:
            line = comment_re.sub("", line)  # XLA's /*index=N*/ tuple comments
        stripped = line.strip()
        mc = _COMP_RE.match(stripped) if "{" in line and "->" in line else None
        if mc:
            cur = mc.group(1)
            comps[cur] = []
            if stripped.startswith("ENTRY"):
                entry_name = cur
            continue
        if cur is None:
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        mo = _OP_RE.match(line)
        if not mo:
            # parameter declarations etc. still define shapes
            pm = re.match(r"^\s*%?([\w\.\-]+)\s*=\s*(\(?.*?\)?)\s+parameter\(", line)
            continue
        name, result_text, kind, rest = mo.groups()
        sm = _SHAPE_RE.search(result_text)
        if sm:
            shapes_by_name[name] = (
                [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
            )
        relems = rbytes = 0
        for dt, dims in _SHAPE_RE.findall(result_text):
            e, b = _shape_elems(dt, dims)
            relems += e
            rbytes += b
        bytes_by_name[name] = rbytes
        records.append((cur, name, result_text, kind, rest, relems, rbytes))

    for cur, name, result_text, kind, rest, relems, rbytes in records:
        op_end = rest.find(")")
        operand_names = _OPERAND_NAME_RE.findall(
            rest[: op_end if op_end >= 0 else len(rest)]
        )
        obytes = sum(bytes_by_name.get(n, 0) for n in operand_names)
        flops = (
            _dot_flops_precise(result_text, rest, shapes_by_name)
            if kind == "dot"
            else 0.0
        )
        callees = _CALL_ATTR_RE.findall(rest)
        cond = None
        mcond = _COND_ATTR_RE.search(rest)
        if mcond:
            cond = mcond.group(1)
        op = _Op(kind=kind, result_bytes=rbytes, result_elems=relems,
                 operand_bytes=obytes, flops=flops, callees=callees, cond=cond)
        if kind == "while":
            mt = _TRIP_RE.search(rest)
            if mt:
                op.trip = int(mt.group(1))
        if kind == "dynamic-update-slice" and len(operand_names) >= 2:
            op.update_bytes = bytes_by_name.get(operand_names[1], 0)
        comps[cur].append(op)
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def analyze_hlo(hlo: str) -> HloCost:
    comps = _parse(hlo)
    # Trip counts: find constants inside condition computations.
    cond_consts: Dict[str, int] = {}
    cur = None
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line.strip()) if "{" in line and "->" in line else None
        if mc:
            cur = mc.group(1)
            continue
        if cur and "constant(" in line:
            for c in _CONST_RE.findall(line):
                cond_consts[cur] = max(cond_consts.get(cur, 1), int(c))

    memo: Dict[str, Tuple[float, float, float, float]] = {}
    _REDUCE_KINDS = {"reduce", "reduce-window", "sort", "gather", "scatter",
                     "select-and-scatter", "cumsum"}

    def _slice_bytes(ops: List[_Op]) -> Tuple[float, float, bool]:
        """(dus update bytes, ds result bytes, contains-reduce) for a comp."""
        dus = ds = 0.0
        has_reduce = False
        for op in ops:
            if op.kind == "dynamic-update-slice":
                dus += op.update_bytes if op.update_bytes else op.result_bytes
            elif op.kind == "dynamic-slice":
                ds += op.result_bytes
            elif op.kind in _REDUCE_KINDS:
                has_reduce = True
        return dus, ds, has_reduce

    def cost_of(name: str) -> Tuple[float, float, float, float]:
        if name in memo:
            return memo[name]
        memo[name] = (0.0, 0.0, 0.0, 0.0)  # cycle guard
        flops = hi = lo = cops = 0.0
        for op in comps.get(name, []):
            if op.kind == "while":
                body = op.callees[0] if op.callees else None
                trips = op.trip or (cond_consts.get(op.cond, 1) if op.cond else 1)
                if body:
                    bf, bh, bl, bc = cost_of(body)
                    flops += trips * bf
                    hi += trips * bh
                    lo += trips * bl
                    cops += trips * bc
                continue
            if op.kind in ("fusion", "call", "conditional", "custom-call"):
                dus_b = ds_b = 0.0
                has_reduce = False
                for callee in op.callees:
                    cf, ch, cl, cc = cost_of(callee)
                    flops += cf
                    cops += cc
                    lo += cl          # nested dots/slices inside the fusion
                    d, s2, r = _slice_bytes(comps.get(callee, []))
                    dus_b += d
                    ds_b += s2
                    has_reduce |= r
                # hi: fusion-boundary traffic; in-place stack updates move
                # only the slice.
                hi += 2 * dus_b if dus_b else 2 * op.result_bytes
                # lo: perfect fusion — only reductions, dots and slice
                # traffic survive; pure elementwise fusions melt into their
                # consumers.
                lo += 2 * dus_b + ds_b
                if has_reduce:
                    lo += 2 * op.result_bytes
                continue
            if op.kind == "dot":
                flops += op.flops
                hi += op.operand_bytes + op.result_bytes
                lo += op.operand_bytes + op.result_bytes
                continue
            if op.kind == "dynamic-update-slice":
                hi += 2 * (op.update_bytes or op.result_bytes)
                lo += 2 * (op.update_bytes or op.result_bytes)
                continue
            if op.kind == "dynamic-slice":
                hi += 2 * op.result_bytes
                lo += 2 * op.result_bytes
                continue
            if op.kind in _REDUCE_KINDS:
                hi += op.operand_bytes + op.result_bytes
                lo += op.operand_bytes + op.result_bytes
                if op.kind not in _SKIP_COPS:
                    cops += op.result_elems
                continue
            if op.kind not in _SKIP_BYTES:
                hi += 2 * op.result_bytes
            if op.kind not in _SKIP_COPS:
                cops += op.result_elems
        memo[name] = (flops, hi, lo, cops)
        return memo[name]

    f, hi, lo, c = (
        cost_of("__entry__") if "__entry__" in comps else (0.0, 0.0, 0.0, 0.0)
    )
    trips = {
        cond: n for cond, n in cond_consts.items() if n > 1
    }
    # The truth lies between the two fusion models; use the geometric mean as
    # the headline number and report both bounds.
    mean = (hi * lo) ** 0.5 if hi and lo else max(hi, lo)
    return HloCost(dot_flops=f, hbm_bytes=mean, hbm_bytes_hi=hi,
                   hbm_bytes_lo=lo, cop_count=c, while_trips=trips)
