"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the per-cell
JSON artifacts written by repro.launch.dryrun.

  PYTHONPATH=src python -m repro.analysis.rooflines [--dir benchmarks/results/dryrun]

Also renders KNN kernel-plan tables (``knn_plan_table``) from
``repro.search.plan.Plan`` objects — the same markdown shape as the
training-cell roofline tables, fed by the planner instead of dryrun JSON:

  PYTHONPATH=src python -m repro.analysis.rooflines --knn
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load_cells(directory: str) -> List[Dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_table(cells: List[Dict], mesh: str = "single") -> str:
    rows = [
        "| arch | shape | dominant | compute | memory | collective | instr "
        "| roofline frac | useful ratio | notes |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("mesh") != mesh or "error" in c:
            continue
        r = c["roofline"]
        notes = "knn-attn" if c.get("knn_attention") else ""
        rows.append(
            f"| {c['arch']} | {c['shape']} | **{r['dominant']}** "
            f"| {_fmt_s(r['compute_s'])} | {_fmt_s(r['memory_s'])} "
            f"| {_fmt_s(r['collective_s'])} | {_fmt_s(r['instruction_s'])} "
            f"| {r['roofline_fraction']:.3f} | {r['useful_ratio']:.2f} | {notes} |"
        )
    return "\n".join(rows)


def dryrun_table(cells: List[Dict]) -> str:
    rows = [
        "| arch | shape | mesh | compile | flops/dev | bytes/dev (lo..hi) "
        "| collective B/dev | top collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if "error" in c:
            rows.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | FAIL | | | "
                f"| {c['error'][:60]} |"
            )
            continue
        kinds = c.get("collective_breakdown", {})
        top = ", ".join(
            f"{k}:{v / 1e6:.0f}MB"
            for k, v in sorted(kinds.items(), key=lambda kv: -kv[1])[:2]
        )
        lo = c.get("hlo_bytes_per_device", 0)
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['compile_s']}s "
            f"| {c['hlo_flops_per_device']:.2e} | {lo:.2e} "
            f"| {c['collective_bytes']:.2e} | {top} |"
        )
    return "\n".join(rows)


def knn_plan_table(plans) -> str:
    """Markdown table over ``repro.search.plan.Plan`` rows.

    The KNN analogue of ``roofline_table``: one row per planned workload,
    straight from the planner that configures the live kernels.
    """
    rows = [
        "| workload | device | L x 2^W | tiles (bm, bn, qb) | I_MEM | I_COP "
        "| wall | attainable | E[recall] |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for label, p in plans:
        rows.append(
            f"| {label} | {p.device} | {p.num_bins} x 2^{p.log2_bin_size} "
            f"| ({p.block_m}, {p.block_n}, {p.query_block}) "
            f"| {p.i_mem:.0f} | {p.i_cop:.1f} | **{p.bottleneck}** "
            f"| {p.attainable_flops / 1e12:.1f} TF/s "
            f"| {p.expected_recall:.4f} |"
        )
    return "\n".join(rows)


def knn_main() -> None:
    """Print the paper-workload plan table for every Table-1 device."""
    from repro.configs.knn_workloads import KNN_WORKLOADS

    plans = [
        (name, w.plan(device=dev))
        for name, w in KNN_WORKLOADS.items()
        for dev in ("tpu_v3", "tpu_v4", "tpu_v5e")
    ]
    print("## KNN kernel plans (repro.search.plan)\n")
    print(knn_plan_table(plans))


def pick_hillclimb(cells: List[Dict]):
    """worst roofline fraction / most collective-bound / most paper-like."""
    ok = [c for c in cells if "error" not in c and c["mesh"] == "single"]
    worst = min(ok, key=lambda c: c["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda c: c["roofline"]["collective_s"]
               / max(c["roofline"]["step_time_s"], 1e-12))
    knn = [c for c in ok if c.get("knn_attention")]
    paper = max(knn, key=lambda c: c["hlo_flops_per_device"]) if knn else ok[0]
    return worst, coll, paper


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--knn", action="store_true",
                    help="print planner-derived KNN kernel plan tables")
    args = ap.parse_args()
    if args.knn:
        knn_main()
        return
    cells = load_cells(args.dir)
    print("## Dry-run (all cells)\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single-pod, per-device terms, TPU v5e)\n")
    print(roofline_table(cells, "single"))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(cells, "multi"))
    w, c, p = pick_hillclimb(cells)
    print(
        f"\nhillclimb picks: worst-frac={w['arch']}x{w['shape']} "
        f"(frac {w['roofline']['roofline_fraction']:.3f}); "
        f"collective-bound={c['arch']}x{c['shape']}; "
        f"paper-representative={p['arch']}x{p['shape']} (knn-attn)"
    )


if __name__ == "__main__":
    main()
