"""Post-SPMD HLO analysis: collective bytes, op census, remat detection.

``collective_bytes`` parses ``compiled.as_text()`` and estimates per-device
wire bytes with ring-algorithm conventions:
  all-reduce          2 x result bytes   (reduce-scatter + all-gather phases)
  all-gather          1 x result bytes   (each device receives ~the result)
  reduce-scatter      1 x operand bytes
  all-to-all          1 x result bytes
  collective-permute  1 x result bytes
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Dict, Tuple

__all__ = ["collective_bytes", "op_census", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*(\(?[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """-> (total wire bytes per device, per-op-kind breakdown)."""
    by_kind: Dict[str, float] = Counter()
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        result_shapes, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # avoid double counting start/done pairs
        result_bytes = _shape_bytes(result_shapes)
        if kind == "all-reduce":
            by_kind[kind] += 2 * result_bytes
        elif kind == "reduce-scatter":
            # operand bytes: shapes inside the call parens.
            operand_text = line[line.index("(") :]
            operands = _shape_bytes(operand_text)
            by_kind[kind] += max(operands, result_bytes)
        else:
            by_kind[kind] += result_bytes
    return float(sum(by_kind.values())), dict(by_kind)


def op_census(hlo_text: str) -> Dict[str, int]:
    """Count interesting op kinds (fusion/remat/reshape diagnostics)."""
    ops = Counter()
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*[^ ]+\s+([a-z][a-z0-9\-]*)\(", line)
        if m:
            ops[m.group(1)] += 1
    return dict(ops)
