"""Steady-state search benchmark: QPS + dispatch overhead, stream vs loop.

Measures what the packed-state PR changed — per-search host/HBM overhead —
across a backend x metric x (M, N, D) grid:

  * steady-state QPS of ``Index.search`` over pre-packed operands,
  * dispatches per search (``backends.DISPATCH_COUNTS``): the streaming
    executor issues ONE for a multi-block batch, the per-block Python loop
    (``SearchSpec(stream=False)``) issues M / query_block,
  * the stream-over-loop wall-clock speedup ("before/after" of the packed
    state PR),
  * model-planned vs legacy hard-coded tile configs (``plan_results``):
    the kernel planner (``repro.search.plan``) must match or beat the old
    (256, 1024, 4096) defaults at bit-identical results,
  * sharded scaling + the host cold tier (``shard_results``): QPS and the
    one-dispatch contract vs fake device count (one subprocess per count),
    and the host tier's segment-wave schedule with per-wave occupancy.

Writes ``BENCH_search.json`` (one run per invocation; history lives in git —
commit full-grid runs, CI smoke runs only touch the working tree).

  python benchmarks/bench_search.py                  # full grid
  python benchmarks/bench_search.py --smoke          # CI: one tiny config,
                                                     # asserts the dispatch
                                                     # contract + no big
                                                     # stream regression

CPU wall-clocks are machine-relative; the dispatch counts are exact
everywhere.  On CPU the dispatch overhead is a large fraction of a small
search, which is exactly why the smoke config can see the streaming win.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.search import Index, SearchSpec, backends, exact_search
from repro.search import telemetry
from repro.search import plan as planlib
from repro.search.packed import PACK_EVENTS

# The pre-planner hard-coded tile configuration (PR-2 and earlier): the
# baseline the model-planned path must match or beat.
LEGACY_BLOCKS = dict(block_m=256, max_block_n=1024, query_block=4096)

# (M, N, D) grid: M spans single-block through 16-block batches at the
# query_block below; N/D stay CPU-tractable while keeping the matmul real.
# The (4096, 2048, 32) entry is the dispatch-bound corner (16 small blocks)
# where the streaming executor's win is largest.
FULL_GRID = [
    (256, 4096, 64),
    (1024, 4096, 64),
    (2048, 16384, 64),
    (2048, 4096, 128),
    (4096, 2048, 32),
]
FULL_BACKENDS = ("xla", "pallas")
FULL_METRICS = ("mips", "l2", "cosine")
QUERY_BLOCK = 256

SMOKE_GRID = [(512, 2048, 32)]
SMOKE_QUERY_BLOCK = 32  # 512 queries = 16 blocks (criterion: M >= 4*qb)


def _time_search(index, queries, repeats, passes=3):
    """Best-of-``passes`` mean wall per search (min filters scheduler noise)."""
    index.search(queries).values.block_until_ready()  # warmup/compile
    best = float("inf")
    for _ in range(passes):
        backends.reset_dispatch_counts()
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = index.search(queries)
        out.values.block_until_ready()
        best = min(best, (time.perf_counter() - t0) / repeats)
        dispatches = sum(backends.DISPATCH_COUNTS.values())
    return best, dispatches / repeats


def bench_config(backend, metric, m, n, d, query_block, repeats, emit):
    key = jax.random.PRNGKey(0)
    kq, kd = jax.random.split(key)
    db = jax.random.normal(kd, (n, d))
    queries = jax.random.normal(kq, (m, d))
    row = {
        "backend": backend, "metric": metric,
        "m": m, "n": n, "d": d, "query_block": query_block,
    }
    for mode, stream in (("stream", True), ("loop", False)):
        index = Index.build(
            db,
            spec=SearchSpec(
                metric=metric, k=10, backend=backend,
                query_block=query_block, stream=stream,
            ),
        )
        wall, dispatches = _time_search(index, queries, repeats)
        row[mode] = {
            "wall_s_per_search": wall,
            "qps": m / wall,
            "dispatches_per_search": dispatches,
        }
    row["stream_speedup"] = (
        row["loop"]["wall_s_per_search"] / row["stream"]["wall_s_per_search"]
    )
    emit(
        f"{backend},{metric},M={m},N={n},D={d}: "
        f"stream {row['stream']['qps']:.0f} qps "
        f"({row['stream']['dispatches_per_search']:.0f} dispatch) vs "
        f"loop {row['loop']['qps']:.0f} qps "
        f"({row['loop']['dispatches_per_search']:.0f} dispatches) "
        f"-> {row['stream_speedup']:.2f}x"
    )
    return row


def bench_plan(backend, metric, m, n, d, repeats, emit):
    """Model-planned tiles vs the pre-planner hard-coded defaults.

    Also asserts bit-parity: the planner may only change layout/padding,
    never results.
    """
    key = jax.random.PRNGKey(0)
    kq, kd = jax.random.split(key)
    db = jax.random.normal(kd, (n, d))
    queries = jax.random.normal(kq, (m, d))
    model = Index.build(db, spec=SearchSpec(metric=metric, k=10, backend=backend))
    legacy = Index.build(
        db, spec=SearchSpec(metric=metric, k=10, backend=backend, **LEGACY_BLOCKS)
    )
    vm, im = model.search(queries)
    vl, il = legacy.search(queries)
    assert (vm == vl).all() and (im == il).all(), (
        f"planner changed results for {backend}/{metric} M={m} N={n} D={d}"
    )
    wall_model, _ = _time_search(model, queries, repeats)
    wall_legacy, _ = _time_search(legacy, queries, repeats)
    plan = model.kernel_plan
    row = {
        "backend": backend, "metric": metric, "m": m, "n": n, "d": d,
        "planned": {
            "block_m": plan.block_m, "block_n": plan.block_n,
            "query_block": plan.query_block, "num_bins": plan.num_bins,
            "bin_size": plan.bin_size, "bottleneck": plan.bottleneck,
            "source": plan.source,
        },
        "model_qps": m / wall_model,
        "legacy_qps": m / wall_legacy,
        "model_over_legacy": wall_legacy / wall_model,
    }
    emit(
        f"plan,{backend},{metric},M={m},N={n},D={d}: "
        f"model {row['model_qps']:.0f} qps "
        f"(bm={plan.block_m},bn={plan.block_n},qb={plan.query_block}) vs "
        f"legacy {row['legacy_qps']:.0f} qps -> "
        f"{row['model_over_legacy']:.2f}x"
    )
    return row


def bench_quant(backend, metric, m, n, d, query_block, repeats, emit):
    """Quantized storage tiers vs f32 (repro.search.quant).

    Reports steady-state QPS per tier, empirical recall vs the f32 tier's
    results, the planner's predicted database HBM-traffic ratio, and the
    one-dispatch/zero-retrace/zero-repack contract counters on the
    quantized path.
    """
    key = jax.random.PRNGKey(0)
    kq, kd = jax.random.split(key)
    db = jax.random.normal(kd, (n, d))
    queries = jax.random.normal(kq, (m, d))
    base = Index.build(
        db,
        spec=SearchSpec(metric=metric, k=10, backend=backend,
                        query_block=query_block),
    )
    _, base_idx = base.search(queries)
    base_sets = [set(r.tolist()) for r in jax.device_get(base_idx)]
    row = {
        "backend": backend, "metric": metric,
        "m": m, "n": n, "d": d, "query_block": query_block, "tiers": {},
    }
    for storage in ("f32", "bf16", "int8", "int4"):
        index = Index.build(
            db,
            spec=SearchSpec(metric=metric, k=10, backend=backend,
                            query_block=query_block, storage=storage),
        )
        index.search(queries)  # warmup: trace + compile + pack
        telemetry.reset_all()  # one reset for every counter surface
        wall, dispatches = _time_search(index, queries, repeats)
        retraces = sum(backends.TRACE_COUNTS.values())
        packs = sum(PACK_EVENTS.values())
        _, idxs = index.search(queries)
        rec = sum(
            len(set(r.tolist()) & s) / 10
            for r, s in zip(jax.device_get(idxs), base_sets)
        ) / m
        # The planner's fused-kernel traffic model: what the tier buys on
        # the memory wall (Eq. 10/20) — pure math, device-independent.
        plan = planlib.plan_search(
            n=n, d=d, k=10, m=query_block, metric=metric,
            backend="pallas", device="tpu_v4", storage=storage,
        )
        row["tiers"][storage] = {
            "wall_s_per_search": wall,
            "qps": m / wall,
            "dispatches_per_search": dispatches,
            "steady_retraces": retraces,
            "steady_pack_events": packs,
            "recall_vs_f32": rec,
            "predicted_hbm_bytes": plan.hbm_bytes,
            "k_scan": plan.k_scan,
        }
        emit(
            f"quant,{backend},{metric},M={m},N={n},D={d},{storage}: "
            f"{m / wall:.0f} qps ({dispatches:.0f} dispatch, "
            f"{retraces} retrace, {packs} packs) recall@f32 {rec:.3f} "
            f"pred-HBM {plan.hbm_bytes / 1e6:.2f}MB"
        )
    f32_bytes = row["tiers"]["f32"]["predicted_hbm_bytes"]
    for storage in ("bf16", "int8", "int4"):
        row["tiers"][storage]["hbm_drop_vs_f32"] = (
            f32_bytes / row["tiers"][storage]["predicted_hbm_bytes"]
        )
    return row


def bench_fused(metric, m, n, d, query_block, repeats, emit):
    """Single-pass fused scan→select vs the two-pass oracle (pallas).

    The fused kernel's win is an HBM-traffic property (Eq. 20: the
    database streamed once plus O(M·k_scan) winners, no score-tile round
    trip), so the hard contracts live on the deterministic cost model at
    the TPU roofline.  Measured wall-clock on this host runs the kernel in
    interpret mode — where the in-kernel merge is Python-priced and the
    sign of the win is not meaningful — so it is reported, and only a
    gross regression fails.  Bit-parity fused vs two-pass is asserted
    unconditionally: the fusion may change traffic, never results.
    """
    from repro.core import roofline

    key = jax.random.PRNGKey(0)
    kq, kd = jax.random.split(key)
    db = jax.random.normal(kd, (n, d))
    queries = jax.random.normal(kq, (m, d))
    row = {"metric": metric, "m": m, "n": n, "d": d,
           "query_block": query_block, "storage": "int4", "modes": {}}
    outs = {}
    for mode, fused in (("fused", True), ("two_pass", False)):
        index = Index.build(
            db,
            spec=SearchSpec(metric=metric, k=10, backend="pallas",
                            query_block=query_block, storage="int4",
                            fused_select=fused),
        )
        outs[mode] = index.search(queries)  # warmup + parity sample
        telemetry.reset_all()  # one reset for every counter surface
        wall, dispatches = _time_search(index, queries, repeats)
        row["modes"][mode] = {
            "wall_s_per_search": wall,
            "qps": m / wall,
            "dispatches_per_search": dispatches,
            "steady_retraces": sum(backends.TRACE_COUNTS.values()),
            "steady_pack_events": sum(PACK_EVENTS.values()),
        }
    assert (outs["fused"].values == outs["two_pass"].values).all() and (
        outs["fused"].indices == outs["two_pass"].indices
    ).all(), f"fused/two-pass divergence on {metric} M={m} N={n} D={d}"

    # Eq. 20 traffic contract, priced at one query block (a one-pass
    # shape: query_block <= block_m, sublane-aligned).  f32 with no
    # rescore is EXACT: queries + db stream + 8-byte winners.  int4 adds
    # the exact-rescore tail, which must stay O(M·k_scan·D) — bounded
    # without any N term (the score-tile round trip the fusion deletes).
    pf = planlib.plan_search(n=n, d=d, k=10, m=query_block, metric=metric,
                             backend="pallas", device="tpu_v4")
    pi = planlib.plan_search(n=n, d=d, k=10, m=query_block, metric=metric,
                             backend="pallas", device="tpu_v4",
                             storage="int4")
    qb = query_block
    row["f32_predicted_hbm_bytes"] = pf.hbm_bytes
    row["f32_expected_hbm_bytes"] = (
        4 * qb * pf.d_pad + 4.0 * pf.padded_n * pf.d_pad + 8 * qb * pf.k_scan
    )
    scan4 = (
        4 * qb * pi.d_pad + 0.5 * pi.padded_n * pi.d_pad + 8 * qb * pi.k_scan
    )
    row["int4_predicted_hbm_bytes"] = pi.hbm_bytes
    row["int4_scan_hbm_bytes"] = scan4
    row["int4_rescore_tail_bound"] = 4.0 * qb * pi.k_scan * pi.d_pad
    # Model-level "fused >= two-pass QPS": same FLOPs, strictly less HBM
    # than the two-pass kernel (Eq. 10 re-reads its winner tiles), so the
    # attainable-FLOP/s knee can only move up.
    hw = roofline.HARDWARE["tpu_v4"]
    two = roofline.partial_reduce_cost(
        qb, pi.padded_n, pi.d_pad, pi.num_bins,
        block_rows=pi.block_m, db_bytes=0.5,
    )
    row["two_pass_model_hbm_bytes"] = two.hbm_bytes
    row["two_pass_model_attainable_flops"] = roofline.attainable_flops(
        two, hw
    )
    row["fused_model_attainable_flops"] = pi.attainable_flops
    emit(
        f"fused,{metric},M={m},N={n},D={d},int4: "
        f"fused {row['modes']['fused']['qps']:.0f} qps vs two-pass "
        f"{row['modes']['two_pass']['qps']:.0f} qps (interpret mode); "
        f"model HBM fused {pi.hbm_bytes / 1e3:.0f}KB vs two-pass "
        f"{two.hbm_bytes / 1e3:.0f}KB"
    )
    return row


# Cluster-pruned front-end config: N must sit well above the planner's
# crossover, and the corpus must be CLUSTERABLE (mixture of Gaussians,
# queries from the same component centers) — on i.i.d. Gaussian data no
# coarse quantizer can prune without large misses, so benchmarking the
# pruned path there would measure the wrong regime.  recall is measured
# against the exact baseline, not the dense approximate path.
CLUSTER_M, CLUSTER_N, CLUSTER_D = 256, 32768, 32
CLUSTER_TARGET = 0.90
CLUSTER_COMPONENTS = 64


def _mixture_corpus(m, n, d, seed=7):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(CLUSTER_COMPONENTS, d)) * 3.0
    db = centers[rng.integers(0, CLUSTER_COMPONENTS, n)] \
        + rng.normal(size=(n, d))
    q = centers[rng.integers(0, CLUSTER_COMPONENTS, m)] \
        + rng.normal(size=(m, d))
    return jnp.asarray(db, jnp.float32), jnp.asarray(q, jnp.float32)


def bench_cluster(backend, metric, m, n, d, query_block, repeats, emit):
    """Cluster-pruned scan vs the dense scan at large N.

    Reports steady-state QPS for ``cluster="auto"`` (planner-enabled
    pruning) vs ``cluster="off"``, measured recall of BOTH against the
    exact baseline, the scanned-row fraction, and the one-dispatch /
    zero-retrace contract counters on the clustered path.
    """
    db, queries = _mixture_corpus(m, n, d)
    _, exact_idx = exact_search(queries, db, 10, metric=metric)
    exact_sets = [set(r.tolist()) for r in jax.device_get(exact_idx)]
    row = {
        "backend": backend, "metric": metric,
        "m": m, "n": n, "d": d, "query_block": query_block,
        "recall_target": CLUSTER_TARGET, "modes": {},
    }
    for mode in ("auto", "off"):
        index = Index.build(
            db,
            spec=SearchSpec(metric=metric, k=10, backend=backend,
                            recall_target=CLUSTER_TARGET,
                            query_block=query_block, cluster=mode),
        )
        _, idxs = index.search(queries)  # warmup + recall sample
        rec = sum(
            len(set(r.tolist()) & s) / 10
            for r, s in zip(jax.device_get(idxs), exact_sets)
        ) / m
        telemetry.reset_all()  # one reset for every counter surface
        wall, dispatches = _time_search(index, queries, repeats)
        cplan = index.pack().cluster.plan if mode == "auto" \
            and index.pack().cluster is not None else None
        row["modes"][mode] = {
            "wall_s_per_search": wall,
            "qps": m / wall,
            "dispatches_per_search": dispatches,
            "steady_retraces": sum(backends.TRACE_COUNTS.values()),
            "steady_pack_events": sum(PACK_EVENTS.values()),
            "recall_vs_exact": rec,
            "cluster_enabled": cplan is not None,
            "scanned_fraction": cplan.scanned_fraction if cplan else 1.0,
        }
        emit(
            f"cluster,{backend},{metric},M={m},N={n},D={d},{mode}: "
            f"{m / wall:.0f} qps ({dispatches:.0f} dispatch) "
            f"recall {rec:.3f} scanned "
            f"{row['modes'][mode]['scanned_fraction']:.3f}"
        )
    row["cluster_speedup"] = (
        row["modes"]["off"]["wall_s_per_search"]
        / row["modes"]["auto"]["wall_s_per_search"]
    )
    emit(f"cluster,{backend},{metric}: pruned scan "
         f"{row['cluster_speedup']:.2f}x the dense scan")
    return row


# Child script for the device-count scaling sweep.  Fake devices only exist
# per-process (XLA_FLAGS is read at jax import), so each device count is one
# subprocess; the result rides back on a marked JSON stdout line.  @NAME@
# placeholders avoid brace-escaping an f-string template.
_SHARD_CHILD = """\
import json, time
import jax
import jax.numpy as jnp
import numpy as np
from repro.search import Index, SearchSpec, backends

NDEV, M, N, D, REPEATS = @NDEV@, @M@, @N@, @D@, @REPEATS@
rng = np.random.default_rng(0)
db = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
q = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
index = Index.build(db, metric="mips", k=10, backend="xla",
                    recall_target=0.95)
if NDEV > 1:
    mesh = jax.make_mesh((NDEV,), ("model",))
    index = index.shard(mesh, db_axis="model")
index.search(q).values.block_until_ready()  # warmup/compile
backends.reset_dispatch_counts()
t0 = time.perf_counter()
for _ in range(REPEATS):
    out = index.search(q)
out.values.block_until_ready()
wall = (time.perf_counter() - t0) / REPEATS
print("@@SHARD@@" + json.dumps({
    "devices": NDEV,
    "backend": "sharded" if NDEV > 1 else "xla",
    "qps": M / wall,
    "wall_s_per_search": wall,
    "dispatches_per_search": sum(backends.DISPATCH_COUNTS.values()) / REPEATS,
    "dispatch_counts": dict(backends.DISPATCH_COUNTS),
}))
"""


def bench_shard(m, n, d, device_counts, repeats, emit):
    """Device-count scaling of the sharded backend + host-tier waves.

    QPS and the one-dispatch-per-batch contract vs fake device count
    (each count is a subprocess — XLA fixes the device count at import),
    plus the host-RAM cold tier's segment-wave schedule and per-wave
    live-row occupancy on the default single device.
    """
    src_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           os.pardir, "src")
    rows = []
    for ndev in device_counts:
        child = _SHARD_CHILD
        for name, val in (("NDEV", ndev), ("M", m), ("N", n), ("D", d),
                          ("REPEATS", repeats)):
            child = child.replace(f"@{name}@", str(val))
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={ndev}"
        )
        env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run([sys.executable, "-c", child], env=env,
                              capture_output=True, text=True, timeout=600)
        if proc.returncode != 0:
            raise RuntimeError(
                f"shard bench child (devices={ndev}) failed:\n"
                f"{proc.stdout}\n{proc.stderr}"
            )
        line = next(l for l in proc.stdout.splitlines()
                    if l.startswith("@@SHARD@@"))
        row = json.loads(line[len("@@SHARD@@"):])
        rows.append(row)
        emit(f"shard,M={m},N={n},D={d},devices={ndev}: "
             f"{row['qps']:.0f} qps "
             f"({row['dispatches_per_search']:.0f} dispatch)")

    # Host-RAM cold tier: budget sized for 1024-row segments so the build
    # streams N/1024 waves; occupancy is the per-wave live-row fraction.
    rng = np.random.default_rng(0)
    hn = max(4096, n)
    db = jnp.asarray(rng.normal(size=(hn, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    index = Index.build(db, metric="mips", k=10, residency="host",
                        hbm_budget_bytes=2 * 1024 * d * 4)
    index.search(q).values.block_until_ready()  # warmup/compile
    backends.reset_dispatch_counts()
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = index.search(q)
    out.values.block_until_ready()
    wall = (time.perf_counter() - t0) / repeats
    searcher = index._build_host_searcher()
    occupancy = searcher.occupancy(index.pack())
    host = {
        "n": hn, "d": d, "m": m,
        "segment_rows": searcher.segment_rows,
        "num_segments": len(occupancy),
        "occupancy": occupancy,
        "qps": m / wall,
        "wall_s_per_search": wall,
        "dispatches_per_search":
            backends.DISPATCH_COUNTS["host"] / repeats,
    }
    emit(f"shard,host-tier,N={hn},D={d}: {host['qps']:.0f} qps over "
         f"{host['num_segments']} waves of {host['segment_rows']} rows")
    return {"m": m, "n": n, "d": d, "devices": rows, "host_tier": host}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default="BENCH_search.json")
    ap.add_argument("--repeats", type=int, default=0, help="0 = auto")
    args = ap.parse_args()

    if args.smoke:
        grid, bks, mets, qb = SMOKE_GRID, ("xla",), ("mips",), SMOKE_QUERY_BLOCK
        repeats = args.repeats or 20
    else:
        grid, bks, mets, qb = FULL_GRID, FULL_BACKENDS, FULL_METRICS, QUERY_BLOCK
        repeats = args.repeats or 10

    results = []
    for backend in bks:
        for metric in mets:
            for m, n, d in grid:
                results.append(
                    bench_config(backend, metric, m, n, d, qb, repeats, print)
                )

    plan_results = []
    for backend in bks:
        for metric in mets:
            for m, n, d in grid:
                plan_results.append(
                    bench_plan(backend, metric, m, n, d, repeats, print)
                )

    quant_results = []
    # One shape per backend — the tiers are the axis, not the sizes.  Use
    # the most database-traffic-heavy grid entry: the storage tiers exist
    # for the Eq. 10 regime where streaming (N, D) dominates; at tiny N·D
    # the over-fetched winner/rescore terms (both O(M)) mask the win.
    qm, qn, qd = max(grid, key=lambda s: s[1] * s[2])
    for backend in bks:
        quant_results.append(
            bench_quant(backend, mets[0], qm, qn, qd, qb, repeats, print)
        )

    # Fused-vs-two-pass section: pallas-only by construction (the fusion
    # is a Pallas kernel property), one shape — interpret mode on CPU
    # makes the measured side expensive, and the hard contracts are on
    # the cost model anyway.
    fm, fn, fd = grid[0]
    fused_results = [
        bench_fused(mets[0], min(fm, 512), fn, fd, qb, min(repeats, 5),
                    print)
    ]

    cluster_results = []
    # One clustered config per backend: the cluster N is its own (large)
    # size — pruning only exists above the planner crossover, which every
    # grid entry above sits below or near.
    for backend in bks:
        cluster_results.append(
            bench_cluster(backend, "l2", CLUSTER_M, CLUSTER_N, CLUSTER_D,
                          qb if qb >= 256 else 256, repeats, print)
        )

    # Device-count scaling (subprocess per count — fake devices are fixed
    # at jax import) + the host cold tier.  Smoke keeps to [1, 2] so the
    # fast tier pays for two interpreter startups, not four.
    shard_devices = (1, 2) if args.smoke else (1, 2, 4, 8)
    sm, sn, sd = grid[0]
    shard_results = bench_shard(sm, sn, sd, shard_devices, repeats, print)

    report = {
        "meta": {
            "jax": jax.__version__,
            "device": jax.default_backend(),
            "platform": platform.platform(),
            "repeats": repeats,
            "smoke": args.smoke,
        },
        "telemetry": telemetry.export_json(),
        "results": results,
        "plan_results": plan_results,
        "quant_results": quant_results,
        "fused_results": fused_results,
        "cluster_results": cluster_results,
        "shard_results": shard_results,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out} ({len(results)} configs)")

    if args.smoke:
        # The hard perf contract (deterministic): one dispatch per streamed
        # batch, M/qb for the loop.  Wall-clock is noisy in CI, so only a
        # gross streaming regression fails.
        r = results[0]
        assert r["stream"]["dispatches_per_search"] == 1, r["stream"]
        assert r["loop"]["dispatches_per_search"] == r["m"] / r["query_block"]
        # Wall-clock gets slack for noisy CI machines (the config above
        # measures ~1.7x locally); only a gross regression fails.
        assert r["stream_speedup"] > 0.8, (
            f"streaming executor only {r['stream_speedup']:.2f}x the "
            "per-block loop — dispatch overhead regression"
        )
        # Planner contract: model-planned tiles match or beat the old
        # hard-coded defaults on every smoke config (bit-parity is asserted
        # inside bench_plan).  Wall-clock slack for CI noise only.
        for p in plan_results:
            assert p["model_over_legacy"] > 0.8, (
                f"model-planned config {p['planned']} is "
                f"{p['model_over_legacy']:.2f}x the legacy default "
                f"on {p['backend']}/{p['metric']} — planner regression"
            )
        # Quantized-tier contracts (deterministic): the planner's predicted
        # database HBM traffic must drop >=2x on the fused-kernel model,
        # and the quantized steady state must keep the one-dispatch /
        # zero-retrace / zero-repack contract of the f32 path.
        for qrow in quant_results:
            tiers = qrow["tiers"]
            assert tiers["int8"]["hbm_drop_vs_f32"] >= 2.0, (
                f"int8 predicted HBM bytes only "
                f"{tiers['int8']['hbm_drop_vs_f32']:.2f}x below f32"
            )
            assert tiers["bf16"]["hbm_drop_vs_f32"] >= 1.5, tiers["bf16"]
            assert tiers["int4"]["hbm_drop_vs_f32"] >= 3.0, (
                f"int4 predicted HBM bytes only "
                f"{tiers['int4']['hbm_drop_vs_f32']:.2f}x below f32"
            )
            for storage in ("bf16", "int8", "int4"):
                t = tiers[storage]
                assert t["dispatches_per_search"] == 1, (storage, t)
                assert t["steady_retraces"] == 0, (storage, t)
                assert t["steady_pack_events"] == 0, (storage, t)
                # int4's wider codes get a laxer floor (T(int4)=2K
                # over-fetch + exact rescore still lands ~0.98 here).
                floor = 0.85 if storage == "int4" else 0.9
                assert t["recall_vs_f32"] >= floor, (storage, t)
        # Fused-kernel contracts (deterministic).  Bit-parity fused vs
        # two-pass was asserted inside bench_fused; here: the Eq. 20
        # traffic model is EXACTLY db-bytes + queries + O(M·k) winners
        # (f32), the quantized tiers add only an O(M·k_scan·D) rescore
        # tail (no N term), the TPU-roofline model puts fused at or above
        # two-pass QPS, and the fused int4 steady state keeps the
        # one-dispatch / zero-retrace / zero-repack contract.
        for frow in fused_results:
            assert (
                frow["f32_predicted_hbm_bytes"]
                == frow["f32_expected_hbm_bytes"]
            ), frow
            tail = (frow["int4_predicted_hbm_bytes"]
                    - frow["int4_scan_hbm_bytes"])
            assert 0 < tail <= frow["int4_rescore_tail_bound"], frow
            assert (frow["int4_predicted_hbm_bytes"]
                    < frow["two_pass_model_hbm_bytes"]), frow
            assert (frow["fused_model_attainable_flops"]
                    >= frow["two_pass_model_attainable_flops"]), frow
            for mode in ("fused", "two_pass"):
                fmode = frow["modes"][mode]
                assert fmode["dispatches_per_search"] == 1, (mode, fmode)
                assert fmode["steady_retraces"] == 0, (mode, fmode)
                assert fmode["steady_pack_events"] == 0, (mode, fmode)
            # interpret mode inverts the perf sign (the merge runs as
            # Python per grid step) — only a gross regression fails.
            assert (frow["modes"]["fused"]["qps"]
                    > 0.2 * frow["modes"]["two_pass"]["qps"]), frow
        # Cluster-pruned front-end contracts: at the large-N config the
        # pruned scan must be a real speedup (>=1.5x, with headroom: the
        # config above measures >=2x locally) while HOLDING the recall
        # target against the exact baseline, scanning a small fraction of
        # the rows, and keeping the one-dispatch / zero-retrace /
        # zero-repack steady-state contract.
        for crow in cluster_results:
            auto, off = crow["modes"]["auto"], crow["modes"]["off"]
            assert auto["cluster_enabled"], crow
            assert not off["cluster_enabled"], crow
            assert crow["cluster_speedup"] >= 1.5, (
                f"pruned scan only {crow['cluster_speedup']:.2f}x the "
                f"dense scan at N={crow['n']} — cluster perf regression"
            )
            assert auto["recall_vs_exact"] >= crow["recall_target"], (
                f"pruned recall {auto['recall_vs_exact']:.3f} below the "
                f"{crow['recall_target']} target — miss/collision "
                "guarantee regression"
            )
            assert auto["scanned_fraction"] < 0.25, auto
            assert auto["dispatches_per_search"] == 1, auto
            assert auto["steady_retraces"] == 0, auto
            assert auto["steady_pack_events"] == 0, auto
        # Sharded + host-tier contracts (deterministic): every device count
        # keeps the one-dispatch-per-batch contract (the top-k merge is part
        # of the same compiled program, not extra dispatches), and the host
        # tier dispatches exactly one wave per segment with fully-live
        # occupancy on a fresh build.
        for srow in shard_results["devices"]:
            assert srow["dispatches_per_search"] == 1, srow
        host = shard_results["host_tier"]
        assert host["num_segments"] >= 2, host
        assert host["dispatches_per_search"] == host["num_segments"], host
        assert all(o == 1.0 for o in host["occupancy"]), host
        print("smoke contract OK")


if __name__ == "__main__":
    main()
