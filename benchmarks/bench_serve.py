"""Concurrent serving benchmark: QPS + latency percentiles under load.

Measures what the SearchServer PR changed — many small concurrent requests
served at coalesced-batch efficiency instead of one dispatch each:

  * **closed-loop**: C concurrent clients, each submitting an m-row
    request and waiting for its result before the next (the classic
    latency-vs-concurrency curve) — wall-clock QPS, p50/p99 latency,
    batching occupancy, dispatches per request;
  * **poisson**: open-loop arrivals at a target rate (independent of
    completions, so queueing shows up honestly) — same metrics plus the
    achieved rate;
  * **coalesce-vs-direct**: R requests totalling B rows pushed through the
    server (virtual clock, zero sleeps) against one pre-formed (B, D)
    ``Index.search`` — the serving overhead everything above pays.
  * **fault-rate axis**: the closed-loop load repeated at 0 / 1% / 5%
    injected transient dispatch faults (seeded ``FaultInjector``) —
    goodput (successfully served rows/s), p50/p99 of *successful*
    requests, retry/failure counters: what the retry-with-backoff layer
    costs and saves under an unreliable dispatch path;
  * **snapshot**: ``Index.save`` / ``Index.restore`` wall time for the
    benchmark index (``time_to_restore_s`` is the cold-replica recovery
    story), with bit-parity asserted against the live index.
  * **telemetry**: a C=8 closed loop against a fresh metrics registry —
    Prometheus series count, trace-span coverage of measured latency,
    exported-histogram vs bench-measured p50/p99 agreement, roofline
    drift at fault rate 0, and the tracing-on vs tracing-off overhead
    (interleaved min-wall, same idiom as coalesce-vs-direct).

Writes ``BENCH_serve.json`` (commit full runs; CI smoke runs write to an
untracked path, exactly like ``bench_search.py``).

  python benchmarks/bench_serve.py                    # full load grid
  python benchmarks/bench_serve.py --smoke            # CI: asserts ONE
                                                      # dispatch per micro-
                                                      # batch, bit-identical
                                                      # scatter, and no gross
                                                      # coalescing overhead

Wall-clock numbers are machine-relative; the dispatch/batch counts and the
parity checks are exact everywhere.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import shutil
import tempfile
import threading
import time

import jax
import numpy as np

from repro.search import Index, SearchSpec, SearchServer, ServeConfig, backends
from repro.search import telemetry
from repro.search.faults import FaultInjector, InjectedFault
from repro.search.serve import VirtualClock

N, D, K = 4096, 64, 10
MAX_BATCH = 64

CLOSED_LOOP_CLIENTS = (1, 4, 16)
POISSON_RATES = (200.0, 1000.0)
REQUEST_ROWS = 4


def _build_index(backend="xla", metric="mips"):
    db = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    return Index.build(db, metric=metric, k=K, backend=backend)


def _percentiles(latencies):
    lat = np.asarray(sorted(latencies))
    return {
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p90_ms": float(np.percentile(lat, 90) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "mean_ms": float(lat.mean() * 1e3),
    }


def _batch_stats(server, requests):
    s = server.stats()
    return {
        "batches": s["batches"],
        "dispatches_per_request": s["batches"] / max(1, requests),
        "occupancy": round(s["occupancy"], 4),
        "oversize_batches": s["oversize_batches"],
        "peak_pending_rows": s["peak_pending_rows"],
    }


def bench_closed_loop(index, clients, requests_per_client, emit):
    """C clients, each: submit -> wait -> repeat.  Wall clock, real worker."""
    server = SearchServer(
        index, ServeConfig(max_batch=MAX_BATCH, max_delay_s=0.001),
        warmup=True,
    )
    queries = [
        np.asarray(jax.random.normal(jax.random.PRNGKey(100 + c),
                                     (REQUEST_ROWS, D)))
        for c in range(clients)
    ]
    latencies, errors = [], []

    def client(cid):
        try:
            mine = []
            for _ in range(requests_per_client):
                t = server.submit(queries[cid])
                t.result(timeout=120)
                mine.append(t.latency_s)
            latencies.extend(mine)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(c,)) for c in range(clients)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    total = clients * requests_per_client
    row = {
        "mode": "closed_loop",
        "clients": clients,
        "requests": total,
        "request_rows": REQUEST_ROWS,
        "wall_s": wall,
        "qps": total * REQUEST_ROWS / wall,
        "rps": total / wall,
        **_percentiles(latencies),
        **_batch_stats(server, total),
    }
    server.close()
    emit(
        f"closed-loop C={clients}: {row['rps']:.0f} req/s "
        f"({row['qps']:.0f} qps), p50 {row['p50_ms']:.2f}ms "
        f"p99 {row['p99_ms']:.2f}ms, "
        f"{row['dispatches_per_request']:.2f} dispatches/req, "
        f"occupancy {row['occupancy']:.2f}"
    )
    return row


def bench_poisson(index, rate_rps, duration_s, emit, seed=0):
    """Open-loop Poisson arrivals at ``rate_rps`` requests/second."""
    server = SearchServer(
        index, ServeConfig(max_batch=MAX_BATCH, max_delay_s=0.001,
                           max_pending_rows=65536),
        warmup=True,
    )
    rng = np.random.default_rng(seed)
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(7), (REQUEST_ROWS, D)))
    tickets = []
    t0 = time.perf_counter()
    next_at = t0
    while True:
        now = time.perf_counter()
        if now - t0 >= duration_s:
            break
        if now < next_at:
            time.sleep(next_at - now)
        tickets.append(server.submit(q))
        next_at += float(rng.exponential(1.0 / rate_rps))
    results = [t.result(timeout=120) for t in tickets]
    wall = time.perf_counter() - t0
    assert len(results) == len(tickets)
    row = {
        "mode": "poisson",
        "offered_rps": rate_rps,
        "achieved_rps": len(tickets) / wall,
        "requests": len(tickets),
        "request_rows": REQUEST_ROWS,
        "wall_s": wall,
        "qps": len(tickets) * REQUEST_ROWS / wall,
        **_percentiles([t.latency_s for t in tickets]),
        **_batch_stats(server, len(tickets)),
    }
    server.close()
    emit(
        f"poisson {rate_rps:.0f} req/s offered: {row['achieved_rps']:.0f} "
        f"achieved, p50 {row['p50_ms']:.2f}ms p99 {row['p99_ms']:.2f}ms, "
        f"{row['dispatches_per_request']:.2f} dispatches/req, "
        f"occupancy {row['occupancy']:.2f}"
    )
    return row


def bench_coalesce_vs_direct(index, total_rows, request_rows, repeats, emit):
    """Server-coalesced batch of B rows vs one pre-formed Index.search(B).

    Virtual clock — zero sleeps, so the wall-clock difference IS the
    serving overhead (submit/stage/scatter bookkeeping).  Also asserts the
    two hard contracts: exactly one device dispatch per micro-batch, and
    bit-identical per-request results.
    """
    n_requests = total_rows // request_rows
    queries = np.asarray(
        jax.random.normal(jax.random.PRNGKey(3), (total_rows, D))
    )
    parts = [
        queries[i * request_rows : (i + 1) * request_rows]
        for i in range(n_requests)
    ]
    server = SearchServer(
        index,
        ServeConfig(max_batch=total_rows, max_pending_rows=4 * total_rows),
        clock=VirtualClock(),
        warmup=True,
    )

    # contract pass (outside timing): one dispatch, bit-identical scatter
    backends.reset_dispatch_counts()
    tickets = [server.submit(p) for p in parts]
    server.run_until_idle()
    dispatches = sum(backends.DISPATCH_COUNTS.values())
    batches = server.stats()["batches"]
    direct = index.search(queries)
    dv, di = np.asarray(direct.values), np.asarray(direct.indices)
    for i, t in enumerate(tickets):
        vals, idxs = t.result()
        lo = i * request_rows
        np.testing.assert_array_equal(
            np.asarray(idxs), di[lo : lo + request_rows]
        )
        np.testing.assert_array_equal(
            np.asarray(vals), dv[lo : lo + request_rows]
        )

    def pass_server():
        t0 = time.perf_counter()
        for _ in range(repeats):
            ts = [server.submit(p) for p in parts]
            server.run_until_idle()
        assert ts[-1].done  # results are host-side after the drain
        return (time.perf_counter() - t0) / repeats

    def pass_direct():
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = index.search(queries)
        out.values.block_until_ready()
        return (time.perf_counter() - t0) / repeats

    def pass_per_request():
        # What serving WITHOUT the coalescing layer looks like: one
        # dispatch per request (the shape the paper's batch-efficiency
        # claim says must lose).
        t0 = time.perf_counter()
        for _ in range(repeats):
            outs = [index.search(p) for p in parts]
        outs[-1].values.block_until_ready()
        return (time.perf_counter() - t0) / repeats

    # warmups, then best-of-4 with the three modes INTERLEAVED per pass —
    # machine noise (CI neighbours, thermal) then biases every mode alike
    # instead of whichever mode happened to run during the spike.
    index.search(queries).values.block_until_ready()
    index.search(parts[0]).values.block_until_ready()
    wall_server = wall_direct = wall_per_request = float("inf")
    for _ in range(4):
        wall_server = min(wall_server, pass_server())
        wall_direct = min(wall_direct, pass_direct())
        wall_per_request = min(wall_per_request, pass_per_request())
    row = {
        "mode": "coalesce_vs_direct",
        "total_rows": total_rows,
        "request_rows": request_rows,
        "requests": n_requests,
        "dispatches_per_micro_batch": dispatches / max(1, batches),
        "server_wall_s": wall_server,
        "direct_wall_s": wall_direct,
        "per_request_wall_s": wall_per_request,
        "server_qps": total_rows / wall_server,
        "direct_qps": total_rows / wall_direct,
        "per_request_qps": total_rows / wall_per_request,
        "server_over_direct": wall_direct / wall_server,
        "server_over_per_request": wall_per_request / wall_server,
    }
    server.close()
    emit(
        f"coalesce-vs-direct B={total_rows} ({n_requests} x {request_rows} "
        f"rows): server {row['server_qps']:.0f} qps vs pre-formed batch "
        f"{row['direct_qps']:.0f} qps -> {row['server_over_direct']:.2f}x; "
        f"vs per-request dispatch {row['per_request_qps']:.0f} qps -> "
        f"{row['server_over_per_request']:.2f}x; "
        f"{row['dispatches_per_micro_batch']:.0f} dispatch/micro-batch"
    )
    return row, dispatches, batches


FAULT_RATES = (0.0, 0.01, 0.05)


def bench_fault_rate(index, fault_rate, clients, requests_per_client, emit,
                     seed=11):
    """Closed-loop load with seeded transient dispatch faults injected.

    The retry loop absorbs most faults (bounded retries + backoff); the
    rest fail their batch with the typed error.  Goodput counts only the
    rows of requests that actually returned results, and the latency
    percentiles are over successful requests — so this row answers the
    operator question directly: what does an x% flaky dispatch path do to
    delivered throughput and tail latency?
    """
    inj = FaultInjector(seed=seed, rates={"serve.dispatch": fault_rate})
    server = SearchServer(
        index, ServeConfig(max_batch=MAX_BATCH, max_delay_s=0.001),
        warmup=True, faults=inj,
    )
    queries = [
        np.asarray(jax.random.normal(jax.random.PRNGKey(300 + c),
                                     (REQUEST_ROWS, D)))
        for c in range(clients)
    ]
    latencies, failures, errors = [], [], []

    def client(cid):
        try:
            for _ in range(requests_per_client):
                t = server.submit(queries[cid])
                try:
                    t.result(timeout=120)
                except InjectedFault:
                    failures.append(t)  # typed taxonomy: expected under load
                else:
                    latencies.append(t.latency_s)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    total = clients * requests_per_client
    s = server.stats()
    row = {
        "mode": "fault_rate",
        "fault_rate": fault_rate,
        "clients": clients,
        "requests": total,
        "request_rows": REQUEST_ROWS,
        "ok_requests": len(latencies),
        "failed_requests": len(failures),
        "wall_s": wall,
        "goodput_qps": len(latencies) * REQUEST_ROWS / wall,
        "transient_faults": s["transient_faults"],
        "dispatch_retries": s["dispatch_retries"],
        "failed_batches": s["failed_batches"],
        **_percentiles(latencies),
    }
    server.close()
    emit(
        f"fault-rate {fault_rate:.0%}: {row['goodput_qps']:.0f} qps goodput "
        f"({row['ok_requests']}/{total} ok), p50 {row['p50_ms']:.2f}ms "
        f"p99 {row['p99_ms']:.2f}ms, {row['dispatch_retries']} retries, "
        f"{row['failed_batches']} failed batches"
    )
    return row


def bench_snapshot(index, emit, repeats=3):
    """Crash-safe snapshot round-trip: save + restore wall time, with
    restored-replica bit-parity asserted (the recovery-correctness half
    of the ``time_to_restore_s`` story)."""
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(9), (64, D)))
    direct = index.search(q)
    tmp = tempfile.mkdtemp(prefix="bench_snap_")
    path = os.path.join(tmp, "snap")
    try:
        save_s = restore_s = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            index.save(path)
            save_s = min(save_s, time.perf_counter() - t0)
            t0 = time.perf_counter()
            restored = Index.restore(path)
            out = restored.search(q)
            out.values.block_until_ready()  # restored replica is HOT here
            restore_s = min(restore_s, time.perf_counter() - t0)
        np.testing.assert_array_equal(
            np.asarray(out.indices), np.asarray(direct.indices)
        )
        np.testing.assert_array_equal(
            np.asarray(out.values), np.asarray(direct.values)
        )
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    row = {
        "mode": "snapshot",
        "rows": int(index.size),
        "save_s": save_s,
        "time_to_restore_s": restore_s,  # load + repack-free first search
    }
    emit(
        f"snapshot: save {save_s * 1e3:.1f}ms, restore-to-first-result "
        f"{restore_s * 1e3:.1f}ms ({row['rows']} rows, bit-identical)"
    )
    return row


def _drive_closed_loop(server, clients, requests_per_client, seed=500):
    """Thread-per-client closed loop against a live server; returns
    ``(wall_s, latencies)``."""
    queries = [
        np.asarray(jax.random.normal(jax.random.PRNGKey(seed + c),
                                     (REQUEST_ROWS, D)))
        for c in range(clients)
    ]
    latencies, errors = [], []

    def client(cid):
        try:
            mine = []
            for _ in range(requests_per_client):
                t = server.submit(queries[cid])
                t.result(timeout=120)
                mine.append(t.latency_s)
            latencies.extend(mine)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return wall, latencies


def bench_telemetry(index, emit, clients=8, requests_per_client=10,
                    repeats=3):
    """Telemetry contract under a C=8 closed loop, plus tracing overhead.

    One traced run against a fresh registry answers the acceptance
    questions directly: how many Prometheus series a serving workload
    exports, what fraction of each request's measured latency its trace
    spans cover, whether the exported latency histogram agrees with the
    bench's own percentiles (they observe the very same ``latency_s``
    values), and whether the roofline-drift monitor sits inside its band
    at fault rate 0.  Tracing overhead is then measured the same way
    ``bench_coalesce_vs_direct`` measures serving overhead: interleaved
    best-of-N min-wall passes with ``trace_buffer`` at its default vs 0
    (tracing disabled).
    """
    total = clients * requests_per_client
    telemetry.reset_all()
    server = SearchServer(
        index, ServeConfig(max_batch=MAX_BATCH, max_delay_s=0.001,
                           trace_buffer=max(256, total)),
        warmup=True,
    )
    wall, latencies = _drive_closed_loop(server, clients, requests_per_client)
    health = server.health()
    traces = server.traces()
    coverage = telemetry.trace_coverage(traces)
    chrome = telemetry.chrome_trace(traces)
    index.telemetry()  # fold the index gauges into the export
    prom = telemetry.export_prometheus()
    series = [ln for ln in prom.splitlines() if ln and not ln.startswith("#")]
    snap = telemetry.registry().histogram_snapshot(
        "repro_serve_request_latency_seconds"
    )
    server.close()

    measured = _percentiles(latencies)
    row = {
        "mode": "telemetry",
        "clients": clients,
        "requests": total,
        "request_rows": REQUEST_ROWS,
        "wall_s": wall,
        "qps": total * REQUEST_ROWS / wall,
        "prom_series": len(series),
        "traced_requests": len(traces),
        "trace_events": len(chrome["traceEvents"]),
        "trace_coverage": coverage,
        "hist_count": snap["count"] if snap else 0,
        "hist_p50_ms": snap["p50"] * 1e3 if snap else None,
        "hist_p99_ms": snap["p99"] * 1e3 if snap else None,
        "drift": health["drift"]["value"],
        "drift_in_band": health["drift"]["in_band"],
        "expected_recall_live": health["expected_recall_live"],
        **measured,
    }

    # Tracing overhead: interleaved min-wall, default tracing vs off.
    wall_on = wall_off = float("inf")
    for _ in range(repeats):
        for buf in (256, 0):
            s = SearchServer(
                index, ServeConfig(max_batch=MAX_BATCH, max_delay_s=0.001,
                                   trace_buffer=buf),
                warmup=True,
            )
            w, _ = _drive_closed_loop(s, clients, requests_per_client)
            s.close()
            if buf:
                wall_on = min(wall_on, w)
            else:
                wall_off = min(wall_off, w)
    row["tracing_overhead"] = wall_on / wall_off - 1.0
    emit(
        f"telemetry C={clients}: {row['prom_series']} prom series, "
        f"span coverage {coverage:.1%} over {len(traces)} traces, "
        f"hist p50 {row['hist_p50_ms']:.2f}ms vs measured "
        f"{measured['p50_ms']:.2f}ms, drift {row['drift']:.2f} "
        f"({'in' if row['drift_in_band'] else 'OUT of'} band), "
        f"tracing overhead {row['tracing_overhead']:+.1%}"
    )
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--duration", type=float, default=2.0,
                    help="seconds per poisson load point")
    args = ap.parse_args()

    index = _build_index()
    results = []

    parity, dispatches, batches = bench_coalesce_vs_direct(
        index, total_rows=512, request_rows=8,
        repeats=10 if args.smoke else 20, emit=print,
    )
    results.append(parity)

    if not args.smoke:
        for clients in CLOSED_LOOP_CLIENTS:
            results.append(
                bench_closed_loop(index, clients, requests_per_client=50,
                                  emit=print)
            )
        for rate in POISSON_RATES:
            results.append(
                bench_poisson(index, rate, args.duration, emit=print)
            )
        fault_rows = [
            bench_fault_rate(index, rate, clients=4, requests_per_client=50,
                             emit=print)
            for rate in FAULT_RATES
        ]
        results.extend(fault_rows)
        results.append(bench_snapshot(index, emit=print))
        telem = bench_telemetry(index, emit=print, clients=8,
                                requests_per_client=25)
        results.append(telem)
    else:
        results.append(
            bench_closed_loop(index, clients=4, requests_per_client=10,
                              emit=print)
        )
        fault_rows = [
            bench_fault_rate(index, rate, clients=2, requests_per_client=10,
                             emit=print)
            for rate in (0.0, 0.05)
        ]
        results.extend(fault_rows)
        snapshot_row = bench_snapshot(index, emit=print, repeats=1)
        results.append(snapshot_row)
        telem = bench_telemetry(index, emit=print, clients=8,
                                requests_per_client=10)
        results.append(telem)

    report = {
        "meta": {
            "jax": jax.__version__,
            "device": jax.default_backend(),
            "platform": platform.platform(),
            "n": N, "d": D, "k": K, "max_batch": MAX_BATCH,
            "smoke": args.smoke,
        },
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out} ({len(results)} load points)")

    if args.smoke:
        # Hard deterministic contracts (bit-parity already asserted inside
        # bench_coalesce_vs_direct): one device dispatch per micro-batch,
        # and coalesced serving is not grossly slower than a pre-formed
        # batch of the same rows (wall-clock slack for noisy CI machines).
        assert parity["dispatches_per_micro_batch"] == 1, parity
        assert dispatches == batches, (dispatches, batches)
        assert parity["server_over_direct"] > 0.8, (
            f"coalesced serving is {parity['server_over_direct']:.2f}x a "
            "pre-formed batch — serving overhead regression"
        )
        # Telemetry contracts (ISSUE 10 acceptance): a closed-loop run
        # exports a real Prometheus surface, the trace spans tile the
        # measured request latency, the exported histogram agrees with
        # the bench's own percentiles over the same latency samples, the
        # roofline-drift monitor is in band at fault rate 0, and tracing
        # is within the <5% overhead budget at C=8.
        assert telem["prom_series"] >= 20, telem["prom_series"]
        assert telem["trace_coverage"] >= 0.95, telem["trace_coverage"]
        assert telem["traced_requests"] == telem["requests"], telem
        assert telem["drift_in_band"], telem
        assert telem["hist_count"] == telem["requests"], telem
        for q in ("p50", "p99"):
            got, want = telem[f"hist_{q}_ms"], telem[f"{q}_ms"]
            assert abs(got - want) <= 0.05 * want + 0.05, (q, got, want)
        assert telem["tracing_overhead"] < 0.05, (
            f"tracing adds {telem['tracing_overhead']:+.1%} at C=8 "
            "closed-loop — over the 5% budget"
        )
        assert parity["server_over_per_request"] > 1.0, (
            f"coalesced serving is {parity['server_over_per_request']:.2f}x "
            "per-request dispatching — the coalescing win disappeared"
        )
        closed = next(r for r in results if r["mode"] == "closed_loop")
        assert closed["dispatches_per_request"] <= 1.0, (
            "closed-loop serving issued more than one dispatch per request "
            f"on average: {closed['dispatches_per_request']:.2f} — "
            "coalescing is not happening"
        )
        clean, faulty = fault_rows[0], fault_rows[-1]
        assert clean["fault_rate"] == 0.0
        assert clean["failed_requests"] == 0 and clean["dispatch_retries"] == 0, (
            f"fault-free serving saw retries/failures: {clean}"
        )
        # every request terminated (result or typed error) — none lost
        for row in fault_rows:
            assert row["ok_requests"] + row["failed_requests"] == row["requests"], row
        # the retry layer keeps delivering under a 5% flaky dispatch path
        assert faulty["goodput_qps"] > 0 and faulty["ok_requests"] > 0, faulty
        assert snapshot_row["time_to_restore_s"] > 0
        print("smoke contract OK")


if __name__ == "__main__":
    main()
