"""Paper Appendix A.6: approx_max_k operator vs reshape+argmax baseline.

The paper reports 9.6x on a TPU v4 core (2.6ms vs 24.9ms).  On CPU we verify
the *kernel-count/work* advantage analytically and report wall-clock at a
scaled-down shape for sanity: the baseline writes the full (M, N) score
matrix to memory (level-3 BLAS bound), ours aggregates in-cache.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.roofline import HARDWARE, KernelCost, attainable_flops
from repro.search import mips


def _time(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def baseline_reshape_argmax(qy, db, l=128):
    m, n = qy.shape[0], db.shape[0]
    dists = jnp.einsum("ik,jk->ij", qy, db)
    reshaped = jax.lax.reshape(dists, (m, l, n // l))
    return jnp.max(reshaped, 2), jnp.argmax(reshaped, 2)


def main(emit, m=256, n=65536, d=128):
    q = jax.random.normal(jax.random.PRNGKey(0), (m, d))
    db = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    t_base = _time(jax.jit(baseline_reshape_argmax), q, db)
    t_ours = _time(jax.jit(lambda q, db: mips(q, db, 10, recall_target=0.95)), q, db)
    emit(
        f"a6,reshape_argmax,us_per_call={1e6 * t_base:.0f},"
        f"ours,us_per_call={1e6 * t_ours:.0f},cpu_speedup={t_base / t_ours:.2f}x"
    )
    # modeled TPU v4 speedup at the paper's shape (M=1024, N=1M, D=128):
    hw = HARDWARE["tpu_v4"]
    mm, nn, dd = 1024, 1_048_576, 128
    flops = 2.0 * mm * nn * dd
    ours_cost = KernelCost(flops=flops, hbm_bytes=4 * (mm * dd + nn * dd + 2 * mm * 128),
                           cops=3 * mm * nn)
    base_cost = KernelCost(flops=flops, hbm_bytes=4 * (mm * dd + nn * dd + 2 * mm * nn),
                           cops=2 * mm * nn)
    t_ours_model = flops / attainable_flops(ours_cost, hw)
    t_base_model = flops / attainable_flops(base_cost, hw)
    emit(
        f"a6,modeled_tpu_v4,ours={1e3 * t_ours_model:.2f}ms,"
        f"baseline={1e3 * t_base_model:.2f}ms,"
        f"speedup={t_base_model / t_ours_model:.1f}x,paper=9.6x(2.6/24.9ms)"
    )


if __name__ == "__main__":
    main(print)
