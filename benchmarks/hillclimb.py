"""Hillclimb harness: re-lower one cell with config overrides and print the
roofline-term delta vs the stored baseline.

  PYTHONPATH=src python -m benchmarks.hillclimb --arch recurrentgemma-9b \
      --shape train_4k --mesh single --set moe_group_size=512 --tag g512

KNN mode (--knn): sweep search-kernel tiles around the analytical plan via
``repro.search.plan.tune_plan`` — the planner subsumed the manual
set-a-knob-and-relower loop for search kernels, so this mode just reports
model choice vs measured best and persists the result in the plan cache.

  PYTHONPATH=src python -m benchmarks.hillclimb --knn --m 512 --n 4096 \
      --d 64 --k 10 --metric l2 --backend xla
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json

from repro.configs import get_config
from repro.configs.base import register


def parse_val(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return v == "True"
    return v


def knn_main(args):
    """Measured refinement of the analytical search plan (plan cache aware)."""
    import jax

    from repro.search import plan as planlib

    model = planlib.plan_search(
        n=args.n, d=args.d, k=args.k, m=args.m, metric=args.metric,
        recall_target=args.recall_target, backend=args.backend,
        device=args.device or None,
    )
    print(
        f"model plan: bm={model.block_m} bn={model.block_n} "
        f"qb={model.query_block} L={model.num_bins} W=2^{model.log2_bin_size} "
        f"bottleneck={model.bottleneck} "
        f"attainable={model.attainable_flops / 1e12:.1f}TF/s "
        f"E[recall]={model.expected_recall:.4f}"
    )
    os.makedirs(args.out, exist_ok=True)
    cache = planlib.PlanCache(os.path.join(args.out, "plan_cache.json"))
    db = jax.random.normal(jax.random.PRNGKey(0), (args.n, args.d))
    measured = planlib.tune_plan(db, model, cache=cache)
    entry = cache.get(model) or {}
    print(
        f"measured best: bm={measured.block_m} bn={measured.block_n} "
        f"qb={measured.query_block} "
        f"wall={entry.get('wall_s', float('nan')):.6f}s "
        f"(cache: {cache.path}, {len(cache)} entries)"
    )
    agrees = (measured.block_m, measured.block_n, measured.query_block) == (
        model.block_m, model.block_n, model.query_block
    )
    print(f"model {'CONFIRMED' if agrees else 'REFINED'} by measurement")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--knn", action="store_true",
                    help="sweep search-kernel tiles instead of a model cell")
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--metric", default="mips")
    ap.add_argument("--backend", default="xla")
    ap.add_argument("--recall-target", type=float, default=0.95)
    ap.add_argument("--device", default="",
                    help="hardware profile name (default: auto-detect)")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--tag", default="variant")
    ap.add_argument("--out", default="benchmarks/results/hillclimb")
    args = ap.parse_args()

    if args.knn:
        knn_main(args)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape are required (unless --knn)")

    cfg = get_config(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_val(v)
    if overrides:
        register(dataclasses.replace(cfg, **overrides))

    from repro.launch.dryrun import run_cell

    res = run_cell(args.arch, args.shape, args.mesh)
    os.makedirs(args.out, exist_ok=True)
    out_path = os.path.join(
        args.out, f"{args.arch}_{args.shape}_{args.mesh}_{args.tag}.json"
    )
    with open(out_path, "w") as f:
        json.dump(res, f, indent=1)

    base_path = os.path.join(
        "benchmarks/results/dryrun", f"{args.arch}_{args.shape}_{args.mesh}.json"
    )
    r = res["roofline"]
    line = (
        f"{args.tag}: dom={r['dominant']} step={r['step_time_s']:.4f}s "
        f"comp={r['compute_s']:.3f} mem={r['memory_s']:.3f} "
        f"coll={r['collective_s']:.3f} instr={r['instruction_s']:.3f} "
        f"frac={r['roofline_fraction']:.3f}"
    )
    print(line)
    if os.path.exists(base_path):
        b = json.load(open(base_path))["roofline"]
        print(
            f"baseline: dom={b['dominant']} step={b['step_time_s']:.4f}s "
            f"comp={b['compute_s']:.3f} mem={b['memory_s']:.3f} "
            f"coll={b['collective_s']:.3f} frac={b['roofline_fraction']:.3f}"
        )
        for term in ("step_time_s", "compute_s", "memory_s", "collective_s"):
            if b[term] > 1e-9:
                print(f"  {term}: {r[term] / b[term]:.3f}x")


if __name__ == "__main__":
    main()
