"""DEPRECATED: the hillclimb harness was subsumed by the kernel planner.

Use ``Index.build(plan="measure")`` or ``repro.search.plan.tune_plan``
directly — the analytical model proposes every kernel parameter and one
bounded on-device sweep refines it, persisted in a
``repro.search.plan.PlanCache`` (``REPRO_PLAN_CACHE``).  This stub keeps
the old ``--knn`` command line alive by forwarding to ``tune_plan``:

  PYTHONPATH=src python -m benchmarks.hillclimb --knn --m 512 --n 4096 \
      --d 64 --k 10 --metric l2 --backend xla

The model-cell mode (``--arch``/``--shape``) was retired; use
``repro.launch.dryrun.run_cell`` plus ``repro.analysis.rooflines`` for
model-config sweeps.
"""
import argparse
import os
import warnings


def main():
    warnings.warn(
        "benchmarks/hillclimb.py is deprecated: use "
        'Index.build(plan="measure") / repro.search.plan.tune_plan '
        "(see docs/performance_model.md)",
        DeprecationWarning,
        stacklevel=2,
    )
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--knn", action="store_true",
                    help="forward to repro.search.plan.tune_plan")
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--metric", default="mips")
    ap.add_argument("--backend", default="xla")
    ap.add_argument("--storage", default="f32",
                    help="database storage tier: f32 | bf16 | int8")
    ap.add_argument("--recall-target", type=float, default=0.95)
    ap.add_argument("--device", default="",
                    help="hardware profile name (default: auto-detect)")
    ap.add_argument("--out", default="benchmarks/results/hillclimb")
    # Retired model-cell flags, kept so old invocations fail helpfully.
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--tag", default="variant")
    args = ap.parse_args()

    if not args.knn:
        ap.error(
            "the model-cell hillclimb mode was retired; use "
            "repro.launch.dryrun.run_cell / repro.analysis.rooflines "
            "(search kernels: re-run with --knn, which forwards to "
            "repro.search.plan.tune_plan)"
        )

    import jax

    from repro.search import plan as planlib

    model = planlib.plan_search(
        n=args.n, d=args.d, k=args.k, m=args.m, metric=args.metric,
        recall_target=args.recall_target, backend=args.backend,
        device=args.device or None, storage=args.storage,
    )
    print(
        f"model plan: bm={model.block_m} bn={model.block_n} "
        f"qb={model.query_block} L={model.num_bins} W=2^{model.log2_bin_size} "
        f"storage={model.storage} bottleneck={model.bottleneck} "
        f"attainable={model.attainable_flops / 1e12:.1f}TF/s "
        f"E[recall]={model.expected_recall:.4f}"
    )
    os.makedirs(args.out, exist_ok=True)
    cache = planlib.PlanCache(os.path.join(args.out, "plan_cache.json"))
    db = jax.random.normal(jax.random.PRNGKey(0), (args.n, args.d))
    measured = planlib.tune_plan(db, model, cache=cache)
    entry = cache.get(model) or {}
    print(
        f"measured best: bm={measured.block_m} bn={measured.block_n} "
        f"qb={measured.query_block} "
        f"wall={entry.get('wall_s', float('nan')):.6f}s "
        f"(cache: {cache.path}, {len(cache)} entries)"
    )
    agrees = (measured.block_m, measured.block_n, measured.query_block) == (
        model.block_m, model.block_n, model.query_block
    )
    print(f"model {'CONFIRMED' if agrees else 'REFINED'} by measurement")


if __name__ == "__main__":
    main()
