"""Hillclimb harness: re-lower one cell with config overrides and print the
roofline-term delta vs the stored baseline.

  PYTHONPATH=src python -m benchmarks.hillclimb --arch recurrentgemma-9b \
      --shape train_4k --mesh single --set moe_group_size=512 --tag g512
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json

from repro.configs import get_config
from repro.configs.base import register


def parse_val(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return v == "True"
    return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--tag", default="variant")
    ap.add_argument("--out", default="benchmarks/results/hillclimb")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_val(v)
    if overrides:
        register(dataclasses.replace(cfg, **overrides))

    from repro.launch.dryrun import run_cell

    res = run_cell(args.arch, args.shape, args.mesh)
    os.makedirs(args.out, exist_ok=True)
    out_path = os.path.join(
        args.out, f"{args.arch}_{args.shape}_{args.mesh}_{args.tag}.json"
    )
    with open(out_path, "w") as f:
        json.dump(res, f, indent=1)

    base_path = os.path.join(
        "benchmarks/results/dryrun", f"{args.arch}_{args.shape}_{args.mesh}.json"
    )
    r = res["roofline"]
    line = (
        f"{args.tag}: dom={r['dominant']} step={r['step_time_s']:.4f}s "
        f"comp={r['compute_s']:.3f} mem={r['memory_s']:.3f} "
        f"coll={r['collective_s']:.3f} instr={r['instruction_s']:.3f} "
        f"frac={r['roofline_fraction']:.3f}"
    )
    print(line)
    if os.path.exists(base_path):
        b = json.load(open(base_path))["roofline"]
        print(
            f"baseline: dom={b['dominant']} step={b['step_time_s']:.4f}s "
            f"comp={b['compute_s']:.3f} mem={b['memory_s']:.3f} "
            f"coll={b['collective_s']:.3f} frac={b['roofline_fraction']:.3f}"
        )
        for term in ("step_time_s", "compute_s", "memory_s", "collective_s"):
            if b[term] > 1e-9:
                print(f"  {term}: {r[term] / b[term]:.3f}x")


if __name__ == "__main__":
    main()
