"""Paper Fig. 2: roofline placement of the PartialReduce benchmarks.

Emits, for each (dataset x hardware), where the kernel lands against the
three walls (compute / memory / instruction) — reproducing the paper's
finding that Sift/L2 regresses on TPU v4 because of the COP wall while the
classic two-term roofline cannot explain it.

Since the planner PR this script is a thin view over ``repro.search.plan``:
the same ``plan_search`` that configures live ``Index.build`` kernels
produces the figure, so the figure can never drift from the shipping
configuration.  (One accounting difference vs the paper's Table 2: the
fused bias row folds the ||x||^2/2 broadcast into the mask COP, so the
planner charges Sift C=5 where the paper's unfused accounting charged 6 —
the COP wall conclusion is unchanged.)
"""
from __future__ import annotations

from repro.configs.knn_workloads import KNN_WORKLOADS
from repro.core.roofline import HARDWARE


def main(emit):
    for name, w in KNN_WORKLOADS.items():
        for hw_name in ("v100", "a100", "tpu_v3", "tpu_v4", "tpu_v5e"):
            plan = w.plan(device=hw_name)
            hw = HARDWARE[hw_name]
            classic = min(hw.peak_flops, hw.hbm_bandwidth * plan.i_mem)
            emit(
                f"fig2,{name},{hw_name},bottleneck={plan.bottleneck},"
                f"attainable={plan.attainable_flops / 1e12:.1f}TF/s,"
                f"peak={hw.peak_flops / 1e12:.0f}TF/s,"
                f"classic_model={classic / 1e12:.1f}TF/s,"
                f"cop_wall_visible="
                f"{'yes' if plan.attainable_flops < classic * 0.99 else 'no'},"
                f"L={plan.num_bins},block_m={plan.block_m},"
                f"block_n={plan.block_n}"
            )


if __name__ == "__main__":
    main(print)
