"""Paper Fig. 2: roofline placement of the PartialReduce benchmarks.

Emits, for each (dataset x hardware), where the kernel lands against the
three walls (compute / memory / instruction) — reproducing the paper's
finding that Sift/L2 regresses on TPU v4 because of the COP wall while the
classic two-term roofline cannot explain it.
"""
from __future__ import annotations

from repro.configs.knn_workloads import KNN_WORKLOADS
from repro.search import plan_bins
from repro.core.roofline import (
    HARDWARE,
    attainable_flops,
    bottleneck,
    partial_reduce_cost,
)


def main(emit):
    for name, w in KNN_WORKLOADS.items():
        plan = plan_bins(w.n, w.k, w.recall_target)
        cost = partial_reduce_cost(
            w.m, w.n, w.d_padded, plan.num_bins, cops_per_dot=w.cops_per_dot
        )
        for hw_name in ("v100", "a100", "tpu_v3", "tpu_v4", "tpu_v5e"):
            hw = HARDWARE[hw_name]
            att = attainable_flops(cost, hw)
            classic = min(hw.peak_flops, hw.hbm_bandwidth * cost.i_mem)
            emit(
                f"fig2,{name},{hw_name},bottleneck={bottleneck(cost, hw)},"
                f"attainable={att / 1e12:.1f}TF/s,peak={hw.peak_flops / 1e12:.0f}TF/s,"
                f"classic_model={classic / 1e12:.1f}TF/s,"
                f"cop_wall_visible={'yes' if att < classic * 0.99 else 'no'}"
            )


if __name__ == "__main__":
    main(print)
