"""Paper Fig. 3: speed-recall trade-off, ours vs baselines.

Baselines re-implemented in JAX (same spirit as the Faiss comparison):
  * flat       — brute force + exact top-k (recall 1.0 reference)
  * ivf-flat   — inverted file (k-means centroids, search fraction lambda)
  * reshape-argmax — the A.6 naive compositional baseline
  * ours       — PartialReduce + ExactRescoring at several recall targets

CPU wall-times are *shape-relative sanity numbers only* (the paper's absolute
speeds need a TPU); recall numbers are exact reproductions of the algorithm.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import make_vector_dataset
from repro.search import Index, exact_mips


def _recall(approx_idx, exact_idx):
    r = []
    for a, e in zip(np.asarray(approx_idx), np.asarray(exact_idx)):
        r.append(len(set(a.tolist()) & set(e.tolist())) / len(e))
    return float(np.mean(r))


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        out[0].block_until_ready()
    return (time.perf_counter() - t0) / iters


def ivf_build(db, n_lists=64, iters=5, seed=0):
    """Tiny k-means for the IVF baseline."""
    rng = np.random.default_rng(seed)
    centroids = db[rng.choice(len(db), n_lists, replace=False)]
    dbj = jnp.asarray(db)
    for _ in range(iters):
        assign = jnp.argmax(dbj @ jnp.asarray(centroids).T, axis=-1)
        centroids = np.stack([
            np.asarray(dbj[assign == c].mean(axis=0))
            if bool((assign == c).any()) else centroids[c]
            for c in range(n_lists)
        ])
    assign = np.asarray(jnp.argmax(dbj @ jnp.asarray(centroids).T, axis=-1))
    lists = [np.where(assign == c)[0] for c in range(n_lists)]
    return jnp.asarray(centroids), lists


def ivf_search(q, db, centroids, lists, k=10, n_probe=4):
    """Search the n_probe nearest lists (lambda = n_probe/n_lists approx)."""
    cq = np.asarray(jnp.argsort(-(q @ centroids.T), axis=-1)[:, :n_probe])
    out = np.zeros((q.shape[0], k), np.int64)
    dbn = np.asarray(db)
    qn = np.asarray(q)
    for i in range(q.shape[0]):
        cand = np.concatenate([lists[c] for c in cq[i]] or [np.array([], np.int64)])
        if len(cand) == 0:
            out[i] = -1
            continue
        scores = qn[i] @ dbn[cand].T
        top = cand[np.argsort(-scores)[:k]]
        out[i, : len(top)] = top
        out[i, len(top):] = -1
    return out


def a6_reshape_argmax(q, db, l=128):
    """Appendix A.6 baseline: einsum -> reshape -> argmax (top-1 per bin)."""
    n = db.shape[0]
    bin_size = n // l
    scores = jnp.einsum("ik,jk->ij", q, db)[:, : l * bin_size]
    r = scores.reshape(q.shape[0], l, bin_size)
    idx = jnp.argmax(r, axis=-1) + jnp.arange(l) * bin_size
    vals = jnp.max(r, axis=-1)
    return vals, idx


def main(emit, n=100_000, d=64, m=256, k=10):
    db = jnp.asarray(make_vector_dataset(n, d, metric="cosine", seed=0))
    q = jnp.asarray(make_vector_dataset(m, d, metric="cosine", seed=1))

    flat = jax.jit(lambda q, db: exact_mips(q, db, k))
    t_flat = _time(flat, q, db)
    _, exact = flat(q, db)
    emit(f"fig3,flat,recall=1.000,us_per_query={1e6 * t_flat / m:.1f}")

    for rt in (0.8, 0.9, 0.95, 0.99):
        index = Index.build(db, metric="mips", k=k, recall_target=rt)
        ours = lambda q, db: index.search(q)  # noqa: E731 - db owned by index
        t = _time(ours, q, db)
        _, idx = ours(q, db)
        emit(
            f"fig3,ours(rt={rt}),recall={_recall(idx, exact):.3f},"
            f"us_per_query={1e6 * t / m:.1f}"
        )

    cent, lists = ivf_build(np.asarray(db), n_lists=64)
    for n_probe in (1, 2, 8):
        t0 = time.perf_counter()
        idx = ivf_search(q, db, cent, lists, k=k, n_probe=n_probe)
        t = time.perf_counter() - t0
        lam = sum(len(lists[c]) for c in range(n_probe)) / n
        emit(
            f"fig3,ivf-flat(probe={n_probe}),recall={_recall(idx, exact):.3f},"
            f"us_per_query={1e6 * t / m:.1f},lambda~{lam:.3f}"
        )

    a6 = jax.jit(a6_reshape_argmax)
    t = _time(a6, q, db)
    _, idx = a6(q, db)
    from repro.search import exact_rescoring

    v, i2 = a6(q, db)
    tv, ti = exact_rescoring(v, i2, k, mode="max")
    emit(
        f"fig3,a6-reshape-argmax,recall={_recall(ti, exact):.3f},"
        f"us_per_query={1e6 * t / m:.1f}"
    )


if __name__ == "__main__":
    main(print)
