# One function per paper table/figure. Prints ``name,...,derived`` CSV lines.
"""Benchmark harness entry point.

  python -m benchmarks.run            # all paper artifacts
  python -m benchmarks.run table2 fig3

Artifacts:
  table2           dataset/kernel accounting + modeled vs measured FLOP/s
  fig2             roofline placement (compute/memory/instruction walls)
  fig3             speed-recall curves, ours vs flat/ivf/a6 baselines
  a6               approx_max_k vs reshape+argmax baseline
  recall           Eq. 13/14 analytic vs empirical recall
  dryrun_summary   summarize benchmarks/results/dryrun cells (if present)
"""
from __future__ import annotations

import sys


def dryrun_summary(emit):
    import glob
    import json
    import os

    files = sorted(glob.glob(os.path.join("benchmarks/results/dryrun", "*.json")))
    if not files:
        emit("dryrun_summary,none (run benchmarks/run_dryrun_sweep.sh)")
        return
    for f in files:
        r = json.load(open(f))
        if "error" in r:
            emit(f"dryrun,{r['arch']},{r['shape']},{r['mesh']},ERROR,{r['error'][:80]}")
            continue
        rf = r["roofline"]
        emit(
            f"dryrun,{r['arch']},{r['shape']},{r['mesh']},dom={rf['dominant']},"
            f"step={rf['step_time_s']:.4f}s,frac={rf['roofline_fraction']:.3f},"
            f"compile={r['compile_s']}s"
        )


def main() -> None:
    from benchmarks import a6_baseline, fig2_roofline, fig3_speed_recall, recall_analytics, table2

    wanted = set(sys.argv[1:]) or {
        "table2", "fig2", "fig3", "a6", "recall", "dryrun_summary"
    }
    emit = print
    if "table2" in wanted:
        table2.main(emit)
    if "fig2" in wanted:
        fig2_roofline.main(emit)
    if "recall" in wanted:
        recall_analytics.main(emit)
    if "a6" in wanted:
        a6_baseline.main(emit)
    if "fig3" in wanted:
        fig3_speed_recall.main(emit)
    if "dryrun_summary" in wanted:
        dryrun_summary(emit)


if __name__ == '__main__':
    main()
