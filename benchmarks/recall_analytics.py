"""Eq. 13/14 validation: empirical recall vs the analytic guarantee across
(K, recall_target) — the paper's central analytical claim."""
from __future__ import annotations

import jax
import numpy as np

from repro.search import approx_max_k, expected_recall, plan_bins


def main(emit, n=65536, m=128):
    for k in (1, 10, 32):
        for rt in (0.8, 0.9, 0.95):
            if k == 1:
                emit(f"recall,k=1,rt={rt},analytic=1.000,empirical=1.000")
                continue
            plan = plan_bins(n, k, rt)
            x = jax.random.normal(jax.random.PRNGKey(k * 100 + int(rt * 100)), (m, n))
            _, idx = approx_max_k(x, k, recall_target=rt)
            _, exact = jax.lax.top_k(x, k)
            rec = np.mean([
                len(set(a.tolist()) & set(e.tolist())) / k
                for a, e in zip(np.asarray(idx), np.asarray(exact))
            ])
            emit(
                f"recall,k={k},rt={rt},L={plan.num_bins},"
                f"analytic={plan.expected_recall:.3f},empirical={rec:.3f}"
            )


if __name__ == "__main__":
    main(print)
