"""Paper Table 2: dataset properties + kernel accounting + modeled peak.

Reproduces the analytically-derivable rows exactly (C, I_COP, padded D) and
derives I_MEM from the Eq. 20 cost model; the measured-GFLOP/s rows cannot be
re-measured on CPU, so we report the *modeled attainable* FLOP/s from the
refined roofline (Eq. 6) next to the paper's measured numbers for v3/v4.
"""
from __future__ import annotations

from repro.configs.knn_workloads import KNN_WORKLOADS
from repro.core.roofline import HARDWARE, attainable_flops, partial_reduce_cost
from repro.search import plan_bins

PAPER_MEASURED = {  # GFLOP/s from Table 2
    ("glove1.2m", "tpu_v3"): 118_524,
    ("glove1.2m", "tpu_v4"): 251_166,
    ("sift1m", "tpu_v3"): 118_062,
    ("sift1m", "tpu_v4"): 172_035,
}


def rows():
    out = []
    for name, w in KNN_WORKLOADS.items():
        plan = plan_bins(w.n, w.k, w.recall_target)
        # block_rows = M: the whole query batch stays VMEM-resident, the
        # database streams once (the paper's profiler reports I_MEM ~ 4700).
        cost = partial_reduce_cost(
            w.m, w.n, w.d_padded, plan.num_bins, cops_per_dot=w.cops_per_dot,
            block_rows=w.m,
        )
        i_cop = 2 * w.d_padded / w.cops_per_dot
        for hw_name in ("tpu_v3", "tpu_v4", "tpu_v5e"):
            hw = HARDWARE[hw_name]
            modeled = attainable_flops(cost, hw)
            measured = PAPER_MEASURED.get((name, hw_name))
            out.append({
                "dataset": name,
                "hw": hw_name,
                "C": w.cops_per_dot,
                "I_MEM": round(cost.i_mem, 1),
                "I_COP": round(i_cop, 1),
                "L": plan.num_bins,
                "modeled_GFLOPs": round(modeled / 1e9),
                "paper_measured_GFLOPs": measured,
                "model_vs_measured": (
                    round(measured / (modeled / 1e9), 3) if measured else None
                ),
            })
    return out


def main(emit):
    for r in rows():
        emit(
            f"table2,{r['dataset']},{r['hw']},C={r['C']},I_COP={r['I_COP']},"
            f"I_MEM={r['I_MEM']},modeled={r['modeled_GFLOPs']}GF/s,"
            f"paper={r['paper_measured_GFLOPs']},ratio={r['model_vs_measured']}"
        )


if __name__ == "__main__":
    main(print)
