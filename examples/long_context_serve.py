"""Long-context serving with the paper's KNN top-k attention.

Builds a model, prefalls a long prompt, then decodes with (a) exact
attention and (b) PartialReduce top-k attention over the KV cache, and
compares outputs + the modeled attention cost — the paper's MIPS kernel
embedded in the serving path.

  PYTHONPATH=src python examples/long_context_serve.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.search import plan_bins
from repro.models import model as M
from repro.models import transformer as tfm


def main():
    cfg = get_config("internlm2-1.8b-smoke")
    b, prompt_len, max_seq = 2, 48, 4096
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, prompt_len), 0,
                                cfg.vocab_size)

    caches = tfm.init_caches(cfg, b, max_seq)
    dec_exact = jax.jit(M.make_decode_step(cfg, use_knn=False, sample="greedy"))
    dec_knn = jax.jit(M.make_decode_step(cfg, use_knn=True, sample="greedy"))

    # replay the prompt (exact path), then compare one decode step both ways
    for t in range(prompt_len):
        _, _, caches = dec_exact(params, tokens[:, t:t + 1], caches,
                                 jnp.int32(t), jax.random.PRNGKey(t))
    nxt = tokens[:, -1:]
    t_exact = dec_exact(params, nxt, caches, jnp.int32(prompt_len),
                        jax.random.PRNGKey(99))
    t_knn = dec_knn(params, nxt, caches, jnp.int32(prompt_len),
                    jax.random.PRNGKey(99))
    agree = bool(jnp.all(t_exact[0] == t_knn[0]))
    diff = float(jnp.max(jnp.abs(
        t_exact[1].astype(jnp.float32) - t_knn[1].astype(jnp.float32))))
    print(f"greedy tokens agree: {agree}; logits maxdiff {diff:.4f}")

    # cost accounting at production scale (the long_500k cell):
    s = 524_288
    plan = plan_bins(s, cfg.knn_attention_k, cfg.knn_recall_target)
    exact_reads = s
    knn_softmax = cfg.knn_attention_k
    print(
        f"at S={s}: exact softmax over {exact_reads} keys vs "
        f"PartialReduce -> {plan.num_bins} bins -> top-{cfg.knn_attention_k} "
        f"exact softmax (E[recall]={plan.expected_recall:.3f}); "
        f"post-selection attention work /{exact_reads // knn_softmax}x"
    )


if __name__ == "__main__":
    main()
