"""Quickstart: the paper's two listings, end to end.

Runs MIPS and Euclidean NN search with the repro's approx_max_k (pure-JAX
path and the fused Pallas kernel in interpret mode) and prints recall vs the
exact answer — reproducing the paper's analytic recall guarantee on random
data in a few seconds on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import approx_max_k, l2nns, mips, plan_bins
from repro.kernels.ops import mips_topk


def recall(approx_idx, exact_idx):
    return float(np.mean([
        len(set(a.tolist()) & set(e.tolist())) / len(e)
        for a, e in zip(np.asarray(approx_idx), np.asarray(exact_idx))
    ]))


def main():
    key = jax.random.PRNGKey(0)
    qy = jax.random.normal(key, (128, 128))
    db = jax.random.normal(jax.random.PRNGKey(1), (100_000, 128))

    # --- Paper Listing 1: MIPS -------------------------------------------
    plan = plan_bins(db.shape[0], 10, 0.95)
    print(f"binning plan: L={plan.num_bins} bins of 2^{plan.log2_bin_size}, "
          f"E[recall]={plan.expected_recall:.3f}")
    vals, idxs = jax.jit(lambda q, d: mips(q, d, 10, recall_target=0.95))(qy, db)
    _, exact = jax.lax.top_k(qy @ db.T, 10)
    print(f"MIPS   (pure JAX)        recall={recall(idxs, exact):.3f}")

    # fused Pallas kernel (interpret mode on CPU; compiled on real TPU)
    _, idxs_k = mips_topk(qy, db, 10, 0.95, interpret=True)
    print(f"MIPS   (Pallas kernel)   recall={recall(idxs_k, exact):.3f}")

    # --- Paper Listing 2: Euclidean NN (Eq. 19 halved norms) -------------
    _, idxs_l2 = jax.jit(lambda q, d: l2nns(q, d, 10, recall_target=0.95))(qy, db)
    d_true = np.linalg.norm(np.asarray(qy)[:, None] - np.asarray(db)[None], axis=-1)
    exact_l2 = np.argsort(d_true, axis=-1)[:, :10]
    print(f"L2 NNS (halved norms)    recall={recall(idxs_l2, exact_l2):.3f}")

    # --- raw operator -----------------------------------------------------
    scores = jnp.einsum("ik,jk->ij", qy, db)
    v, i = approx_max_k(scores, k=10, recall_target=0.95)
    print(f"approx_max_k direct      recall={recall(i, exact):.3f}")


if __name__ == "__main__":
    main()
