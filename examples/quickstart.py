"""Quickstart: the paper's algorithm behind the unified ``repro.search`` API.

One front door for every metric and backend:

    index = Index.build(db, metric=..., k=..., recall_target=...)
    values, indices = index.search(queries)

Runs MIPS, L2 and cosine search on the XLA and (interpret-mode) Pallas
backends, shows the paper-promised frequent-update path (add/delete with no
rebuild), and prints recall vs the exact answer — reproducing the analytic
recall guarantee on random data in a few seconds on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.search import Index, exact_search

K = 10


def recall(approx_idx, exact_idx):
    return float(np.mean([
        len(set(a.tolist()) & set(e.tolist())) / len(e)
        for a, e in zip(np.asarray(approx_idx), np.asarray(exact_idx))
    ]))


def main():
    qy = jax.random.normal(jax.random.PRNGKey(0), (128, 128))
    db = jax.random.normal(jax.random.PRNGKey(1), (100_000, 128))

    # --- one Index, every metric, every backend ---------------------------
    for metric in ("mips", "l2", "cosine"):
        _, exact = exact_search(qy, db, K, metric=metric)
        for backend in ("xla", "pallas"):  # pallas: interpret on CPU
            index = Index.build(
                db, metric=metric, k=K, recall_target=0.95, backend=backend
            )
            _, idxs = index.search(qy)
            print(
                f"{metric:6s} {backend:6s} recall={recall(idxs, exact):.3f} "
                f"(plan E[recall]={index.expected_recall:.3f}, "
                f"L={index.plan.num_bins} bins of 2^{index.plan.log2_bin_size})"
            )

    # --- frequent updates: no index rebuild (paper's usability claim) -----
    index = Index.build(db[:90_000], metric="mips", k=K, recall_target=0.95)
    index.add(db[90_000:])                      # append the rest
    _, exact = exact_search(qy, db, K, metric="mips")
    _, idxs = index.search(qy)
    print(f"after add:    recall={recall(idxs, exact):.3f} "
          f"(size={index.size})")

    top1 = np.asarray(exact)[:, 0]
    index.delete(top1)                          # tombstone each query's top-1
    _, idxs = index.search(qy)
    leaked = set(np.asarray(idxs).ravel().tolist()) & set(top1.tolist())
    print(f"after delete: top-1 rows gone={not leaked} (size={index.size})")

    # --- compile cache: repeat same-shape searches never retrace ----------
    index.search(qy)
    print(f"compile cache: {index.cache_info()}")

    # --- the model-driven plan behind the index (docs/performance_model.md)
    report = index.explain()
    plan, pred = report["plan"], report["predicted"]
    print(
        f"plan[{plan['source']}]: tiles=({plan['block_m']}, "
        f"{plan['block_n']}, {plan['query_block']}) "
        f"L={plan['num_bins']}x2^{plan['log2_bin_size']} -> "
        f"{pred['bottleneck']}-bound, "
        f"attainable {pred['attainable_flops'] / 1e12:.1f} TFLOP/s "
        f"on {pred['device']}"
    )


if __name__ == "__main__":
    main()
