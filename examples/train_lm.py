"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
CPU with the full production path (prefetched pipeline, cosine schedule,
async checkpointing, auto-resume).

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import sys

from repro.configs.base import ModelConfig, register

# ~100M params: 8L x 512d x 16H, vocab 32k.
register(ModelConfig(
    name="examples-lm-100m",
    family="dense",
    num_layers=8,
    d_model=512,
    num_heads=16,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=32768,
    q_chunk=128,
))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m_ckpt")
    args = ap.parse_args()

    from repro.launch import train

    sys.argv = [
        "train", "--arch", "examples-lm-100m",
        "--steps", str(args.steps),
        "--seq", str(args.seq), "--global-batch", str(args.global_batch),
        "--lr", "1e-3", "--warmup", "20",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", str(args.log_every),
    ]
    train.main()


if __name__ == "__main__":
    main()
