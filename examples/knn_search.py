"""Distributed KNN example (paper §7) through the unified search API: shard
an ``Index`` over a device mesh, PartialReduce per shard, all-gather the bin
winners, rescore globally.

Also demonstrates the kNN-LM retrieval integration, index-free updates on
the sharded index, and the tuning-free cluster-pruned front-end
(``cluster="auto"``) on a large clusterable corpus.  Uses 8 simulated
devices (safe to re-exec: this file sets XLA_FLAGS before importing jax).

  PYTHONPATH=src python examples/knn_search.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.data.pipeline import make_vector_dataset  # noqa: E402
from repro.retrieval.datastore import KNNDatastore, knn_lm_logits  # noqa: E402
from repro.search import Index, exact_search  # noqa: E402


def recall(a, e):
    return float(np.mean([
        len(set(x.tolist()) & set(y.tolist())) / len(y)
        for x, y in zip(np.asarray(a), np.asarray(e))
    ]))


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

    # Database + held-out queries split from one draw: clustered indexes
    # (cluster="auto" enables above the planner crossover — these builds
    # qualify) assume queries are drawn from the database distribution,
    # the same contract every IVF system carries.  For genuinely
    # out-of-distribution query streams, build with cluster="off".
    full = jnp.asarray(
        make_vector_dataset(65536 + 64, 64, metric="cosine", seed=0)
    )
    db, q = full[:65536], full[65536:]

    for metric in ("mips", "l2"):
        index = Index.build(db, metric=metric, k=10, recall_target=0.95)
        sharded = index.shard(mesh, db_axis="model", batch_axis="data")
        _, idx = sharded.search(q)
        _, exact = exact_search(q, db, 10, metric=metric)
        print(f"distributed {metric:4s} recall: {recall(idx, exact):.3f}  "
              f"({sharded!r})")

    # Index-free updates work sharded too: append rows, tombstone others.
    sharded = Index.build(db[:65024], k=10).shard(mesh, db_axis="model")
    sharded.add(db[65024:])
    _, idx = sharded.search(q)
    _, exact = exact_search(q, db, 10)
    print(f"after sharded add:   recall={recall(idx, exact):.3f}")

    # Cluster-pruned scan: on a large clusterable corpus (embeddings,
    # mixtures), cluster="auto" — the default — puts a planner-derived
    # k-means front-end before the scan.  No knobs: probe count and spill
    # come from (N, k, recall_target); below the planner crossover the
    # index is bit-identical to cluster="off".
    rng = np.random.default_rng(7)
    centers = 3.0 * rng.standard_normal((64, 32)).astype(np.float32)
    cdb = jnp.asarray(
        centers[rng.integers(0, 64, size=32768)]
        + rng.standard_normal((32768, 32)).astype(np.float32))
    cq = jnp.asarray(
        centers[rng.integers(0, 64, size=256)]
        + rng.standard_normal((256, 32)).astype(np.float32))
    clustered = Index.build(cdb, metric="l2", k=10, recall_target=0.9,
                            cluster="auto")
    info = clustered.explain()["cluster"]
    _, idx = clustered.search(cq)
    _, exact = exact_search(cq, cdb, 10, metric="l2")
    print(f"cluster-pruned l2:   recall={recall(idx, exact):.3f} "
          f"(expected {info['expected_recall']:.3f} = "
          f"{info['collision_term']:.3f} collision x "
          f"{info['miss_term']:.3f} miss), "
          f"scanned {info['scanned_fraction']:.1%} of N "
          f"with {info['probes']}/{info['num_clusters']} probes")

    # kNN-LM: retrieve neighbour tokens and interpolate with LM logits.
    value_tokens = jax.random.randint(jax.random.PRNGKey(2), (db.shape[0],), 0, 1000)
    store = KNNDatastore(db, value_tokens, mesh, k=16)
    scores, toks = store.lookup(q)
    lm_logits = jax.random.normal(jax.random.PRNGKey(3), (q.shape[0], 1000))
    mixed = knn_lm_logits(lm_logits, scores, toks, lam=0.25)
    print(f"kNN-LM mixed logits: {mixed.shape}, "
          f"finite={bool(jnp.all(jnp.isfinite(mixed)))}")


if __name__ == "__main__":
    main()
