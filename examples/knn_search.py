"""Distributed KNN example (paper §7): shard a datastore over a device mesh,
run PartialReduce per shard, all-gather the bin winners, rescore globally.

Also demonstrates the kNN-LM retrieval integration.  Uses 8 simulated
devices (safe to re-exec: this file sets XLA_FLAGS before importing jax).

  PYTHONPATH=src python examples/knn_search.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.distributed import sharded_l2nns, sharded_mips  # noqa: E402
from repro.data.pipeline import make_vector_dataset  # noqa: E402
from repro.retrieval.datastore import KNNDatastore, knn_lm_logits  # noqa: E402


def recall(a, e):
    return float(np.mean([
        len(set(x.tolist()) & set(y.tolist())) / len(y)
        for x, y in zip(np.asarray(a), np.asarray(e))
    ]))


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

    db = jnp.asarray(make_vector_dataset(65536, 64, metric="cosine", seed=0))
    q = jnp.asarray(make_vector_dataset(64, 64, metric="cosine", seed=1))
    qs = jax.device_put(q, NamedSharding(mesh, P("data", None)))
    dbs = jax.device_put(db, NamedSharding(mesh, P("model", None)))
    print(f"database sharded: {dbs.sharding.spec}, "
          f"{db.shape[0] // mesh.shape['model']} rows/shard")

    _, idx = sharded_mips(qs, dbs, 10, mesh, batch_axis="data")
    _, exact = jax.lax.top_k(q @ db.T, 10)
    print(f"distributed MIPS recall: {recall(idx, exact):.3f}")

    _, idx2 = sharded_l2nns(qs, dbs, 10, mesh, batch_axis="data")
    d = np.linalg.norm(np.asarray(q)[:, None] - np.asarray(db)[None], axis=-1)
    print(f"distributed L2   recall: {recall(idx2, np.argsort(d, -1)[:, :10]):.3f}")

    # kNN-LM: retrieve neighbour tokens and interpolate with LM logits.
    value_tokens = jax.random.randint(jax.random.PRNGKey(2), (db.shape[0],), 0, 1000)
    store = KNNDatastore(db, value_tokens, mesh, k=16)
    scores, toks = store.lookup(qs)
    lm_logits = jax.random.normal(jax.random.PRNGKey(3), (q.shape[0], 1000))
    mixed = knn_lm_logits(lm_logits, scores, toks, lam=0.25)
    print(f"kNN-LM mixed logits: {mixed.shape}, "
          f"finite={bool(jnp.all(jnp.isfinite(mixed)))}")


if __name__ == "__main__":
    main()
