#!/usr/bin/env bash
# CI entry point.
#
#   scripts/ci.sh          # fast subset: skips tests marked @pytest.mark.slow
#   scripts/ci.sh full     # the tier-1 command (everything, -x -q)
#
# Run from the repo root. Keeps the fast path under a few minutes on CPU.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-fast}" == "full" ]]; then
    # The full tier is a superset of fast: docs lint + doctests too.
    python scripts/docs_lint.py
    python -m pytest -q --doctest-modules src/repro/search
    exec python -m pytest -x -q
else
    # Shim-import lint: nothing under src/ may import the deprecated
    # compatibility shims (they exist for DOWNSTREAM callers only; the
    # shims themselves and their re-export targets are the one exception).
    python scripts/shim_lint.py
    # Perf contracts first (fail fast on re-introduced per-search padding /
    # dispatch-loop regressions, cluster-pruning regressions, and on
    # serving-layer coalescing regressions), then the fault-injection
    # suite (deadline/retry/watchdog/snapshot contracts; its seeded chaos
    # smoke is @pytest.mark.slow and runs in the full tier), then the
    # benchmark smoke runs (planner-vs-legacy,
    # one-dispatch-per-coalesced-batch + stream-path parity, pruned-scan
    # speedup/recall contracts, and the fault-rate/snapshot serve
    # contracts), docs lint + public-API doctests, then the rest of the
    # fast tier (test_packed/test_serve/test_cluster/test_faults already
    # ran — don't repeat them).  (smoke runs write to untracked paths so
    # they never clobber the committed full-grid BENCH_search.json /
    # BENCH_serve.json seeds)
    python -m pytest -x -q tests/test_packed.py tests/test_serve.py \
        tests/test_cluster.py tests/test_telemetry.py
    python -m pytest -x -q -m "not slow" tests/test_faults.py
    # Layout-parity grid under 8 fake devices (subprocess harness in
    # tests/conftest.py); the 16/48-device grids are @slow / full tier.
    python -m pytest -x -q -m "not slow" tests/test_sharded2d.py
    python benchmarks/bench_search.py --smoke --out BENCH_search.smoke.json
    python benchmarks/bench_serve.py --smoke --out BENCH_serve.smoke.json
    python scripts/docs_lint.py
    python -m pytest -x -q --doctest-modules src/repro/search
    exec python -m pytest -x -q -m "not slow" \
        --ignore=tests/test_packed.py --ignore=tests/test_serve.py \
        --ignore=tests/test_cluster.py --ignore=tests/test_faults.py \
        --ignore=tests/test_sharded2d.py --ignore=tests/test_telemetry.py
fi
