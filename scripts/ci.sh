#!/usr/bin/env bash
# CI entry point.
#
#   scripts/ci.sh          # fast subset: skips tests marked @pytest.mark.slow
#   scripts/ci.sh full     # the tier-1 command (everything, -x -q)
#
# Run from the repo root. Keeps the fast path under a few minutes on CPU.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-fast}" == "full" ]]; then
    exec python -m pytest -x -q
else
    exec python -m pytest -x -q -m "not slow"
fi
