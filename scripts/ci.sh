#!/usr/bin/env bash
# CI entry point.
#
#   scripts/ci.sh          # fast subset: skips tests marked @pytest.mark.slow
#   scripts/ci.sh full     # the tier-1 command (everything, -x -q)
#
# Run from the repo root. Keeps the fast path under a few minutes on CPU.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-fast}" == "full" ]]; then
    # The full tier is a superset of fast: docs lint + doctests too.
    python scripts/docs_lint.py
    python -m pytest -q --doctest-modules src/repro/search
    exec python -m pytest -x -q
else
    # Perf contract first (fail fast on re-introduced per-search padding /
    # dispatch-loop regressions), then the benchmark smoke run (includes
    # the planner-vs-legacy contract), docs lint + public-API doctests,
    # then the rest of the fast tier (test_packed already ran — don't
    # repeat it).  (smoke writes to an untracked path so it never clobbers
    # the committed full-grid BENCH_search.json seed)
    python -m pytest -x -q tests/test_packed.py
    python benchmarks/bench_search.py --smoke --out BENCH_search.smoke.json
    python scripts/docs_lint.py
    python -m pytest -x -q --doctest-modules src/repro/search
    exec python -m pytest -x -q -m "not slow" --ignore=tests/test_packed.py
fi
