#!/usr/bin/env python
"""Fail if anything under src/ imports a deprecated compatibility shim.

The shims — ``repro.core.knn``, ``repro.kernels.ops``,
``repro.core.distributed`` — exist for DOWNSTREAM callers migrating to
``repro.search``; internal code importing them would silently re-entrench
the deprecated API (and its DeprecationWarning) inside the package itself.

Exempt: the shim modules themselves and the parent ``__init__`` files
that lazily re-expose them as attributes (via ``importlib``) for
backwards compatibility.

Catches ``import x``, ``from x import y``, ``from parent import shim``,
and literal ``importlib.import_module("x")`` calls; docstrings and
comments are naturally ignored (AST-based).
"""
import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SRC = ROOT / "src"

SHIMS = {
    "repro.core.knn",
    "repro.kernels.ops",
    "repro.core.distributed",
}
# parent package -> submodule name, for "from repro.core import knn"
SHIM_PARENTS = {tuple(s.rsplit(".", 1)) for s in SHIMS}

EXEMPT = {
    SRC / "repro" / "core" / "knn.py",
    SRC / "repro" / "kernels" / "ops.py",
    SRC / "repro" / "core" / "distributed.py",
    # lazy attribute re-export of the shims for downstream callers
    SRC / "repro" / "core" / "__init__.py",
    SRC / "repro" / "kernels" / "__init__.py",
}


def _violations(path: pathlib.Path) -> list:
    tree = ast.parse(path.read_text(), filename=str(path))
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name in SHIMS:
                    out.append((node.lineno, f"import {alias.name}"))
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod in SHIMS:
                out.append((node.lineno, f"from {mod} import ..."))
            for alias in node.names:
                if (mod, alias.name) in SHIM_PARENTS:
                    out.append(
                        (node.lineno, f"from {mod} import {alias.name}")
                    )
        elif isinstance(node, ast.Call):
            # importlib.import_module("repro.core.knn") and friends
            f = node.func
            name = getattr(f, "attr", getattr(f, "id", ""))
            if name == "import_module" and node.args:
                arg = node.args[0]
                if isinstance(arg, ast.Constant) and arg.value in SHIMS:
                    out.append(
                        (node.lineno, f'import_module("{arg.value}")')
                    )
    return out


def main() -> int:
    bad = []
    for path in sorted(SRC.rglob("*.py")):
        if path in EXEMPT:
            continue
        for lineno, what in _violations(path):
            bad.append(f"{path.relative_to(ROOT)}:{lineno}: {what}")
    if bad:
        print("deprecated-shim imports inside src/ (use repro.search):")
        for b in bad:
            print(f"  {b}")
        return 1
    print(f"shim lint OK ({len(list(SRC.rglob('*.py')))} files clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
