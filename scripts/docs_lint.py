#!/usr/bin/env python
"""Docs lint (CI fast tier): keep the docs suite mechanically honest.

Checks, over README.md and docs/*.md:

  1. Internal markdown links resolve: relative link targets must exist on
     disk; ``#anchor`` fragments must match a heading in the target file.
  2. Every ``path/to/file.py::name`` token names a real file defining
     ``name`` (function, class, method or module-level assignment).
  3. Every equation cited in docs/performance_model.md (``Eq. N``,
     ranges expanded) appears on at least one line that also carries a
     valid ``file::function`` token — the "every equation maps to code"
     acceptance criterion.
  4. Every public ``repro.search`` symbol (``__all__``) is mentioned
     somewhere in the docs suite.

Exit code 1 with a per-problem listing on failure.  Run from the repo
root (scripts/ci.sh does): ``python scripts/docs_lint.py``.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ["README.md"] + sorted(
    os.path.join("docs", f)
    for f in os.listdir(os.path.join(REPO, "docs"))
    if f.endswith(".md")
)

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
TOKEN_RE = re.compile(r"([\w/\.\-]+\.py)::([A-Za-z_][A-Za-z0-9_]*)")
EQ_RE = re.compile(r"Eq\.\s*(\d+)(?:\s*[–-]\s*(\d+))?")
HEADING_RE = re.compile(r"^#+\s+(.*)$", re.M)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug (good enough for our headings)."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_(),:→×‖²⟨⟩/.§]", "", s)
    s = re.sub(r"\s+", "-", s.strip())
    return s


def headings_of(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        return {slugify(h) for h in HEADING_RE.findall(f.read())}


def check_links(doc: str, text: str, problems: list) -> None:
    base = os.path.dirname(os.path.join(REPO, doc))
    for target in LINK_RE.findall(text):
        if re.match(r"^[a-z]+://", target) or target.startswith("mailto:"):
            continue
        path, _, anchor = target.partition("#")
        full = os.path.join(base, path) if path else os.path.join(REPO, doc)
        if not os.path.exists(full):
            problems.append(f"{doc}: broken link target {target!r}")
            continue
        if anchor and full.endswith(".md"):
            if slugify(anchor) not in headings_of(full):
                problems.append(
                    f"{doc}: link anchor #{anchor} not found in {path or doc}"
                )


def token_defined(path: str, name: str) -> bool:
    try:
        with open(os.path.join(REPO, path), encoding="utf-8") as f:
            src = f.read()
    except OSError:
        return False
    return bool(
        re.search(
            rf"^\s*(?:def\s+{name}\s*\(|class\s+{name}\b|{name}\s*[:=])",
            src, re.M,
        )
    )


def check_tokens(doc: str, text: str, problems: list) -> set:
    """Validate file::name tokens; return the set of valid ones."""
    valid = set()
    for path, name in TOKEN_RE.findall(text):
        if not os.path.exists(os.path.join(REPO, path)):
            problems.append(f"{doc}: token {path}::{name} — no such file")
        elif not token_defined(path, name):
            problems.append(
                f"{doc}: token {path}::{name} — {name!r} not defined there"
            )
        else:
            valid.add((path, name))
    return valid


def check_equation_map(doc: str, text: str, problems: list) -> None:
    cited, mapped = set(), set()
    for line in text.splitlines():
        eqs = set()
        for lo, hi in EQ_RE.findall(line):
            lo = int(lo)
            eqs.update(range(lo, int(hi) + 1) if hi else (lo,))
        cited |= eqs
        if eqs and TOKEN_RE.search(line):
            # the token(s) on this line are themselves validated by
            # check_tokens; an invalid token already fails the lint.
            mapped |= eqs
    for eq in sorted(cited - mapped):
        problems.append(
            f"{doc}: Eq. {eq} is cited but never mapped to a "
            "file::function on any line"
        )


def check_public_symbols(all_text: str, problems: list) -> None:
    sys.path.insert(0, os.path.join(REPO, "src"))
    import repro.search as search

    for name in search.__all__:
        if not re.search(rf"\b{re.escape(name)}\b", all_text):
            problems.append(
                f"public symbol repro.search.{name} is not mentioned in "
                "README.md or docs/"
            )


def main() -> int:
    problems: list = []
    texts = {}
    for doc in DOC_FILES:
        with open(os.path.join(REPO, doc), encoding="utf-8") as f:
            texts[doc] = f.read()
    for doc, text in texts.items():
        check_links(doc, text, problems)
        check_tokens(doc, text, problems)
        if doc.endswith("performance_model.md"):
            check_equation_map(doc, text, problems)
    check_public_symbols("\n".join(texts.values()), problems)
    if problems:
        print(f"docs lint: {len(problems)} problem(s)")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(
        f"docs lint OK ({len(texts)} files, "
        f"{sum(len(TOKEN_RE.findall(t)) for t in texts.values())} "
        "code tokens verified)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
