#!/usr/bin/env python
"""Dump the unified telemetry surface after a tiny serving workload.

Runs a small closed-loop workload through ``SearchServer`` (so every
layer — dispatch counters, pack events, serve events, latency
histograms, traces, the roofline-drift monitor — has something to
report) and writes the three export formats the telemetry layer speaks:

  * ``--format prom``   Prometheus text exposition (default; what a
    scrape endpoint would serve — pipe to a file and point promtool
    at it),
  * ``--format json``   the structured registry snapshot
    (``telemetry.export_json()``),
  * ``--format chrome`` Chrome ``traceEvents`` JSON of the per-request
    traces — open in ``chrome://tracing`` or Perfetto for the
    submit → queue → coalesce → stage → dispatch → scatter flame graph.

``--out PATH`` writes to a file instead of stdout.  Use ``--requests`` /
``--clients`` to scale the workload; shapes stay small so the dump runs
in seconds on CPU.

    PYTHONPATH=src python scripts/telemetry_dump.py
    PYTHONPATH=src python scripts/telemetry_dump.py --format chrome \
        --out trace.json
"""
from __future__ import annotations

import argparse
import json
import sys
import threading

import jax
import numpy as np

from repro.search import (
    Index,
    SearchServer,
    ServeConfig,
    telemetry,
)

N, D, K = 2048, 64, 10
REQUEST_ROWS = 4


def run_workload(clients: int, requests_per_client: int) -> SearchServer:
    """Drive a closed loop and return the still-open server (caller
    reads traces/health, then closes)."""
    db = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    index = Index.build(db, metric="mips", k=K)
    server = SearchServer(
        index,
        ServeConfig(max_batch=32, max_delay_s=0.001,
                    trace_buffer=max(256, clients * requests_per_client)),
        warmup=True,
    )
    queries = [
        np.asarray(jax.random.normal(jax.random.PRNGKey(1 + c),
                                     (REQUEST_ROWS, D)))
        for c in range(clients)
    ]

    def client(cid):
        for _ in range(requests_per_client):
            server.submit(queries[cid]).result(timeout=120)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    server.health()       # refresh uptime / drift / recall gauges
    index.telemetry()     # fold the index gauges into the export
    return server


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--format", choices=("prom", "json", "chrome"),
                    default="prom")
    ap.add_argument("--out", default=None,
                    help="write here instead of stdout")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8,
                    help="requests per client")
    args = ap.parse_args()

    telemetry.reset_all()
    server = run_workload(args.clients, args.requests)
    try:
        if args.format == "prom":
            text = telemetry.export_prometheus()
        elif args.format == "json":
            text = json.dumps(telemetry.export_json(), indent=2)
        else:
            text = json.dumps(telemetry.chrome_trace(server.traces()),
                              indent=2)
    finally:
        server.close()

    if args.out:
        with open(args.out, "w") as f:
            f.write(text if text.endswith("\n") else text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)


if __name__ == "__main__":
    main()
