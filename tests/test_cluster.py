"""Tests for the cluster-pruned scan front-end (``repro.search.cluster``).

Covers the subsystem's contracts end to end:

  * the planner derives every parameter (C, rho, capacities, scan budget)
    from (N, k, recall_target) — there are no user knobs, and the spec
    rejects anything other than "auto"/"off";
  * below the cost crossover ``cluster="auto"`` builds nothing and is
    bit-identical to ``cluster="off"`` on every backend/storage combo;
  * above the crossover the pruned scan returns valid, live, exact-scored
    neighbours on xla/pallas/sharded, composes with the quantized storage
    tiers, and never leaks an empty table slot or a tombstoned row;
  * the packed-state contracts survive: add assigns incrementally, spill
    growth triggers a lazy recluster at add() time, and the steady state
    stays zero-retrace / one-dispatch / zero-db-sized-pads;
  * ``Index.explain()`` reports the scanned fraction and the
    collision x miss recall decomposition.

The correctness corpus is a mixture of Gaussians (queries drawn from the
same component centers): that is the regime the miss bound models.  On
i.i.d. Gaussian data all points are nearly equidistant and no coarse
quantizer can prune well — the planner's crossover still says "prune"
there (it prices FLOPs, not geometry), but the build-time sampled miss
check measures the geometry and rejects the tables, falling back to the
dense scan bit-identically (covered below).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.search import (
    ClusterPlan,
    Index,
    SearchServer,
    SearchSpec,
    ServeConfig,
    VirtualClock,
    exact_search,
    plan_clusters,
)
from repro.search import backends
from repro.search import cluster as clusterlib
from repro.search.backends import DISPATCH_COUNTS, TRACE_COUNTS
from repro.search.packed import PACK_EVENTS, reset_pack_events

N = 8192          # above the planner crossover
SMALL_N = 2048    # below it
D = 32
K = 10
TARGET = 0.95
COMPONENTS = 64


@pytest.fixture(autouse=True)
def _reset_counters():
    backends.reset_trace_counts()
    backends.reset_dispatch_counts()
    reset_pack_events()
    yield


def _mixture(seed, n=N, m=64, d=D):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(COMPONENTS, d)) * 2.5
    db = centers[rng.integers(0, COMPONENTS, n)] + rng.normal(size=(n, d))
    q = centers[rng.integers(0, COMPONENTS, m)] + rng.normal(size=(m, d))
    return jnp.asarray(db, jnp.float32), jnp.asarray(q, jnp.float32)


@pytest.fixture(scope="module")
def data():
    return _mixture(0)


def _recall(idxs, truth, k=K):
    a, b = np.asarray(idxs), np.asarray(truth)
    return np.mean(
        [len(set(r.tolist()) & set(t.tolist())) / k for r, t in zip(a, b)]
    )


# --- planner derivations -----------------------------------------------------


def test_plan_clusters_crossover():
    """Small N stays dense; large N enables pruning — the decision is the
    planner's cost model, never a user knob."""
    for n in (1024, SMALL_N, 4096):
        cp = plan_clusters(n=n, k_scan=K, recall_target=TARGET)
        assert isinstance(cp, ClusterPlan) and not cp.enabled
    for n in (N, 2 * N, 8 * N):
        cp = plan_clusters(n=n, k_scan=K, recall_target=TARGET)
        assert cp.enabled
        assert cp.num_clusters & (cp.num_clusters - 1) == 0  # power of two
        assert 1 <= cp.probes < cp.num_clusters
        assert cp.scan_rows < n
        assert 0.0 < cp.target_scan < 1.0
        assert cp.predicted_speedup >= 2.0


@pytest.mark.parametrize("target", [0.90, 0.95, 0.99])
@pytest.mark.parametrize("n", [N, 2 * N])
def test_plan_clusters_product_bound_meets_target(n, target):
    """collision x miss >= target for every derivation the planner emits."""
    cp = plan_clusters(n=n, k_scan=32, recall_target=target)
    assert cp.enabled
    decomp = cp.recall_decomposition(32)
    assert decomp["collision_term"] <= 1.0
    assert decomp["miss_term"] == 1.0 - cp.miss_budget
    assert decomp["expected_recall"] >= target
    assert decomp["expected_recall"] == pytest.approx(
        decomp["collision_term"] * decomp["miss_term"]
    )


def test_spec_rejects_cluster_knobs():
    with pytest.raises(ValueError, match="planner-derived"):
        SearchSpec(cluster="16-probes")
    assert SearchSpec().cluster == "auto"  # the default is auto


def test_capacity_slack_guarantees_table_space():
    """C * rows_per_cluster >= 1.25 N: the greedy fill can always place a
    row somewhere, so build never drops data."""
    for n in (N, 3 * N, 16 * N):
        cp = plan_clusters(n=n, k_scan=K, recall_target=TARGET)
        assert cp.num_clusters * cp.rows_per_cluster >= 1.25 * n


# --- off / below-crossover: bit-identical ------------------------------------


@pytest.mark.parametrize("backend,metric,storage", [
    ("xla", "mips", "f32"),
    ("xla", "l2", "int8"),
    ("xla", "cosine", "f32"),
    ("pallas", "l2", "f32"),
])
def test_below_crossover_auto_is_bit_identical_to_off(
    backend, metric, storage
):
    db, q = _mixture(1, n=SMALL_N)
    auto = Index.build(db, metric=metric, k=K, backend=backend,
                       storage=storage, cluster="auto")
    off = Index.build(db, metric=metric, k=K, backend=backend,
                      storage=storage, cluster="off")
    assert auto.pack().cluster is None  # nothing was built
    va, ia = auto.search(q)
    vo, io = off.search(q)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(io))
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vo))


def test_cluster_off_never_builds_tables(data):
    db, _ = data
    index = Index.build(db, metric="l2", k=K, backend="xla", cluster="off")
    assert index.pack().cluster is None
    assert PACK_EVENTS["cluster_built"] == 0


# --- pruned scan correctness -------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("metric", ["mips", "l2", "cosine"])
def test_pruned_scan_returns_exactly_scored_live_rows(data, backend, metric):
    """Every returned id is a real (live) row and its value is the exact
    metric score of that row — pruning changes WHICH rows are scanned,
    never how a scanned row is scored."""
    db, q = data
    index = Index.build(db, metric=metric, k=K, backend=backend,
                        recall_target=TARGET)
    assert index.pack().cluster is not None  # planner enabled pruning
    vals, idxs = index.search(q)
    vals, idxs = np.asarray(vals), np.asarray(idxs)
    assert ((idxs >= 0) & (idxs < N)).all()  # no EMPTY_SLOT leak
    ev, ei = exact_search(q, db, K, metric=metric)
    recall = _recall(idxs, ei)
    assert recall >= TARGET - 0.12, (
        f"{backend}/{metric}: pruned recall {recall:.3f} collapsed"
    )
    # exact-scoring check: recompute each returned score from raw data
    qn, dbn = np.asarray(q, np.float64), np.asarray(db, np.float64)
    for row in range(0, q.shape[0], 7):
        for j in range(K):
            rid = int(idxs[row, j])
            dot = float(qn[row] @ dbn[rid])
            if metric == "mips":
                ref = dot
            elif metric == "l2":
                # public values are ascending relaxed distances
                ref = -(dot - float(dbn[rid] @ dbn[rid]) / 2.0)
            else:  # cosine
                ref = dot / (
                    np.linalg.norm(qn[row]) * np.linalg.norm(dbn[rid])
                )
            assert vals[row, j] == pytest.approx(ref, abs=1e-3)


def test_deleted_rows_never_returned_from_pruned_scan(data):
    db, q = data
    index = Index.build(db, metric="l2", k=K, backend="xla")
    assert index.pack().cluster is not None
    _, before = index.search(q)
    doomed = np.unique(np.asarray(before)[:, 0])  # delete top hits
    index.delete(jnp.asarray(doomed))
    _, after = index.search(q)
    leaked = set(np.asarray(after).ravel().tolist()) & set(doomed.tolist())
    assert not leaked, f"tombstoned rows leaked through the gather: {leaked}"


def test_scanned_fraction_is_actually_small(data):
    db, _ = data
    index = Index.build(db, metric="l2", k=K, backend="xla")
    cp = index.pack().cluster.plan
    assert cp.scanned_fraction < 0.25
    assert cp.scan_rows == cp.probes * cp.rows_per_cluster \
        + cp.spill_capacity


# --- quantized tiers compose -------------------------------------------------


@pytest.mark.parametrize("storage", ["bf16", "int8"])
def test_cluster_composes_with_quantized_storage(data, storage):
    """Pruned quantized scan -> exact f32 rescore: the over-fetches stack
    and the returned values are exact scores (rescore output), not the
    reduced-precision scan scores."""
    db, q = data
    index = Index.build(db, metric="l2", k=K, backend="xla",
                        storage=storage, recall_target=TARGET)
    assert index.pack().cluster is not None
    vals, idxs = index.search(q)
    vals, idxs = np.asarray(vals), np.asarray(idxs)
    assert ((idxs >= 0) & (idxs < N)).all()
    _, ei = exact_search(q, db, K, metric="l2")
    assert _recall(idxs, ei) >= TARGET - 0.12
    # rescore exactness: values match f32 recomputation, not int8 scores
    qn, dbn = np.asarray(q, np.float64), np.asarray(db, np.float64)
    for row in range(0, q.shape[0], 11):
        rid = int(idxs[row, 0])
        ref = -(float(qn[row] @ dbn[rid]) - float(dbn[rid] @ dbn[rid]) / 2)
        assert vals[row, 0] == pytest.approx(ref, abs=1e-3)


# --- sharded backend ---------------------------------------------------------


def test_sharded_cluster_search_single_shard(data):
    db, q = data
    mesh = jax.make_mesh((1,), ("model",))
    index = Index.build(db, metric="l2", k=K, backend="xla").shard(
        mesh, db_axis="model"
    )
    pk = index.pack()
    assert pk.cluster is not None  # tables carried through the relayout
    vals, idxs = index.search(q)
    idxs = np.asarray(idxs)
    assert ((idxs >= 0) & (idxs < index.capacity)).all()
    _, ei = exact_search(q, db, K, metric="l2")
    assert _recall(idxs, ei) >= TARGET - 0.12


@pytest.mark.parametrize("storage", ["f32", "int8"])
def test_sharded_cluster_quant_and_f32_operand_binding(data, storage):
    """The sharded searcher takes quant and cluster operands in one
    signature; both storage tiers must bind them correctly (a positional
    mix-up would feed centroids where scales belong)."""
    db, q = data
    mesh = jax.make_mesh((1,), ("model",))
    index = Index.build(db, metric="l2", k=K, backend="xla",
                        storage=storage).shard(mesh, db_axis="model")
    _, idxs = index.search(q)
    _, ei = exact_search(q, db, K, metric="l2")
    assert _recall(idxs, ei) >= TARGET - 0.12


# --- packed add/delete contract ----------------------------------------------


def test_add_assigns_incrementally_without_rebuild():
    db, q = _mixture(2, n=N - 128)
    index = Index.build(db, metric="l2", k=K, backend="xla", capacity=N)
    cs = index.pack().cluster
    assert cs is not None
    total0 = int(cs.counts.sum()) + cs.spill_count
    reset_pack_events()
    new_rows, _ = _mixture(3, n=64)
    index.add(new_rows[:64])
    assert PACK_EVENTS["cluster_assigned"] == 1
    assert PACK_EVENTS["cluster_built"] == 0  # incremental, not a rebuild
    assert PACK_EVENTS["recluster"] == 0
    cs = index.pack().cluster
    assert int(cs.counts.sum()) + cs.spill_count == total0 + 64
    # the appended rows are findable: search for them exactly
    vals, idxs = index.search(new_rows[:8])
    found = set(np.asarray(idxs)[:, 0].tolist())
    appended = set(range(N - 128, N - 128 + 8))
    assert found & appended, "freshly added rows never surfaced"


def test_spill_growth_triggers_lazy_recluster():
    """Spill growth past the planner threshold triggers exactly one
    rebuild at add() time — and the rebuild resets the trigger."""
    db, _ = _mixture(4, n=N - 64)
    index = Index.build(db, metric="l2", k=K, backend="xla", capacity=N)
    cs = index.pack().cluster
    # simulate incremental assignment having grown the spill block past
    # the threshold (deterministic, corpus-independent)
    cs.spill_count = min(
        cs.plan.spill_capacity,
        cs.spill_baseline + cs.plan.spill_capacity,
    )
    grew = cs.spill_count - cs.spill_baseline
    if grew <= cs.plan.spill_capacity * clusterlib._SPILL_REPLAN_FRACTION:
        cs.spill_baseline = 0  # force growth even on a spill-full corpus
    assert cs.needs_recluster
    reset_pack_events()
    index.add(jnp.ones((1, D), jnp.float32))
    assert PACK_EVENTS["recluster"] == 1
    cs = index.pack().cluster
    assert not cs.needs_recluster  # trigger is reset by the rebuild
    reset_pack_events()
    index.add(jnp.ones((1, D), jnp.float32))
    assert PACK_EVENTS["recluster"] == 0  # no thrash


# --- steady-state contracts --------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_zero_retrace_one_dispatch_with_interleaved_updates(data, backend):
    """The clustered path keeps the PR-2 steady-state contract: after the
    warmup compile, interleaved add/delete/search traffic re-traces
    nothing, repacks nothing, and each search is ONE device dispatch."""
    db, q = data
    rng = np.random.default_rng(5)
    index = Index.build(db[: N - 64], metric="l2", k=K, backend=backend,
                        capacity=N)
    assert index.pack().cluster is not None
    index.search(q)  # warmup
    backends.reset_trace_counts()
    backends.reset_dispatch_counts()
    reset_pack_events()
    index._cache.reset_counters()
    for _ in range(3):
        index.add(jnp.asarray(rng.normal(size=(8, D)), jnp.float32))
        index.delete(jnp.asarray(rng.integers(0, N - 64, 4)))
        index.search(q)
    assert not dict(TRACE_COUNTS), "clustered steady state retraced"
    assert DISPATCH_COUNTS[backend] == 3, "more than one dispatch/search"
    assert PACK_EVENTS["packed"] == 0, "a search-time repack happened"
    assert PACK_EVENTS["relayout"] == 0
    assert index.cache_info()["misses"] == 0


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_clustered_program_never_pads_database(data, backend):
    """Jaxpr probe: the compiled pruned-scan program pads only query- and
    candidate-sized arrays, never anything database-sized."""
    db, q = data
    index = Index.build(db, metric="l2", k=K, backend=backend)
    pk = index.pack()
    assert pk.cluster is not None
    fn = index._build_block_fn(backend, pk)
    jaxpr = jax.make_jaxpr(fn)(q, *pk.operands()).jaxpr
    pads = _pad_shapes(jaxpr)
    db_elems = pk.db.shape[0] * pk.db.shape[1]
    assert all(int(np.prod(s)) < db_elems for s in pads), (
        f"database-sized pad in the clustered program: {pads}"
    )


def _subjaxprs(p):
    if hasattr(p, "jaxpr"):
        yield p.jaxpr
    elif hasattr(p, "eqns"):
        yield p
    elif isinstance(p, (list, tuple)):
        for x in p:
            yield from _subjaxprs(x)


def _pad_shapes(jaxpr):
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pad":
            out.append(tuple(eqn.outvars[0].aval.shape))
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                out.extend(_pad_shapes(sub))
    return out


# --- serving -----------------------------------------------------------------


def test_search_server_over_clustered_index(data):
    """SearchServer micro-batching works unchanged over a clustered index
    and returns the same neighbours as a direct search."""
    db, q = data
    index = Index.build(db, metric="l2", k=K, backend="xla")
    assert index.pack().cluster is not None
    server = SearchServer(index, ServeConfig(max_batch=32),
                          clock=VirtualClock())
    tickets = [server.submit(q[i : i + 4]) for i in range(0, 16, 4)]
    server.run_until_idle()
    direct_v, direct_i = index.search(q[:16])
    got_i = np.concatenate([np.asarray(t.result().indices) for t in tickets])
    np.testing.assert_array_equal(got_i, np.asarray(direct_i)[:16])


# --- explain -----------------------------------------------------------------


def test_explain_reports_cluster_decomposition(data):
    db, _ = data
    index = Index.build(db, metric="l2", k=K, backend="xla",
                        recall_target=TARGET)
    report = index.explain()
    cl = report["cluster"]
    assert cl["mode"] == "auto" and cl["enabled"]
    assert 0.0 < cl["scanned_fraction"] < 1.0
    assert cl["expected_recall"] == pytest.approx(
        cl["collision_term"] * cl["miss_term"]
    )
    assert cl["expected_recall"] >= TARGET
    assert report["expected_recall"] == cl["expected_recall"]
    assert index.expected_recall == cl["expected_recall"]


def test_explain_below_crossover_reports_rejection():
    db, _ = _mixture(6, n=SMALL_N)
    index = Index.build(db, metric="l2", k=K, backend="xla")
    cl = index.explain()["cluster"]
    assert cl["mode"] == "auto" and not cl["enabled"]
    assert cl["predicted_speedup"] < 2.0  # why the planner said no


# --- build-time sampled miss check (regime detector) -------------------------


def _gaussian_db(seed, n=N, d=D):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)), jnp.float32)


def test_structureless_data_rejected_by_miss_check():
    """i.i.d. Gaussian above the crossover: the planner says "prune" but
    the measured miss rate blows the budget, so the tables are discarded
    and the index is bit-identical to cluster="off" (the quickstart
    regression: recall must not collapse on unclusterable data)."""
    db = _gaussian_db(11)
    q = _gaussian_db(12, n=64)
    auto = Index.build(db, metric="l2", k=K, backend="xla", cluster="auto")
    off = Index.build(db, metric="l2", k=K, backend="xla", cluster="off")
    assert auto.kernel_plan.cluster.enabled      # crossover said yes...
    assert auto.pack().cluster is None           # ...the measurement said no
    assert PACK_EVENTS["cluster_rejected"] >= 1
    va, ia = auto.search(q)
    vo, io = off.search(q)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(io))
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vo))
    # the dense fallback keeps the recall guarantee the planner promised
    assert auto.expected_recall == off.expected_recall


def test_rejection_surfaces_in_explain():
    db = _gaussian_db(13)
    index = Index.build(db, metric="l2", k=K, backend="xla")
    cl = index.explain()["cluster"]
    assert cl["mode"] == "auto" and not cl["enabled"]
    assert cl["rejected_by"] == "sampled_miss_check"
    assert cl["sampled_miss"] > cl["miss_budget"]


def test_sampled_miss_rate_separates_regimes():
    """The measurement itself: small on the mixture corpus (within the
    acceptance threshold), large on i.i.d. Gaussian (far past it)."""
    db, _ = _mixture(14)
    mixed = Index.build(db, metric="l2", k=K, backend="xla").pack()
    rate = clusterlib.sampled_miss_rate(
        mixed.cluster, mixed.rows(), mixed.bias_row()[:mixed.n], None, K
    )
    threshold = clusterlib.miss_check_threshold(
        mixed.cluster.plan.miss_budget
    )
    assert rate <= threshold
    gauss = Index.build(_gaussian_db(15), metric="l2", k=K, backend="xla")
    assert gauss.pack().cluster_rejected_miss > 2 * threshold


def test_miss_check_threshold_floor():
    # tight budgets (high targets) keep the absolute floor so sampling
    # noise cannot cause spurious rejections
    assert clusterlib.miss_check_threshold(0.005) == 0.08
    assert clusterlib.miss_check_threshold(0.05) == pytest.approx(0.1)
