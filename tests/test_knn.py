"""KNN search ops: MIPS / L2 / cosine, the Eq. 19 halved-norm trick."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.knn import (
    cosine_nns,
    exact_l2nns,
    exact_mips,
    half_norms,
    l2nns,
    mips,
)


def _recall(approx_idx, exact_idx):
    r = []
    for a, e in zip(np.asarray(approx_idx), np.asarray(exact_idx)):
        r.append(len(set(a.tolist()) & set(e.tolist())) / len(e))
    return float(np.mean(r))


@pytest.fixture(scope="module")
def data():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (64, 64))
    db = jax.random.normal(jax.random.PRNGKey(1), (8192, 64))
    return q, db


def test_mips_recall(data):
    q, db = data
    _, idx = mips(q, db, 10, recall_target=0.95)
    _, exact = exact_mips(q, db, 10)
    assert _recall(idx, exact) >= 0.9


def test_l2_recall(data):
    q, db = data
    _, idx = l2nns(q, db, 10, recall_target=0.95)
    d = np.linalg.norm(np.asarray(q)[:, None] - np.asarray(db)[None], axis=-1)
    exact = np.argsort(d, axis=-1)[:, :10]
    assert _recall(idx, exact) >= 0.9


def test_l2_halfnorm_equivalence(data):
    """Eq. 15-19: argmin ||q-x|| == argmin ||x||^2/2 - <q,x>."""
    q, db = data
    d_true = np.linalg.norm(np.asarray(q)[:, None] - np.asarray(db)[None], axis=-1)
    relaxed = np.asarray(half_norms(db))[None, :] - np.asarray(q) @ np.asarray(db).T
    np.testing.assert_array_equal(
        np.argsort(d_true, axis=-1)[:, :20], np.argsort(relaxed, axis=-1)[:, :20]
    )


def test_l2_exact_path_matches_numpy(data):
    q, db = data
    _, idx = exact_l2nns(q, db, 10)
    d = np.linalg.norm(np.asarray(q)[:, None] - np.asarray(db)[None], axis=-1)
    exact = np.argsort(d, axis=-1)[:, :10]
    assert _recall(idx, exact) == 1.0


def test_cosine_equals_mips_on_normalized(data):
    q, db = data
    dbn = db / jnp.linalg.norm(db, axis=-1, keepdims=True)
    _, i_cos = cosine_nns(q, dbn, 10, recall_target=0.99)
    scores = np.asarray(q / jnp.linalg.norm(q, axis=-1, keepdims=True)) @ np.asarray(dbn).T
    exact = np.argsort(-scores, axis=-1)[:, :10]
    assert _recall(i_cos, exact) >= 0.95


def test_precomputed_half_norms_path(data):
    q, db = data
    hn = half_norms(db)
    v1, i1 = l2nns(q, db, 5, db_half_norm=hn, recall_target=0.99)
    v2, i2 = l2nns(q, db, 5, recall_target=0.99)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
