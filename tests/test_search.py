"""Unified repro.search API: backend parity, updates, compile cache.

Covers the acceptance contract of the front-door redesign:
  * per-metric parity across xla / pallas-interpret / sharded backends,
  * recall after Index.add / Index.delete meets BinPlan.expected_recall on
    all three backends,
  * no retrace on same-shape repeat searches (compile cache),
  * the L2 relaxed-distance value contract holds identically everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.search import (
    Index,
    SearchSpec,
    backends,
    exact_search,
    get_metric,
    l2nns,
)
from repro.search.backends import TRACE_COUNTS

METRICS = ("mips", "l2", "cosine")
K = 10


def _recall(approx_idx, exact_idx):
    r = []
    for a, e in zip(np.asarray(approx_idx), np.asarray(exact_idx)):
        r.append(len(set(a.tolist()) & set(e.tolist())) / len(e))
    return float(np.mean(r))


@pytest.fixture(scope="module")
def data():
    q = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    db = jax.random.normal(jax.random.PRNGKey(1), (4096, 32))
    return q, db


@pytest.fixture(scope="module")
def mesh1():
    """Single-device mesh: exercises the sharded code path in-process."""
    return jax.make_mesh((1,), ("model",))


def _build(db, metric, backend, mesh=None, **kw):
    if backend == "sharded":
        return Index.build(
            db, metric=metric, k=K, recall_target=0.95, **kw
        ).shard(mesh, db_axis="model")
    return Index.build(
        db, metric=metric, k=K, recall_target=0.95, backend=backend, **kw
    )


# --- backend x metric parity ------------------------------------------------


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("backend", ["xla", "pallas", "sharded"])
def test_backend_meets_recall_target(data, mesh1, metric, backend):
    q, db = data
    index = _build(db, metric, backend, mesh1)
    vals, idxs = index.search(q)
    _, exact = exact_search(q, db, K, metric=metric)
    assert vals.shape == idxs.shape == (64, K)
    assert _recall(idxs, exact) >= index.expected_recall - 0.05


@pytest.mark.parametrize("metric", METRICS)
def test_cross_backend_value_parity(data, mesh1, metric):
    """Same plan => same candidates; values agree in sign AND magnitude
    across all three backends wherever indices agree (satellite: one L2
    convention, asserted cross-backend)."""
    q, db = data
    results = {
        b: Index.build(
            db, metric=metric, k=K, recall_target=0.95, backend=b
        ).search(q)
        for b in ("xla", "pallas")
    }
    results["sharded"] = _build(db, metric, "sharded", mesh1).search(q)
    ref_v, ref_i = results["xla"]
    for b in ("pallas", "sharded"):
        v, i = results[b]
        agree = np.asarray(i) == np.asarray(ref_i)
        assert agree.mean() > 0.95  # near-ties may reorder
        np.testing.assert_allclose(
            np.asarray(v)[agree], np.asarray(ref_v)[agree], rtol=1e-4
        )
        if get_metric(metric).negate_output:
            # ascending best-first (distances)
            assert (np.diff(np.asarray(v), axis=-1) >= -1e-5).all()
        else:
            # descending best-first (similarities)
            assert (np.diff(np.asarray(v), axis=-1) <= 1e-5).all()


def test_l2_values_are_relaxed_distances(data):
    """The documented contract: ||x||^2/2 - <q,x> at the returned indices."""
    q, db = data
    vals, idxs = Index.build(db, metric="l2", k=K, backend="xla").search(q)
    hn = 0.5 * np.sum(np.asarray(db) ** 2, axis=-1)
    expect = hn[np.asarray(idxs)] - np.take_along_axis(
        np.asarray(q) @ np.asarray(db).T, np.asarray(idxs), axis=-1
    )
    np.testing.assert_allclose(np.asarray(vals), expect, rtol=1e-4, atol=1e-5)
    # legacy functional path agrees bit-for-bit in convention
    lv, li = l2nns(q, db, K, recall_target=0.95)
    np.testing.assert_array_equal(np.asarray(li), np.asarray(idxs))
    np.testing.assert_allclose(
        np.asarray(lv), np.asarray(vals), rtol=1e-5, atol=1e-6
    )


# --- frequent updates: add / delete -----------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas", "sharded"])
def test_recall_after_add_and_delete(data, mesh1, backend):
    """Index.add / Index.delete followed by .search meets the plan's
    expected recall on every backend (acceptance criterion)."""
    q, db = data
    index = _build(db[:2048], "mips", backend, mesh1)
    index.add(db[2048:])
    assert index.size == 4096

    _, exact = exact_search(q, db, K, metric="mips")
    _, idxs = index.search(q)
    assert _recall(idxs, exact) >= index.expected_recall - 0.05

    # tombstone each query's current top-1; they must vanish from results
    # and recall against the remaining rows must still meet the plan.
    top1 = np.unique(np.asarray(exact)[:, 0])
    index.delete(top1)
    assert index.size == 4096 - len(top1)
    _, idxs2 = index.search(q)
    assert not set(np.asarray(idxs2).ravel().tolist()) & set(top1.tolist())

    scores = np.asarray(q) @ np.asarray(db).T
    scores[:, top1] = -np.inf
    exact_live = np.argsort(-scores, axis=-1)[:, :K]
    assert _recall(idxs2, exact_live) >= index.expected_recall - 0.05


def test_delete_with_duplicate_ids_counts_once(data):
    _, db = data
    index = Index.build(db[:64], k=4)
    index.delete([5, 5, 5])
    assert index.size == 63
    index.delete([5, 6])  # 5 already dead: only 6 is newly removed
    assert index.size == 62


def test_add_grows_capacity_in_blocks(data):
    _, db = data
    index = Index.build(db[:1000], k=K, capacity_block=512)
    assert index.capacity == 1000
    index.add(db[1000:1100])
    assert index.capacity % 512 == 0 and index.capacity >= 1100
    assert index.size == 1100
    # padded rows are tombstoned: never returned
    q = jax.random.normal(jax.random.PRNGKey(7), (8, 32))
    _, idxs = index.search(q)
    assert int(np.asarray(idxs).max()) < 1100


# --- compile cache ----------------------------------------------------------


def test_no_retrace_on_same_shape_repeat(data):
    q, db = data
    index = Index.build(db, metric="mips", k=K, backend="xla")
    index.search(q)
    backends.reset_trace_counts()  # warmup traced; steady state must not
    for _ in range(3):
        index.search(q)
    assert not dict(TRACE_COUNTS)
    info = index.cache_info()
    assert info["hits"] >= 3 and info["entries"] == 1
    # a new query shape is a new entry, not a silent retrace of the old one
    index.search(q[:16])
    assert index.cache_info()["entries"] == 2


def test_delete_does_not_retrace(data):
    q, db = data
    index = Index.build(db, metric="l2", k=K, backend="xla")
    index.search(q)
    backends.reset_trace_counts()
    index.delete([0, 1, 2])
    index.search(q)  # same shapes: only the bias operand changed
    assert not dict(TRACE_COUNTS)


# --- API surface ------------------------------------------------------------


def test_spec_validation():
    with pytest.raises(ValueError):
        SearchSpec(k=0)
    with pytest.raises(ValueError):
        SearchSpec(recall_target=1.5)
    with pytest.raises(ValueError):
        SearchSpec(backend="gpu")
    with pytest.raises(ValueError):
        Index.build(jnp.zeros((16, 4)), metric="manhattan")


def test_sharded_backend_requires_mesh():
    index = Index.build(jnp.zeros((64, 4)), backend="sharded")
    with pytest.raises(ValueError, match="mesh"):
        index.search(jnp.zeros((2, 4)))


def test_query_auto_tiling_matches_single_shot(data):
    q, db = data
    whole = Index.build(db, k=K, backend="xla").search(q)
    tiled = Index.build(db, k=K, backend="xla", query_block=24).search(q)
    np.testing.assert_array_equal(
        np.asarray(whole.indices), np.asarray(tiled.indices)
    )
    np.testing.assert_allclose(
        np.asarray(whole.values), np.asarray(tiled.values), rtol=1e-6
    )


def test_cosine_works_on_pallas_backend(data):
    """The old API had cosine only on the XLA path; the front door closes
    that gap (raw, unnormalized database in, normalized search out)."""
    q, db = data
    db_scaled = db * jnp.linspace(0.1, 5.0, db.shape[0])[:, None]  # wild norms
    index = Index.build(db_scaled, metric="cosine", k=K, backend="pallas")
    _, idxs = index.search(q)
    _, exact = exact_search(q, db_scaled, K, metric="cosine")
    assert _recall(idxs, exact) >= index.expected_recall - 0.05


def test_default_backend_resolution():
    assert backends.default_backend(None) in ("xla", "pallas")
    index = Index.build(jnp.ones((128, 8)))
    assert index._resolve_backend() in ("xla", "pallas")
