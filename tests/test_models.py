"""Per-architecture smoke tests (reduced configs, CPU) + decode parity.

One forward/train step per assigned arch asserting output shapes + no NaNs,
plus decode-replay-vs-full-forward parity for representative families and
correctness of the paper-integrated KNN attention path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import model as M
from repro.models import transformer as tfm

B, S = 2, 32


def _batch(cfg, key=jax.random.PRNGKey(0)):
    batch = {"labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.input_mode == "embeddings" and not cfg.is_encoder_decoder:
        batch["embeddings"] = jax.random.normal(key, (B, S, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.mrope:
        pos = jnp.arange(S)
        batch["mrope_positions"] = jnp.stack([pos, pos, pos])
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_forward_and_loss(arch):
    cfg = get_config(arch + "-smoke")
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    main = batch.get("tokens", batch.get("embeddings"))
    kwargs = {}
    if cfg.is_encoder_decoder:
        kwargs["enc_embeds"] = batch["enc_embeds"]
    if cfg.mrope:
        kwargs["mrope_positions"] = batch["mrope_positions"]
    logits = tfm.forward_train(params, cfg, main, **kwargs)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss = M.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch + "-smoke")
    state = M.init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(M.make_train_step(cfg, learning_rate=1e-3))
    state2, metrics = step(state, _batch(cfg))
    assert int(state2.step) == 1
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params, state2.params,
    )
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize(
    "arch",
    ["internlm2-1.8b", "deepseek-v2-236b", "mamba2-2.7b", "recurrentgemma-9b",
     "whisper-medium"],
)
def test_decode_replay_matches_full_forward(arch):
    """Replaying tokens through decode reproduces full-forward logits."""
    cfg = get_config(arch + "-smoke")
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    kwargs = {}
    cross_kv = None
    if cfg.is_encoder_decoder:
        enc = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model))
        kwargs["enc_embeds"] = enc
        enc_out = tfm._encode(params, cfg, enc)
        cross_kv = tfm.build_cross_kv(params, cfg, enc_out)

    caches = tfm.init_caches(cfg, B, S + 4)
    dec = jax.jit(M.make_decode_step(cfg, sample="greedy"), static_argnames=())
    lt = None
    for t in range(S):
        _, lt, caches = dec(
            params, tokens[:, t : t + 1], caches, jnp.int32(t),
            jax.random.PRNGKey(t), cross_kv,
        )
    full = tfm.forward_train(params, cfg, tokens, **kwargs)
    diff = float(
        jnp.max(jnp.abs(full[:, -1].astype(jnp.float32) - lt[:, -1].astype(jnp.float32)))
    )
    assert diff < 0.05, f"decode/train divergence {diff}"


def test_knn_attention_approximates_exact():
    """KNN top-k decode attention ~= exact attention when k covers the mass."""
    from repro.models.attention import knn_decode_attention, _NEG_INF

    key = jax.random.PRNGKey(0)
    b, s, h, hd = 2, 1024, 4, 32
    q = jax.random.normal(key, (b, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, hd)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, hd))
    valid = jnp.ones((s,), bool)
    out_knn = knn_decode_attention(q, k, v, valid, k=256, recall_target=0.99)
    scores = jnp.einsum("bhd,bkhd->bhk", q, k) * hd**-0.5
    probs = jax.nn.softmax(scores, -1)
    out_exact = jnp.einsum("bhk,bkhd->bhd", probs, v)
    # top-256 of 1024 keys carries almost all softmax mass here
    err = float(jnp.max(jnp.abs(out_knn - out_exact)))
    assert err < 0.15, err


def test_moe_routing_is_topk_and_normalized():
    from repro.models.moe import moe_apply, moe_defs
    from repro.models.params import init_params

    cfg_d, e, k = 32, 8, 2
    params = init_params(jax.random.PRNGKey(0), moe_defs(cfg_d, 16, e))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg_d))
    y = moe_apply(params, x, experts_per_token=k, num_experts=e,
                  group_size=32, capacity_factor=4.0)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    # approx routing path also runs
    y2 = moe_apply(params, x, experts_per_token=k, num_experts=e,
                   group_size=32, capacity_factor=4.0, routing="approx")
    assert bool(jnp.all(jnp.isfinite(y2)))


def test_vocab_padding_never_sampled():
    cfg = get_config("granite-moe-3b-a800m-smoke")
    # force a padded vocab
    import dataclasses

    cfg = dataclasses.replace(cfg, vocab_size=250)  # padded to 256
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    caches = tfm.init_caches(cfg, 4, 16)
    dec = jax.jit(M.make_decode_step(cfg))
    toks = jnp.zeros((4, 1), jnp.int32)
    for t in range(8):
        toks, _, caches = dec(params, toks, caches, jnp.int32(t), jax.random.PRNGKey(t))
        assert int(toks.max()) < 250
