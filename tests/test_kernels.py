"""Pallas PartialReduce kernel vs pure-jnp oracle: shape/dtype sweeps in
interpret mode (the brief's per-kernel validation contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.knn import exact_mips
from repro.kernels.ops import l2_topk, mips_topk
from repro.kernels.partial_reduce import partial_reduce_pallas
from repro.kernels.ref import partial_reduce_ref


def _recall(approx_idx, exact_idx):
    r = []
    for a, e in zip(np.asarray(approx_idx), np.asarray(exact_idx)):
        r.append(len(set(a.tolist()) & set(e.tolist())) / len(e))
    return float(np.mean(r))


@pytest.mark.parametrize("m,n,d,bin_size,block_m,block_n", [
    (256, 2048, 128, 64, 256, 512),
    (256, 2048, 128, 256, 128, 1024),
    (512, 4096, 256, 128, 256, 1024),
    (256, 1024, 128, 1024, 256, 1024),   # one bin per block
    (256, 2048, 384, 32, 256, 512),      # d > 128 multiple
])
def test_kernel_matches_ref_shapes(m, n, d, bin_size, block_m, block_n):
    key = jax.random.PRNGKey(m + n + d)
    q = jax.random.normal(key, (m, d), jnp.float32)
    db = jax.random.normal(jax.random.PRNGKey(1), (n, d), jnp.float32)
    bias = jnp.zeros((1, n), jnp.float32)
    kv, ki = partial_reduce_pallas(
        q, db, bias, bin_size=bin_size, block_m=block_m, block_n=block_n,
        interpret=True,
    )
    rv, ri = partial_reduce_ref(q, db, bias, bin_size=bin_size)
    np.testing.assert_allclose(np.asarray(kv), np.asarray(rv), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (256, 128)).astype(dtype)
    db = jax.random.normal(jax.random.PRNGKey(1), (1024, 128)).astype(dtype)
    bias = jnp.zeros((1, 1024), jnp.float32)
    kv, ki = partial_reduce_pallas(
        q, db, bias, bin_size=64, block_m=256, block_n=512, interpret=True
    )
    rv, ri = partial_reduce_ref(q, db, bias, bin_size=64)
    np.testing.assert_allclose(np.asarray(kv), np.asarray(rv), atol=1e-2)
    # bf16 rounding can flip near-ties; require near-total index agreement
    agree = (np.asarray(ki) == np.asarray(ri)).mean()
    assert agree > 0.995


def test_kernel_bias_fuses_l2(data=None):
    """bias = -||x||^2/2 turns the kernel into Eq. 19 L2 search."""
    q = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    db = jax.random.normal(jax.random.PRNGKey(1), (1500, 32))
    v, idx = l2_topk(q, db, 10, 0.98, interpret=True)
    d = np.linalg.norm(np.asarray(q)[:, None] - np.asarray(db)[None], axis=-1)
    exact = np.argsort(d, axis=-1)[:, :10]
    assert _recall(idx, exact) >= 0.9
    # returned values are the relaxed distances, monotone with true d
    order = np.argsort(np.asarray(v), axis=-1)
    np.testing.assert_array_equal(order, np.tile(np.arange(10), (64, 1)))


def test_fused_mips_end_to_end_unaligned():
    """Non-pow2 N, non-128 D: padding + masking path (Appendix A.5)."""
    q = jax.random.normal(jax.random.PRNGKey(2), (100, 100))
    db = jax.random.normal(jax.random.PRNGKey(3), (5001, 100))
    v, idx = mips_topk(q, db, 10, 0.95, interpret=True)
    _, exact = exact_mips(q, db, 10)
    assert _recall(idx, exact) >= 0.9
    assert int(np.asarray(idx).max()) < 5001  # no padded index leaks


def test_fused_mips_matches_unfused_recall():
    from repro.core.knn import mips as jnp_mips

    q = jax.random.normal(jax.random.PRNGKey(4), (64, 64))
    db = jax.random.normal(jax.random.PRNGKey(5), (4096, 64))
    _, i_kernel = mips_topk(q, db, 10, 0.95, interpret=True)
    _, i_jnp = jnp_mips(q, db, 10, recall_target=0.95)
    _, exact = exact_mips(q, db, 10)
    # same binning plan => identical recall characteristics
    assert abs(_recall(i_kernel, exact) - _recall(i_jnp, exact)) < 0.05


def test_kernel_serves_knn_attention_selection():
    """The fused PartialReduce kernel IS the decode-attention selector:
    scoring q against the KV cache is MIPS with keys as the database, so the
    same kernel drives both the KNN search API and the serving path."""
    import jax.numpy as jnp

    from repro.core.topk import approx_max_k

    b, h, s, hd = 2, 4, 2048, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, hd))
    keys = jax.random.normal(jax.random.PRNGKey(1), (s, hd))
    # jnp path used inside knn_decode_attention:
    scores = jnp.einsum("bhd,kd->bhk", q, keys)
    _, idx_jnp = approx_max_k(scores, 32, recall_target=0.95)
    # fused kernel path: queries are the (B*H) flattened heads.
    _, idx_kernel = mips_topk(
        q.reshape(b * h, hd), keys, 32, 0.95, interpret=True
    )
    agree = (np.asarray(idx_jnp).reshape(b * h, 32) ==
             np.asarray(idx_kernel)).mean()
    assert agree > 0.95  # same plan; near-ties may differ in f32 vs kernel
