"""approx_max_k / approx_min_k semantics + empirical recall guarantees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; module skips cleanly without
from hypothesis import given, settings, strategies as st

from repro.core.partial_reduce import partial_reduce
from repro.core.rescoring import bitonic_sort_pairs, exact_rescoring
from repro.core.topk import approx_max_k, approx_min_k


def _recall(approx_idx, exact_idx):
    r = []
    for a, e in zip(np.asarray(approx_idx), np.asarray(exact_idx)):
        r.append(len(set(a.tolist()) & set(e.tolist())) / len(e))
    return float(np.mean(r))


def test_approx_max_k_beats_recall_target():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 8192))
    _, idx = approx_max_k(x, 10, recall_target=0.95)
    _, exact = jax.lax.top_k(x, 10)
    assert _recall(idx, exact) >= 0.93  # analytic bound is in-expectation


def test_matches_upstream_operator_semantics():
    """Cross-validate against the authors' upstreamed jax.lax.approx_max_k."""
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 4096))
    v_ours, i_ours = approx_max_k(x, 5, recall_target=0.9)
    v_up, i_up = jax.lax.approx_max_k(x, 5, recall_target=0.9)
    _, exact = jax.lax.top_k(x, 5)
    assert _recall(i_ours, exact) >= 0.85
    assert _recall(i_up, exact) >= 0.85
    # values are true scores at the returned indices for both
    g = jnp.take_along_axis(x, i_ours, axis=-1)
    np.testing.assert_allclose(np.asarray(v_ours), np.asarray(g), rtol=1e-6)


def test_approx_min_k_is_negated_max():
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 2048))
    v_min, i_min = approx_min_k(x, 7)
    v_max, i_max = approx_max_k(-x, 7)
    np.testing.assert_allclose(np.asarray(v_min), -np.asarray(v_max), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i_min), np.asarray(i_max))


def test_aggregate_to_topk_false_returns_bins():
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 4096))
    vals, idxs = approx_max_k(x, 10, recall_target=0.95, aggregate_to_topk=False)
    assert vals.shape[-1] >= 10  # L bins, not k
    assert vals.shape == idxs.shape
    g = jnp.take_along_axis(x, idxs, axis=-1)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(g), rtol=1e-6)


def test_values_sorted_descending():
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 4096))
    vals, _ = approx_max_k(x, 10)
    v = np.asarray(vals)
    assert (np.diff(v, axis=-1) <= 1e-6).all()


@given(
    m=st.integers(1, 8),
    n=st.sampled_from([256, 1000, 4096, 10000]),
    k=st.integers(1, 16),
    seed=st.integers(0, 2**30),
)
@settings(max_examples=25, deadline=None)
def test_property_recall_in_expectation(m, n, k, seed):
    """Empirical recall over many queries stays near E[recall] (Eq. 13)."""
    if k > n // 4:
        return
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n))
    _, idx = approx_max_k(x, k, recall_target=0.9)
    _, exact = jax.lax.top_k(x, k)
    # Individual rows fluctuate; the guarantee is in expectation.  With up to
    # 8 rows allow generous slack below the 0.9 target.
    assert _recall(idx, exact) >= 0.55


def test_bitonic_sort_matches_topk():
    vals = jax.random.normal(jax.random.PRNGKey(5), (6, 100))
    idxs = jnp.tile(jnp.arange(100), (6, 1))
    bv, bi = exact_rescoring(vals, idxs, 10, use_bitonic=True)
    tv, ti = jax.lax.top_k(vals, 10)
    np.testing.assert_allclose(np.asarray(bv), np.asarray(tv), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(ti))


@given(n=st.integers(2, 300), seed=st.integers(0, 2**30))
@settings(max_examples=40, deadline=None)
def test_property_bitonic_full_sort(n, seed):
    vals = jax.random.normal(jax.random.PRNGKey(seed), (2, n))
    idxs = jnp.tile(jnp.arange(n), (2, 1))
    sv, si = bitonic_sort_pairs(vals, idxs, descending=True)
    ref = np.sort(np.asarray(vals), axis=-1)[:, ::-1]
    np.testing.assert_allclose(np.asarray(sv), ref, rtol=1e-6)


def test_partial_reduce_min_mode():
    x = jax.random.normal(jax.random.PRNGKey(6), (3, 1024))
    vals, idxs = partial_reduce(x, 5, 0.9, mode="min")
    g = jnp.take_along_axis(x, idxs, axis=-1)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(g), rtol=1e-6)
