"""Roofline model tests: Eq. 6-9, Table 1/2 accounting, Eq. 20."""
import pytest

from repro.configs.knn_workloads import KNN_WORKLOADS
from repro.core.roofline import (
    HARDWARE,
    KernelCost,
    attainable_flops,
    bottleneck,
    cops_per_dot,
    partial_reduce_cost,
)


def test_eq9_cop_budget():
    """Eq. 9: C <= 2*D*gamma/pi — the paper's D=128 examples."""
    v4 = HARDWARE["tpu_v4"]
    a100 = HARDWARE["a100"]
    assert int(2 * 128 * v4.peak_cops / v4.peak_flops) == 4
    assert int(2 * 128 * a100.peak_cops / a100.peak_flops) == 16


def test_table2_cop_accounting():
    """Appendix A.5: Glove C=4, Sift C=6."""
    glove = KNN_WORKLOADS["glove1.2m"]
    sift = KNN_WORKLOADS["sift1m"]
    assert glove.cops_per_dot == 4
    assert sift.cops_per_dot == 6
    assert cops_per_dot(l2=True, non_pow2_n=True, broadcast_norm=True) == 6


def test_table2_icop_values():
    """I_COP = 2D/C: 64.0 for Glove (D=128 padded), 42.7 for Sift."""
    glove = KNN_WORKLOADS["glove1.2m"]
    sift = KNN_WORKLOADS["sift1m"]
    assert 2 * glove.d_padded / glove.cops_per_dot == pytest.approx(64.0)
    assert 2 * sift.d_padded / sift.cops_per_dot == pytest.approx(42.67, abs=0.01)


def test_fig2_regression_prediction():
    """The refined model (Eq. 6) predicts the paper's Fig. 2 result:
    Sift/L2 hits the COP wall on TPU v4 but not TPU v3."""
    v3, v4 = HARDWARE["tpu_v3"], HARDWARE["tpu_v4"]
    sift = KNN_WORKLOADS["sift1m"]
    cost = partial_reduce_cost(
        sift.m, sift.n, sift.d_padded, 256, cops_per_dot=sift.cops_per_dot
    )
    # v4: instruction-bound (attainable < pi); v3: compute-bound.
    assert bottleneck(cost, v4) == "instruction"
    assert attainable_flops(cost, v4) < 0.8 * v4.peak_flops
    assert bottleneck(cost, v3) == "compute"
    assert attainable_flops(cost, v3) == pytest.approx(v3.peak_flops)
    # measured numbers from Table 2 are consistent: 172 TFLOP/s < 274 peak
    assert attainable_flops(cost, v4) == pytest.approx(
        v4.peak_cops * (2 * sift.d_padded / sift.cops_per_dot), rel=0.01
    )


def test_eq20_memory_intensity():
    """I_MEM ~ min(M, N) when L << M,N and ib large (Eq. 10/20).

    The paper's profiler reports I_MEM ~ 4700: the full 10k-query block stays
    VMEM-resident (ib = M), so the database streams once."""
    cost = partial_reduce_cost(10_000, 1_000_000, 128, 256, block_rows=10_000)
    assert 3_000 < cost.i_mem < 7_000  # paper: 4758 (Glove) / 4701 (Sift)
    # a small ib pays M/ib database re-reads and lands near D/2 territory
    small = partial_reduce_cost(10_000, 1_000_000, 128, 256, block_rows=512)
    assert small.i_mem < cost.i_mem / 5


def test_level3_blas_wall():
    """Unfused scoring (write all M*N distances) is memory-bound (Remark 1)."""
    m, n, d = 10_000, 1_000_000, 128
    unfused = KernelCost(
        flops=2.0 * m * n * d, hbm_bytes=4.0 * (m * d + n * d + m * n),
        cops=m * n,
    )
    assert unfused.i_mem == pytest.approx(d / 2, rel=0.3)
    for hw in ("tpu_v3", "tpu_v4", "tpu_v5e"):
        assert bottleneck(unfused, HARDWARE[hw]) in ("memory", "instruction")


def test_fused_kernel_reaches_peak_on_mips():
    """Our v5e target: MIPS C=3 (+1 masking) stays compute-bound."""
    hw = HARDWARE["tpu_v5e"]
    cost = partial_reduce_cost(10_000, 1_000_000, 128, 256, cops_per_dot=4)
    assert bottleneck(cost, hw) == "compute"
    assert attainable_flops(cost, hw) == pytest.approx(hw.peak_flops)
