"""End-to-end system tests: train-loss-decreases, checkpoint-restart parity,
and (fast) dry-run machinery on the host mesh."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import SyntheticTokenSource
from repro.models import model as M


def _jnp_batch(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_train_loss_decreases():
    cfg = get_config("internlm2-1.8b-smoke")
    src = SyntheticTokenSource(cfg.vocab_size, 32, 8, seed=0)
    state = M.init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(M.make_train_step(cfg, learning_rate=3e-3))
    losses = []
    for i in range(30):
        state, metrics = step(state, _jnp_batch(src.batch(i % 4)))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[:3] + losses[-3:]
    assert all(np.isfinite(losses))


def test_checkpoint_restart_exact_resume(tmp_path):
    """Kill-and-restart reproduces the exact same training trajectory."""
    cfg = get_config("stablelm-1.6b-smoke")
    src = SyntheticTokenSource(cfg.vocab_size, 16, 4, seed=1)
    step = jax.jit(M.make_train_step(cfg, learning_rate=1e-3))

    state = M.init_train_state(jax.random.PRNGKey(0), cfg)
    for i in range(3):
        state, _ = step(state, _jnp_batch(src.batch(i)))
    save_checkpoint(str(tmp_path), 3, state)
    ref = state
    for i in range(3, 5):
        ref, m_ref = step(ref, _jnp_batch(src.batch(i)))

    like = jax.eval_shape(lambda: M.init_train_state(jax.random.PRNGKey(0), cfg))
    restored, at = restore_checkpoint(str(tmp_path), like)
    restored = jax.tree.map(jnp.asarray, restored)
    assert at == 3
    re = M.TrainState(*restored)
    for i in range(3, 5):
        re, m_re = step(re, _jnp_batch(src.batch(i)))
    np.testing.assert_allclose(
        float(m_ref["loss"]), float(m_re["loss"]), rtol=1e-5
    )


def test_grad_compression_variant_close():
    """bf16 gradient compression changes the loss trajectory only slightly."""
    cfg = get_config("internlm2-1.8b-smoke")
    src = SyntheticTokenSource(cfg.vocab_size, 16, 4, seed=2)
    s1 = M.init_train_state(jax.random.PRNGKey(0), cfg)
    s2 = M.init_train_state(jax.random.PRNGKey(0), cfg)
    f1 = jax.jit(M.make_train_step(cfg, learning_rate=1e-3))
    f2 = jax.jit(M.make_train_step(cfg, learning_rate=1e-3, grad_dtype="bfloat16"))
    for i in range(5):
        b = _jnp_batch(src.batch(i))
        s1, m1 = f1(s1, b)
        s2, m2 = f2(s2, b)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 0.05


def test_input_specs_cover_all_cells():
    """input_specs produces ShapeDtypeStructs (no allocation) for all cells."""
    from repro.configs import ASSIGNED_ARCHS, SHAPES

    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            spec = M.input_specs(cfg, shape)
            leaves = [l for l in jax.tree.leaves(spec) if l is not None]
            assert leaves, (arch, shape.name)
            for leaf in leaves:
                assert isinstance(leaf, jax.ShapeDtypeStruct), (arch, shape.name)


@pytest.mark.slow
def test_dryrun_cli_single_cell(tmp_path):
    """The dry-run CLI lowers+compiles a full-size cell in a subprocess."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "stablelm-1.6b", "--shape", "train_4k",
         "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    import json

    res = json.load(open(tmp_path / "stablelm-1.6b_train_4k_single.json"))
    assert res["hlo_flops"] > 0
    assert res["roofline"]["dominant"] in (
        "compute", "memory", "collective", "instruction"
    )
