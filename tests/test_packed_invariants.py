"""Property tests for the packed-state invariants (satellite of PR 4).

Three families of invariants the packed search state
(``repro.search.packed``) relies on but only spot-checked until now:

  * **Metric consistency** — for every ``rowwise`` metric,
    ``prepare_database`` restricted to a slice equals ``prepare_update``
    of that slice (db rows AND bias), for arbitrary slices; this is the
    exact property ``Index.add`` exploits to prepare only appended rows.
  * **Fused bias-row correctness** — after an *arbitrary interleaving* of
    ``add`` / ``delete`` (with duplicate ids, growth events, deletes of
    not-yet-compacted rows), the packed bias row and db rows are equal to
    a reference rebuilt from scratch with ``fuse_bias`` over the raw
    database and live mask.
  * **Tail-mask containment** — the pallas layout pads N up to the tile
    contract; padded (and tombstoned) rows must never surface in top-k,
    even when k presses against the live row count.

Runs under Hypothesis when it is installed (the repo's property-test
convention, cf. ``tests/test_binning.py``); in environments without it the
suite falls back to a fixed, deterministically-sampled example grid over
the same strategies, so these invariants keep CI coverage instead of
skipping (the container image has no ``hypothesis``).
"""
import itertools
import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.search import Index, SearchSpec, fuse_bias, get_metric
from repro.search.packed import pack_state

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # deterministic fallback, see module docstring
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, values):
            self.values = list(values)

    class st:  # noqa: N801 — mirrors the hypothesis namespace
        @staticmethod
        def integers(min_value, max_value):
            span = max_value - min_value
            picks = sorted({
                min_value,
                max_value,
                min_value + span // 2,
                min_value + span // 3,
                min_value + (2 * span) // 3,
            })
            return _Strategy(picks)

        @staticmethod
        def sampled_from(seq):
            return _Strategy(seq)

    def settings(**_kwargs):
        def deco(fn):
            return fn

        return deco

    def given(**strategies):
        """Run the property over a fixed sample of the strategy product.

        A deterministic ``random.Random`` picks (at most) 16 combinations,
        always including the all-minimum and all-maximum corners.
        """

        def deco(fn):
            names = list(strategies)
            pools = [strategies[n].values for n in names]

            # NOT functools.wraps: pytest must see a zero-argument
            # signature, or it mistakes the strategy params for fixtures.
            def wrapper():
                combos = list(itertools.product(*pools))
                corners = [combos[0], combos[-1]]
                rnd = random.Random(0xC0FFEE)
                body = (
                    rnd.sample(combos, 8) if len(combos) > 8 else combos
                )
                seen = set()
                for combo in corners + body:
                    if combo in seen:
                        continue
                    seen.add(combo)
                    fn(**dict(zip(names, combo)))

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco


METRICS = ("mips", "l2", "cosine")
D = 16


def _db(seed: int, n: int, d: int = D) -> jnp.ndarray:
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d))


# --- Metric.prepare / prepare_update / rowwise consistency -------------------


@settings(max_examples=25, deadline=None)
@given(
    metric=st.sampled_from(METRICS),
    seed=st.integers(min_value=0, max_value=2**16),
    n=st.integers(min_value=2, max_value=96),
    cut_num=st.integers(min_value=0, max_value=7),
)
def test_prepare_update_matches_full_prepare_on_any_slice(
    metric, seed, n, cut_num
):
    """rowwise contract: prepare_database(db)[i:j] == prepare_update(db[i:j])
    for db rows and bias alike — the property Index.add builds on."""
    m = get_metric(metric)
    assert m.rowwise
    db = _db(seed, n)
    cut = (cut_num * n) // 8  # slice start anywhere in [0, n)
    full_rows, full_bias = m.prepare_database(db)
    part_rows, part_bias = m.prepare_update(db[cut:])
    np.testing.assert_allclose(
        np.asarray(full_rows[cut:]), np.asarray(part_rows), rtol=1e-6
    )
    if full_bias is None:
        assert part_bias is None
    else:
        np.testing.assert_allclose(
            np.asarray(full_bias[cut:]), np.asarray(part_bias), rtol=1e-6
        )


# --- fused bias row under arbitrary add/delete interleavings -----------------


def _apply_random_ops(index, pool, rng, n_ops):
    """Drive ``index`` with a random interleaving of add/delete; mirror the
    same ops on a host-side reference (db rows + live mask)."""
    ref_db = [np.asarray(r) for r in np.asarray(index._db[: index._size])]
    ref_live = [True] * index._size
    cursor = index._size
    for _ in range(n_ops):
        if rng.random() < 0.5 and cursor < pool.shape[0]:
            r = int(rng.integers(1, 5))
            rows = pool[cursor : cursor + r]
            if rows.shape[0] == 0:
                continue
            index.add(rows)
            ref_db.extend(np.asarray(rows))
            ref_live.extend([True] * rows.shape[0])
            cursor += rows.shape[0]
        else:
            # duplicate ids within a call and re-deletes across calls are
            # both legal; ids may also hit rows added moments ago
            ids = rng.integers(0, len(ref_db), size=int(rng.integers(1, 4)))
            index.delete(ids.tolist())
            for i in ids:
                ref_live[int(i)] = False
    return np.stack(ref_db), np.asarray(ref_live)


@settings(max_examples=15, deadline=None)
@given(
    metric=st.sampled_from(METRICS),
    backend=st.sampled_from(("xla", "pallas")),
    seed=st.integers(min_value=0, max_value=2**16),
    n_ops=st.integers(min_value=1, max_value=12),
)
def test_bias_row_matches_reference_under_interleaving(
    metric, backend, seed, n_ops
):
    """After ANY interleaving of add/delete (growth included), the packed
    state equals a from-scratch reference pack of the same rows + live
    mask: incremental patches never drift."""
    rng = np.random.default_rng(seed)
    pool = _db(seed, 160)
    n0 = int(rng.integers(8, 48))
    index = Index.build(
        pool[:n0], metric=metric, k=4, backend=backend, capacity_block=32
    )
    ref_rows, ref_live = _apply_random_ops(index, pool, rng, n_ops)

    pk = index.pack()
    m = get_metric(metric)
    prepped, metric_bias = m.prepare_database(jnp.asarray(ref_rows))
    want_bias = np.asarray(
        fuse_bias(
            metric_bias,
            jnp.asarray(ref_live),
            num_rows=ref_rows.shape[0],
        )
    )
    n_written = ref_rows.shape[0]
    np.testing.assert_allclose(
        np.asarray(pk.rows()[:n_written]), np.asarray(prepped), rtol=1e-6
    )
    np.testing.assert_array_equal(
        np.asarray(pk.bias_row()[:n_written]), want_bias
    )
    # everything past the append high-water mark is dead capacity
    from repro.search.backends import MASK_VALUE

    tail = np.asarray(pk.bias_row()[n_written:])
    assert (tail == MASK_VALUE).all()
    # and the index agrees with the reference live count
    assert index.size == int(ref_live.sum())


# --- tail mask never leaks padded rows into top-k ----------------------------


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    n=st.integers(min_value=33, max_value=203),
    k=st.integers(min_value=1, max_value=16),
    n_delete=st.integers(min_value=0, max_value=24),
)
def test_tail_mask_never_leaks_padded_rows(seed, n, k, n_delete):
    """Pallas layout: N is padded up to the kernel tile contract and rows
    may be tombstoned — no padded or deleted row index may ever appear in
    top-k, even with k pressing against the live count."""
    k = min(k, max(1, n - n_delete - 1))
    db = _db(seed, n)
    index = Index.build(db, metric="mips", k=k, backend="pallas")
    rng = np.random.default_rng(seed)
    dead = (
        np.unique(rng.integers(0, n, size=n_delete)) if n_delete else
        np.asarray([], np.int64)
    )
    if dead.size:
        index.delete(dead.tolist())
    q = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, D))
    _, idxs = index.search(q)
    got = np.asarray(idxs)
    assert got.min() >= 0
    assert got.max() < n, (
        f"padded row index {got.max()} >= n={n} leaked into top-k"
    )
    assert not (set(got.ravel().tolist()) & set(dead.tolist())), (
        "tombstoned row leaked into top-k"
    )


# --- quantized tiers: incremental patches == from-scratch pack ---------------


@settings(max_examples=15, deadline=None)
@given(
    metric=st.sampled_from(METRICS),
    storage=st.sampled_from(("bf16", "int8")),
    seed=st.integers(min_value=0, max_value=2**16),
    n_ops=st.integers(min_value=1, max_value=10),
)
def test_quantized_state_matches_reference_under_interleaving(
    metric, storage, seed, n_ops
):
    """quantize -> prepare_update consistency: after ANY add/delete
    interleaving (growth included) the quantized rows, int8 scales, scan
    bias (with its stored-value bias correction) and f32 rescore tail all
    equal a from-scratch ``pack_state`` of the same rows + live mask."""
    from repro.search.packed import pack_state
    from repro.search.spec import SearchSpec

    rng = np.random.default_rng(seed)
    pool = _db(seed, 160)
    n0 = int(rng.integers(8, 48))
    index = Index.build(
        pool[:n0], metric=metric, k=4, backend="xla", storage=storage,
        capacity_block=32,
    )
    ref_rows, ref_live = _apply_random_ops(index, pool, rng, n_ops)

    pk = index.pack()
    n_written = ref_rows.shape[0]
    cap = index.capacity
    ref_padded = jnp.zeros((cap, D)).at[:n_written].set(ref_rows)
    ref_live_padded = (
        jnp.zeros((cap,), bool).at[:n_written].set(jnp.asarray(ref_live))
    )
    want = pack_state(
        ref_padded, ref_live_padded, get_metric(metric), index.spec, "xla"
    )
    np.testing.assert_array_equal(np.asarray(pk.db), np.asarray(want.db))
    np.testing.assert_array_equal(
        np.asarray(pk.bias), np.asarray(want.bias)
    )
    if storage == "int8":
        # dead capacity past the high-water mark is bias-masked, so its
        # scale is arbitrary (growth pads 0, a fresh pack floors it) —
        # the written region must agree exactly.
        np.testing.assert_array_equal(
            np.asarray(pk.scale_row()[:n_written]),
            np.asarray(want.scale_row()[:n_written]),
        )
    np.testing.assert_allclose(
        np.asarray(pk.rescore_db), np.asarray(want.rescore_db), rtol=1e-6
    )
    np.testing.assert_array_equal(
        np.asarray(pk.rescore_bias), np.asarray(want.rescore_bias)
    )
    assert index.size == int(ref_live.sum())


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    n=st.integers(min_value=33, max_value=203),
    k=st.integers(min_value=1, max_value=16),
    n_delete=st.integers(min_value=0, max_value=24),
)
def test_rescore_tail_never_leaks_tombstoned_rows(seed, n, k, n_delete):
    """The exact rescore pass recomputes true scores from the f32 tail —
    without its own tombstone mask it would resurrect deleted rows with
    *winning* scores.  Same adversarial grid as the f32 tail-mask test,
    on the quantized pallas layout."""
    k = min(k, max(1, n - n_delete - 1))
    db = _db(seed, n)
    index = Index.build(db, metric="mips", k=k, backend="pallas",
                        storage="int8")
    rng = np.random.default_rng(seed)
    dead = (
        np.unique(rng.integers(0, n, size=n_delete)) if n_delete else
        np.asarray([], np.int64)
    )
    if dead.size:
        index.delete(dead.tolist())
    q = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, D))
    _, idxs = index.search(q)
    got = np.asarray(idxs)
    assert got.min() >= 0
    assert got.max() < n, (
        f"padded row index {got.max()} >= n={n} leaked into quantized top-k"
    )
    assert not (set(got.ravel().tolist()) & set(dead.tolist())), (
        "tombstoned row resurrected by the rescore tail"
    )


# --- result indices: unique, live, sentinel-masked (PR 9) --------------------


@settings(max_examples=15, deadline=None)
@given(
    backend=st.sampled_from(("xla", "pallas")),
    storage=st.sampled_from(("f32", "bf16", "int8", "int4")),
    seed=st.integers(min_value=0, max_value=2**16),
    density_pct=st.integers(min_value=0, max_value=90),
)
def test_result_indices_unique_and_live(backend, storage, seed, density_pct):
    """Across backend × storage (int4 included) × add/delete interleavings
    × tombstone densities up to 90 %: every returned index with a real
    (non-masked) score is unique within its row, in range, and live; on
    the pallas path a masked entry carries the sentinel index -1 (never a
    phantom alias of a real row — the masked-winner clamp bug)."""
    from repro.search.backends import MASK_VALUE

    rng = np.random.default_rng(seed)
    pool = _db(seed, 160)
    n0 = int(rng.integers(40, 96))
    index = Index.build(
        pool[:n0], metric="mips", k=8, backend=backend, storage=storage,
        capacity_block=32,
    )
    _, ref_live = _apply_random_ops(index, pool, rng, int(rng.integers(1, 6)))
    n_written = ref_live.shape[0]
    target_dead = (n_written * density_pct) // 100
    extra = [i for i in range(n_written) if ref_live[i]][: target_dead]
    if extra:
        index.delete(extra)
        ref_live[np.asarray(extra)] = False
    live_ids = set(np.flatnonzero(ref_live).tolist())
    q = jax.random.normal(jax.random.PRNGKey(seed + 3), (6, D))
    vals, idxs = index.search(q)
    vals, idxs = np.asarray(vals), np.asarray(idxs)
    for row_v, row_i in zip(vals, idxs):
        real = row_i[np.abs(row_v) < -MASK_VALUE * 0.5]
        assert len(set(real.tolist())) == len(real), f"duplicates: {row_i}"
        assert all(int(i) in live_ids for i in real), (
            f"dead/padded row surfaced: {row_i}"
        )
        masked = row_i[np.abs(row_v) >= -MASK_VALUE * 0.5]
        if backend == "pallas":
            assert (masked == -1).all(), (
                f"pallas masked winners must be -1, got {masked}"
            )


def test_quantized_mass_delete_returns_only_sentinels():
    db = _db(11, 40)
    index = Index.build(db, metric="l2", k=4, backend="xla", storage="int8")
    index.delete(list(range(40)))
    assert index.size == 0
    vals, idxs = index.search(
        jax.random.normal(jax.random.PRNGKey(9), (4, D))
    )
    from repro.search.backends import MASK_VALUE

    # L2 negates at the boundary: masked scores surface as -MASK_VALUE
    assert (np.asarray(vals) >= -MASK_VALUE).all()
    assert int(np.asarray(idxs).max()) < 40


def test_fallback_grid_is_active_without_hypothesis():
    """Make the fallback visible in test output: exactly one of the two
    modes is in effect, and the strategies sample real values either way."""
    s = st.integers(min_value=0, max_value=10)
    if HAVE_HYPOTHESIS:
        # a real hypothesis strategy, not our shim
        assert type(s).__module__.startswith("hypothesis")
        assert not hasattr(s, "values")
    else:
        assert s.values[0] == 0 and s.values[-1] == 10


# direct (non-property) regression pins for corners the sampling above
# might visit rarely: growth exactly at the capacity boundary, and a
# delete-everything index.


def test_growth_boundary_keeps_bias_reference():
    pool = _db(3, 80)
    index = Index.build(pool[:32], metric="l2", k=4, backend="xla",
                        capacity_block=32)
    index.add(pool[32:64])   # fills capacity exactly
    index.add(pool[64:65])   # forces growth by one block
    pk = index.pack()
    m = get_metric("l2")
    prepped, bias = m.prepare_database(pool[:65])
    np.testing.assert_allclose(
        np.asarray(pk.rows()[:65]), np.asarray(prepped), rtol=1e-6
    )
    np.testing.assert_array_equal(
        np.asarray(pk.bias_row()[:65]),
        np.asarray(fuse_bias(bias, jnp.ones((65,), bool))),
    )


def test_all_rows_deleted_returns_only_sentinels():
    db = _db(5, 40)
    index = Index.build(db, metric="mips", k=4, backend="pallas")
    index.delete(list(range(40)))
    assert index.size == 0
    vals, idxs = index.search(jax.random.normal(jax.random.PRNGKey(9), (4, D)))
    from repro.search.backends import MASK_VALUE

    assert (np.asarray(vals) <= MASK_VALUE).all()
    assert int(np.asarray(idxs).max()) < 40


# --- snapshot restore-then-serve bit-parity (PR 7) ---------------------------
#
# ``Index.save`` / ``Index.restore`` must reproduce the packed state well
# enough that a restored replica returns BIT-identical results — across
# every backend x storage-tier x cluster combination — without re-running
# build / k-means / quantization (asserted via ``PACK_EVENTS``).

import os  # noqa: E402  (section-local import, mirrors the PR-7 tests)

import pytest  # noqa: E402

from repro.search.packed import PACK_EVENTS, reset_pack_events  # noqa: E402


def _restore_parity(index, queries, tmp_path, *, mesh_axis=None):
    direct = index.search(queries)
    path = os.path.join(tmp_path, "snap")
    index.save(path)
    reset_pack_events()
    restored = Index.restore(path)
    if mesh_axis is not None:  # snapshots land unmeshed; re-shard explicitly
        restored = restored.shard(jax.make_mesh((1,), (mesh_axis,)),
                                  db_axis=mesh_axis)
    got = restored.search(queries)
    np.testing.assert_array_equal(
        np.asarray(got.indices), np.asarray(direct.indices)
    )
    np.testing.assert_array_equal(
        np.asarray(got.values), np.asarray(direct.values)
    )
    assert PACK_EVENTS["restore"] == 1
    assert PACK_EVENTS["full_pack"] == 0, (
        f"restore re-ran a packing pass: {dict(PACK_EVENTS)}"
    )
    return restored


@pytest.mark.parametrize("storage", ["f32", "bf16", "int8", "int4"])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_restore_parity_backend_x_storage(backend, storage, tmp_path):
    db = _db(11, 512)
    index = Index.build(db, metric="l2", k=8, backend=backend,
                        storage=storage)
    q = jax.random.normal(jax.random.PRNGKey(12), (16, D))
    _restore_parity(index, q, tmp_path)


@pytest.mark.parametrize("storage", ["f32", "bf16", "int8"])
def test_restore_parity_clustered(storage, tmp_path):
    # the mixture corpus is the regime where cluster="auto" actually
    # enables pruning (cf. tests/test_cluster.py); restore must bring the
    # k-means tables back verbatim, never re-cluster
    rng = np.random.default_rng(13)
    centers = rng.normal(size=(64, D)) * 2.5
    db = jnp.asarray(
        centers[rng.integers(0, 64, 8192)] + rng.normal(size=(8192, D)),
        jnp.float32,
    )
    q = jnp.asarray(
        centers[rng.integers(0, 64, 16)] + rng.normal(size=(16, D)),
        jnp.float32,
    )
    index = Index.build(db, metric="l2", k=10, backend="xla",
                        storage=storage)
    assert index.explain()["cluster"]["enabled"]
    restored = _restore_parity(index, q, tmp_path)
    rep = restored.explain()["cluster"]
    assert rep["enabled"]  # the pruned path, not a silent dense fallback
    assert PACK_EVENTS["cluster_built"] == 0, dict(PACK_EVENTS)


def test_restore_parity_sharded_single_device(tmp_path):
    mesh = jax.make_mesh((1,), ("model",))
    db = _db(14, 512)
    index = Index.build(db, metric="mips", k=8).shard(mesh, db_axis="model")
    q = jax.random.normal(jax.random.PRNGKey(15), (8, D))
    _restore_parity(index, q, tmp_path, mesh_axis="model")


def test_restore_parity_sharded_2d(tmp_path):
    """A 2-D (query x database) sharded index snapshots its full logical
    arrays; restore lands unmeshed and re-sharding onto a 2-D mesh brings
    back bit-identical results with no re-pack."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    db = _db(16, 512)
    index = Index.build(db, metric="l2", k=8).shard(
        mesh, db_axis=("data", "model")
    )
    q = jax.random.normal(jax.random.PRNGKey(17), (8, D))
    direct = index.search(q)
    path = os.path.join(tmp_path, "snap2d")
    index.save(path)
    reset_pack_events()
    restored = Index.restore(path).shard(
        jax.make_mesh((1, 1), ("data", "model")),
        db_axis=("data", "model"), batch_axis=None,
    )
    got = restored.search(q)
    np.testing.assert_array_equal(
        np.asarray(got.indices), np.asarray(direct.indices)
    )
    np.testing.assert_array_equal(
        np.asarray(got.values), np.asarray(direct.values)
    )
    assert PACK_EVENTS["restore"] == 1
    assert PACK_EVENTS["full_pack"] == 0, dict(PACK_EVENTS)


@pytest.mark.parametrize("storage", ["f32", "int8"])
def test_restore_parity_host_tier(storage, tmp_path):
    """A host-resident index restores bit-identically — residency and the
    planned segment schedule ride in the snapshot spec, so the restored
    replica streams the same waves without re-packing."""
    db = _db(18, 2048)
    index = Index.build(db, metric="l2", k=8, storage=storage,
                        residency="host", segment_rows=1024)
    q = jax.random.normal(jax.random.PRNGKey(19), (8, D))
    restored = _restore_parity(index, q, tmp_path)
    assert restored.spec.residency == "host"
    assert restored.spec.segment_rows == 1024
    assert restored.explain()["residency"]["num_segments"] == 2


# --- stage composition == the compiled search (PR 8) -------------------------
#
# The backends are assemblies of ``repro.search.stages`` primitives; the
# property below re-assembles the dense pipeline *eagerly* (no jit) from
# the live packed operands and demands bit-parity with ``Index.search``
# after arbitrary add/delete interleavings — i.e. stage composition
# commutes with the incremental-update machinery.


@settings(max_examples=10, deadline=None)
@given(
    metric=st.sampled_from(METRICS),
    storage=st.sampled_from(("f32", "int8")),
    seed=st.integers(min_value=0, max_value=2**16),
    n_ops=st.integers(min_value=1, max_value=10),
)
def test_stage_composition_matches_search_under_interleaving(
    metric, storage, seed, n_ops
):
    from repro.search import stages
    from repro.search.packed import scan_k_for

    rng = np.random.default_rng(seed)
    pool = _db(seed, 160)
    n0 = int(rng.integers(8, 48))
    index = Index.build(
        pool[:n0], metric=metric, k=4, backend="xla", storage=storage,
        capacity_block=32, cluster="off",
    )
    _apply_random_ops(index, pool, rng, n_ops)
    q = jax.random.normal(jax.random.PRNGKey(seed + 2), (6, D))
    want = index.search(q)

    pk = index.pack()
    spec = index.spec
    m = get_metric(metric)
    qp = m.prepare_queries(q)
    scores = stages.score_rows(qp, pk.db, pk.bias, pk.scale)
    if pk.rescore_db is not None:
        k_scan = scan_k_for(spec, pk.n)
        vals, idxs = stages.scan_candidates(
            scores, k_scan, recall_target=spec.recall_target,
            reduction_input_size_override=spec.reduction_input_size_override,
            aggregate_to_topk=False,
        )
        vals, idxs = stages.rescore_candidates(
            qp, vals, idxs, pk.rescore_db, pk.rescore_bias, spec.k, k_scan,
            spec.use_bitonic,
        )
    else:
        vals, idxs = stages.scan_candidates(
            scores, spec.k, recall_target=spec.recall_target,
            reduction_input_size_override=spec.reduction_input_size_override,
            aggregate_to_topk=True, use_bitonic=spec.use_bitonic,
        )
    vals = stages.finalize_values(vals, m.negate_output)
    np.testing.assert_array_equal(np.asarray(idxs), np.asarray(want.indices))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(want.values))
