"""Recall analytics (paper Eq. 13/14) — unit + hypothesis property tests."""
import math

import pytest

pytest.importorskip("hypothesis")  # property tests; module skips cleanly without
from hypothesis import given, settings, strategies as st

from repro.core.binning import (
    bins_for_recall,
    bins_for_recall_approx,
    expected_recall,
    plan_bins,
)


def test_expected_recall_k1_is_one():
    assert expected_recall(1, 1) == 1.0
    assert expected_recall(100, 1) == 1.0


def test_expected_recall_matches_formula():
    # Eq. 13: ((L-1)/L)^(K-1)
    assert expected_recall(100, 10) == pytest.approx((99 / 100) ** 9)
    assert expected_recall(2, 2) == pytest.approx(0.5)


def test_bins_for_recall_paper_example():
    # K=10, r=0.95: L >= 1/(1-0.95^(1/9)) ~= 176; approx (K-1)/(1-r) = 180.
    l = bins_for_recall(10, 0.95)
    assert 170 <= l <= 180
    assert abs(bins_for_recall_approx(10, 0.95) - 180) < 1e-9


@given(k=st.integers(2, 128), r=st.floats(0.5, 0.999))
@settings(max_examples=200, deadline=None)
def test_bins_meet_recall_target(k, r):
    """The chosen L always achieves E[recall] >= r (the paper's guarantee)."""
    l = bins_for_recall(k, r)
    assert expected_recall(l, k) >= r
    # And L-1 would not (minimality), modulo the k>=L floor.
    if l > 1:
        assert expected_recall(l - 1, k) < r or l == k


@given(k=st.integers(2, 64), r=st.floats(0.8, 0.99))
@settings(max_examples=100, deadline=None)
def test_approximation_is_upper_bound_region(k, r):
    """(K-1)/(1-r) approximates the exact bound within ~15% (Appendix A.4)."""
    exact = bins_for_recall(k, r)
    approx = bins_for_recall_approx(k, r)
    # ceil() on the exact bound can cost one extra bin at small k.
    assert approx >= 0.85 * exact - 1


@given(
    n=st.integers(100, 2_000_000),
    k=st.integers(1, 64),
    r=st.floats(0.6, 0.99),
)
@settings(max_examples=200, deadline=None)
def test_plan_bins_invariants(n, k, r):
    if k > n:
        return
    plan = plan_bins(n, k, r)
    assert plan.num_bins * plan.bin_size == plan.padded_n
    assert plan.padded_n >= n
    assert plan.num_bins >= min(k, n)
    assert plan.bin_size == 1 << plan.log2_bin_size
    # bins cover the input without >2x overshoot
    assert plan.padded_n < 2 * n + plan.bin_size


def test_plan_bins_sharded_accounting():
    """reduction_input_size_override spreads the global bin budget (§7)."""
    full = plan_bins(1_000_000, 10, 0.95)
    shard = plan_bins(1_000_000 // 8, 10, 0.95, reduction_input_size_override=1_000_000)
    # Each shard holds ~1/8th of the bins at the same bin size scale.
    assert shard.num_bins * 8 >= full.num_bins * 0.5
    assert shard.expected_recall >= 0.93


def test_plan_bins_degenerate_small_n():
    plan = plan_bins(16, 10, 0.95)
    assert plan.bin_size == 1  # falls back to exact layout
    assert plan.num_bins == 16


def test_plan_bins_rejects_bad_input():
    with pytest.raises(ValueError):
        plan_bins(10, 11, 0.95)
    with pytest.raises(ValueError):
        bins_for_recall(10, 1.5)
