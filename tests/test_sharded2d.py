"""Layout-parity grid: every distribution layout is bit-identical to the
replicated single-device oracle.

The load-bearing claim of the §7 distributed design is that sharding is
*invisible* in the results: per-row scores are computed by the same stage
primitives (``repro.search.stages``) in every layout, shard/segment bin
boundaries align with the oracle's, and only (value, global id) winners
cross the ICI — so in the high-recall regime the (values, indices) pairs
match the replicated oracle bit for bit.  This grid enforces exactly that
over layout x metric x storage, including tombstoned rows and the padded
tails sharding adds, on 8 (fast) / 16 / 48 (``@slow``) fake devices.

Clustered pruning is approximate per construction (bin collisions inside
the pruned candidate list depend on the ownership partition), so its grid
asserts the honest invariants instead: equal-shard-count layouts are
mutually bit-identical, and every layout meets the planner's analytic
recall floor.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.search import backends, hosttier
from repro.search.stages import MASK_VALUE

# (A, B) mesh factorization per grid size: A shards the query batch
# ("data"), B — or the (A, B) tuple — shards the database ("model").
_MESHES = {8: (2, 4), 16: (2, 8), 48: (6, 8)}

_GRID_CHILD = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.search import Index, backends

A, B = @A@, @B@
NDEV = A * B
N, D, M, K = 4999, 32, 24, 7
RT = 0.999  # high-recall regime: bin layouts align -> exact parity

rng = np.random.default_rng(7)
db = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
q = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
mesh1 = jax.make_mesh((NDEV,), ("model",))
mesh2 = jax.make_mesh((A, B), ("data", "model"))

CONFIGS = [("mips", "f32"), ("l2", "f32"), ("cosine", "f32"),
           ("l2", "bf16"), ("mips", "int8"), ("cosine", "int8")]
report = {}
for metric, storage in CONFIGS:
    oracle = Index.build(db, metric=metric, k=K, backend="xla",
                         recall_target=RT, storage=storage, cluster="off")
    _, oi0 = oracle.search(q)
    dead = np.unique(np.asarray(oi0)[:, 0])
    oracle.delete(dead)  # tombstones: each query loses its best row
    ov, oi = oracle.search(q)
    ov, oi = np.asarray(ov), np.asarray(oi)
    assert oi.max() < N, "oracle leaked a padded/tombstoned id"
    assert not set(oi.ravel().tolist()) & set(dead.tolist())

    layouts = {
        "sharded-1d": oracle.shard(mesh1, db_axis="model"),
        "sharded-2d": oracle.shard(mesh2, db_axis="model",
                                   batch_axis="data"),
        "sharded-2d-tuple": oracle.shard(mesh2, db_axis=("data", "model")),
    }
    # Host cold tier: built (not sharded) from the same rows, same
    # deletes; 2**18-byte budget forces the minimum 1024-row segment,
    # so N=4999 streams as 5 waves.
    host = Index.build(db, metric=metric, k=K, recall_target=RT,
                       storage=storage, cluster="off", residency="host",
                       hbm_budget_bytes=2 ** 18)
    host.delete(dead)
    waves = host.explain()["residency"]["num_segments"]
    assert waves >= 4, waves
    layouts["host"] = host

    for name, idx in layouts.items():
        before_sh = backends.DISPATCH_COUNTS["sharded"]
        before_host = backends.DISPATCH_COUNTS["host"]
        traces0 = backends.TRACE_COUNTS["host"]
        sv, si = idx.search(q)
        sv, si = np.asarray(sv), np.asarray(si)
        assert np.array_equal(ov, sv), (metric, storage, name, "values")
        assert np.array_equal(oi, si), (metric, storage, name, "indices")
        assert si.max() < N, (name, "padded-tail id leaked")
        if name == "host":
            assert backends.DISPATCH_COUNTS["host"] - before_host == waves
            # steady state: re-search retraces nothing
            traces1 = backends.TRACE_COUNTS["host"]
            idx.search(q)
            assert backends.TRACE_COUNTS["host"] == traces1, "host retrace"
        else:
            # one device dispatch per query batch, whatever the layout
            assert backends.DISPATCH_COUNTS["sharded"] - before_sh == 1
        report[(metric, storage, name)] = True
publish({"cases": report, "ndev": NDEV, "host_waves": waves})
"""

_CLUSTER_CHILD = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.search import Index, exact_search

A, B = @A@, @B@
NDEV = A * B
N, D, M, K = 8199, 32, 24, 7

rng = np.random.default_rng(0)
centers = rng.normal(size=(64, D)) * 2.5
db = jnp.asarray(centers[rng.integers(0, 64, N)]
                 + rng.normal(size=(N, D)), jnp.float32)
q = jnp.asarray(centers[rng.integers(0, 64, M)]
                + rng.normal(size=(M, D)), jnp.float32)
mesh1 = jax.make_mesh((NDEV,), ("model",))
mesh2 = jax.make_mesh((A, B), ("data", "model"))

oracle = Index.build(db, metric="l2", k=K, backend="xla",
                     recall_target=0.95, cluster="auto")
assert oracle._cluster_plan_in_effect() is not None, "crossover not hit"
results = {
    "sharded-1d": oracle.shard(mesh1, db_axis="model").search(q),
    "sharded-2d": oracle.shard(mesh2, db_axis="model",
                               batch_axis="data").search(q),
    "sharded-2d-tuple":
        oracle.shard(mesh2, db_axis=("data", "model")).search(q),
}
# Equal shard counts => identical ownership partition => bit-identical.
a, b = results["sharded-1d"], results["sharded-2d-tuple"]
assert np.array_equal(np.asarray(a.values), np.asarray(b.values))
assert np.array_equal(np.asarray(a.indices), np.asarray(b.indices))
# Every layout meets the analytic recall floor against the exact scan.
_, exact = exact_search(q, db, K, metric="l2")
floors = {}
for name, res in results.items():
    rec = float(np.mean(
        [len(set(r.tolist()) & set(t.tolist())) / K
         for r, t in zip(np.asarray(res.indices), np.asarray(exact))]
    ))
    assert rec >= oracle.expected_recall - 0.07, (name, rec)
    floors[name] = rec
publish({"recalls": floors, "expected": oracle.expected_recall})
"""


def _fill(template: str, n: int) -> str:
    a, b = _MESHES[n]
    return template.replace("@A@", str(a)).replace("@B@", str(b))


def test_layout_parity_grid(fake_devices, device_grid):
    """1-D, 2-D, 2-D-tuple and host-tiered searches return bit-identical
    (values, indices) — global user-space ids — to the replicated oracle,
    across metric x storage, with tombstoned and padded-tail rows."""
    res = fake_devices(_fill(_GRID_CHILD, device_grid), n=device_grid)
    assert res["ndev"] == device_grid
    assert res["host_waves"] >= 4
    assert len(res["cases"]) == 6 * 4 and all(res["cases"].values())


def test_clustered_layout_invariants(fake_devices, device_grid):
    """Cluster-pruned sharded layouts: equal shard counts bit-match each
    other; all meet the planner's recall floor (pruning is approximate,
    so cross-shard-count bit-parity is not a claim the design makes)."""
    res = fake_devices(_fill(_CLUSTER_CHILD, device_grid), n=device_grid)
    assert set(res["recalls"]) == {
        "sharded-1d", "sharded-2d", "sharded-2d-tuple"
    }


def test_wave_program_jaxpr_single_scan():
    """The host-tier wave program lowers to exactly one (M, seg) scan
    matmul per wave — the jaxpr half of the one-dispatch/zero-retrace
    steady-state contract (the counter half lives in the parity grid)."""
    m, seg, d, k = 8, 1024, 32, 5
    jaxpr = jax.make_jaxpr(
        lambda q, db, b, off, cv, ci: hosttier.wave_program(
            q, db, b, None, None, None, off, cv, ci,
            metric="l2", k=k, k_scan=k, recall_target=0.999,
            global_n=4 * seg, rescore=False, is_last=False,
            use_bitonic=False,
        )
    )(
        jnp.zeros((m, d)), jnp.zeros((seg, d)), jnp.zeros((seg,)),
        jnp.int32(0), jnp.full((m, k), MASK_VALUE), jnp.zeros((m, k),
                                                              jnp.int32),
    )
    def count_dots(jx):
        n = sum(e.primitive.name == "dot_general" for e in jx.eqns)
        for e in jx.eqns:
            for p in e.params.values():
                if hasattr(p, "jaxpr"):  # nested (pjit/closed-call) jaxprs
                    n += count_dots(p.jaxpr)
        return n

    dots = count_dots(jaxpr.jaxpr)
    assert dots == 1, f"expected 1 scan matmul, got {dots}"


def test_host_tier_occupancy_reports_live_fraction():
    """Segment-wave occupancy (benchmark observability): tombstoning a
    whole segment's rows drops that wave's live fraction to zero while
    the schedule shape — and thus the compiled program — is unchanged."""
    from repro.search import Index

    rng = np.random.default_rng(3)
    db = jnp.asarray(rng.normal(size=(2048, 16)), jnp.float32)
    idx = Index.build(db, metric="mips", k=3, residency="host",
                      segment_rows=1024)
    searcher = idx._build_host_searcher()
    occ = searcher.occupancy(idx.pack())
    assert occ == [1.0, 1.0]
    idx.delete(np.arange(1024))
    occ = searcher.occupancy(idx.pack())
    assert occ[0] == 0.0 and occ[1] == 1.0
