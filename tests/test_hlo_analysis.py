"""HLO analysis layer: trip-count-aware flop/byte walk + collective parser."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import collective_bytes, op_census
from repro.analysis.hlo_cost import analyze_hlo


def test_scan_trip_counts_multiply_flops():
    def f(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(f).lower(w, x).compile()
    cost = analyze_hlo(c.as_text())
    assert cost.dot_flops == 2 * 256**3 * 10
    assert 10 in cost.while_trips.values()


def test_batched_dot_flops_exact():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 256, 64), jnp.float32)
    c = jax.jit(f).lower(a, b).compile()
    assert analyze_hlo(c.as_text()).dot_flops == 2 * 4 * 128 * 256 * 64


def test_nested_scan_multiplies():
    def f(x):
        def outer(h, _):
            def inner(g, _):
                return g @ g, None
            g, _ = jax.lax.scan(inner, h, None, length=3)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    assert analyze_hlo(c.as_text()).dot_flops == 2 * 64**3 * 15


def test_memory_bounds_ordering():
    def f(x, w):
        return jax.nn.relu(x @ w).sum()

    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    cost = analyze_hlo(c.as_text())
    assert cost.hbm_bytes_lo <= cost.hbm_bytes <= cost.hbm_bytes_hi
    # at minimum the two operand reads happen
    assert cost.hbm_bytes_lo >= 2 * 512 * 512 * 4


def test_collective_parser_synthetic():
    hlo = """
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ar = f32[16,16]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
  %ag = f32[64,16]{1,0} all-gather(%ar), dimensions={0}
  ROOT %cp = f32[64,16]{1,0} collective-permute(%ag), source_target_pairs={{0,1}}
}
"""
    total, kinds = collective_bytes(hlo)
    f16x16 = 16 * 16 * 4
    f64x16 = 64 * 16 * 4
    assert kinds["all-reduce"] == 2 * f16x16
    assert kinds["all-gather"] == f64x16
    assert kinds["collective-permute"] == f64x16
    assert total == 2 * f16x16 + 2 * f64x16


def test_op_census_counts():
    hlo = """
ENTRY %m (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %a = f32[4]{0} add(%p, %p)
  ROOT %b = f32[4]{0} multiply(%a, %a)
}
"""
    census = op_census(hlo)
    assert census.get("add") == 1
    assert census.get("multiply") == 1
