"""Statistical validation of the paper's recall-in-expectation guarantee.

The headline analytical claim (Eq. 13–14, §5.1) is that the bin layout the
planner derives — L bins, top-1 kept per bin — achieves
``E[recall] = ((L-1)/L)^(K-1) >= recall_target`` *in expectation* over the
random placement of the true top-K entries.  Until now that equation only
*configured* the kernels; nothing checked that searches actually deliver
it.  This suite closes the loop empirically:

  * many independent (database, queries) draws per configuration, fixed
    seeds — the run is bit-reproducible;
  * empirical mean recall against the exact baseline is compared with the
    target minus a concentration margin: per-query recall lies in [0, 1],
    so by Hoeffding the probability that the empirical mean of n samples
    falls ``eps = sqrt(ln(1/delta) / 2n)`` below its expectation is at
    most ``delta`` (we use delta = 1e-6; samples within one trial share a
    database, but for i.i.d. Gaussian data that coupling is negligible —
    and with fixed seeds the test is deterministic anyway: the margin
    calibrates "fail only on a real regression", it is not re-rolled luck);
  * the sweep covers metric x backend x (k, recall_target) corners, all
    under the planner's default ``plan="model"`` configuration — the same
    path production ``Index.build`` takes.

A failure therefore means one of: the bin layout no longer matches Eq. 14,
PartialReduce drops more than the model allows (e.g. a masking bug), or
rescoring corrupts the candidate set — all real regressions, not noise.
"""
import math

import jax
import numpy as np
import pytest

from repro.search import Index, exact_search

N = 2048
D = 24
DELTA = 1e-6  # false-failure probability budget for the Hoeffding margin


def _hoeffding_eps(n_samples: int, delta: float = DELTA) -> float:
    """One-sided deviation eps with P(mean < E[mean] - eps) <= delta for n
    independent samples bounded in [0, 1] (binomial/Hoeffding bound)."""
    return math.sqrt(math.log(1.0 / delta) / (2.0 * n_samples))


def _gaussian_draw(kd, kq, n, m, d):
    """The default i.i.d. Gaussian (db, queries) draw."""
    return jax.random.normal(kd, (n, d)), jax.random.normal(kq, (m, d))


def _mixture_draw(kd, kq, n, m, d, components=64, sep=2.5):
    """Mixture-of-Gaussians (db, queries) draw — queries from the SAME
    component centers as the database.

    This is the regime the cluster-pruned front-end's miss bound models
    (``repro.search.cluster``): neighbour mass concentrated in a few
    clusters, so pruned probing finds it.  On i.i.d. Gaussian data every
    point is nearly equidistant and NO coarse quantizer can prune without
    large misses — that is a property of the data, not a code bug, which
    is why the cluster corners below use this draw instead of reusing
    ``_gaussian_draw``.
    """
    kc, ka, kn = jax.random.split(kd, 3)
    centers = jax.random.normal(kc, (components, d)) * sep
    assign = jax.random.randint(ka, (n,), 0, components)
    db = centers[assign] + jax.random.normal(kn, (n, d))
    kqa, kqn = jax.random.split(kq)
    qassign = jax.random.randint(kqa, (m,), 0, components)
    q = centers[qassign] + jax.random.normal(kqn, (m, d))
    return db, q


def _recall_samples(metric, backend, k, recall_target, *, trials, m, seed=0,
                    storage="f32", cluster="auto", n=N, d=D,
                    draw=_gaussian_draw):
    """Per-query recall samples over ``trials`` fresh (db, queries) draws.

    Returns (samples, expected_recall) where ``expected_recall`` is the
    planner's analytic Eq. 13 value for the layout it chose (for quantized
    ``storage`` tiers: the over-fetched ``((L-1)/L)^(K'-1)`` bound the
    two-pass guarantee rests on; for a cluster-pruned index: the product
    P(no bin collision) x P(no cluster miss)).
    """
    samples = []
    expected = None
    root = jax.random.PRNGKey(seed)
    for t in range(trials):
        kd, kq = jax.random.split(jax.random.fold_in(root, t))
        db, q = draw(kd, kq, n, m, d)
        index = Index.build(
            db, metric=metric, k=k, recall_target=recall_target,
            backend=backend, storage=storage, cluster=cluster,
        )
        assert index.kernel_plan.source == "model"  # the default config
        # Eq. 14: the planner's layout must meet the target analytically.
        assert index.expected_recall >= recall_target
        expected = index.expected_recall
        _, idxs = index.search(q)
        _, exact = exact_search(q, db, k, metric=metric)
        approx = np.asarray(idxs)
        truth = np.asarray(exact)
        for row in range(m):
            hits = len(set(approx[row].tolist()) & set(truth[row].tolist()))
            samples.append(hits / k)
    return np.asarray(samples), expected


# (metric, backend, k, recall_target) corners: every metric, both
# single-device backends, k from "a few" to "many", targets from loose to
# near the guarantee's ceiling.  The pallas entries run the fused kernel in
# interpret mode on CPU, so they use a smaller sample budget.
FAST_CORNERS = [
    ("mips", "xla", 10, 0.95, 6, 256),
    ("l2", "xla", 32, 0.90, 6, 256),
    ("cosine", "xla", 4, 0.99, 6, 256),
    ("mips", "pallas", 8, 0.90, 3, 128),
    ("l2", "pallas", 16, 0.95, 3, 128),
]


@pytest.mark.parametrize(
    "metric,backend,k,recall_target,trials,m", FAST_CORNERS
)
def test_recall_meets_target_in_expectation(
    metric, backend, k, recall_target, trials, m
):
    samples, expected = _recall_samples(
        metric, backend, k, recall_target, trials=trials, m=m
    )
    eps = _hoeffding_eps(len(samples))
    mean = float(samples.mean())
    # The paper's guarantee: E[recall] >= recall_target (Eq. 14) ...
    assert mean >= recall_target - eps, (
        f"{metric}/{backend} k={k}: empirical recall {mean:.4f} is below "
        f"target {recall_target} by more than the {eps:.4f} confidence "
        f"margin over {len(samples)} samples — a real regression"
    )
    # ... and the planner's own Eq. 13 expectation for the layout it chose
    # (a tighter bound, since the discrete bin count rounds recall up).
    assert mean >= expected - eps, (
        f"{metric}/{backend} k={k}: empirical recall {mean:.4f} vs "
        f"analytic E[recall] {expected:.4f} (margin {eps:.4f})"
    )


# Quantized storage tiers (repro.search.quant): the scan ranks by reduced-
# precision scores, the bins are over-fetched (quant.scan_k) and an exact
# second pass rescores — the SAME Eq. 13–14 guarantee must hold at the
# user's k within the same Hoeffding margin.  Corners span tier x metric x
# backend; pallas again with a smaller budget (interpret mode).
QUANT_CORNERS = [
    ("mips", "xla", "bf16", 10, 0.95, 4, 256),
    ("l2", "xla", "int8", 10, 0.95, 4, 256),
    ("cosine", "xla", "int8", 4, 0.99, 4, 256),
    ("l2", "pallas", "bf16", 16, 0.90, 2, 128),
    ("mips", "pallas", "int8", 8, 0.90, 2, 128),
    # int4: half-byte rows, T(int4)=2K over-fetch (quant.scan_k) — the
    # widest-error tier the two-pass guarantee must still absorb.
    ("l2", "xla", "int4", 10, 0.90, 4, 256),
    ("mips", "pallas", "int4", 8, 0.90, 2, 128),
]


@pytest.mark.parametrize(
    "metric,backend,storage,k,recall_target,trials,m", QUANT_CORNERS
)
def test_recall_meets_target_quantized(
    metric, backend, storage, k, recall_target, trials, m
):
    samples, expected = _recall_samples(
        metric, backend, k, recall_target, trials=trials, m=m, seed=3,
        storage=storage,
    )
    eps = _hoeffding_eps(len(samples))
    mean = float(samples.mean())
    assert mean >= recall_target - eps, (
        f"{metric}/{backend}/{storage} k={k}: quantized recall {mean:.4f} "
        f"below target {recall_target} beyond the {eps:.4f} margin over "
        f"{len(samples)} samples — the over-fetch/rescore guarantee broke"
    )
    # the over-fetched layout's own (conservative) Eq. 13 expectation
    assert expected >= recall_target
    assert mean >= expected - eps, (
        f"{metric}/{backend}/{storage} k={k}: {mean:.4f} vs over-fetched "
        f"E[recall] {expected:.4f} (margin {eps:.4f})"
    )


# Cluster-pruned front-end (repro.search.cluster): above the planner's
# crossover the scan covers only the top-rho clusters plus the spill
# block, and the guarantee becomes P(no bin collision) x P(no cluster
# miss) >= recall_target — still with ZERO user tuning parameters (the
# spec only says cluster="auto").  N is above the crossover so the
# planner actually enables pruning; the corpus is the mixture draw the
# miss bound models (see _mixture_draw).  One corner stacks cluster
# pruning over the int8 tier, so the quantized over-fetch and the pruned
# gather compose in a single search.
CLUSTER_N = 8192
CLUSTER_CORNERS = [
    ("mips", "xla", "f32", 10, 0.95, 2, 256),
    ("l2", "xla", "f32", 32, 0.90, 2, 256),
    ("cosine", "xla", "f32", 4, 0.95, 2, 256),
    ("l2", "xla", "int8", 10, 0.95, 2, 256),
    ("l2", "pallas", "f32", 16, 0.90, 1, 128),
]


@pytest.mark.parametrize(
    "metric,backend,storage,k,recall_target,trials,m", CLUSTER_CORNERS
)
def test_recall_meets_target_cluster_pruned(
    metric, backend, storage, k, recall_target, trials, m
):
    samples, expected = _recall_samples(
        metric, backend, k, recall_target, trials=trials, m=m, seed=17,
        storage=storage, cluster="auto", n=CLUSTER_N, draw=_mixture_draw,
    )
    # The planner must have actually enabled pruning at this N — otherwise
    # this test silently degenerates to the dense path.
    probe = Index.build(
        jax.random.normal(jax.random.PRNGKey(0), (CLUSTER_N, D)),
        metric=metric, k=k, recall_target=recall_target, backend=backend,
        storage=storage,
    )
    assert probe.kernel_plan.cluster is not None
    assert probe.kernel_plan.cluster.enabled
    eps = _hoeffding_eps(len(samples))
    mean = float(samples.mean())
    assert mean >= recall_target - eps, (
        f"{metric}/{backend}/{storage} k={k}: cluster-pruned recall "
        f"{mean:.4f} below target {recall_target} beyond the {eps:.4f} "
        f"margin over {len(samples)} samples — the collision x miss "
        f"guarantee broke"
    )
    # the planner's own product bound must itself certify the target
    assert expected >= recall_target


def test_recall_is_approximate_not_exact():
    """Sanity for the whole suite: the approximate path must actually lose
    some neighbours (empirical recall < 1), otherwise every guarantee test
    above is vacuous (e.g. a silent fallback to exact top-k)."""
    samples, expected = _recall_samples(
        "mips", "xla", 32, 0.90, trials=4, m=256
    )
    assert expected < 1.0
    assert samples.mean() < 1.0 - 1e-4, (
        "approximate search returned exact results across 1024 queries — "
        "the recall-guarantee suite is no longer testing the approximate "
        "path"
    )


def test_recall_guarantee_sharded_global_accounting():
    """Paper §7: on the sharded backend the bin budget is split across
    shards but recall is accounted against the *global* N — the guarantee
    must survive that redistribution."""
    mesh = jax.make_mesh((1,), ("model",))
    samples = []
    expected = None
    root = jax.random.PRNGKey(7)
    for t in range(3):
        kd, kq = jax.random.split(jax.random.fold_in(root, t))
        db = jax.random.normal(kd, (N, D))
        q = jax.random.normal(kq, (128, D))
        index = Index.build(db, metric="mips", k=10, recall_target=0.9).shard(
            mesh, db_axis="model"
        )
        assert index.expected_recall >= 0.9
        expected = index.expected_recall
        _, idxs = index.search(q)
        _, exact = exact_search(q, db, 10, metric="mips")
        approx, truth = np.asarray(idxs), np.asarray(exact)
        samples.extend(
            len(set(a.tolist()) & set(b.tolist())) / 10
            for a, b in zip(approx, truth)
        )
    samples = np.asarray(samples)
    eps = _hoeffding_eps(len(samples))
    assert samples.mean() >= 0.9 - eps
    assert samples.mean() >= expected - eps


def test_recall_guarantee_sharded_2d_global_accounting():
    """Eq. 13–14 under 2-D (query x database) sharding: per-shard bins are
    laid out against the GLOBAL N (`reduction_input_size_override`), so
    the ((L-1)/L)^(K-1) bound composes across the db axes exactly as in
    the 1-D §7 argument — the measured recall must clear both the target
    and the planner's analytic expectation, and the plan must price the
    per-shard scan (not the global one) plus the ICI gather."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    samples = []
    expected = None
    root = jax.random.PRNGKey(21)
    for t in range(3):
        kd, kq = jax.random.split(jax.random.fold_in(root, t))
        db = jax.random.normal(kd, (N, D))
        q = jax.random.normal(kq, (128, D))
        index = Index.build(db, metric="mips", k=10, recall_target=0.9).shard(
            mesh, db_axis=("data", "model"), batch_axis=None
        )
        assert index.expected_recall >= 0.9
        expected = index.expected_recall
        report = index.explain()
        assert report["sharding"]["db_axes"] == ["data", "model"]
        assert report["sharding"]["per_shard_n"] * \
            report["sharding"]["db_shards"] >= N
        # one shard on the (1,1) test mesh => nothing crosses the ICI;
        # the planner prices the O(k) gather once shards exist
        assert report["sharding"]["ici_gather_bytes"] == 0.0
        from repro.search import plan as planlib

        pod = planlib.plan_search(n=N, d=D, k=10, metric="mips",
                                  recall_target=0.9, backend="sharded",
                                  db_shards=8)
        assert pod.db_shards == 8 and pod.ici_bytes > 0 and pod.ici_s > 0
        _, idxs = index.search(q)
        _, exact = exact_search(q, db, 10, metric="mips")
        approx, truth = np.asarray(idxs), np.asarray(exact)
        samples.extend(
            len(set(a.tolist()) & set(b.tolist())) / 10
            for a, b in zip(approx, truth)
        )
    samples = np.asarray(samples)
    eps = _hoeffding_eps(len(samples))
    assert samples.mean() >= 0.9 - eps
    assert samples.mean() >= expected - eps


@pytest.mark.slow
@pytest.mark.parametrize("metric", ["mips", "l2", "cosine"])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("k,recall_target", [(4, 0.99), (10, 0.95), (32, 0.90)])
def test_recall_guarantee_full_sweep(metric, backend, k, recall_target):
    """The exhaustive metric x backend x (k, target) grid (slow tier)."""
    trials, m = (6, 256) if backend == "xla" else (3, 128)
    samples, expected = _recall_samples(
        metric, backend, k, recall_target, trials=trials, m=m, seed=11
    )
    eps = _hoeffding_eps(len(samples))
    assert samples.mean() >= recall_target - eps
    assert samples.mean() >= expected - eps
