"""Fault-tolerant serving: deterministic chaos tests.

Everything here is seeded and (except the wall-clock watchdog tests)
driven on the virtual clock, so every failure scenario replays exactly:

  * the ``FaultInjector`` itself — schedule exactness, rate determinism
    across ``reset()``, point validation, the global registry;
  * the serve retry loop — a transient dispatch fault is retried with
    backoff and still returns bit-identical results through ONE extra
    dispatch (never a retrace); exhausted retries surface the typed error;
  * deadlines — an expired ticket fails with ``DeadlineExceeded`` and its
    rows are NEVER dispatched (``DISPATCH_COUNTS`` stays empty), including
    expiry during retry backoff and mixed expired/live batches;
  * worker death — virtual ``step()`` restart and the wall-clock watchdog
    both recover without losing queued tickets (requeue contract);
  * overload — sustained-full admission sheds with ``Overloaded`` carrying
    a ``retry_after_s`` estimate, and ``health()`` reports the taxonomy;
  * crash-safe snapshots — ``Index.save``/``restore`` round-trips are
    bit-identical without re-running build/k-means/quantization, and a
    fault between the tmp write and the commit rename leaves the previous
    snapshot loadable (the crash-safety contract);
  * a seeded chaos smoke (``@pytest.mark.slow``): a fixed fault schedule
    over a request stream — every ticket terminates with a result or a
    typed error, none hang or vanish, and the fault-free phase afterwards
    still holds the one-dispatch / zero-retrace contracts.
"""
import os

import jax
import numpy as np
import pytest

from repro.search import (
    DeadlineExceeded,
    Index,
    Overloaded,
    QueueFull,
    SearchServer,
    ServeConfig,
    VirtualClock,
    backends,
    faults,
)
from repro.search.backends import DISPATCH_COUNTS, TRACE_COUNTS
from repro.search.faults import (
    FatalFault,
    FaultInjector,
    TransientFault,
    WorkerDeath,
)
from repro.search.packed import PACK_EVENTS, reset_pack_events
from repro.search.serve import SERVE_EVENTS, reset_serve_events

K = 10
D = 16


@pytest.fixture(scope="module")
def index():
    db = jax.random.normal(jax.random.PRNGKey(1), (2048, D))
    return Index.build(db, metric="mips", k=K, backend="xla")


@pytest.fixture(autouse=True)
def _reset_counters():
    backends.reset_trace_counts()
    backends.reset_dispatch_counts()
    reset_serve_events()
    reset_pack_events()
    yield
    faults.uninstall()  # never leak an injector into another test


def _vserver(index, inj=None, clock=None, **cfg):
    cfg.setdefault("max_batch", 32)
    return SearchServer(
        index, ServeConfig(**cfg), clock=clock or VirtualClock(), faults=inj
    )


def _queries(seed, m):
    return np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (m, D)))


# --- the injector itself -----------------------------------------------------


def test_schedule_fires_exactly_the_nth_hit():
    inj = FaultInjector(schedule=[("serve.dispatch", 3, "fatal")])
    inj.fire("serve.dispatch")
    inj.fire("serve.dispatch")
    with pytest.raises(FatalFault) as e:
        inj.fire("serve.dispatch")
    assert (e.value.point, e.value.hit) == ("serve.dispatch", 3)
    inj.fire("serve.dispatch")  # hit 4: passes again
    assert inj.hits["serve.dispatch"] == 4
    assert inj.fired["serve.dispatch"] == 1


def test_rate_based_firing_is_deterministic_across_reset():
    inj = FaultInjector(seed=7, rates={"serve.dispatch": 0.3})

    def pattern(n=200):
        fired = []
        for i in range(n):
            try:
                inj.fire("serve.dispatch")
            except TransientFault:
                fired.append(i)
        return fired

    first = pattern()
    assert first, "0.3 over 200 hits must fire sometimes"
    inj.reset()
    assert pattern() == first  # same seed + reset -> identical replay
    # an independent point's stream is untouched by the dispatch draws
    twin = FaultInjector(seed=7, rates={"serve.dispatch": 0.3,
                                        "serve.transfer": 0.3})
    fired = []
    for i in range(200):
        try:
            twin.fire("serve.dispatch")
        except TransientFault:
            fired.append(i)
        if i % 3 == 0:  # interleave extra traffic on another point
            try:
                twin.fire("serve.transfer")
            except TransientFault:
                pass
    assert fired == first


def test_injector_validates_points_and_kinds():
    with pytest.raises(ValueError, match="unknown injection point"):
        FaultInjector(rates={"serve.nope": 0.5})
    with pytest.raises(ValueError, match="unknown injection point"):
        FaultInjector(schedule=[("bogus", 1, "fatal")])
    with pytest.raises(ValueError, match="kind"):
        FaultInjector(schedule=[("serve.dispatch", 1, "oops")])
    with pytest.raises(ValueError, match="1-based"):
        FaultInjector(schedule=[("serve.dispatch", 0, "fatal")])
    with pytest.raises(ValueError, match="rate"):
        FaultInjector(rates={"serve.dispatch": 1.5})
    inj = FaultInjector()
    with pytest.raises(ValueError, match="unknown injection point"):
        inj.fire("nope")


def test_global_registry_scoping():
    assert faults.active() is None
    faults.fire("serve.worker")  # no-op without an injector
    with faults.injected(FaultInjector()) as inj:
        assert faults.active() is inj
        with faults.injected(FaultInjector()) as inner:
            assert faults.active() is inner
        assert faults.active() is inj  # nesting restores the outer one
    assert faults.active() is None


# --- retries: transient dispatch faults --------------------------------------


def test_transient_dispatch_fault_is_retried_bit_identically(index):
    q = _queries(10, 6)
    direct = index.search(q)
    inj = FaultInjector(schedule=[("serve.dispatch", 1, "transient")])
    server = _vserver(index, inj)
    server.precompile()
    backends.reset_dispatch_counts()
    backends.reset_trace_counts()
    vals, idxs = server.submit(q).result()
    np.testing.assert_array_equal(np.asarray(idxs), np.asarray(direct.indices))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(direct.values))
    s = server.stats()
    assert s["transient_faults"] == 1
    assert s["dispatch_retries"] == 1
    assert s["failed_batches"] == 0
    assert SERVE_EVENTS["dispatch_retries"] == 1
    # the fault fired BEFORE the dispatch: one batch -> still one dispatch,
    # and a retry of a precompiled bucket never retraces
    assert DISPATCH_COUNTS["xla"] == 1, dict(DISPATCH_COUNTS)
    assert not dict(TRACE_COUNTS)
    server.close()


def test_exhausted_retries_surface_the_typed_error(index):
    inj = FaultInjector(rates={"serve.dispatch": 1.0})
    server = _vserver(index, inj, max_dispatch_retries=2)
    t = server.submit(_queries(11, 4))
    with pytest.raises(TransientFault):
        t.result()
    s = server.stats()
    assert s["transient_faults"] == 3  # initial + 2 retries
    assert s["dispatch_retries"] == 2
    assert s["failed_batches"] == 1
    server.close()


def test_fatal_fault_fails_fast_without_retry(index):
    inj = FaultInjector(schedule=[("serve.dispatch", 1, "fatal")])
    server = _vserver(index, inj)
    t_dead = server.submit(_queries(12, 4))
    with pytest.raises(FatalFault):
        t_dead.result()
    assert server.stats()["dispatch_retries"] == 0
    # the server keeps serving after a fatal batch
    q = _queries(13, 4)
    np.testing.assert_array_equal(
        np.asarray(server.submit(q).result().indices),
        np.asarray(index.search(q).indices),
    )
    server.close()


@pytest.mark.parametrize(
    "point", ["serve.staging_alloc", "serve.transfer", "serve.scatter"]
)
def test_pipeline_stage_faults_fail_with_typed_errors(index, point):
    inj = FaultInjector(schedule=[(point, 1, "fatal")])
    server = _vserver(index, inj)
    t = server.submit(_queries(14, 4))
    if point == "serve.scatter":
        # scatter runs when the NEXT service pass (or idle drain) finalizes
        server.run_until_idle()
    with pytest.raises(FatalFault) as e:
        t.result()
    assert e.value.point == point
    server.close()


# --- deadlines ---------------------------------------------------------------


def test_expired_ticket_is_never_dispatched(index):
    clock = VirtualClock()
    server = _vserver(index, clock=clock)
    server.precompile()
    backends.reset_dispatch_counts()
    t = server.submit(_queries(20, 4), deadline_s=0.5)
    clock.advance(1.0)  # deadline passes while queued
    with pytest.raises(DeadlineExceeded):
        t.result()
    assert sum(DISPATCH_COUNTS.values()) == 0, dict(DISPATCH_COUNTS)
    assert server.stats()["deadline_expired"] == 1
    assert SERVE_EVENTS["deadline_expired"] == 1
    assert server.pending_rows == 0  # the dead ticket freed its rows
    server.close()


def test_mixed_expired_and_live_batch(index):
    clock = VirtualClock()
    server = _vserver(index, clock=clock)
    dead = server.submit(_queries(21, 4), deadline_s=0.5)
    clock.advance(1.0)
    q = _queries(22, 4)
    live = server.submit(q, deadline_s=10.0)  # still well within deadline
    server.run_until_idle()
    with pytest.raises(DeadlineExceeded):
        dead.result()
    np.testing.assert_array_equal(
        np.asarray(live.result().indices), np.asarray(index.search(q).indices)
    )
    server.close()


def test_deadline_expires_during_retry_backoff(index):
    # backoff advances the virtual clock past the ticket's deadline: the
    # retry must drop it instead of dispatching dead work
    clock = VirtualClock()
    inj = FaultInjector(rates={"serve.dispatch": 1.0})
    server = _vserver(
        index, inj, clock=clock,
        max_dispatch_retries=5, retry_backoff_s=0.4,
    )
    server.precompile()
    backends.reset_dispatch_counts()
    t = server.submit(_queries(23, 4), deadline_s=1.0)
    with pytest.raises(DeadlineExceeded):
        t.result()
    assert sum(DISPATCH_COUNTS.values()) == 0
    # fewer retries than the budget: the deadline cut the loop short
    assert server.stats()["dispatch_retries"] < 5
    server.close()


def test_submit_rejects_nonpositive_deadline(index):
    server = _vserver(index)
    with pytest.raises(ValueError, match="deadline_s"):
        server.submit(_queries(24, 2), deadline_s=0.0)
    server.close()


# --- worker death / watchdog -------------------------------------------------


def test_virtual_worker_death_requeues_and_recovers(index):
    q = _queries(30, 4)
    inj = FaultInjector(schedule=[("serve.dispatch", 1, "death")])
    server = _vserver(index, inj)
    t = server.submit(q)
    # the popped batch is requeued by the dying pass; step() absorbs the
    # death and the next pass serves it
    np.testing.assert_array_equal(
        np.asarray(t.result().indices), np.asarray(index.search(q).indices)
    )
    s = server.stats()
    assert s["worker_deaths"] == 1
    assert s["worker_restarts"] == 1
    assert s["requeued_tickets"] == 1
    assert SERVE_EVENTS["requeued_tickets"] == 1
    server.close()


def test_death_between_batches_loses_nothing(index):
    # serve.worker fires before anything is popped: queue fully intact
    inj = FaultInjector(schedule=[("serve.worker", 1, "death")])
    server = _vserver(index, inj)
    qs = [_queries(31 + i, 3) for i in range(3)]
    tickets = [server.submit(q) for q in qs]
    server.run_until_idle()
    for q, t in zip(qs, tickets):
        np.testing.assert_array_equal(
            np.asarray(t.result().indices),
            np.asarray(index.search(q).indices),
        )
    assert server.stats()["requeued_tickets"] == 0
    server.close()


def test_wall_clock_watchdog_restarts_dead_worker(index):
    q = _queries(33, 4)
    inj = FaultInjector(schedule=[("serve.dispatch", 1, "death")])
    server = SearchServer(
        index, ServeConfig(max_batch=32, max_delay_s=0.0), faults=inj
    )
    t = server.submit(q)
    vals, idxs = t.result(timeout=60)
    np.testing.assert_array_equal(
        np.asarray(idxs), np.asarray(index.search(q).indices)
    )
    assert server.stats()["worker_restarts"] == 1
    assert server.health()["worker_alive"]
    # the restarted worker is the same joinable thread: close() still works
    server.close()
    assert server.health()["status"] == "ok"  # closed cleanly, not degraded


def test_worker_death_mid_mutation_gate(index):
    """Death injected at serve.dispatch while the main thread holds
    ``mutation()``: the fault fires BEFORE the worker takes the gate, so
    the restarted worker never deadlocks on a gate its dead self held."""
    db = jax.random.normal(jax.random.PRNGKey(40), (512, D))
    ix = Index.build(db, metric="mips", k=4, capacity=1024)
    inj = FaultInjector(schedule=[("serve.dispatch", 1, "death")])
    server = SearchServer(
        ix, ServeConfig(max_batch=32, max_delay_s=0.0), faults=inj
    )
    with server.mutation():
        t = server.submit(_queries(41, 4))  # worker may die while we hold it
        ix.add(_queries(42, 8))
    vals, idxs = t.result(timeout=60)
    assert vals.shape == (4, 4)
    assert server.stats()["worker_deaths"] == 1
    server.close()


# --- overload shedding -------------------------------------------------------


def test_sustained_overload_sheds_with_retry_after(index):
    clock = VirtualClock()
    server = _vserver(
        index, clock=clock, max_pending_rows=8, overload_grace_s=0.2
    )
    server.submit(_queries(50, 8))  # fills the queue
    with pytest.raises(QueueFull) as e:  # inside grace: plain QueueFull
        server.submit(_queries(51, 4))
    assert not isinstance(e.value, Overloaded)
    clock.advance(0.5)  # still full past the grace window
    with pytest.raises(Overloaded) as e:
        server.submit(_queries(52, 4))
    assert e.value.retry_after_s > 0
    assert e.value.rows_pending == 8
    assert server.health()["status"] == "overloaded"
    assert SERVE_EVENTS["load_shed"] == 1
    server.run_until_idle()  # drain clears the overload state
    assert server.health()["status"] == "ok"
    server.submit(_queries(53, 4))  # admitted again
    server.run_until_idle()
    server.close()


def test_health_reports_failure_taxonomy(index):
    inj = FaultInjector(schedule=[("serve.dispatch", 1, "transient"),
                                  ("serve.worker", 2, "death")])
    server = _vserver(index, inj)
    server.submit(_queries(54, 4)).result()
    h = server.health()
    assert h["status"] == "ok"
    assert h["worker_alive"] and not h["closed"]
    assert h["transient_faults"] == 1
    assert h["dispatch_retries"] == 1
    assert h["worker_deaths"] == 1
    assert h["pending_rows"] == 0 and h["queued_requests"] == 0
    for key in ("deadline_expired", "failed_batches", "load_shed",
                "requeued_tickets", "worker_restarts"):
        assert key in h
    server.close()


# --- index mutation faults ---------------------------------------------------


def test_index_add_fault_is_all_or_nothing(index):
    db = jax.random.normal(jax.random.PRNGKey(60), (256, D))
    ix = Index.build(db, metric="mips", k=4, capacity=1024)
    with faults.injected(FaultInjector(schedule=[("index.add", 1, "fatal")])):
        with pytest.raises(FatalFault):
            ix.add(_queries(61, 8))
        assert ix.size == 256  # nothing was appended
        ix.add(_queries(61, 8))  # hit 2: clean — and the index still works
    assert ix.size == 264
    with faults.injected(
        FaultInjector(schedule=[("index.delete", 1, "fatal")])
    ):
        with pytest.raises(FatalFault):
            ix.delete([0, 1])
        assert ix.size == 264


def test_extend_fault_under_serving_keeps_server_alive():
    from repro.retrieval.datastore import KNNDatastore

    keys = jax.random.normal(jax.random.PRNGKey(62), (512, D))
    toks = jax.random.randint(jax.random.PRNGKey(63), (512,), 0, 100)
    ds = KNNDatastore(keys, toks, k=4, capacity=2048)
    ds.attach_server(clock=VirtualClock(), config=ServeConfig(max_batch=32))
    with faults.injected(FaultInjector(schedule=[("index.add", 1, "fatal")])):
        with pytest.raises(FatalFault):
            ds.extend(_queries(64, 16), np.full((16,), 1))
        assert len(ds) == 512
        # serving continues across the failed mutation...
        q = _queries(65, 4)
        scores, _ = ds.lookup(q)
        assert scores.shape == (4, 4)
        # ...and the next extend succeeds
        ds.extend(_queries(64, 16), np.full((16,), 1))
    assert len(ds) == 512 + 16
    ds.server.close()


# --- crash-safe snapshots ----------------------------------------------------


def test_snapshot_restore_is_bit_identical_without_rebuild(index, tmp_path):
    q = _queries(70, 8)
    direct = index.search(q)
    path = os.path.join(tmp_path, "snap")
    index.save(path)
    reset_pack_events()
    restored = Index.restore(path)
    got = restored.search(q)
    np.testing.assert_array_equal(
        np.asarray(got.indices), np.asarray(direct.indices)
    )
    np.testing.assert_array_equal(
        np.asarray(got.values), np.asarray(direct.values)
    )
    # restore reconstructs packed state directly: no build/pack/quantize
    assert PACK_EVENTS["restore"] == 1
    assert PACK_EVENTS["full_pack"] == 0, dict(PACK_EVENTS)
    assert PACK_EVENTS["cluster_built"] == 0, dict(PACK_EVENTS)


def test_snapshot_commit_fault_leaves_previous_snapshot_loadable(tmp_path):
    db = jax.random.normal(jax.random.PRNGKey(71), (256, D))
    ix = Index.build(db, metric="mips", k=4, capacity=512)
    q = _queries(72, 4)
    before = np.asarray(ix.search(q).indices)
    path = os.path.join(tmp_path, "snap")
    ix.save(path)
    ix.add(_queries(73, 8))
    with faults.injected(
        FaultInjector(schedule=[("checkpoint.commit", 1, "fatal")])
    ):
        with pytest.raises(FatalFault):
            ix.save(path)  # crashes after tmp write, before the rename
    survivor = Index.restore(path)  # the ORIGINAL snapshot must load
    assert survivor.size == 256
    np.testing.assert_array_equal(
        np.asarray(survivor.search(q).indices), before
    )
    # a later clean save supersedes it
    ix.save(path)
    assert Index.restore(path).size == 264


def test_index_save_fault_fires_before_any_write(tmp_path):
    db = jax.random.normal(jax.random.PRNGKey(74), (256, D))
    ix = Index.build(db, metric="mips", k=4)
    path = os.path.join(tmp_path, "snap")
    with faults.injected(
        FaultInjector(schedule=[("index.save", 1, "fatal")])
    ):
        with pytest.raises(FatalFault):
            ix.save(path)
    assert not os.path.exists(path)
    assert not os.path.exists(path + ".tmp")


def test_restore_rejects_foreign_and_future_snapshots(tmp_path):
    from repro.checkpoint.checkpoint import save_snapshot
    from repro.search.index import SNAPSHOT_FORMAT, SNAPSHOT_VERSION

    alien = os.path.join(tmp_path, "alien")
    save_snapshot(alien, {"x": np.zeros(2)}, {"format": "other.thing"})
    with pytest.raises(ValueError, match="not an index snapshot"):
        Index.restore(alien)
    future = os.path.join(tmp_path, "future")
    save_snapshot(
        future, {"x": np.zeros(2)},
        {"format": SNAPSHOT_FORMAT, "version": SNAPSHOT_VERSION + 1},
    )
    with pytest.raises(ValueError, match="version"):
        Index.restore(future)


def test_restore_then_serve_matches_direct(index, tmp_path):
    path = os.path.join(tmp_path, "snap")
    index.save(path)
    restored = Index.restore(path)
    server = _vserver(restored)
    q = _queries(75, 6)
    np.testing.assert_array_equal(
        np.asarray(server.submit(q).result().indices),
        np.asarray(index.search(q).indices),
    )
    server.close()


# --- seeded chaos smoke ------------------------------------------------------


@pytest.mark.slow
def test_seeded_chaos_run_loses_no_tickets(index):
    """A fixed fault schedule over a request stream: every ticket
    terminates (result or typed error), none hang or vanish — then a
    fault-free phase re-asserts the one-dispatch / zero-retrace contracts
    (retries and restarts must not have poisoned the compile caches)."""
    clock = VirtualClock()
    inj = FaultInjector(
        seed=3,
        rates={"serve.dispatch": 0.15},
        schedule=[
            ("serve.worker", 2, "death"),
            ("serve.dispatch", 5, "fatal"),
            ("serve.staging_alloc", 3, "fatal"),
            ("serve.dispatch", 9, "death"),
            ("serve.scatter", 4, "fatal"),
        ],
    )
    server = _vserver(index, inj, clock=clock, max_pending_rows=4096,
                      max_dispatch_retries=2, retry_backoff_s=0.01)
    server.precompile()
    rng = np.random.default_rng(3)
    tickets = []
    for wave in range(10):
        for r in range(4):
            m = int(rng.integers(1, 9))
            deadline = (
                None if r % 3 else float(rng.uniform(0.05, 5.0))
            )
            q = _queries(1000 + 10 * wave + r, m)
            tickets.append((q, server.submit(q, deadline_s=deadline)))
        clock.advance(float(rng.uniform(0.0, 0.5)))
        server.run_until_idle()
    server.run_until_idle()

    ok = failed = 0
    for q, t in tickets:
        assert t.done, "chaos run left a ticket hanging"
        try:
            vals, idxs = t.result()
        except (faults.InjectedFault, DeadlineExceeded):
            failed += 1  # typed taxonomy only — never a bare RuntimeError
        else:
            ok += 1
            np.testing.assert_array_equal(
                np.asarray(idxs), np.asarray(index.search(q).indices)
            )
    assert ok + failed == len(tickets)
    assert ok > 0 and failed > 0  # the schedule really exercised both paths
    assert server.pending_rows == 0

    # fault-free phase: contracts hold after all that chaos
    server._faults = None
    backends.reset_dispatch_counts()
    backends.reset_trace_counts()
    reset_serve_events()
    qs = [_queries(2000 + i, 8) for i in range(4)]  # one 32-row batch
    clean = [server.submit(q) for q in qs]
    server.run_until_idle()
    served_dispatches = DISPATCH_COUNTS["xla"]  # before the parity searches
    for q, t in zip(qs, clean):
        np.testing.assert_array_equal(
            np.asarray(t.result().indices),
            np.asarray(index.search(q).indices),
        )
    assert served_dispatches == 1, dict(DISPATCH_COUNTS)
    assert not dict(TRACE_COUNTS)
    assert SERVE_EVENTS["batches"] == 1
    assert SERVE_EVENTS["failed_batches"] == 0
    server.close()
