"""Unified telemetry layer: registry, tracing, drift monitor, thread safety.

Covers the ISSUE-10 contracts deterministically:

  * registry semantics — labeled counters/gauges, windowed-histogram
    quantiles, Prometheus/JSON export round-trips, adopted legacy
    counter dicts, one ``reset_all()``;
  * per-request tracing — exact span timings under the virtual clock
    (no wall-clock assumptions), ring-buffer bounds, Chrome-trace
    export, 100% span coverage of measured latency;
  * roofline-drift monitor — calibration after warmup, degraded
    transition under an injected ``"delay"``-kind slow dispatch
    (``DelayFault``: slow, *successful* — nothing raised);
  * thread safety — the ``+=`` lost-update race is gone:
    ``AtomicCounter`` hammered from many threads stays exact, and a
    wall-clock server keeps exact counters while readers poll
    stats/exports concurrently;
  * zero overhead — tracing off (``trace_buffer=0``) changes no
    dispatch/trace counters and no results.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.search import (
    AtomicCounter,
    DriftMonitor,
    Index,
    MetricsRegistry,
    SearchServer,
    ServeConfig,
    VirtualClock,
    backends,
    chrome_trace,
    telemetry,
    trace_coverage,
)
from repro.search.backends import DISPATCH_COUNTS, TRACE_COUNTS
from repro.search.faults import DelayFault, FatalFault, FaultInjector
from repro.search.serve import SERVE_EVENTS, reset_serve_events

K = 10
D = 16


@pytest.fixture(scope="module")
def index():
    db = jax.random.normal(jax.random.PRNGKey(1), (2048, D))
    return Index.build(db, metric="mips", k=K, backend="xla")


@pytest.fixture(autouse=True)
def _reset_telemetry():
    telemetry.reset_all()
    yield
    telemetry.reset_all()


def _vserver(index, **cfg):
    cfg.setdefault("max_batch", 32)
    return SearchServer(index, ServeConfig(**cfg), clock=VirtualClock())


def _queries(seed, m):
    return np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (m, D)))


# --- metrics registry --------------------------------------------------------


def test_registry_counters_gauges_labels():
    reg = MetricsRegistry()
    reg.inc("req_total", backend="xla")
    reg.inc("req_total", 2, backend="pallas")
    reg.inc("req_total", backend="xla")
    assert reg.counter_value("req_total", backend="xla") == 2
    assert reg.counter_value("req_total", backend="pallas") == 2
    assert reg.counter_value("req_total", backend="host") == 0
    reg.set_gauge("depth", 7, tier="hot")
    reg.set_gauge("depth", 3, tier="hot")  # gauges overwrite
    assert reg.gauge_value("depth", tier="hot") == 3
    assert reg.gauge_value("depth", tier="cold") is None


def test_registry_histogram_quantiles_match_numpy():
    reg = MetricsRegistry()
    values = list(range(1, 101))
    for v in values:
        reg.observe("lat", v)
    snap = reg.histogram_snapshot("lat")
    assert snap["count"] == 100
    assert snap["sum"] == sum(values)
    for q in (50, 90, 99):
        assert snap[f"p{q}"] == pytest.approx(np.percentile(values, q))


def test_registry_histogram_window_is_bounded():
    reg = MetricsRegistry(histogram_window=8)
    for v in range(100):
        reg.observe("lat", v)
    snap = reg.histogram_snapshot("lat")
    assert snap["count"] == 100          # lifetime count survives
    assert snap["window"] == 8           # quantiles over the last 8 only
    assert snap["min"] == 92


def test_export_round_trip_json_and_prometheus():
    reg = MetricsRegistry()
    reg.inc("repro_req_total", 3, backend="xla", storage="int8")
    reg.set_gauge("repro_depth", 5)
    reg.observe("repro_lat_seconds", 0.25)
    js = reg.export_json()
    assert js["counters"]["repro_req_total"][0]["value"] == 3
    assert js["counters"]["repro_req_total"][0]["labels"] == {
        "backend": "xla", "storage": "int8"
    }
    assert js["gauges"]["repro_depth"][0]["value"] == 5
    assert js["histograms"]["repro_lat_seconds"][0]["count"] == 1
    text = reg.export_prometheus()
    assert 'repro_req_total{backend="xla",storage="int8"} 3' in text
    assert "repro_depth 5" in text
    assert 'repro_lat_seconds{quantile="0.5"} 0.25' in text
    assert "repro_lat_seconds_count 1" in text
    assert "repro_lat_seconds_sum 0.25" in text


def test_registry_adopts_legacy_counter_dicts():
    reg = MetricsRegistry()
    legacy = AtomicCounter()
    reg.register_counter_dict("legacy_total", legacy, "event")
    legacy.inc("hit", 4)
    # exports read the live dict — no copy was taken at registration
    assert 'legacy_total{event="hit"} 4' in reg.export_prometheus()
    reg.reset()
    assert dict(legacy) == {}  # reset clears adopted dicts too


def test_reset_all_clears_every_legacy_dict(index):
    server = _vserver(index)
    server.submit(_queries(0, 4))
    server.run_until_idle()
    assert DISPATCH_COUNTS and SERVE_EVENTS
    telemetry.reset_all()
    assert dict(DISPATCH_COUNTS) == {}
    assert dict(SERVE_EVENTS) == {}
    assert dict(TRACE_COUNTS) == {}
    server.close()


def test_deprecated_reset_aliases_still_work():
    DISPATCH_COUNTS.inc("xla")
    TRACE_COUNTS.inc("xla")
    SERVE_EVENTS.inc("batches")
    backends.reset_dispatch_counts()
    backends.reset_trace_counts()
    reset_serve_events()
    assert dict(DISPATCH_COUNTS) == {}
    assert dict(TRACE_COUNTS) == {}
    assert dict(SERVE_EVENTS) == {}


# --- thread safety (the += lost-update bugfix) -------------------------------


def test_atomic_counter_is_exact_under_contention():
    c = AtomicCounter()
    threads, per = 8, 5000

    def hammer():
        for _ in range(per):
            c.inc("hits")

    ts = [threading.Thread(target=hammer) for _ in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    # a bare ``c["hits"] += 1`` loses increments under this load; the
    # locked read-modify-write must not
    assert c["hits"] == threads * per


def test_registry_counter_is_exact_under_contention():
    reg = MetricsRegistry()
    threads, per = 8, 2000

    def hammer():
        for _ in range(per):
            reg.inc("total", event="x")

    ts = [threading.Thread(target=hammer) for _ in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert reg.counter_value("total", event="x") == threads * per


def test_wall_clock_server_counters_exact_with_concurrent_readers(index):
    """Submitters and telemetry readers race the serve worker; every
    counter read is consistent and the final totals are exact."""
    server = SearchServer(
        index, ServeConfig(max_batch=32, max_delay_s=0.001), warmup=True
    )
    clients, per = 4, 25
    stop = threading.Event()
    errors = []

    def reader():
        while not stop.is_set():
            server.stats()
            server.health()
            telemetry.export_prometheus()
            dict(SERVE_EVENTS)

    def client(cid):
        try:
            q = _queries(100 + cid, 4)
            for _ in range(per):
                server.submit(q).result(timeout=60)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    rd = threading.Thread(target=reader)
    rd.start()
    ts = [threading.Thread(target=client, args=(c,)) for c in range(clients)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    stop.set()
    rd.join()
    assert not errors
    s = server.stats()
    assert s["completed_requests"] == clients * per
    assert s["coalesced_requests"] == clients * per
    assert SERVE_EVENTS["coalesced_requests"] == clients * per
    server.close()


# --- per-request tracing -----------------------------------------------------


def test_virtual_clock_span_timings_are_deterministic(index):
    clock = VirtualClock()
    server = SearchServer(index, ServeConfig(max_batch=32), clock=clock)
    t = server.submit(_queries(7, 4))
    clock.advance(0.25)
    server.run_until_idle()
    (tr,) = server.traces()
    assert tr.status == "done"
    assert t.latency_s == pytest.approx(0.25)
    spans = {s.name: s for s in tr.spans}
    assert set(spans) == {
        "submit", "queue", "coalesce", "stage", "dispatch", "scatter"
    }
    # the queue span is exactly the virtual wait; the service spans all
    # happen at the same virtual instant (zero length, still contiguous)
    assert spans["queue"].start == pytest.approx(0.0)
    assert spans["queue"].duration_s == pytest.approx(0.25)
    for name in ("coalesce", "stage", "dispatch", "scatter"):
        assert spans[name].duration_s == pytest.approx(0.0)
        assert spans[name].start == pytest.approx(0.25)
    # spans tile [submit, complete]: full coverage, by construction
    assert tr.covered_s() == pytest.approx(tr.duration_s)
    assert trace_coverage([tr]) == pytest.approx(1.0)
    server.close()


def test_trace_ring_buffer_is_bounded(index):
    server = _vserver(index, trace_buffer=4)
    tickets = [server.submit(_queries(20 + i, 2)) for i in range(10)]
    server.run_until_idle()
    assert all(t.done for t in tickets)
    traces = server.traces()
    assert len(traces) == 4  # only the most recent 4 retained
    ids = [tr.trace_id for tr in traces]
    assert ids == sorted(ids)  # oldest first
    assert server.traces(2) == traces[-2:]
    server.close()


def test_failed_request_trace_records_failure(index):
    inj = FaultInjector(schedule=[("serve.dispatch", 1, "fatal")])
    server = SearchServer(
        index, ServeConfig(max_batch=32), clock=VirtualClock(), faults=inj
    )
    t = server.submit(_queries(9, 4))
    server.run_until_idle()
    with pytest.raises(FatalFault):
        t.result()
    (tr,) = server.traces()
    assert tr.status == "failed"
    assert any(s.name == "failed" for s in tr.spans)
    server.close()


def test_chrome_trace_export_shape(index):
    server = _vserver(index)
    server.submit(_queries(11, 4))
    server.run_until_idle()
    doc = chrome_trace(server.traces())
    events = doc["traceEvents"]
    xs = [e for e in events if e.get("ph") == "X"]
    assert {e["name"] for e in xs} >= {"queue", "dispatch", "scatter"}
    for e in xs:
        assert e["cat"] == "serve"
        assert e["dur"] >= 0
        assert e["args"]["rows"] == 4
    assert any(e.get("ph") == "M" for e in events)  # thread names
    server.close()


def test_tracing_off_is_zero_overhead(index):
    """trace_buffer=0 must not change the device-facing contracts: same
    dispatch/trace counters, bit-identical results, no traces kept."""
    q = _queries(13, 8)

    def run(trace_buffer):
        telemetry.reset_all()
        server = _vserver(index, trace_buffer=trace_buffer)
        server.precompile()
        backends.reset_dispatch_counts()
        backends.reset_trace_counts()
        t = server.submit(q)
        server.run_until_idle()
        vals, idxs = t.result()
        counts = (dict(DISPATCH_COUNTS), dict(TRACE_COUNTS))
        n_traces = len(server.traces())
        server.close()
        return np.asarray(vals), np.asarray(idxs), counts, n_traces

    vals_on, idxs_on, counts_on, traces_on = run(256)
    vals_off, idxs_off, counts_off, traces_off = run(0)
    assert counts_on == counts_off
    assert traces_on == 1 and traces_off == 0
    np.testing.assert_array_equal(vals_on, vals_off)
    np.testing.assert_array_equal(idxs_on, idxs_off)


# --- roofline-drift monitor --------------------------------------------------


def test_drift_monitor_calibrates_then_degrades():
    mon = DriftMonitor(band=(0.5, 2.0), warmup=2, alpha=1.0)
    r = mon.report()
    assert not r["calibrated"] and r["value"] == 1.0 and r["in_band"]
    mon.record("32", 1e-3, 1e-4)   # platform offset: measured 10x model
    mon.record("32", 1e-3, 1e-4)
    r = mon.report()
    assert r["calibrated"]
    # the absolute 10x offset calibrates out: steady state sits at 1.0
    assert r["value"] == pytest.approx(1.0)
    assert r["in_band"]
    mon.record("32", 1e-2, 1e-4)   # now 10x slower than its own baseline
    r = mon.report()
    assert r["value"] == pytest.approx(10.0)
    assert not r["in_band"]


def test_delay_fault_is_slow_but_successful():
    inj = FaultInjector(
        schedule=[("serve.dispatch", 1, "delay")], delay_s=0.02
    )
    t0 = time.perf_counter()
    inj.fire("serve.dispatch")  # must NOT raise
    assert time.perf_counter() - t0 >= 0.02
    assert inj.fired["serve.dispatch"] == 1
    assert issubclass(DelayFault, Exception)  # taxonomy marker only


def test_injected_slow_dispatch_degrades_health(index):
    """Clean batches calibrate the drift baseline; delay-fault batches
    then run ~100x slower than it — health must flip to degraded."""
    warm = 4
    inj = FaultInjector(
        schedule=[("serve.dispatch", h, "delay") for h in (warm + 1,
                                                           warm + 2)],
        delay_s=0.3,
    )
    server = SearchServer(
        index,
        ServeConfig(max_batch=32, drift_warmup=3, drift_alpha=0.5),
        clock=VirtualClock(),
        faults=inj,
    )
    server.precompile()
    for i in range(warm):
        server.submit(_queries(50 + i, 4))
        server.run_until_idle()
    h = server.health()
    assert h["drift"]["calibrated"] and h["drift"]["in_band"]
    assert h["status"] == "ok"
    for i in range(2):  # the scheduled 0.3s delay fires inside dispatch
        server.submit(_queries(60 + i, 4))
        server.run_until_idle()
    h = server.health()
    assert not h["drift"]["in_band"]
    assert h["status"] == "degraded"
    assert inj.fired["serve.dispatch"] == 2
    server.close()


def test_health_reports_uptime_last_fault_and_recall(index):
    clock = VirtualClock()
    inj = FaultInjector(schedule=[("serve.dispatch", 1, "fatal")])
    server = SearchServer(
        index, ServeConfig(max_batch=32), clock=clock, faults=inj
    )
    h = server.health()
    assert h["last_fault"] is None
    clock.advance(2.0)
    assert server.health()["uptime_s"] == pytest.approx(2.0)
    t = server.submit(_queries(70, 4))
    server.run_until_idle()
    with pytest.raises(FatalFault):
        t.result()
    h = server.health()
    assert h["last_fault"]["error"] == "FatalFault"
    assert h["last_fault"]["point"] == "serve.dispatch"
    assert h["expected_recall_live"] == pytest.approx(
        float(index.plan.expected_recall)
    )
    server.close()


# --- end-to-end export surface -----------------------------------------------


def test_server_workload_exports_expected_series(index):
    server = _vserver(index)
    for i in range(3):
        server.submit(_queries(80 + i, 4))
    server.run_until_idle()
    server.health()
    index.telemetry()
    text = telemetry.export_prometheus()
    for series in (
        "repro_dispatches_total",
        "repro_serve_events_total",
        "repro_serve_request_latency_seconds",
        "repro_serve_batch_rows",
        "repro_serve_uptime_seconds",
        "repro_index_size",
        "repro_index_expected_recall_live",
    ):
        assert series in text, series
    js = telemetry.export_json()
    assert js["counters"]["repro_dispatches_total"]
    assert js["histograms"]["repro_serve_request_latency_seconds"]
    server.close()
