"""Shared pytest fixtures: the many-fake-device subprocess harness.

A single pytest process must keep its single CPU device (setting
``xla_force_host_platform_device_count`` globally would leak into every
other test), so multi-device tests run their body in a *subprocess* whose
XLA_FLAGS force N fake host devices.  ``fake_devices`` packages that
pattern once: the child snippet gets a ``publish(obj)`` helper whose
argument is pickled back to the parent, so tests assert on structured
results instead of grepping stdout.

``device_grid`` parametrizes a test over pod-ish grid sizes; anything past
8 devices is ``@slow``-marked (compile times grow superlinearly with the
fake-device count) and excluded from the fast CI tier's ``-m "not slow"``.
"""
import os
import pickle
import subprocess
import sys
import tempfile

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PRELUDE = """\
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import pickle as _pickle


def publish(obj):
    with open({path!r}, "wb") as _f:
        _pickle.dump(obj, _f)


"""


class FakeDeviceRunner:
    """Run a source snippet under N fake XLA host devices.

    Returns whatever the snippet ``publish()``-ed (None if it never
    called it).  A non-zero child exit raises with the child's stdout and
    stderr attached, so in-child ``assert`` failures read like local ones.
    """

    def __call__(self, source: str, n: int = 8, timeout: float = 600.0):
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        env.pop("XLA_FLAGS", None)
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "result.pkl")
            script = _PRELUDE.format(n=n, path=path) + source
            out = subprocess.run(
                [sys.executable, "-c", script], env=env,
                capture_output=True, text=True, timeout=timeout,
                cwd=REPO_ROOT,
            )
            if out.returncode != 0:
                raise AssertionError(
                    f"fake-device child (n={n}) failed:\n"
                    f"--- stdout ---\n{out.stdout}\n"
                    f"--- stderr ---\n{out.stderr}"
                )
            if os.path.exists(path):
                with open(path, "rb") as f:
                    return pickle.load(f)
            return None


@pytest.fixture
def fake_devices():
    return FakeDeviceRunner()


@pytest.fixture(params=[
    8,
    pytest.param(16, marks=pytest.mark.slow),
    pytest.param(48, marks=pytest.mark.slow),
])
def device_grid(request):
    """Fake-device grid sizes: 8 in the fast tier, 16/48 behind @slow."""
    return request.param
