"""Kernel planner tests: Eq. 4-10/13-14 as configuration, edge cases, parity.

The hard acceptance criteria of the planner PR:
  * ``Index.build(plan="model")`` (the default) is bit-identical to the old
    hard-coded tiles,
  * the planner never emits an invalid layout on degenerate workloads,
  * ``Index.explain()`` reports the plan with predicted roofline numbers,
  * ``plan="measure"`` refines via sweep and persists in the plan cache.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core.binning import round_up
from repro.core.roofline import HARDWARE
from repro.search import Index, SearchSpec, plan_search, tune_plan
from repro.search.plan import Plan, PlanCache, detect_device

LEGACY = dict(block_m=256, max_block_n=1024, query_block=4096)


def _data(n, d, m=64, seed=0):
    kq, kd = jax.random.split(jax.random.PRNGKey(seed))
    return (
        jax.random.normal(kd, (n, d)),
        jax.random.normal(kq, (m, d)),
    )


# --- plan validity ----------------------------------------------------------


def _assert_valid(p: Plan):
    """A plan must always describe a realizable layout."""
    assert p.num_bins >= 1
    assert p.padded_n >= p.n
    assert p.num_bins * p.bin_size == p.padded_n
    assert p.block_n % p.bin_size == 0
    assert p.block_n >= p.bin_size
    # tiles never balloon past the data (up to bin/sublane alignment;
    # 32 is the largest sublane count across dtypes)
    assert p.block_n <= round_up(p.n, max(p.bin_size, 32))
    assert p.block_m >= 8 and p.block_m % 8 == 0
    assert p.query_block >= 8
    assert p.d_pad % 128 == 0 and p.d_pad >= p.d
    assert 0.0 < p.expected_recall <= 1.0
    assert p.bottleneck in ("compute", "memory", "instruction")
    assert p.flops > 0 and p.attainable_flops > 0 and p.predicted_s > 0


@pytest.mark.parametrize(
    "kwargs",
    [
        dict(n=4096, d=64, k=10),                      # vanilla
        dict(n=100, d=8, k=1),                          # k=1: bins degenerate
        dict(n=40, d=16, k=4),                          # N < any default tile
        dict(n=1024, d=100, k=10),                      # D not a x128 multiple
        dict(n=1024, d=130, k=10),                      # D just past a lane
        dict(n=128, d=32, k=64, recall_target=0.999),   # recall at the ceiling
        dict(n=256, d=32, k=256),                       # k == n
        dict(n=1_000_000, d=128, k=10, m=10_000),       # paper scale
        dict(n=4096, d=64, k=10, dtype="bfloat16"),     # dtype-aware tiling
    ],
)
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_planner_edge_cases_emit_valid_layouts(kwargs, backend):
    p = plan_search(backend=backend, device="tpu_v4", **kwargs)
    _assert_valid(p)
    assert p.source == "model"


def test_recall_ceiling_falls_back_to_exact_layout():
    """A recall target above what L < N bins can give => bin size 1."""
    p = plan_search(n=128, d=32, k=64, recall_target=0.999, device="tpu_v4")
    assert p.log2_bin_size == 0
    assert p.num_bins == p.n


def test_k1_needs_one_bin():
    p = plan_search(n=100, d=8, k=1, device="tpu_v4")
    assert p.expected_recall == 1.0  # the best entry always wins its bin


def test_invalid_requests_raise():
    with pytest.raises(ValueError):
        plan_search(n=10, d=4, k=11, device="tpu_v4")  # k > n
    with pytest.raises(ValueError):
        plan_search(n=0, d=4, k=1, device="tpu_v4")
    with pytest.raises(ValueError):
        plan_search(n=10, d=4, k=2, device="not_a_device")


def test_overrides_pin_choices():
    p = plan_search(
        n=4096, d=64, k=10, device="tpu_v4",
        block_m=64, max_block_n=512, query_block=128,
    )
    assert (p.block_m, p.block_n, p.query_block)[0] == 64
    assert p.block_n <= 512
    assert p.query_block == 128
    assert p.source == "user"


def test_block_m_escalates_off_the_memory_wall():
    """Paper-scale L2 on TPU v4: the planner must not leave the kernel
    memory-bound when a larger query tile fixes it (Fig. 2 as a decision)."""
    p = plan_search(
        n=1_000_000, d=128, k=10, m=10_000, metric="l2", device="tpu_v4",
        backend="pallas",
    )
    assert p.bottleneck != "memory"
    assert p.block_m > 256  # escalated beyond the legacy anchor
    # Sift/L2 on v4 hits the COP wall (the paper's headline regression)
    assert p.bottleneck == "instruction"
    assert p.attainable_flops < 0.9 * HARDWARE["tpu_v4"].peak_flops


def test_device_detection_resolves():
    assert detect_device() in HARDWARE  # live backend, whatever it is
    assert detect_device("cpu") == "cpu"


# --- Index integration ------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("metric", ["mips", "l2", "cosine"])
def test_model_plan_bit_parity_with_legacy_tiles(backend, metric):
    """plan="model" (default) must produce identical results to the old
    hard-coded (256, 1024, 4096) configuration."""
    db, q = _data(1000, 60, m=100)
    new = Index.build(db, spec=SearchSpec(metric=metric, k=7, backend=backend))
    old = Index.build(
        db, spec=SearchSpec(metric=metric, k=7, backend=backend, **LEGACY)
    )
    v1, i1 = new.search(q)
    v2, i2 = old.search(q)
    assert (i1 == i2).all()
    assert (v1 == v2).all()


def test_built_spec_is_resolved_and_plan_exposed():
    db, _ = _data(512, 32)
    index = Index.build(db, k=5)
    assert index.spec.resolved
    p = index.kernel_plan
    _assert_valid(p)
    assert p.source == "model"
    assert index.spec.block_m == p.block_m
    assert index.spec.max_block_n == p.block_n
    assert index.spec.query_block == p.query_block


def test_pallas_tiles_respect_sublane_alignment():
    """block_n must satisfy the TPU tiling contract for the compute dtype
    (sublane-multiple rows), not just the bin-size multiple — interpret
    mode would not catch a Mosaic mistiling on real hardware."""
    p = plan_search(n=1000, d=60, k=7, backend="pallas",
                    dtype="bfloat16", device="tpu_v4")
    assert p.block_n % 16 == 0 and p.block_m % 16 == 0
    p2 = plan_search(n=100, d=16, k=5, backend="pallas", device="tpu_v4")
    assert p2.block_n % 8 == 0  # f32 sublane, even with bin_size 1


def test_pinned_max_block_n_matches_packed_layout():
    """A pin larger than the data is honoured exactly the way the packed
    layout honours it — kernel_plan must describe the executed tile."""
    db, _ = _data(100, 16)
    index = Index.build(db, k=3, backend="pallas", block_m=256,
                        max_block_n=1024, query_block=4096)
    assert index.kernel_plan.block_n == index.pack().block_n


def test_legacy_shim_attribute_access_still_works():
    """`import repro.core; repro.core.knn.mips` worked pre-planner (eager
    shim imports) and must keep working through the lazy re-exports."""
    import importlib
    import warnings

    import repro.core
    import repro.kernels

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert callable(repro.core.knn.mips)
        assert callable(repro.core.mips)
        assert callable(repro.kernels.ops.mips_topk)
        assert callable(repro.kernels.mips_topk)
    assert importlib.import_module("repro.core.knn") is repro.core.knn


def test_small_database_is_not_padded_to_default_tile():
    """N < legacy tile: the planner stops block_n at the data (the packed
    pallas layout then carries no multi-x padding)."""
    db, q = _data(40, 16)
    index = Index.build(db, k=4, backend="pallas")
    p = index.kernel_plan
    assert p.block_n <= round_up(40, max(p.bin_size, 8))
    pk = index.pack()
    assert pk.db.shape[0] <= round_up(40, p.block_n)
    v, i = index.search(q)
    ev, ei = index.metric.exact(q, db, 4)
    assert (i == ei).all()  # tiny N: approx == exact


def test_explicit_plan_object_accepted():
    db, q = _data(256, 32)
    p = plan_search(n=256, d=32, k=3, device="cpu", backend="xla")
    index = Index.build(db, k=3, backend="xla", plan=p)
    assert index.kernel_plan is p
    index.search(q)  # runs


def test_bad_plan_mode_raises():
    db, _ = _data(64, 8)
    with pytest.raises(ValueError):
        Index.build(db, k=2, plan="hillclimb")


def test_plan_survives_growth_and_shard_consistently():
    db, q = _data(500, 24)
    index = Index.build(db, k=5, backend="xla", capacity_block=256)
    index.add(jax.random.normal(jax.random.PRNGKey(9), (600, 24)))
    p = index.kernel_plan
    assert p.n == index.capacity  # re-planned over the grown row space
    _assert_valid(p)
    v, i = index.search(q)
    assert v.shape == (64, 5)


# --- explain ----------------------------------------------------------------


def test_explain_reports_plan_and_predictions():
    db, _ = _data(1024, 48)
    index = Index.build(db, metric="l2", k=10)
    report = index.explain()
    assert report["plan"]["source"] == "model"
    assert report["plan"]["num_bins"] >= 10
    pred = report["predicted"]
    assert pred["bottleneck"] in ("compute", "memory", "instruction")
    assert pred["attainable_flops"] > 0
    assert pred["wall_s"] > 0 and pred["qps"] > 0
    assert 0 < report["expected_recall"] <= 1
    assert report["packed"]["bin_size"] == report["plan"]["bin_size"]


def test_explain_measure_and_hlo_crosscheck():
    db, _ = _data(512, 40)
    index = Index.build(db, k=5, backend="xla")
    report = index.explain(m=64, measure=True, validate_hlo=True)
    meas = report["measured"]
    assert meas["wall_s"] > 0 and meas["qps"] > 0
    assert meas["achieved_flops"] > 0
    # HLO self-audit: the dense xla path runs the unpadded (64, 40) x
    # (512, 40) einsum and the model costs exactly that program, so the
    # compiled dot FLOPs must agree with the model's.
    hlo = report["hlo"]
    assert hlo["hlo_dot_flops"] == 2 * 64 * 512 * 40
    assert hlo["flops_ratio"] == pytest.approx(1.0)


def test_plan_inherits_database_dtype():
    """spec.dtype=None means "inherit the input dtype" — the planner must
    size tiles (and report) for the dtype that actually runs."""
    db = jnp.ones((256, 32), jnp.bfloat16)
    index = Index.build(db, k=3)
    assert index.kernel_plan.dtype == "bfloat16"
    # bf16 sublane floor is 16, so a planner-chosen block_m respects it
    assert index.kernel_plan.block_m % 8 == 0


def test_replans_preserve_recall_accounting_override():
    """Growth re-plans must keep reduction_input_size_override, matching
    the packed relayout's bin math (paper §7 accounting)."""
    db, _ = _data(512, 16)
    index = Index.build(
        db, k=5, backend="xla", capacity_block=256,
        reduction_input_size_override=4096,
    )
    assert index.kernel_plan.reduction_input_size_override == 4096
    before = index.kernel_plan.expected_recall
    index.add(jax.random.normal(jax.random.PRNGKey(3), (600, 16)))
    p = index.kernel_plan
    assert p.reduction_input_size_override == 4096
    # accounting still against the global-N override, and the plan's bin
    # layout equals what the packed state actually laid out
    assert p.num_bins == index.pack().plan.num_bins
    assert p.expected_recall == index.pack().plan.expected_recall
    assert before > 0


def test_xla_cost_models_unpadded_program():
    """The xla plan costs the raw (n, d) einsum, not the pallas padding."""
    p = plan_search(n=500, d=64, k=5, m=64, backend="xla", device="cpu")
    assert p.flops == 2 * 64 * 500 * 64
    pp = plan_search(n=500, d=64, k=5, m=64, backend="pallas", device="cpu")
    assert pp.flops == 2 * 64 * pp.padded_n * 128


def test_explain_rescales_prediction_with_m():
    db, _ = _data(512, 32)
    index = Index.build(db, k=5)
    small = index.explain(m=8)["predicted"]["flops"]
    large = index.explain(m=800)["predicted"]["flops"]
    assert large == pytest.approx(100 * small)


# --- measured refinement + cache -------------------------------------------


def test_tune_plan_persists_and_hits_cache(tmp_path):
    db, _ = _data(256, 16)
    model = plan_search(n=256, d=16, k=3, m=32, backend="xla", device="cpu")
    cache = PlanCache(str(tmp_path / "plans.json"))
    tuned = tune_plan(db, model, cache=cache, repeats=1)
    assert tuned.source == "measure"
    _assert_valid(tuned)
    assert len(cache) == 1
    entry = cache.get(model)
    assert entry["block_m"] == tuned.block_m
    assert entry["wall_s"] > 0
    # a fresh cache object re-reads the file; the sweep must not rerun
    # (we verify via the identical tile triple coming straight from disk)
    reloaded = PlanCache(str(tmp_path / "plans.json"))
    tuned2 = tune_plan(db, model, cache=reloaded, repeats=1)
    assert (tuned2.block_m, tuned2.block_n, tuned2.query_block) == (
        tuned.block_m, tuned.block_n, tuned.query_block
    )


def test_build_with_measure_mode(tmp_path):
    db, q = _data(256, 16, m=16)
    cache = PlanCache(str(tmp_path / "plans.json"))
    index = Index.build(db, k=3, backend="xla", plan="measure",
                        plan_cache=cache)
    assert index.kernel_plan.source == "measure"
    assert len(cache) == 1
    v, i = index.search(q)
    # measured tiles may differ from the model's, results may not
    ref = Index.build(db, k=3, backend="xla")
    rv, ri = ref.search(q)
    assert (i == ri).all() and (v == rv).all()


def test_measure_respects_pins_and_keys_cache_separately(tmp_path):
    """A pinned spec field is never varied by the sweep, the reported plan
    matches the executed spec, and pinned results get their own cache key."""
    db, _ = _data(256, 16)
    cache = PlanCache(str(tmp_path / "plans.json"))
    index = Index.build(db, k=3, backend="xla", plan="measure",
                        plan_cache=cache, query_block=64)
    assert index.spec.query_block == 64
    assert index.kernel_plan.query_block == 64  # report == execution
    assert len(cache) == 1
    # the pinned entry must not be served to an unpinned lookup
    assert cache.get(index.kernel_plan) is None


def test_plan_to_spec_round_trip():
    p = plan_search(n=2048, d=64, k=10, device="cpu", backend="xla")
    spec = p.to_spec(SearchSpec(metric="l2", k=10, query_block=64))
    assert spec.query_block == 64       # explicit override wins
    assert spec.block_m == p.block_m    # planner fills the rest
    assert spec.max_block_n == p.block_n
    assert spec.resolved


def test_measured_plan_prediction_matches_its_tiles(tmp_path):
    """tune_plan must re-derive the roofline prediction for the winning
    tiles — not report the model tiles' numbers under measured tiles."""
    model = plan_search(n=1024, d=32, k=5, m=256, backend="pallas",
                        device="tpu_v4")
    cache = PlanCache(str(tmp_path / "p.json"))
    cache.put(model, {
        "block_m": model.block_m * 2, "block_n": model.block_n,
        "query_block": model.query_block, "wall_s": 1.0,
    })
    tuned = tune_plan(None, model, cache=cache)  # cache hit: db unused
    assert tuned.source == "measure"
    assert tuned.block_m == model.block_m * 2
    ref = plan_search(
        n=1024, d=32, k=5, m=256, backend="pallas", device="tpu_v4",
        block_m=model.block_m * 2, max_block_n=model.block_n,
        query_block=model.query_block,
    )
    assert tuned.hbm_bytes == ref.hbm_bytes
    assert tuned.bottleneck == ref.bottleneck
    assert tuned.predicted_s == ref.predicted_s


def test_sharded_query_block_not_shrunk_by_global_n():
    """The sharded score tile is (qb, n_local) per shard; the planner must
    not shrink qb against the *global* N it cannot apportion."""
    p = plan_search(n=1 << 22, d=64, k=10, backend="sharded",
                    device="tpu_v4")
    assert p.query_block == 4096


def test_plan_cache_corrupt_file_is_empty(tmp_path):
    path = tmp_path / "broken.json"
    path.write_text("{not json")
    cache = PlanCache(str(path))
    assert len(cache) == 0


def test_summary_is_json_friendly():
    import json

    p = plan_search(n=512, d=32, k=5, device="tpu_v5e")
    s = p.summary()
    json.dumps(s)  # no numpy scalars / dataclass leftovers
    assert s["bin_size"] == 1 << s["log2_bin_size"]
