"""Perf-contract tests for the packed search state + streaming executor.

These encode the performance model (Eq. 10: per-dispatch memory traffic
~ O(min(M, N))) as CI assertions, so a regression that re-introduces
per-search (N, D) padding / metric re-preparation — or per-block Python
dispatch loops — fails the fast tier:

  * steady-state repeat searches: zero packs, zero retraces, cache hits
    only; the compiled pallas program pads nothing database-sized (jaxpr
    inspection),
  * ``add`` metric-prepares only the appended slice; growth relayouts
    without a full pack; ``delete`` patches only the bias row and never
    syncs the host,
  * a multi-block batch is ONE dispatch, and the streaming executor is
    bit-identical to the per-block loop for divisible and ragged M.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.search import Index, SearchSpec, backends
from repro.search.backends import DISPATCH_COUNTS, TRACE_COUNTS
from repro.search.packed import PACK_EVENTS, reset_pack_events

K = 10


@pytest.fixture(scope="module")
def data():
    q = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    db = jax.random.normal(jax.random.PRNGKey(1), (4096, 32))
    return q, db


@pytest.fixture(autouse=True)
def _reset_counters():
    backends.reset_trace_counts()
    backends.reset_dispatch_counts()
    reset_pack_events()
    yield


# --- jaxpr inspection: the compiled program pads only query-sized arrays ----


def _subjaxprs(p):
    if hasattr(p, "jaxpr"):  # ClosedJaxpr
        yield p.jaxpr
    elif hasattr(p, "eqns"):  # raw Jaxpr (e.g. pallas kernel jaxpr)
        yield p
    elif isinstance(p, (list, tuple)):
        for x in p:
            yield from _subjaxprs(x)


def _pad_shapes(jaxpr):
    """Every ``pad`` primitive's output shape, recursing into subjaxprs."""
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pad":
            out.append(tuple(eqn.outvars[0].aval.shape))
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                out.extend(_pad_shapes(sub))
    return out


def test_packed_pallas_program_never_pads_database(data):
    q, db = data
    index = Index.build(db, metric="l2", k=K, backend="pallas")
    pk = index.pack()
    fn = index._build_block_fn("pallas", pk)
    pads = _pad_shapes(jax.make_jaxpr(fn)(q, pk.db, pk.bias).jaxpr)
    db_elems = pk.db.shape[0] * pk.db.shape[1]
    assert pads, "query padding should still appear (sanity)"
    assert all(int(np.prod(s)) < db_elems for s in pads), (
        f"database-sized pad re-introduced into the search program: {pads}"
    )


@pytest.mark.parametrize("storage", ["bf16", "int8", "int4"])
def test_quantized_pallas_program_never_pads_database(data, storage):
    """The PR-2 traffic contract extends to quantized tiers: the compiled
    two-pass program pads only query-sized arrays — the quantized scan
    consumes pre-packed operands and the rescore pass is an O(M·K')
    gather, so nothing database-sized is padded (or materialized) per
    dispatch."""
    q, db = data
    index = Index.build(db, metric="l2", k=K, backend="pallas",
                        storage=storage)
    pk = index.pack()
    fn = index._build_block_fn("pallas", pk)
    pads = _pad_shapes(jax.make_jaxpr(fn)(q, *pk.operands()).jaxpr)
    db_elems = pk.db.shape[0] * pk.db.shape[1]
    assert pads, "query padding should still appear (sanity)"
    assert all(int(np.prod(s)) < db_elems for s in pads), (
        f"database-sized pad in the quantized search program: {pads}"
    )


def test_legacy_oneshot_path_does_pad_database(data):
    """Sensitivity check: the same probe flags the pack-inside-jit path,
    so a silent Index regression onto it cannot pass the test above."""
    q, db = data
    pads = _pad_shapes(
        jax.make_jaxpr(
            lambda a, b: backends.pallas_search(
                a, b, None, metric="mips", interpret=True
            )
        )(q, db).jaxpr
    )
    assert any(int(np.prod(s)) >= db.shape[0] * 128 for s in pads)


# --- fused scan→select vs the two-pass parity oracle -------------------------


@pytest.mark.parametrize("metric", ["mips", "l2", "cosine"])
@pytest.mark.parametrize("storage", ["f32", "bf16", "int8", "int4"])
def test_fused_select_matches_two_pass_oracle(data, metric, storage):
    """The single-pass fused kernel (VMEM top-k carry) must be BIT-identical
    to the two-pass scan→merge_topk composition on every metric × storage
    tier — the acceptance grid of the fused-select tentpole."""
    q, db = data
    fused = Index.build(
        db, metric=metric, k=K, backend="pallas", storage=storage
    ).search(q)
    oracle = Index.build(
        db,
        spec=SearchSpec(metric=metric, k=K, backend="pallas",
                        storage=storage, fused_select=False),
    ).search(q)
    np.testing.assert_array_equal(
        np.asarray(fused.indices), np.asarray(oracle.indices)
    )
    np.testing.assert_array_equal(
        np.asarray(fused.values), np.asarray(oracle.values)
    )


@pytest.mark.parametrize("fused", [True, False])
@pytest.mark.parametrize("storage", ["f32", "int8", "int4"])
def test_masked_winners_are_sentinels_not_phantom_duplicates(fused, storage):
    """Regression (masked-winner clamp bug): with fewer live rows than k,
    the masked tail of each result row used to be clamped into [0, n) and
    surfaced as duplicate phantom copies of row n-1 after the -inf merge
    tie.  Masked entries must now carry the sentinel index -1, and the
    live prefix must be duplicate-free."""
    from repro.search.backends import MASK_VALUE

    db = jax.random.normal(jax.random.PRNGKey(21), (64, 32))
    index = Index.build(
        db,
        spec=SearchSpec(metric="mips", k=K, backend="pallas",
                        storage=storage, fused_select=fused),
    )
    index.delete(list(range(6, 64)))  # 6 live rows < k=10
    q = jax.random.normal(jax.random.PRNGKey(22), (5, 32))
    vals, idxs = index.search(q)
    vals, idxs = np.asarray(vals), np.asarray(idxs)
    for row_v, row_i in zip(vals, idxs):
        live = row_i[row_v > MASK_VALUE * 0.5]
        masked = row_i[row_v <= MASK_VALUE * 0.5]
        assert len(set(live.tolist())) == len(live), (
            f"duplicate live winners: {row_i}"
        )
        assert (live >= 0).all() and (live < 6).all()
        assert masked.size and (masked == -1).all(), (
            f"masked winners must be -1 sentinels, got {masked}"
        )


def test_single_query_clamps_block_m(data):
    """Regression (query-pad bug): an M=1 dispatch used to be padded to a
    full block_m=256 query tile, wasting 256x the MXU work.  The kernel
    now clamps the query tile to the sublane-rounded batch — the compiled
    program pads queries to 8 rows, and the plan prices 8 rows of FLOPs."""
    _, db = data
    index = Index.build(db, metric="mips", k=K, backend="pallas")
    pk = index.pack()
    fn = index._build_block_fn("pallas", pk)
    q1 = jax.random.normal(jax.random.PRNGKey(23), (1, 32))
    pads = _pad_shapes(jax.make_jaxpr(fn)(q1, pk.db, pk.bias).jaxpr)
    assert all(s[0] != 256 for s in pads if len(s) == 2), (
        f"M=1 still padded to a full 256-row query tile: {pads}"
    )
    assert any(s[0] == 8 for s in pads if len(s) == 2), (
        f"expected an 8-row (one sublane tile) query pad, got {pads}"
    )
    # And the planner models the same clamped shape: 8 padded query rows.
    e = index.explain(m=1)
    plan = e["plan"]
    assert e["predicted"]["flops"] == (
        2.0 * 8 * plan["padded_n"] * plan["d_pad"]
    )


def test_scan_k_capped_at_live_count_after_mass_delete(data):
    """Regression (stale over-fetch bug): ``scan_k`` was derived from
    capacity and never revalidated against the live count, so a
    delete-heavy index over-fetched tombstones into the exact rescore
    gather.  The program built after the deletes caps k_scan at the live
    count, and the results match the exact answer over the survivors."""
    from repro.search import exact_search
    from repro.search.packed import scan_k_for

    q, db = data
    spec = SearchSpec(metric="mips", k=K, backend="pallas", storage="int8")
    # unit: the cap binds at program-build time, never below k
    assert scan_k_for(spec, 4096) == 2 * K
    assert scan_k_for(spec, 4096, live=12) == 12
    assert scan_k_for(spec, 4096, live=3) == K
    index = Index.build(db, metric="mips", k=K, backend="pallas",
                        storage="int8")
    survivors = list(range(0, 4096, 341))  # 13 live rows > k
    index.delete([i for i in range(4096) if i not in survivors])
    assert index.size == len(survivors) == 13
    vals, idxs = index.search(q)  # first compile: sees the live count
    _, exact_idx = exact_search(q, db[jnp.asarray(survivors)], K)
    got = np.asarray(idxs)
    want = np.asarray(survivors)[np.asarray(exact_idx)]
    assert (np.sort(got, axis=1) == np.sort(want, axis=1)).all()


# --- steady state: zero packs, zero retraces --------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_steady_state_repeat_search_does_no_database_work(data, backend):
    q, db = data
    index = Index.build(db, metric="cosine", k=K, backend=backend)
    index.search(q)  # warmup: trace + compile once
    backends.reset_trace_counts()
    reset_pack_events()
    index._cache.reset_counters()
    for _ in range(5):
        index.search(q)
    assert not dict(PACK_EVENTS), "repeat search repacked the database"
    assert not dict(TRACE_COUNTS), "repeat search retraced"
    info = index.cache_info()
    assert info["hits"] == 5 and info["misses"] == 0


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("storage", ["bf16", "int8"])
def test_quantized_steady_state_keeps_traffic_contract(data, backend, storage):
    """Zero repacks, zero retraces, cache hits only on quantized tiers —
    scale/rescore operands are passed per dispatch, never re-derived."""
    q, db = data
    index = Index.build(db, metric="l2", k=K, backend=backend,
                        storage=storage)
    index.search(q)  # warmup: trace + compile once
    backends.reset_trace_counts()
    reset_pack_events()
    index._cache.reset_counters()
    for _ in range(5):
        index.search(q)
    assert not dict(PACK_EVENTS), "quantized repeat search repacked"
    assert not dict(TRACE_COUNTS), "quantized repeat search retraced"
    info = index.cache_info()
    assert info["hits"] == 5 and info["misses"] == 0


def test_quantized_multi_block_batch_is_one_dispatch(data):
    _, db = data
    qb = 16
    index = Index.build(db, k=K, backend="xla", storage="int8",
                        query_block=qb)
    big = jax.random.normal(jax.random.PRNGKey(3), (8 * qb, 32))
    index.search(big)  # warmup
    backends.reset_dispatch_counts()
    index._cache.reset_counters()
    index.search(big)
    assert DISPATCH_COUNTS["xla"] == 1, "quantized 8-block batch >1 dispatch"
    assert index.cache_info()["hits"] == 1


def test_quantized_mutations_stay_incremental(data):
    """add/delete on a quantized tier patch the packed state in place —
    same PACK_EVENTS taxonomy as f32, no hidden full packs."""
    _, db = data
    index = Index.build(db[:2048], metric="l2", k=K, backend="xla",
                        storage="int8", capacity=4096)
    reset_pack_events()
    index.add(db[2048:])
    assert dict(PACK_EVENTS) == {"rows_updated": 1}
    reset_pack_events()
    index.delete([1, 2, 3])
    assert dict(PACK_EVENTS) == {"bias_patched": 1}
    # live count stays a lazy device scalar (no host sync on delete)
    assert not isinstance(index._num_live, int)


def test_multi_block_batch_is_one_dispatch(data):
    _, db = data
    qb = 16
    index = Index.build(db, k=K, backend="xla", query_block=qb)
    big = jax.random.normal(jax.random.PRNGKey(3), (8 * qb, 32))
    index.search(big)  # warmup
    backends.reset_trace_counts()
    backends.reset_dispatch_counts()
    index._cache.reset_counters()
    index.search(big)
    assert DISPATCH_COUNTS["xla"] == 1, "8-block batch took >1 dispatch"
    assert index.cache_info()["hits"] == 1
    assert not dict(TRACE_COUNTS)


# --- incremental mutations ---------------------------------------------------


@pytest.mark.parametrize("metric", ["mips", "l2", "cosine"])
def test_add_prepares_only_the_appended_slice(data, metric):
    _, db = data
    index = Index.build(
        db[:2048], metric=metric, k=K, backend="xla", capacity=4096
    )
    reset_pack_events()
    index.add(db[2048:])
    assert dict(PACK_EVENTS) == {"rows_updated": 1}
    # Numerics: the incrementally packed state equals a from-scratch pack
    # of the full database at the same capacity.
    full = Index.build(db, metric=metric, k=K, backend="xla", capacity=4096)
    np.testing.assert_allclose(
        np.asarray(index.pack().db), np.asarray(full.pack().db), rtol=1e-6
    )
    np.testing.assert_array_equal(
        np.asarray(index.pack().bias), np.asarray(full.pack().bias)
    )


def test_add_with_growth_relayouts_without_full_pack(data):
    _, db = data
    index = Index.build(db[:1024], metric="l2", k=K, backend="pallas")
    reset_pack_events()
    index.add(db[1024:1100])
    ev = dict(PACK_EVENTS)
    assert ev == {"relayout": 1, "rows_updated": 1}, ev
    # grown region stays dead until written: nothing above the high-water
    # mark is ever returned
    q = jax.random.normal(jax.random.PRNGKey(7), (8, 32))
    _, idxs = index.search(q)
    assert int(np.asarray(idxs).max()) < 1100


def test_non_rowwise_metric_forces_full_repack_at_add_time(data):
    from repro.search import Metric, exact_mips, register_metric
    from repro.search.metrics import _REGISTRY

    register_metric(
        Metric(
            name="coupled-mips",
            negate_output=False,
            prepare_database=lambda db: (db, None),
            prepare_queries=lambda q: q,
            exact=exact_mips,
            rowwise=False,
        ),
        overwrite=True,
    )
    try:
        _, db = data
        index = Index.build(
            db[:2048], metric="coupled-mips", k=K, backend="xla",
            capacity=4096,
        )
        reset_pack_events()
        index.add(db[2048:])
        ev = dict(PACK_EVENTS)
        # repack happens (at add() time), never an undefined slice update
        assert ev.get("full_pack") == 1 and "rows_updated" not in ev, ev
        with pytest.raises(ValueError, match="row-wise"):
            index.metric.prepare_update(db[:4])
    finally:
        _REGISTRY.pop("coupled-mips", None)


def test_delete_patches_bias_only_and_never_syncs(data):
    q, db = data
    index = Index.build(db, metric="mips", k=K, backend="xla")
    index.search(q)
    reset_pack_events()
    index.delete([1, 2, 3])
    assert dict(PACK_EVENTS) == {"bias_patched": 1}
    # live count stays a lazy device scalar until read
    assert not isinstance(index._num_live, int)
    assert index.size == 4093
    assert isinstance(index._num_live, int)
    # deleted ids are really gone from results
    _, idxs = index.search(q)
    assert not {1, 2, 3} & set(np.asarray(idxs).ravel().tolist())


def test_shard_reuses_packed_layout(data):
    q, db = data
    mesh = jax.make_mesh((1,), ("model",))
    index = Index.build(db, metric="cosine", k=K)
    reset_pack_events()
    sharded = index.shard(mesh, db_axis="model")
    ev = dict(PACK_EVENTS)
    assert "full_pack" not in ev and ev.get("relayout") == 1, ev
    vals, idxs = sharded.search(q)
    assert vals.shape == (64, K)


# --- streaming executor parity ----------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("m", [128, 100])  # divisible / ragged by query_block
def test_stream_matches_per_block_loop(data, backend, m):
    _, db = data
    queries = jax.random.normal(jax.random.PRNGKey(5), (m, 32))
    stream = Index.build(
        db[:1024], k=K, backend=backend, query_block=16
    ).search(queries)
    loop = Index.build(
        db[:1024],
        spec=SearchSpec(k=K, backend=backend, query_block=16, stream=False),
    ).search(queries)
    np.testing.assert_array_equal(
        np.asarray(stream.indices), np.asarray(loop.indices)
    )
    np.testing.assert_array_equal(
        np.asarray(stream.values), np.asarray(loop.values)
    )


def test_stream_matches_loop_sharded(data):
    _, db = data
    mesh = jax.make_mesh((1,), ("model",))
    queries = jax.random.normal(jax.random.PRNGKey(5), (100, 32))
    stream = (
        Index.build(db[:1024], k=K, query_block=16)
        .shard(mesh, db_axis="model")
        .search(queries)
    )
    loop = (
        Index.build(
            db[:1024], spec=SearchSpec(k=K, query_block=16, stream=False)
        )
        .shard(mesh, db_axis="model")
        .search(queries)
    )
    np.testing.assert_array_equal(
        np.asarray(stream.indices), np.asarray(loop.indices)
    )
    np.testing.assert_array_equal(
        np.asarray(stream.values), np.asarray(loop.values)
    )


_MULTIDEVICE_SCRIPT = r"""
import jax, numpy as np
from repro.search import Index, SearchSpec, exact_search

mesh = jax.make_mesh((2, 4), ("data", "model"))
db = jax.random.normal(jax.random.PRNGKey(1), (4096, 64))
q = jax.random.normal(jax.random.PRNGKey(0), (128, 64))

stream = Index.build(db, k=10, query_block=32).shard(
    mesh, db_axis="model", batch_axis="data")
loop = Index.build(db, spec=SearchSpec(k=10, query_block=32, stream=False)
    ).shard(mesh, db_axis="model", batch_axis="data")
s, l = stream.search(q), loop.search(q)
assert np.array_equal(np.asarray(s.indices), np.asarray(l.indices))
assert np.array_equal(np.asarray(s.values), np.asarray(l.values))

# both must actually be CORRECT, not merely equal: the old concatenate-based
# loop silently psummed shard_map outputs (x n_shards) on >1 db shards.
_, e = exact_search(q, db, 10)
rec = np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
               for a, b in zip(np.asarray(s.indices), np.asarray(e))])
assert rec >= stream.expected_recall - 0.07, rec
assert int(np.asarray(s.indices).max()) < 4096
publish({"recall": float(rec), "expected": float(stream.expected_recall)})
"""


def test_stream_matches_loop_multidevice(fake_devices):
    """8 fake devices in a subprocess (the main process stays 1-device):
    multi-block sharded search is bit-identical stream vs loop AND correct
    against the exact baseline."""
    res = fake_devices(_MULTIDEVICE_SCRIPT, n=8)
    assert res["recall"] >= res["expected"] - 0.07
