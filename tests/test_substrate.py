"""Substrate tests: data pipeline, optimizer, checkpointing/FT, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data.pipeline import Prefetcher, SyntheticTokenSource, make_vector_dataset
from repro.ft.elastic import choose_mesh_shape
from repro.ft.straggler import StragglerPolicy
from repro.optim.adamw import adamw_init, adamw_update, cosine_schedule


def test_data_determinism_and_host_sharding():
    a = SyntheticTokenSource(1000, 16, 8, seed=3, host_id=0, host_count=2)
    b = SyntheticTokenSource(1000, 16, 8, seed=3, host_id=0, host_count=2)
    c = SyntheticTokenSource(1000, 16, 8, seed=3, host_id=1, host_count=2)
    np.testing.assert_array_equal(a.batch(5)["tokens"], b.batch(5)["tokens"])
    assert not np.array_equal(a.batch(5)["tokens"], c.batch(5)["tokens"])
    assert a.batch(0)["tokens"].shape == (4, 16)  # global 8 over 2 hosts
    assert a.batch(0)["tokens"].max() < 1000


def test_prefetcher_orders_batches():
    src = SyntheticTokenSource(100, 8, 4, seed=0)
    pf = Prefetcher(src, start_step=7)
    try:
        s0, b0 = pf.next()
        s1, b1 = pf.next()
        assert (s0, s1) == (7, 8)
        np.testing.assert_array_equal(b0["tokens"], src.batch(7)["tokens"])
    finally:
        pf.close()


def test_vector_dataset_shapes():
    x = make_vector_dataset(1000, 32, metric="cosine")
    assert x.shape == (1000, 32)
    np.testing.assert_allclose(np.linalg.norm(x, axis=-1), 1.0, rtol=1e-5)


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    target = jnp.array([1.0, 2.0])
    for step in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state = adamw_update(
            params, grads, state, step=jnp.int32(step),
            learning_rate=5e-2, weight_decay=0.0,
        )
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_cosine_schedule_shape():
    sched = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.int32(0))) < 2e-4
    assert float(sched(jnp.int32(10))) == pytest.approx(1e-3, rel=0.15)
    assert float(sched(jnp.int32(100))) == pytest.approx(1e-4, rel=0.2)


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(10, dtype=jnp.float32), "b": {"c": jnp.ones((3, 3))}}
    save_checkpoint(str(tmp_path), 7, state)
    like = jax.tree.map(jnp.zeros_like, state)
    restored, step = restore_checkpoint(str(tmp_path), like)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10))


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    state = {"x": jnp.ones(4)}
    save_checkpoint(str(tmp_path), 1, state)
    # a torn write must be invisible
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_step(str(tmp_path)) == 1


def test_async_checkpointer_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in range(4):
        ck.save(s, {"x": jnp.full((4,), s)})
    ck.wait()
    assert latest_step(str(tmp_path)) == 3
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(kept) == 2  # gc keeps last 2
    restored, _ = restore_checkpoint(str(tmp_path), {"x": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.full(4, 3.0))


def test_elastic_mesh_shapes():
    assert choose_mesh_shape(256) == ((16, 16), ("data", "model"))
    assert choose_mesh_shape(512) == ((1, 32, 16), ("pod", "data", "model"))
    # losing a host: 248 chips -> keep TP=8 at least
    shape, axes = choose_mesh_shape(248, model_parallel=16)
    total = 1
    for s in shape:
        total *= s
    assert total <= 248 and shape[-1] >= 8


def test_straggler_policy_flags_persistent_slow_host():
    pol = StragglerPolicy(threshold=1.5, grace_steps=3, min_steps=2)
    act = None
    for step in range(10):
        times = {0: 1.0, 1: 1.0, 2: 1.0, 3: 2.5}
        act = pol.observe(times)
        if act.kind != "none":
            break
    assert act.kind == "swap" and act.host == 3


def test_straggler_policy_tolerates_transient():
    pol = StragglerPolicy(threshold=1.5, grace_steps=5, min_steps=2)
    for step in range(20):
        times = {0: 1.0, 1: 1.0, 2: 2.5 if step == 7 else 1.0}
        act = pol.observe(times)
        assert act.kind == "none"


def test_serving_engine_generates():
    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.serving.engine import Request, ServingEngine

    cfg = get_config("internlm2-1.8b-smoke")
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, batch=2, max_seq=64)
    eng.admit([
        Request(rid=1, prompt=np.array([3, 5, 7], np.int32), max_new_tokens=4),
        Request(rid=2, prompt=np.array([11, 2], np.int32), max_new_tokens=4),
    ])
    out = eng.run(4)
    # both requests completed and produced 4 tokens each before leaving
    assert out == {} or all(len(v) <= 4 for v in out.values())


def test_serving_engine_retrieval_via_search_index():
    """The engine's retrieval hook goes through the unified repro.search
    front door, including in-place datastore growth between lookups."""
    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.search import Index
    from repro.serving.engine import ServingEngine

    cfg = get_config("internlm2-1.8b-smoke")
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, batch=2, max_seq=64)

    keys = jax.random.normal(jax.random.PRNGKey(1), (1024, 32))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1024,), 0, 100)
    eng.attach_retrieval(Index.build(keys, metric="mips", k=4), tokens)
    q = keys[:3] + 0.01  # near-duplicates: top-1 should be the row itself
    scores, toks = eng.retrieve(q)
    assert scores.shape == toks.shape == (3, 4)
    _, idxs = eng.retrieval_index.search(q)
    assert (np.asarray(idxs)[:, 0] == np.arange(3)).all()

    # serve-time ingestion: add new keys, no rebuild, immediately searchable
    new_keys = jax.random.normal(jax.random.PRNGKey(3), (8, 32))
    eng.retrieval_index.add(new_keys)
    with pytest.raises(ValueError, match="extend value tokens"):
        eng.retrieve(q)  # stale token table must fail loudly, not clamp
    eng.retrieval_tokens = jnp.pad(tokens, (0, eng.retrieval_index.capacity - 1024))
    _, idxs = eng.retrieval_index.search(new_keys[:2] + 0.01)
    assert (np.asarray(idxs)[:, 0] >= 1024).all()


def test_cache_bytes_accounting():
    from repro.configs import get_config
    from repro.serving.kvcache import cache_bytes_per_token, plan_max_seq

    mla = get_config("deepseek-v2-236b")
    gqa = get_config("internlm2-1.8b")
    ssm = get_config("mamba2-2.7b")
    # MLA latent cache is far smaller than GQA KV per layer-token
    assert cache_bytes_per_token(mla) < cache_bytes_per_token(gqa) * 4
    assert cache_bytes_per_token(ssm) == 0  # O(1) state
    assert plan_max_seq(ssm, 1, 1e9) > 1e8
