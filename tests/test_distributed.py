"""Distributed KNN (paper §7) on 8 fake devices.

Runs via the ``fake_devices`` subprocess harness (tests/conftest.py) so
the main pytest process keeps a single CPU device.
"""
_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.distributed import sharded_mips, sharded_l2nns
from repro.retrieval.datastore import KNNDatastore, knn_lm_logits
from repro.search import Index, exact_search

mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
q = jax.random.normal(key, (16, 64))
db = jax.random.normal(jax.random.PRNGKey(1), (4096, 64))
qs = jax.device_put(q, NamedSharding(mesh, P("data", None)))
dbs = jax.device_put(db, NamedSharding(mesh, P("model", None)))

def recall(a, e):
    return np.mean([len(set(x.tolist()) & set(y.tolist()))/len(y)
                    for x, y in zip(np.asarray(a), np.asarray(e))])

_, i = sharded_mips(qs, dbs, 10, mesh, batch_axis="data", recall_target=0.95)
_, ei = jax.lax.top_k(q @ db.T, 10)
r = recall(i, ei)
assert r >= 0.9, f"mips recall {r}"

_, i2 = sharded_l2nns(qs, dbs, 10, mesh, batch_axis="data", recall_target=0.95)
d = np.linalg.norm(np.asarray(q)[:,None]-np.asarray(db)[None], axis=-1)
ei2 = np.argsort(d, -1)[:, :10]
r2 = recall(i2, ei2)
assert r2 >= 0.9, f"l2 recall {r2}"

# kNN-LM datastore over the mesh
tokens = jax.random.randint(jax.random.PRNGKey(2), (4096,), 0, 1000)
ds = KNNDatastore(db, tokens, mesh, k=8)
scores, toks = ds.lookup(qs)
assert scores.shape == (16, 8) and toks.shape == (16, 8)
lm_logits = jax.random.normal(jax.random.PRNGKey(3), (16, 1000))
mixed = knn_lm_logits(lm_logits, scores, toks)
assert mixed.shape == (16, 1000)
assert bool(jnp.all(jnp.isfinite(mixed)))

# unified front door: sharded Index with add/delete on 8 real shards
for metric in ("mips", "l2", "cosine"):
    sharded = Index.build(db[:3072], metric=metric, k=10,
                          recall_target=0.95).shard(
        mesh, db_axis="model", batch_axis="data")
    sharded.add(db[3072:])
    _, si = sharded.search(q)
    _, ei = exact_search(q, db, 10, metric=metric)
    sr = recall(si, ei)
    assert sr >= sharded.expected_recall - 0.07, f"{metric} sharded {sr}"
sharded.delete(np.asarray(ei)[:, 0])
_, si2 = sharded.search(q)
assert not set(np.asarray(si2).ravel().tolist()) & set(
    np.asarray(ei)[:, 0].tolist())
publish({"mips_recall": r, "l2_recall": r2})
"""


def test_distributed_knn_8_devices(fake_devices):
    res = fake_devices(_SCRIPT, n=8)
    assert res["mips_recall"] >= 0.9 and res["l2_recall"] >= 0.9
