"""Quantized storage tiers (``repro.search.quant``): correctness contracts.

What this suite pins down:

  * ``storage="f32"`` is bit-identical to the pre-quantization path on
    every backend x metric (the acceptance criterion: the new subsystem
    must be invisible until opted into).
  * bf16/int8 two-pass search returns *exact* values for the indices it
    returns (the rescore pass recomputes true scores), meets a recall
    floor on every backend, and never resurrects tombstoned rows.
  * Quantization primitives: per-row int8 error bound, bf16 round-trip,
    scan_k over-fetch math, the metric-bias correction (scan bias is
    computed from the *stored* values).
  * Incremental ``add`` equals a from-scratch pack on quantized tiers
    (rows, scale, bias, rescore tail), and ``explain()`` reports traffic
    from the stored dtype.
  * Unsupported metric x storage combos fail at build/spec time with an
    actionable error, not a kernel-level failure.

Statistical recall validation lives in ``tests/test_recall_guarantee.py``
(storage axis); traffic-contract (jaxpr/counter) checks in
``tests/test_packed.py``; add/delete interleaving invariants in
``tests/test_packed_invariants.py``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.search import (
    Index,
    SearchSpec,
    exact_search,
    get_metric,
)
from repro.search import quant
from repro.search.metrics import _REGISTRY, Metric, exact_mips, register_metric

N, D, K = 2048, 24, 8


@pytest.fixture(scope="module")
def data():
    db = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    q = jax.random.normal(jax.random.PRNGKey(0), (64, D))
    return q, db


def _recall(idxs, exact_idxs, k):
    a, b = np.asarray(idxs), np.asarray(exact_idxs)
    return np.mean(
        [len(set(r.tolist()) & set(e.tolist())) / k for r, e in zip(a, b)]
    )


# --- quantization primitives -------------------------------------------------


def test_int8_per_row_error_bound():
    rows = jax.random.normal(jax.random.PRNGKey(3), (32, 64)) * jnp.arange(
        1, 33
    )[:, None]  # wildly different row norms — per-row scales must adapt
    stored, scale = quant.quantize_rows(rows, "int8")
    assert stored.dtype == jnp.int8 and scale.shape == (32,)
    err = np.abs(np.asarray(quant.dequantize_rows(stored, scale) - rows))
    # symmetric rounding: per-entry error <= scale/2 (+ float slack)
    bound = np.asarray(scale)[:, None] * 0.5 + 1e-6
    assert (err <= bound).all()


def test_bf16_roundtrip_and_zero_rows():
    rows = jnp.zeros((4, 8)).at[0, 0].set(1.0)
    stored, scale = quant.quantize_rows(rows, "bf16")
    assert stored.dtype == jnp.bfloat16 and scale is None
    np.testing.assert_allclose(
        np.asarray(quant.dequantize_rows(stored, None)),
        np.asarray(rows), rtol=1e-2,
    )
    # all-zero rows must not divide by zero in the int8 path
    z, zs = quant.quantize_rows(jnp.zeros((3, 8)), "int8")
    assert (np.asarray(z) == 0).all() and np.isfinite(np.asarray(zs)).all()


def test_scan_k_overfetch():
    assert quant.scan_k("f32", 10) == 10
    assert quant.scan_k("bf16", 10) == 15
    assert quant.scan_k("int8", 10) == 20
    assert quant.scan_k("int4", 10) == 30  # T(int4) = 2K extra candidates
    assert quant.scan_k("int8", 10, n=12) == 12  # clamped to the database
    with pytest.raises(ValueError, match="storage tier"):
        quant.scan_k("fp4", 10)


def test_int4_per_row_error_bound():
    rows = jax.random.normal(jax.random.PRNGKey(5), (32, 64)) * jnp.arange(
        1, 33
    )[:, None]
    stored, scale = quant.quantize_rows(rows, "int4")
    codes = np.asarray(stored)
    # canonical form: int8 container, one code per element, codes in [-7, 7]
    assert stored.dtype == jnp.int8 and codes.shape == rows.shape
    assert codes.min() >= -7 and codes.max() <= 7
    np.testing.assert_allclose(
        np.asarray(scale), np.abs(np.asarray(rows)).max(axis=-1) / 7.0,
        rtol=1e-6,
    )
    err = np.abs(np.asarray(quant.dequantize_rows(stored, scale) - rows))
    assert (err <= np.asarray(scale)[:, None] * 0.5 + 1e-6).all()


@pytest.mark.parametrize("d", [8, 64, 7])  # odd d exercises the zero-pad
def test_int4_pack_roundtrip(d):
    rows = jax.random.normal(jax.random.PRNGKey(7), (16, d)) * 3.0
    codes, _ = quant.quantize_rows(rows, "int4")
    packed = quant.pack_int4_rows(codes)
    assert packed.dtype == jnp.int8 and packed.shape == (16, (d + 1) // 2)
    unpacked = np.asarray(quant.unpack_int4_rows(packed))[:, :d]
    np.testing.assert_array_equal(unpacked, np.asarray(codes))


def test_storage_bias_is_computed_from_stored_values(data):
    """The L2 scan bias must be -||x_hat||^2/2 of the *dequantized stored*
    rows, not of the f32 originals — otherwise quantized scan scores are
    internally inconsistent."""
    _, db = data
    m = get_metric("l2")
    qr = m.prepare_storage(db, "int8")
    want = -0.5 * np.sum(
        np.asarray(quant.dequantize_rows(qr.rows, qr.scale)) ** 2, axis=-1
    )
    np.testing.assert_allclose(np.asarray(qr.bias), want, rtol=1e-5)
    # and the rescore tail keeps the exact f32 bias
    np.testing.assert_allclose(
        np.asarray(qr.exact_bias),
        -0.5 * np.sum(np.asarray(db) ** 2, axis=-1),
        rtol=1e-6,
    )


# --- f32 bit-identity (the "invisible until opted into" criterion) -----------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("metric", ["mips", "l2", "cosine"])
def test_f32_storage_is_bit_identical(data, backend, metric):
    q, db = data
    plain = Index.build(db, metric=metric, k=K, backend=backend).search(q)
    tiered = Index.build(
        db, metric=metric, k=K, backend=backend, storage="f32"
    ).search(q)
    np.testing.assert_array_equal(
        np.asarray(plain.values), np.asarray(tiered.values)
    )
    np.testing.assert_array_equal(
        np.asarray(plain.indices), np.asarray(tiered.indices)
    )


def test_f32_storage_is_bit_identical_sharded(data):
    q, db = data
    mesh = jax.make_mesh((1,), ("model",))
    plain = Index.build(db, k=K).shard(mesh, db_axis="model").search(q)
    tiered = (
        Index.build(db, k=K, storage="f32")
        .shard(mesh, db_axis="model")
        .search(q)
    )
    np.testing.assert_array_equal(
        np.asarray(plain.values), np.asarray(tiered.values)
    )
    np.testing.assert_array_equal(
        np.asarray(plain.indices), np.asarray(tiered.indices)
    )


# --- two-pass search: recall + exact values ----------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("storage", ["bf16", "int8", "int4"])
@pytest.mark.parametrize("metric", ["mips", "l2", "cosine"])
def test_quantized_search_recall_floor(data, backend, storage, metric):
    q, db = data
    index = Index.build(
        db, metric=metric, k=K, backend=backend, storage=storage,
        recall_target=0.95,
    )
    assert index.expected_recall >= 0.95  # over-fetched Eq. 13 bound
    _, idxs = index.search(q)
    _, exact = exact_search(q, db, K, metric=metric)
    assert _recall(idxs, exact, K) >= 0.9


@pytest.mark.parametrize("storage", ["bf16", "int8"])
def test_quantized_search_sharded(data, storage):
    q, db = data
    mesh = jax.make_mesh((1,), ("model",))
    index = Index.build(db, metric="l2", k=K, storage=storage).shard(
        mesh, db_axis="model"
    )
    _, idxs = index.search(q)
    _, exact = exact_search(q, db, K, metric="l2")
    assert _recall(idxs, exact, K) >= 0.9


@pytest.mark.parametrize("metric", ["mips", "l2"])
def test_rescored_values_are_exact(data, metric):
    """The values returned for quantized tiers come from the f32 rescore
    pass — they must equal the exact metric scores of the returned
    indices, not the quantized scan's approximations."""
    q, db = data
    index = Index.build(db, metric=metric, k=K, backend="xla",
                        storage="int8")
    vals, idxs = index.search(q)
    ev, ei = exact_search(q, db, N, metric=metric)  # full ranking
    lookup = {}
    for row, (rv, ri) in enumerate(zip(np.asarray(ev), np.asarray(ei))):
        for v, i in zip(rv, ri):
            lookup[(row, int(i))] = v
    got = np.asarray(vals)
    for row in range(got.shape[0]):
        for col, i in enumerate(np.asarray(idxs)[row]):
            np.testing.assert_allclose(
                got[row, col], lookup[(row, int(i))], rtol=1e-5, atol=1e-5,
                err_msg=f"row {row} idx {i}: returned value is not the "
                "exact score (rescore pass skipped or biased?)",
            )


def test_rescore_off_returns_approximate_values(data):
    """rescore=False (footprint mode): still searches, values carry
    quantization error, no rescore tail is materialized."""
    q, db = data
    index = Index.build(db, metric="mips", k=K, backend="xla",
                        storage="int8", rescore=False)
    pk = index.pack()
    assert pk.rescore_db is None and pk.rescore_bias is None
    _, idxs = index.search(q)
    _, exact = exact_search(q, db, K, metric="mips")
    assert _recall(idxs, exact, K) >= 0.8  # no over-fetch, looser floor


def test_quantized_tombstones_never_return(data):
    q, db = data
    for backend in ("xla", "pallas"):
        index = Index.build(db, metric="mips", k=K, backend=backend,
                            storage="int8")
        # delete the entire exact top-1 column so the scan's favourites die
        _, exact = exact_search(q, db, K, metric="mips")
        dead = sorted(set(np.asarray(exact)[:, 0].tolist()))
        index.delete(dead)
        _, idxs = index.search(q)
        assert not (set(np.asarray(idxs).ravel().tolist()) & set(dead)), (
            f"{backend}: tombstoned rows resurfaced via the rescore tail"
        )


# --- incremental mutations match a from-scratch pack -------------------------


@pytest.mark.parametrize("storage", ["bf16", "int8", "int4"])
def test_incremental_add_matches_full_pack_quantized(data, storage):
    _, db = data
    inc = Index.build(db[:1024], metric="l2", k=K, backend="xla",
                      storage=storage, capacity=N)
    inc.add(db[1024:])
    full = Index.build(db, metric="l2", k=K, backend="xla",
                       storage=storage, capacity=N)
    a, b = inc.pack(), full.pack()
    np.testing.assert_array_equal(np.asarray(a.db), np.asarray(b.db))
    np.testing.assert_array_equal(np.asarray(a.bias), np.asarray(b.bias))
    if storage in ("int8", "int4"):
        np.testing.assert_array_equal(
            np.asarray(a.scale), np.asarray(b.scale)
        )
    np.testing.assert_allclose(
        np.asarray(a.rescore_db), np.asarray(b.rescore_db), rtol=1e-6
    )
    np.testing.assert_array_equal(
        np.asarray(a.rescore_bias), np.asarray(b.rescore_bias)
    )


def test_incremental_add_matches_full_pack_bf16_compute_dtype(data):
    """dtype="bfloat16" + storage="int8": the incremental path must repeat
    the full pack's cast-to-compute-dtype-then-quantize order exactly."""
    _, db = data
    kw = dict(metric="l2", k=K, backend="xla", storage="int8",
              dtype="bfloat16", capacity=N)
    inc = Index.build(db[:1024], **kw)
    inc.add(db[1024:])
    full = Index.build(db, **kw)
    np.testing.assert_array_equal(
        np.asarray(inc.pack().db), np.asarray(full.pack().db)
    )
    np.testing.assert_array_equal(
        np.asarray(inc.pack().scale), np.asarray(full.pack().scale)
    )


# --- planner / explain report the stored dtype -------------------------------


def test_explain_reports_storage_traffic(data):
    _, db = data
    f32 = Index.build(db, k=K, backend="xla").explain()
    i8 = Index.build(db, k=K, backend="xla", storage="int8").explain()
    assert f32["storage"]["tier"] == "f32"
    assert f32["storage"]["db_bytes_per_element"] == 4
    assert i8["storage"]["tier"] == "int8"
    assert i8["storage"]["db_bytes_per_element"] == 1
    assert i8["storage"]["rescore"] and i8["storage"]["k_scan"] == 2 * K
    assert (
        i8["storage"]["db_resident_bytes"]
        == f32["storage"]["db_resident_bytes"] / 4
    )
    assert i8["plan"]["storage"] == "int8"


def test_explain_reports_int4_storage_traffic(data):
    """int4 is priced at two codes per byte on the Pallas path (the only
    backend that streams the packed nibbles; dense backends keep the
    canonical 1-byte codes and the planner floors them at int8 cost)."""
    _, db = data
    f32 = Index.build(db, k=K, backend="pallas").explain()
    i4 = Index.build(db, k=K, backend="pallas", storage="int4").explain()
    assert i4["storage"]["tier"] == "int4"
    assert i4["storage"]["db_bytes_per_element"] == 0.5
    assert (
        i4["storage"]["db_resident_bytes"]
        == f32["storage"]["db_resident_bytes"] / 8
    )
    assert i4["storage"]["rescore"] and i4["storage"]["k_scan"] == 3 * K
    assert i4["plan"]["storage"] == "int4"
    # the fused-select scan is the default, and its predicted traffic is
    # what the bench smoke compares against measured db bytes
    assert i4["storage"]["fused_select"]
    assert i4["storage"]["predicted_hbm_bytes"] == i4["plan"]["hbm_bytes"]


def test_planner_traffic_drops_on_fused_kernel():
    """Eq. 10/20 with 1- and 2-byte rows: at a memory-bound shape the
    fused-kernel model must predict >=2x (int8) less HBM traffic — the
    roofline shift the storage tier exists for.  (The dense XLA model is
    dominated by its f32 score matrix, so the drop shows on pallas.)"""
    from repro.search.plan import plan_search

    kw = dict(n=1 << 20, d=128, k=10, m=256, backend="pallas",
              device="tpu_v4")
    f32 = plan_search(**kw)
    bf16 = plan_search(storage="bf16", **kw)
    i8 = plan_search(storage="int8", **kw)
    assert f32.hbm_bytes / i8.hbm_bytes >= 2.0
    assert f32.hbm_bytes / bf16.hbm_bytes >= 1.5
    # reduced traffic moves the knee: attainable FLOP/s never decreases
    assert i8.attainable_flops >= f32.attainable_flops
    assert bf16.attainable_flops >= f32.attainable_flops


def test_quantized_hlo_check_runs(data):
    _, db = data
    report = Index.build(db, k=K, backend="xla", storage="int8").explain(
        validate_hlo=True
    )
    assert "hlo" in report and "skipped" not in report["hlo"]
    assert report["hlo"]["hlo_dot_flops"] > 0


# --- validation: actionable errors, not kernel failures ----------------------


def test_unknown_storage_tier_rejected():
    with pytest.raises(ValueError, match="storage tier"):
        SearchSpec(storage="fp4")


def test_rescore_requires_quantized_tier():
    with pytest.raises(ValueError, match="quantized storage tier"):
        SearchSpec(storage="f32", rescore=True)


def test_rescore_needs_aggregate_to_topk():
    with pytest.raises(ValueError, match="aggregate_to_topk"):
        SearchSpec(storage="int8", rescore=True, aggregate_to_topk=False)
    # auto-resolution: raw-winners mode silently disables the second pass
    assert not SearchSpec(
        storage="int8", aggregate_to_topk=False
    ).rescore_enabled


def test_metric_storage_combo_rejected_actionably(data):
    """A metric whose prepare does not normalize (the ISSUE's 'int8 cosine
    without normalized prepare') must be rejected at spec/build time."""
    register_metric(
        Metric(
            name="raw-cosine",
            negate_output=False,
            prepare_database=lambda db: (db, None),  # NOT normalized
            prepare_queries=lambda q: q,
            exact=exact_mips,
            storage_tiers=("f32", "bf16"),
        ),
        overwrite=True,
    )
    try:
        _, db = data
        with pytest.raises(ValueError, match="storage='int8'"):
            SearchSpec(metric="raw-cosine", storage="int8")
        # the declared tiers still work
        Index.build(db, metric="raw-cosine", k=K, backend="xla",
                    storage="bf16").search(jnp.asarray(data[0]))
    finally:
        _REGISTRY.pop("raw-cosine", None)


def test_late_registered_metric_storage_combo_caught_at_build(data):
    """SearchSpec validates lazily (the metric may not be registered yet);
    Index.build must still catch the bad combo eagerly."""
    spec = SearchSpec(metric="late-raw-cosine", k=K, storage="int8")  # ok
    register_metric(
        Metric(
            name="late-raw-cosine",
            negate_output=False,
            prepare_database=lambda db: (db, None),
            prepare_queries=lambda q: q,
            exact=exact_mips,
            storage_tiers=("f32",),
        ),
        overwrite=True,
    )
    try:
        _, db = data
        with pytest.raises(ValueError, match="storage='int8'"):
            Index.build(db, spec=spec)
    finally:
        _REGISTRY.pop("late-raw-cosine", None)
